//! Integration tests for the PR 7 telemetry plane.
//!
//! The bit-identity invariant itself is pinned in
//! `tests/regression_pins.rs::telemetry_on_is_bit_identical_to_off`; this
//! suite covers the *content* side: the NoRoute counter split, the
//! anomaly-triggered flight-recorder dump, and the Prometheus / JSON
//! export artifacts of a real overload run.

use infadapter::config::{AdmissionConfig, Config};
use infadapter::dispatcher::{AdmissionGate, NoRoute, RequestPath, RouteOutcome};
use infadapter::fleet::{FleetMode, FleetScenario};
use infadapter::profiler::ProfileSet;
use infadapter::telemetry::{parse_exposition, ShardTelemetry, STAGES};
use std::path::Path;

#[test]
fn reweight_to_zero_counts_nocapacity_not_unconfigured() {
    // Regression for the NoRoute counter split: a dispatcher whose quota
    // table was *reweighted to zero* (the adapter granted no capacity)
    // must count as NoCapacity — only a dispatcher that never saw a
    // weight table at all is Unconfigured.  Conflating them hides "the
    // policy zeroed this service out" behind "nothing is wired up yet".
    let mut telem = ShardTelemetry::new(true);
    let mut path = RequestPath::new(AdmissionGate::disabled());

    // never configured: Unconfigured
    let out = path.handle(0.1, 0);
    assert_eq!(out, RouteOutcome::Denied(NoRoute::Unconfigured));
    if let RouteOutcome::Denied(r) = out {
        telem.record_noroute(r);
    }
    assert_eq!(telem.noroute_unconfigured, 1);
    assert_eq!(telem.noroute_nocapacity, 0);

    // configured with real capacity: routable
    path.set_weights(&[("resnet18".into(), 2.0)]);
    assert!(matches!(path.handle(0.2, 0), RouteOutcome::Routed(_)));

    // reweighted to zero: NoCapacity, never Unconfigured
    path.set_weights(&[("resnet18".into(), 0.0)]);
    let out = path.handle(0.3, 0);
    assert_eq!(out, RouteOutcome::Denied(NoRoute::NoCapacity));
    if let RouteOutcome::Denied(r) = out {
        telem.record_noroute(r);
    }
    assert_eq!(telem.noroute_unconfigured, 1, "zero-weight is not unconfigured");
    assert_eq!(telem.noroute_nocapacity, 1);

    // and an empty table after configuration is still NoCapacity
    path.set_weights(&[]);
    if let RouteOutcome::Denied(r) = path.handle(0.4, 0) {
        telem.record_noroute(r);
    } else {
        panic!("an emptied table must deny");
    }
    assert_eq!(telem.noroute_unconfigured, 1);
    assert_eq!(telem.noroute_nocapacity, 2);
}

#[test]
fn disabled_shard_telemetry_ignores_noroute() {
    let mut telem = ShardTelemetry::new(false);
    telem.record_noroute(NoRoute::Unconfigured);
    telem.record_noroute(NoRoute::NoCapacity);
    assert_eq!(telem, ShardTelemetry::new(false));
}

#[test]
fn overload_run_trips_the_flight_recorder_and_exports() {
    // The acceptance artifact: a fleet run that genuinely sheds must trip
    // the flight recorder, and the dump must carry the last K ticks'
    // decisions with per-stage timings and solver/cache counters — the
    // same content `fleet --telemetry` writes to <prefix>_flight.json.
    let profiles = ProfileSet::paper_like();
    let mut config = Config::default();
    config.adapter.forecaster = "last_max".into();
    config.seed = 5;
    config.admission = AdmissionConfig {
        enabled: true,
        ..AdmissionConfig::default()
    };
    let mut scenario =
        FleetScenario::synthetic_overload(2, 30.0, 420, 8, true, &config, &profiles);
    scenario.telemetry.enabled = true;
    scenario.telemetry.flight_ticks = 4;
    scenario.telemetry.shed_trip_fraction = 0.05;
    let out = scenario.run(&FleetMode::Arbiter, Path::new("/nonexistent"));
    assert!(out.summary.shed > 0, "the overload run must actually shed");

    let ft = out.telemetry.as_ref().expect("telemetry plane missing");
    assert!(ft.ticks >= 4, "need at least a full flight window");
    assert!(
        ft.flight.tripped(),
        "a shedding overload run must trip the recorder"
    );
    for (tick, reason) in ft.flight.trips() {
        assert!((1..=ft.ticks).contains(tick));
        assert!(
            reason == "shed" || reason == "slo_burn",
            "unknown trip reason {reason}"
        );
    }

    // the dump holds exactly the last K=4 ticks, newest last
    let dump = ft.flight.dump();
    assert_eq!(dump.get("window").unwrap().as_f64().unwrap(), 4.0);
    let ticks = dump.get("ticks").unwrap().as_arr().unwrap();
    assert_eq!(ticks.len(), 4);
    let trips = dump.get("trips").unwrap().as_arr().unwrap();
    assert!(!trips.is_empty());
    for (i, t) in ticks.iter().enumerate() {
        let ordinal = t.get("tick").unwrap().as_f64().unwrap() as u64;
        assert_eq!(ordinal, ft.ticks - 3 + i as u64, "ring must be contiguous");
        let stages = t.get("stages").unwrap();
        for s in STAGES {
            // per-stage wall-clock is present for every stage, every tick
            assert!(stages.get(&format!("{s}_ns")).unwrap().as_f64().unwrap() >= 0.0);
        }
        let services = t.get("services").unwrap().as_arr().unwrap();
        assert_eq!(services.len(), 2);
        for svc in services {
            // the decision: what the arbiter granted vs what was asked
            assert!(svc.get("grant").is_some());
            assert!(svc.get("lambda_hat").unwrap().as_f64().unwrap() >= 0.0);
            assert!(svc.get("target_cores").unwrap().as_f64().unwrap() >= 0.0);
            assert!(svc.get("supply_rps").unwrap().as_f64().unwrap() >= 0.0);
            // solver / cache introspection rides along on every row
            let hits = svc.get("cache_hits").unwrap().as_f64().unwrap();
            let warm = svc.get("cache_warm").unwrap().as_f64().unwrap();
            let cold = svc.get("cache_cold").unwrap().as_f64().unwrap();
            assert!(hits + warm + cold >= 1.0, "cache counters missing");
            assert!(svc.get("solver_nodes").unwrap().as_f64().unwrap() >= 1.0);
            assert!(svc.get("curve_prunes").is_some());
            assert!(svc.get("seed_rescores").is_some());
        }
    }
    // the dump is valid JSON end to end
    let reparsed = infadapter::util::json::parse(&dump.to_string_pretty()).unwrap();
    assert_eq!(
        reparsed.get("window").unwrap().as_f64().unwrap(),
        4.0,
        "dump must round-trip through the JSON writer"
    );

    // Prometheus exposition round-trips and agrees with the summary
    let ts = out.summary.telemetry.expect("summary telemetry missing");
    let parsed = parse_exposition(&ft.registry().to_prometheus());
    assert_eq!(parsed["infadapter_ticks_total"], ft.ticks as f64);
    assert_eq!(parsed["infadapter_admitted_total"], ts.admitted as f64);
    assert_eq!(parsed["infadapter_shed_total"], ts.shed as f64);
    assert_eq!(
        parsed["infadapter_solver_nodes_total"],
        ts.solver_nodes as f64
    );
    assert_eq!(
        parsed["infadapter_curve_cache_hits_total"]
            + parsed["infadapter_curve_cache_warm_total"]
            + parsed["infadapter_curve_cache_cold_total"],
        (ts.cache_hits + ts.cache_warm + ts.cache_cold) as f64
    );
    assert_eq!(
        parsed["infadapter_flight_trips_total"],
        ft.flight.trips().len() as f64
    );
    assert!(parsed["infadapter_stage_solve_ns_count"] >= ft.ticks as f64);

    // and the JSON snapshot artifact mirrors the same registry
    let snap = ft.snapshot_json();
    assert_eq!(
        snap.get("ticks").unwrap().as_f64().unwrap(),
        ft.ticks as f64
    );
    assert_eq!(
        snap.get("flight_trips").unwrap().as_f64().unwrap(),
        ft.flight.trips().len() as f64
    );
    let reg = snap.get("registry").unwrap();
    assert_eq!(
        reg.get("counters")
            .unwrap()
            .get("infadapter_shed_total")
            .unwrap()
            .as_f64()
            .unwrap(),
        ts.shed as f64
    );
}

#[test]
fn telemetry_summary_books_balance_with_the_run() {
    // The merged TelemetrySummary must agree with the run's own metrics:
    // every shed the gate counted is a shed the summary counted, and the
    // per-tier admit/shed splits cover the totals.
    let profiles = ProfileSet::paper_like();
    let mut config = Config::default();
    config.adapter.forecaster = "last_max".into();
    config.seed = 5;
    config.admission.enabled = true;
    let mut scenario =
        FleetScenario::synthetic_overload(2, 30.0, 420, 8, true, &config, &profiles);
    scenario.telemetry.enabled = true;
    let out = scenario.run(&FleetMode::Arbiter, Path::new("/nonexistent"));
    let ts = out.summary.telemetry.expect("summary telemetry missing");
    assert_eq!(ts.shed, out.summary.shed);
    let ft = out.telemetry.as_ref().expect("telemetry plane missing");
    assert_eq!(ft.shard.admitted(), ts.admitted);
    assert_eq!(ft.shard.shed(), ts.shed);
    let tier_shed: u64 = out.summary.tiers.iter().map(|t| t.shed).sum();
    assert_eq!(tier_shed, ts.shed, "per-tier sheds must cover the total");
    // solver work happened and the cache books are complete
    assert!(ts.solver_nodes > 0);
    assert_eq!(
        ft.cache.total(),
        ts.cache_hits + ts.cache_warm + ts.cache_cold
    );
}
