//! Integration tests for deterministic record/replay (ISSUE 10).
//!
//! * **Record → replay is bit-identical.**  A recorded fleet run, saved
//!   to disk (JSON and binary), reloaded, and re-driven from its embedded
//!   scenario produces zero divergences — across seeds, `solver_threads`
//!   ∈ {1, 8} on either side of the round trip, and with the fault plane
//!   off or armed (the Part B overload and Part D crash-storm acceptance
//!   scenarios).
//! * **Divergences are sharp.**  A single perturbed decision field in a
//!   saved trace is reported at its exact tick with the first differing
//!   field named — not as a bare summary diff.
//! * **Golden traces pin the decision stream.**  Committed traces for the
//!   single-service, fleet-overload, and crash-storm scenarios replay
//!   with zero divergences; any change to the decision path shows up as
//!   a divergence at a specific tick.  Missing goldens are regenerated
//!   and then verified (see `rust/tests/golden/README.md`).
//! * **CSV traces carry tiers.**  A tiered fleet driven from
//!   `csv:` traces (the `# tiers:` directive) sheds lowest-tier-first —
//!   the class mix survives the file round trip into the scenario.

use infadapter::config::Config;
use infadapter::fleet::{FleetMode, FleetScenario};
use infadapter::profiler::ProfileSet;
use infadapter::replay::Replayer;
use infadapter::util::testutil::TempDir;
use infadapter::workload::Trace;
use std::path::{Path, PathBuf};

/// The Part B overload recipe (PR 4's acceptance scenario): both services
/// burst at once against an 8-core budget with admission on; `faults`
/// arms the Part D crash storm on top.
fn overload_scenario(seed: u64, threads: usize, faults: bool) -> FleetScenario {
    let mut config = Config::default();
    config.adapter.forecaster = "last_max".into();
    config.seed = seed;
    config.admission.enabled = true;
    if faults {
        config
            .fault
            .apply_spec(
                "crash:0.004:60:300,slowstart:2,straggler:0.002:30:4,stall:0.05,reactions:on,retries:2",
            )
            .expect("valid fault spec");
    }
    let mut s = FleetScenario::synthetic_overload(
        2,
        30.0,
        420,
        8,
        true,
        &config,
        &ProfileSet::paper_like(),
    );
    s.solver_threads = threads;
    s
}

#[test]
fn prop_record_replay_has_zero_divergences() {
    // Deterministic sweep standing in for randomized property inputs:
    // (seed, record threads, replay threads, faults, trace format).  The
    // thread crossings are the acceptance criterion — a trace recorded
    // serially must replay bit-identically at 8 threads and vice versa,
    // with and without the crash storm.
    let dir = TempDir::new();
    let artifacts = Path::new("/nonexistent");
    let cases = [
        (5u64, 1usize, 8usize, false, "b_overload.json"),
        (5, 8, 1, true, "d_storm.bin"),
        (29, 1, 1, true, "storm_serial.json"),
        (101, 8, 8, false, "overload_parallel.bin"),
    ];
    for (seed, rec_threads, rep_threads, faults, file) in cases {
        let scenario = overload_scenario(seed, rec_threads, faults);
        let (out, trace) = scenario.run_recorded(&FleetMode::Arbiter, artifacts);
        assert!(out.summary.shed > 0, "the overload recipe must shed");
        assert!(trace.ticks.len() > 1, "the recorder must record ticks");
        if faults {
            assert!(
                trace.faults.iter().any(|f| !f.crashed.is_empty()),
                "the armed storm must crash pods"
            );
        } else {
            assert!(trace.faults.is_empty(), "no faults may be drawn unarmed");
        }
        let path = dir.path().join(file);
        trace.save(&path).unwrap();
        let mut replayer = Replayer::load(&path).unwrap();
        replayer.trace.scenario.solver_threads = rep_threads;
        let report = replayer.replay(artifacts).unwrap();
        assert!(
            report.divergences.is_empty(),
            "seed {seed}, threads {rec_threads}->{rep_threads}, faults {faults}: {:?}",
            report.divergences
        );
        assert_eq!(report.ticks, trace.ticks.len() as u64);
    }
}

#[test]
fn perturbed_decision_is_reported_at_its_tick_with_the_field_named() {
    let dir = TempDir::new();
    let artifacts = Path::new("/nonexistent");
    let scenario = overload_scenario(5, 1, true);
    let (_, mut trace) = scenario.run_recorded(&FleetMode::Arbiter, artifacts);
    assert!(trace.ticks.len() > 3);
    // perturb one scalar decision field mid-run ...
    let k = trace.ticks.len() / 2;
    let expect_tick = trace.ticks[k].tick;
    trace.ticks[k].services[0].lambda_hat += 1.0;
    // ... and push it through a file round trip: detection must survive
    // serialization bit-exactly (a lossy codec would mask or invent diffs)
    let p = dir.path().join("perturbed.bin");
    trace.save(&p).unwrap();
    let report = Replayer::load(&p).unwrap().replay(artifacts).unwrap();
    assert_eq!(report.divergences.len(), 1, "{:?}", report.divergences);
    let d = &report.divergences[0];
    assert_eq!(d.tick, expect_tick);
    assert_eq!(d.field, "lambda_hat");
    assert_eq!(d.service, "svc0");
    let line = d.to_string();
    assert!(
        line.contains(&format!("at tick {expect_tick}")),
        "divergence line must carry the tick: {line}"
    );
    assert!(line.starts_with("expected Decision lambda_hat="), "{line}");
    assert!(line.contains(", got "), "{line}");

    // a perturbed fault draw is caught the same way
    let (_, mut trace2) = scenario.run_recorded(&FleetMode::Arbiter, artifacts);
    let idx = trace2
        .faults
        .iter()
        .position(|f| !f.crashed.is_empty())
        .expect("the storm must crash");
    trace2.faults[idx].crashed.push(9999);
    let p2 = dir.path().join("fault.json");
    trace2.save(&p2).unwrap();
    let report = Replayer::load(&p2).unwrap().replay(artifacts).unwrap();
    assert_eq!(report.divergences.len(), 1, "{:?}", report.divergences);
    assert_eq!(report.divergences[0].field, format!("fault[{idx}].crashed"));
}

fn golden_path(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/golden")
        .join(name)
}

/// Replay a committed golden trace, regenerating it first when absent
/// (fresh checkout / intentional refresh — see the golden README).  Either
/// way the trace must replay with zero divergences.
fn check_golden(name: &str, scenario: &FleetScenario) {
    let artifacts = Path::new("/nonexistent");
    let path = golden_path(name);
    if !path.exists() {
        std::fs::create_dir_all(path.parent().expect("golden dir")).unwrap();
        let (_, trace) = scenario.run_recorded(&FleetMode::Arbiter, artifacts);
        trace.save(&path).unwrap();
    }
    let report = Replayer::load(&path).unwrap().replay(artifacts).unwrap();
    assert!(
        report.divergences.is_empty(),
        "golden {name} diverged — the decision stream changed; if intentional, \
         delete {path:?} and re-run the test to regenerate it:\n{}",
        report
            .divergences
            .iter()
            .take(5)
            .map(|d| d.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
    assert!(report.ticks > 1);
}

#[test]
fn golden_single_service_replays_clean() {
    let mut config = Config::default();
    config.adapter.forecaster = "last_max".into();
    config.seed = 5;
    let scenario =
        FleetScenario::synthetic(1, 30.0, 300, 8, &config, &ProfileSet::paper_like());
    check_golden("single_service.json", &scenario);
}

#[test]
fn golden_fleet_overload_replays_clean() {
    check_golden("fleet_overload.json", &overload_scenario(5, 1, false));
}

#[test]
fn golden_crash_storm_replays_clean() {
    check_golden("crash_storm.json", &overload_scenario(5, 1, true));
}

#[test]
fn csv_trace_with_tier_mix_sheds_lowest_tier_first() {
    // The satellite-3 regression: `csv:` traces used to drop class_mix,
    // so a tiered fleet driven from files silently lost per-request tiers
    // (every request fell back to its service's tier).  Route the overload
    // scenario's traces through the CSV path with a 50/50 tier mix and
    // check the admission gate still sheds the low tier first.
    let dir = TempDir::new();
    let artifacts = Path::new("/nonexistent");
    let mut scenario = overload_scenario(17, 1, false);
    for (i, svc) in scenario.services.iter_mut().enumerate() {
        let p = dir.path().join(format!("svc{i}.csv"));
        let tiered = svc.trace.clone().with_class_mix(vec![(0, 1.0), (1, 1.0)]);
        Trace::to_csv(&tiered, &p).unwrap();
        let back = Trace::from_spec(&format!("csv:{}", p.display()), 0.0, 0, 0).unwrap();
        assert_eq!(back.class_mix, tiered.class_mix, "mix must survive csv:");
        assert_eq!(back.rates, tiered.rates, "rates must survive value-exact");
        svc.trace = back;
        // per-request tiers come from the mix now, not the service tier
        svc.tier = 0;
    }
    let out = scenario.run(&FleetMode::Arbiter, artifacts);
    assert!(out.summary.shed > 0, "overload must shed");
    let tiers = &out.summary.tiers;
    assert_eq!(tiers.len(), 2, "both mixed tiers must appear: {tiers:?}");
    assert!(
        tiers[1].shed > tiers[0].shed,
        "shedding must land lowest-tier-first: {tiers:?}"
    );
}
