//! Property suite for the tick-loop substrates (ISSUE 9).
//!
//! * The calendar-queue [`TimerWheel`] must pop element-for-element in
//!   the binary heap's ascending `(t, seq)` order on randomized event
//!   streams — including coincident boundary timestamps (exact float
//!   ties at whole seconds / adapter intervals, broken by `seq`) and
//!   far-future events that land in the overflow level — across several
//!   bucket geometries and with pushes interleaved between pops.  This
//!   is the wheel's whole contract: the shard event loop is bit-identical
//!   to the old heap-backed loop *because* the pop sequence is.
//! * The persistent [`WorkerPool`] must survive panicking tasks without
//!   hanging or poisoning itself: the panic resurfaces at the dispatch
//!   call and the same pool keeps serving subsequent generations.

use infadapter::util::pool::WorkerPool;
use infadapter::util::sched::TimerWheel;
use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering as AtomicOrdering};

/// Reference mirror of the shard event key: ascending `(t, seq)` via
/// `total_cmp`, exactly the `Ord` the old `BinaryHeap<Reverse<Event>>`
/// scheduler used.
#[derive(Debug, Clone, Copy)]
struct Ev(f64, u64);

impl PartialEq for Ev {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Ev {}

impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Ev {
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.total_cmp(&other.0).then(self.1.cmp(&other.1))
    }
}

/// Deterministic LCG so the property streams replay exactly.
struct Lcg(u64);

impl Lcg {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        self.0
    }

    fn f64(&mut self) -> f64 {
        (self.next() >> 11) as f64 / (1u64 << 53) as f64
    }
}

/// Draw one event timestamp: mostly uniform over the horizon, with a
/// deliberate bias toward *exact* boundary values (whole seconds and
/// 30 s adapter intervals — the coincident-timestamp regime the shard's
/// boundary tie rule depends on) and an occasional far-future outlier
/// that must route through the wheel's overflow level.
fn draw_t(rng: &mut Lcg, horizon: f64, reuse: &[f64]) -> f64 {
    match rng.next() % 10 {
        // exact whole-second boundary
        0 | 1 => (rng.f64() * horizon).floor(),
        // exact adapter-interval boundary
        2 => ((rng.f64() * horizon / 30.0).floor()) * 30.0,
        // exact repeat of an already-scheduled timestamp (tie on t,
        // ordered purely by seq)
        3 if !reuse.is_empty() => reuse[(rng.next() as usize) % reuse.len()],
        // far future: beyond every ring, into overflow
        4 => 1e4 + rng.f64() * 1e6,
        _ => rng.f64() * horizon,
    }
}

/// Run one randomized stream through both schedulers and assert the pop
/// sequences identical, element for element (bitwise on `t`).
fn check_equivalence(seed: u64, w0: f64, n0: usize, n1: usize) {
    let mut rng = Lcg(seed);
    let mut wheel: TimerWheel<u32> = TimerWheel::with_geometry(w0, n0, n1);
    let mut heap: BinaryHeap<Reverse<Ev>> = BinaryHeap::new();
    let mut scheduled: Vec<f64> = Vec::new();
    let mut seq = 0u64;
    let horizon = 600.0;
    let mut pushes = 0usize;
    let mut pops = 0usize;
    // Interleaved phase: pushes and pops mixed, biased toward pushes so
    // the population grows, then a full drain.
    while pushes < 4000 {
        if rng.next() % 3 != 0 || heap.is_empty() {
            let t = draw_t(&mut rng, horizon, &scheduled);
            seq += 1;
            scheduled.push(t);
            wheel.push(t, seq, pushes as u32);
            heap.push(Reverse(Ev(t, seq)));
            pushes += 1;
        } else {
            let Reverse(Ev(ht, hs)) = heap.pop().unwrap();
            let (wt, ws, _) = wheel.pop().expect("wheel has what the heap has");
            assert_eq!(
                (wt.to_bits(), ws),
                (ht.to_bits(), hs),
                "pop #{pops} diverged (seed {seed}, geometry {w0}/{n0}/{n1})"
            );
            pops += 1;
        }
    }
    assert_eq!(wheel.len(), heap.len());
    while let Some(Reverse(Ev(ht, hs))) = heap.pop() {
        let (wt, ws, _) = wheel.pop().expect("wheel drains with the heap");
        assert_eq!(
            (wt.to_bits(), ws),
            (ht.to_bits(), hs),
            "drain pop #{pops} diverged (seed {seed}, geometry {w0}/{n0}/{n1})"
        );
        pops += 1;
    }
    assert!(wheel.is_empty());
    assert_eq!(pops, pushes);
}

#[test]
fn wheel_pop_sequence_is_heap_identical_on_randomized_streams() {
    for seed in [1u64, 7, 42, 1234, 0xDEAD_BEEF] {
        check_equivalence(seed, 1.0 / 32.0, 64, 256);
    }
}

#[test]
fn wheel_pop_sequence_is_heap_identical_across_geometries() {
    // Tiny rings force constant cascades and overflow rescues; coarse
    // buckets force long sorted-bucket runs with many ties per slot.
    for &(w0, n0, n1) in &[(0.5, 2, 2), (1.0 / 8.0, 4, 8), (2.0, 16, 4), (1.0 / 128.0, 8, 16)] {
        check_equivalence(99, w0, n0, n1);
        check_equivalence(100, w0, n0, n1);
    }
}

#[test]
fn wheel_sized_for_matches_heap_on_a_trace_shaped_stream() {
    // The engine's own construction path: geometry derived from a peak
    // rate and horizon, stream shaped like a shard's (arrivals seeded
    // up-front, completions pushed as pops happen).
    let mut wheel: TimerWheel<u32> = TimerWheel::sized_for(250.0, 600.0);
    let mut heap: BinaryHeap<Reverse<Ev>> = BinaryHeap::new();
    let mut rng = Lcg(2024);
    let mut seq = 0u64;
    for i in 0..3000 {
        let t = rng.f64() * 600.0;
        seq += 1;
        wheel.push(t, seq, i);
        heap.push(Reverse(Ev(t, seq)));
    }
    let mut popped = 0u32;
    while let Some(Reverse(Ev(ht, hs))) = heap.pop() {
        let (wt, ws, _) = wheel.pop().unwrap();
        assert_eq!((wt.to_bits(), ws), (ht.to_bits(), hs));
        popped += 1;
        // every third pop schedules a "completion" shortly after, like
        // a dispatch pushing its service-time event
        if popped % 3 == 0 {
            let t = ht + 0.05 + rng.f64() * 0.4;
            seq += 1;
            wheel.push(t, seq, popped);
            heap.push(Reverse(Ev(t, seq)));
        }
    }
    assert!(wheel.is_empty());
    assert!(wheel.high_water() > 0);
}

#[test]
fn pool_survives_panicking_tasks_and_keeps_serving() {
    let pool = WorkerPool::new(8, true);
    let hits = AtomicUsize::new(0);
    for round in 0..10usize {
        let bad = round % 64;
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.dispatch(64, &|i| {
                if i == bad {
                    panic!("injected failure at task {i}");
                }
                hits.fetch_add(1, AtomicOrdering::Relaxed);
            });
        }));
        assert!(result.is_err(), "round {round}: the panic must resurface");
        // The same pool keeps working: a clean generation right after the
        // aborted one runs every index exactly once.
        let count = AtomicUsize::new(0);
        pool.dispatch(32, &|_| {
            count.fetch_add(1, AtomicOrdering::Relaxed);
        });
        assert_eq!(count.load(AtomicOrdering::Relaxed), 32);
    }
    // Abort discipline: the panicking task never counts, and siblings
    // stop claiming once the abort flag lands (those already running may
    // still finish, so the bound is an upper one, not exact).
    assert!(hits.load(AtomicOrdering::Relaxed) <= 10 * 63);
    assert_eq!(pool.dispatches(), 20);
}

#[test]
fn pool_dispatch_runs_disjoint_slots_exactly_once() {
    // The parallel_zip contract seen from the pool side: every index
    // claimed exactly once per generation, results landing in disjoint
    // slots, across many generations on one pool.
    let pool = WorkerPool::new(4, false);
    let mut slots = vec![0u64; 257];
    for gen in 0..50u64 {
        let base = slots.as_mut_ptr() as usize;
        pool.dispatch(slots.len(), &|i| {
            // SAFETY: each index is claimed exactly once per dispatch and
            // the borrow ends before dispatch returns.
            unsafe { *(base as *mut u64).add(i) += gen + i as u64 }
        });
    }
    for (i, &v) in slots.iter().enumerate() {
        let expect: u64 = (0..50u64).map(|g| g + i as u64).sum();
        assert_eq!(v, expect, "slot {i}");
    }
}
