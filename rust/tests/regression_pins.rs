//! Regression pins for the PR 4 acceptance criterion: **with defaults
//! (admission disabled, single tier, burn boost off) the admission-
//! controlled request path is an exact no-op** — single-service and fleet
//! runs perform the same event sequence and RNG draws as the pre-admission
//! pipeline, so every summary statistic is bit-identical.
//!
//! The knobs are pinned from both sides: a default run is compared against
//! a run with every new knob set to a *neutral but non-default* value
//! (admission config present but disabled, an explicit single-tier class
//! mix, a non-default error budget with the burn boost off, equal non-zero
//! tiers).  Any code path that let one of those knobs leak into routing,
//! shedding, arbitration, or RNG draws breaks these exact equalities.
//!
//! PR 5 extends the pins to admission-aware value curves: **`shed_penalty
//! = 0` (the default) is an exact no-op** — the scorer's priced term, the
//! widened dominance caps, the B&B shed bound, and the `CurveCache`
//! pricing key must all collapse to the PR 4 behaviour bit for bit on the
//! single-service, fleet, and overload paths — and a `CurveCache` hit
//! never returns a curve computed under a different penalty.
//!
//! PR 6 pins the sharded, parallel fleet data plane: **`solver_threads`
//! is a wall-clock knob only** — the parallel solve/decide stages must be
//! bit-identical to the serial reference path at every thread count
//! (summaries, per-interval rows, tier breakdowns), and the N=1
//! single-service wrapper never changes behaviour under the knob.
//!
//! PR 7 pins the telemetry plane: **telemetry is a pure observer** — a
//! run with the registry, stage profiler, and flight recorder enabled
//! must make bit-identical decisions to a telemetry-off run (summaries,
//! per-interval rows, tier breakdowns), at every solver thread count.
//!
//! PR 8 pins the fault plane from both sides: **faults off is an exact
//! no-op** — a config with the `fault` section present (knobs set, master
//! switch off) never draws from the fault RNG streams, so every engine
//! path is bit-identical to the pre-fault pipeline — and **faults on are
//! deterministic** — a fixed fault seed replays the same crash storm at
//! every `solver_threads` count (every draw happens at a serial boundary
//! in service-index order).
//!
//! PR 10 pins the replay plane: **recording is a pure observer** — a run
//! with the record hooks armed (arrival fingerprints, per-tick decision
//! records, fault draws) makes bit-identical decisions to an unrecorded
//! run, at every solver thread count, with the fault plane on.

use infadapter::adapter::InfAdapterPolicy;
use infadapter::config::{AdmissionConfig, Config, FaultConfig, ObjectiveWeights};
use infadapter::fleet::{FleetMode, FleetScenario};
use infadapter::forecaster::LastMaxForecaster;
use infadapter::metrics::RunSummary;
use infadapter::profiler::ProfileSet;
use infadapter::serving::sim::{SimConfig, SimEngine};
use infadapter::solver::BranchBoundSolver;
use infadapter::workload::Trace;
use std::path::Path;

fn inf_policy(budget: usize) -> InfAdapterPolicy {
    InfAdapterPolicy::new(
        ProfileSet::paper_like(),
        Box::new(LastMaxForecaster::new(120, 1.0)),
        Box::new(BranchBoundSolver),
        ObjectiveWeights::default(),
        0.75,
        budget,
        1.1,
    )
}

fn assert_summaries_identical(a: &RunSummary, b: &RunSummary) {
    assert_eq!(a.total_requests, b.total_requests);
    assert_eq!(a.dropped, b.dropped);
    assert_eq!(a.failed, b.failed);
    assert_eq!(a.shed, b.shed);
    assert_eq!(a.slo_violation_rate, b.slo_violation_rate);
    assert_eq!(a.goodput_rps, b.goodput_rps);
    assert_eq!(a.avg_accuracy, b.avg_accuracy);
    assert_eq!(a.avg_accuracy_loss, b.avg_accuracy_loss);
    assert_eq!(a.core_seconds, b.core_seconds);
    assert_eq!(a.p99_latency_s, b.p99_latency_s);
    assert_eq!(a.p50_latency_s, b.p50_latency_s);
    assert_eq!(a.mean_latency_s, b.mean_latency_s);
}

#[test]
fn single_service_neutral_knobs_are_bit_identical() {
    let profiles = ProfileSet::paper_like();
    let trace = Trace::bursty(40.0, 100.0, 420, 9);
    // explicit single-tier class mix: must assign tier 0 to every request
    // without touching any RNG stream
    let mixed_trace = trace.clone().with_class_mix(vec![(0, 1.0)]);

    let mut p1 = inf_policy(20);
    let default_cfg = SimConfig {
        seed: 9,
        ..Default::default()
    };
    let base = SimEngine::new(profiles.clone(), default_cfg.clone()).run(&mut p1, &trace);

    // a present-but-disabled admission config with non-default knobs
    let mut p2 = inf_policy(20);
    let neutral_cfg = SimConfig {
        seed: 9,
        admission: AdmissionConfig {
            enabled: false,
            burst_s: 7.0,
            slack: 2.0,
            ctl_window_s: 0.25,
        },
        ..Default::default()
    };
    let neutral = SimEngine::new(profiles.clone(), neutral_cfg).run(&mut p2, &mixed_trace);

    let a = base.metrics.summary("default", base.duration_s);
    let b = neutral.metrics.summary("neutral", neutral.duration_s);
    assert_summaries_identical(&a, &b);
    assert_eq!(base.decisions.len(), neutral.decisions.len());
    for ((t1, d1), (t2, d2)) in base.decisions.iter().zip(&neutral.decisions) {
        assert_eq!(t1, t2);
        assert_eq!(d1.target, d2.target);
        assert_eq!(d1.quotas, d2.quotas);
        assert_eq!(d1.batches, d2.batches);
        assert_eq!(d1.predicted_lambda, d2.predicted_lambda);
    }
}

#[test]
fn fleet_neutral_knobs_are_bit_identical() {
    let profiles = ProfileSet::paper_like();
    let mut config = Config::default();
    config.adapter.forecaster = "last_max".into();
    config.seed = 17;
    let base_scenario =
        FleetScenario::synthetic(2, 30.0, 600, 12, &config, &profiles);

    // neutral variants of every new knob: equal non-zero tiers (the
    // arbiter's single-tier fast path), non-default error budgets with
    // the burn boost off, disabled admission with non-default shape
    let mut neutral_scenario = base_scenario.clone();
    neutral_scenario.admission = AdmissionConfig {
        enabled: false,
        burst_s: 3.0,
        slack: 1.5,
        ctl_window_s: 0.5,
    };
    neutral_scenario.burn_boost = 0.0;
    neutral_scenario.shed_penalty = 0.0;
    for s in neutral_scenario.services.iter_mut() {
        s.tier = 3;
        s.error_budget = 0.5;
    }

    let dir = Path::new("/nonexistent");
    let base = base_scenario.run(&FleetMode::Arbiter, dir);
    let neutral = neutral_scenario.run(&FleetMode::Arbiter, dir);
    assert_eq!(base.summary.total_requests, neutral.summary.total_requests);
    assert_eq!(base.summary.shed, 0);
    assert_eq!(neutral.summary.shed, 0);
    assert_eq!(
        base.summary.slo_violation_rate,
        neutral.summary.slo_violation_rate
    );
    assert_eq!(base.summary.core_seconds, neutral.summary.core_seconds);
    for (x, y) in base.summary.services.iter().zip(&neutral.summary.services) {
        assert_summaries_identical(x, y);
    }
}

#[test]
fn shed_penalty_zero_is_bit_identical_on_every_engine_path() {
    let profiles = ProfileSet::paper_like();

    // (1) single-service path: an explicitly zero-priced policy against
    // the default — the scorer guard, the dominance caps, and the
    // decision path must be untouched.
    let trace = Trace::bursty(40.0, 100.0, 420, 9);
    let cfg = SimConfig {
        seed: 9,
        ..Default::default()
    };
    let mut p1 = inf_policy(20);
    let base = SimEngine::new(profiles.clone(), cfg.clone()).run(&mut p1, &trace);
    let mut p2 = inf_policy(20).with_shed_pricing(0.0);
    let neutral = SimEngine::new(profiles.clone(), cfg).run(&mut p2, &trace);
    assert_summaries_identical(
        &base.metrics.summary("default", base.duration_s),
        &neutral.metrics.summary("neutral", neutral.duration_s),
    );
    assert_eq!(base.decisions.len(), neutral.decisions.len());
    for ((t1, d1), (t2, d2)) in base.decisions.iter().zip(&neutral.decisions) {
        assert_eq!(t1, t2);
        assert_eq!(d1.target, d2.target);
        assert_eq!(d1.quotas, d2.quotas);
        assert_eq!(d1.supply_rps, d2.supply_rps);
    }

    // (2) arbitrated fleet path: explicit shed_penalty = 0 plus neutral
    // single-tier class mixes (the tier-weighting path runs, multiplied
    // by the zero price) vs the plain scenario.
    let mut config = Config::default();
    config.adapter.forecaster = "last_max".into();
    config.seed = 17;
    let base_scenario = FleetScenario::synthetic(2, 30.0, 600, 12, &config, &profiles);
    let mut neutral_scenario = base_scenario.clone();
    neutral_scenario.shed_penalty = 0.0;
    for s in neutral_scenario.services.iter_mut() {
        s.trace = s.trace.clone().with_class_mix(vec![(s.tier, 1.0)]);
    }
    let dir = Path::new("/nonexistent");
    let a = base_scenario.run(&FleetMode::Arbiter, dir);
    let b = neutral_scenario.run(&FleetMode::Arbiter, dir);
    for (x, y) in a.summary.services.iter().zip(&b.summary.services) {
        assert_summaries_identical(x, y);
    }

    // (3) overload path with admission shedding for real: the zero price
    // must not move a single shed, grant, or violation.
    let mut config = Config::default();
    config.adapter.forecaster = "last_max".into();
    config.seed = 5;
    config.admission.enabled = true;
    let base_scenario =
        FleetScenario::synthetic_overload(2, 30.0, 420, 8, true, &config, &profiles);
    let mut neutral_scenario = base_scenario.clone();
    neutral_scenario.shed_penalty = 0.0;
    for s in neutral_scenario.services.iter_mut() {
        s.trace = s.trace.clone().with_class_mix(vec![(s.tier, 1.0)]);
    }
    let a = base_scenario.run(&FleetMode::Arbiter, dir);
    let b = neutral_scenario.run(&FleetMode::Arbiter, dir);
    assert!(a.summary.shed > 0, "the overload pin must actually shed");
    assert_eq!(a.summary.shed, b.summary.shed);
    for (x, y) in a.summary.services.iter().zip(&b.summary.services) {
        assert_summaries_identical(x, y);
    }
    for (x, y) in a.summary.tiers.iter().zip(&b.summary.tiers) {
        assert_eq!(x.tier, y.tier);
        assert_eq!(x.total, y.total);
        assert_eq!(x.shed, y.shed);
        assert_eq!(x.violations, y.violations);
    }
}

#[test]
fn curve_cache_hits_never_cross_penalties() {
    // The ISSUE's cache pin: a CurveCache hit must never return a curve
    // computed under a different shed penalty (or, when priced, a
    // different offered rate) — the cached object is a different
    // function of the grant.
    use infadapter::fleet::CurveCache;
    use std::collections::BTreeMap;

    let mut policy = inf_policy(20).with_shed_pricing(1.0);
    // overload forecast so the pricing genuinely shapes the curve
    policy.observe_and_predict(&vec![300.0; 60]);
    let committed = BTreeMap::new();
    let mut cache = CurveCache::new();
    let priced = cache.curve(&policy, 330.0, &committed, 20);
    assert_eq!(cache.stats.hits, 0);
    // identical pricing: a genuine hit, returning the identical curve
    let again = cache.curve(&policy, 330.0, &committed, 20);
    assert_eq!(cache.stats.hits, 1);
    assert_eq!(priced, again);
    // a different penalty must re-solve — and produce different values
    policy.shed_penalty = 0.0;
    let unpriced = cache.curve(&policy, 330.0, &committed, 20);
    assert_eq!(cache.stats.hits, 1, "cross-penalty lookups must not hit");
    assert_eq!(unpriced, policy.value_curve(330.0, &committed, 20));
    assert_ne!(priced, unpriced, "the two penalties price different curves");
    // and back: still no stale cross-penalty hit
    policy.shed_penalty = 1.0;
    let repriced = cache.curve(&policy, 330.0, &committed, 20);
    assert_eq!(cache.stats.hits, 1);
    assert_eq!(repriced, priced, "same inputs re-solve to the same curve");
}

#[test]
fn parallel_fleet_is_bit_identical_to_serial() {
    // The ISSUE 6 invariant: thread count is a wall-clock knob, never a
    // results knob.  The overload scenario exercises every stage of the
    // tick protocol — admission shedding, arbitration, per-service curve
    // solves, tiered class mixes — so a single divergent float anywhere
    // in the parallel solve/decide fan-out shows up here.
    let profiles = ProfileSet::paper_like();
    let mut config = Config::default();
    config.adapter.forecaster = "last_max".into();
    config.seed = 5;
    config.admission.enabled = true;
    let base = FleetScenario::synthetic_overload(2, 30.0, 420, 8, true, &config, &profiles);
    let dir = Path::new("/nonexistent");
    let run_at = |threads: usize| {
        let mut s = base.clone();
        s.solver_threads = threads;
        s.run(&FleetMode::Arbiter, dir)
    };
    let serial = run_at(1);
    assert!(
        serial.summary.shed > 0,
        "the overload pin must actually shed"
    );
    for threads in [2usize, 8] {
        let parallel = run_at(threads);
        assert_eq!(
            serial.summary.total_requests,
            parallel.summary.total_requests
        );
        assert_eq!(serial.summary.shed, parallel.summary.shed);
        assert_eq!(
            serial.summary.slo_violation_rate,
            parallel.summary.slo_violation_rate
        );
        assert_eq!(serial.summary.core_seconds, parallel.summary.core_seconds);
        assert_eq!(
            serial.summary.services.len(),
            parallel.summary.services.len()
        );
        for (x, y) in serial.summary.services.iter().zip(&parallel.summary.services) {
            assert_summaries_identical(x, y);
        }
        assert_eq!(serial.summary.tiers.len(), parallel.summary.tiers.len());
        for (x, y) in serial.summary.tiers.iter().zip(&parallel.summary.tiers) {
            assert_eq!(x, y, "tier breakdowns diverge at {threads} threads");
        }
        for (a, b) in serial.per_service.iter().zip(&parallel.per_service) {
            assert_eq!(a.duration_s, b.duration_s);
            assert_eq!(
                a.metrics.rows(a.duration_s),
                b.metrics.rows(b.duration_s),
                "interval rows diverge at {threads} threads"
            );
        }
    }
}

#[test]
fn telemetry_on_is_bit_identical_to_off() {
    // The ISSUE 7 invariant: telemetry observes, it never participates.
    // Counters, the stage profiler, and the flight recorder may read any
    // decision, but no decision may read them — so an overload run (the
    // scenario that exercises admission shedding, tiered class mixes,
    // NoRoute fallbacks, SLO-burn trips, and the solve/decide fan-out)
    // must be bit-identical with the plane on or off, at both the serial
    // reference thread count and a parallel one.
    let profiles = ProfileSet::paper_like();
    let mut config = Config::default();
    config.adapter.forecaster = "last_max".into();
    config.seed = 5;
    config.admission.enabled = true;
    let base = FleetScenario::synthetic_overload(2, 30.0, 420, 8, true, &config, &profiles);
    let dir = Path::new("/nonexistent");
    for threads in [1usize, 8] {
        let run_at = |telemetry: bool| {
            let mut s = base.clone();
            s.solver_threads = threads;
            s.telemetry.enabled = telemetry;
            s.run(&FleetMode::Arbiter, dir)
        };
        let off = run_at(false);
        let on = run_at(true);
        assert!(off.summary.shed > 0, "the overload pin must actually shed");
        assert!(
            off.telemetry.is_none() && off.summary.telemetry.is_none(),
            "a telemetry-off run must not carry a telemetry section"
        );
        // the on-run must genuinely instrument — an accidentally dead
        // plane would make this pin vacuous
        let ft = on.telemetry.as_ref().expect("telemetry plane missing");
        assert!(ft.ticks > 0);
        let ts = on.summary.telemetry.expect("summary telemetry missing");
        assert!(ts.admitted > 0 && ts.shed > 0);

        assert_eq!(off.summary.total_requests, on.summary.total_requests);
        assert_eq!(off.summary.shed, on.summary.shed);
        assert_eq!(off.summary.slo_violation_rate, on.summary.slo_violation_rate);
        assert_eq!(off.summary.core_seconds, on.summary.core_seconds);
        assert_eq!(off.summary.services.len(), on.summary.services.len());
        for (x, y) in off.summary.services.iter().zip(&on.summary.services) {
            assert_summaries_identical(x, y);
        }
        assert_eq!(off.summary.tiers.len(), on.summary.tiers.len());
        for (x, y) in off.summary.tiers.iter().zip(&on.summary.tiers) {
            assert_eq!(x, y, "tier breakdowns diverge at {threads} threads");
        }
        for (a, b) in off.per_service.iter().zip(&on.per_service) {
            assert_eq!(a.duration_s, b.duration_s);
            assert_eq!(
                a.metrics.rows(a.duration_s),
                b.metrics.rows(b.duration_s),
                "interval rows diverge at {threads} threads"
            );
        }
        // and the telemetry plane's own books must balance with the run
        assert_eq!(ts.shed, on.summary.shed, "shed counter disagrees");
    }
}

#[test]
fn single_service_engine_ignores_solver_threads() {
    // The N=1 wrapper is always the serial reference path: requesting 8
    // solver threads on a single-service engine must not change a thing
    // (one service means one solve — there is nothing to fan out).
    let profiles = ProfileSet::paper_like();
    let trace = Trace::bursty(40.0, 100.0, 420, 9);
    let mut p1 = inf_policy(20);
    let base = SimEngine::new(
        profiles.clone(),
        SimConfig {
            seed: 9,
            ..Default::default()
        },
    )
    .run(&mut p1, &trace);
    let mut p2 = inf_policy(20);
    let threaded = SimEngine::new(
        profiles.clone(),
        SimConfig {
            seed: 9,
            solver_threads: 8,
            ..Default::default()
        },
    )
    .run(&mut p2, &trace);
    assert_summaries_identical(
        &base.metrics.summary("serial", base.duration_s),
        &threaded.metrics.summary("threaded", threaded.duration_s),
    );
    assert_eq!(base.decisions.len(), threaded.decisions.len());
    for ((t1, d1), (t2, d2)) in base.decisions.iter().zip(&threaded.decisions) {
        assert_eq!(t1, t2);
        assert_eq!(d1.target, d2.target);
        assert_eq!(d1.quotas, d2.quotas);
        assert_eq!(d1.batches, d2.batches);
        assert_eq!(d1.predicted_lambda, d2.predicted_lambda);
    }
    assert_eq!(
        base.metrics.rows(base.duration_s),
        threaded.metrics.rows(threaded.duration_s)
    );
}

#[test]
fn burn_boost_zero_matches_burning_fleet_partitions() {
    // Even with services *actually burning* their SLO budget, burn_boost=0
    // must leave the arbiter's partitions untouched: an overloaded fleet
    // run is bit-identical whether the error budgets are tight or loose.
    let profiles = ProfileSet::paper_like();
    let mut config = Config::default();
    config.adapter.forecaster = "last_max".into();
    config.seed = 5;
    let tight = FleetScenario::synthetic_overload(2, 30.0, 420, 8, false, &config, &profiles);
    let mut loose = tight.clone();
    for s in loose.services.iter_mut() {
        s.error_budget = 1.0;
    }
    let dir = Path::new("/nonexistent");
    let a = tight.run(&FleetMode::Arbiter, dir);
    let b = loose.run(&FleetMode::Arbiter, dir);
    for (x, y) in a.summary.services.iter().zip(&b.summary.services) {
        assert_summaries_identical(x, y);
    }
}

/// A `fault` section with every knob at a non-default value but the
/// master switch off: nothing may draw, react, eject, or retry.
fn disarmed_faults() -> FaultConfig {
    let mut f = FaultConfig::default();
    f.apply_spec("crash:0.5:1:1e9,slowstart:3,straggler:0.5:10:8,stall:0.5,reactions:on,retries:4,backoff:0.2,eject:1,probe:1,hedge:on")
        .expect("valid spec");
    f.enabled = false;
    f
}

#[test]
fn faults_off_is_bit_identical_on_every_engine_path() {
    // The ISSUE 8 invariant, side one: a disabled fault plane is an exact
    // no-op — no RNG stream is touched, no health policy armed, no
    // straggler multiplier applied — on the single-service wrapper, the
    // arbitrated fleet, and the admission-shedding overload path.
    let profiles = ProfileSet::paper_like();

    // (1) single-service path
    let trace = Trace::bursty(40.0, 100.0, 420, 9);
    let mut p1 = inf_policy(20);
    let base = SimEngine::new(
        profiles.clone(),
        SimConfig {
            seed: 9,
            ..Default::default()
        },
    )
    .run(&mut p1, &trace);
    let mut p2 = inf_policy(20);
    let disarmed = SimEngine::new(
        profiles.clone(),
        SimConfig {
            seed: 9,
            fault: disarmed_faults(),
            ..Default::default()
        },
    )
    .run(&mut p2, &trace);
    assert_summaries_identical(
        &base.metrics.summary("default", base.duration_s),
        &disarmed.metrics.summary("disarmed", disarmed.duration_s),
    );
    assert_eq!(
        base.metrics.rows(base.duration_s),
        disarmed.metrics.rows(disarmed.duration_s)
    );

    // (2) arbitrated fleet path
    let mut config = Config::default();
    config.adapter.forecaster = "last_max".into();
    config.seed = 17;
    let base_scenario = FleetScenario::synthetic(2, 30.0, 600, 12, &config, &profiles);
    let mut disarmed_scenario = base_scenario.clone();
    disarmed_scenario.fault = disarmed_faults();
    let dir = Path::new("/nonexistent");
    let a = base_scenario.run(&FleetMode::Arbiter, dir);
    let b = disarmed_scenario.run(&FleetMode::Arbiter, dir);
    for (x, y) in a.summary.services.iter().zip(&b.summary.services) {
        assert_summaries_identical(x, y);
    }

    // (3) overload path (admission shedding + tiers), serial and parallel
    let mut config = Config::default();
    config.adapter.forecaster = "last_max".into();
    config.seed = 5;
    config.admission.enabled = true;
    let base = FleetScenario::synthetic_overload(2, 30.0, 420, 8, true, &config, &profiles);
    for threads in [1usize, 8] {
        let run_at = |fault: FaultConfig| {
            let mut s = base.clone();
            s.solver_threads = threads;
            s.fault = fault;
            s.run(&FleetMode::Arbiter, dir)
        };
        let off = run_at(FaultConfig::default());
        let disarmed = run_at(disarmed_faults());
        assert!(off.summary.shed > 0, "the overload pin must actually shed");
        assert_eq!(off.summary.failed, 0, "no fault plane, no failures");
        assert_eq!(off.summary.total_requests, disarmed.summary.total_requests);
        assert_eq!(off.summary.shed, disarmed.summary.shed);
        assert_eq!(
            off.summary.slo_violation_rate,
            disarmed.summary.slo_violation_rate
        );
        assert_eq!(off.summary.core_seconds, disarmed.summary.core_seconds);
        for (x, y) in off.summary.services.iter().zip(&disarmed.summary.services) {
            assert_summaries_identical(x, y);
        }
        for (x, y) in off.summary.tiers.iter().zip(&disarmed.summary.tiers) {
            assert_eq!(x, y, "tier breakdowns diverge at {threads} threads");
        }
        for (a, b) in off.per_service.iter().zip(&disarmed.per_service) {
            assert_eq!(
                a.metrics.rows(a.duration_s),
                b.metrics.rows(b.duration_s),
                "interval rows diverge at {threads} threads"
            );
        }
    }
}

#[test]
fn fault_seed_replays_identically_at_every_thread_count() {
    // The ISSUE 8 invariant, side two: an armed fault plane is seeded —
    // the same crash storm (crashes, stragglers, stalls, retries, and
    // every downstream reaction) replays bit-identically at solver_threads
    // 1, 2, and 8, because every fault draw happens at a serial boundary
    // of the tick protocol in service-index order over sorted pod ids.
    let profiles = ProfileSet::paper_like();
    let mut config = Config::default();
    config.adapter.forecaster = "last_max".into();
    config.seed = 5;
    config.admission.enabled = true;
    config
        .fault
        .apply_spec("crash:0.004:60:300,slowstart:2,straggler:0.002:30:4,stall:0.05,reactions:on,retries:2")
        .expect("valid spec");
    let base = FleetScenario::synthetic_overload(2, 30.0, 420, 8, true, &config, &profiles);
    let dir = Path::new("/nonexistent");
    let run_at = |threads: usize| {
        let mut s = base.clone();
        s.solver_threads = threads;
        s.telemetry.enabled = true; // counters prove the storm happened
        s.run(&FleetMode::Arbiter, dir)
    };
    let serial = run_at(1);
    let ts = serial
        .summary
        .telemetry
        .expect("telemetry summary missing");
    assert!(ts.pod_crashes > 0, "the storm must actually crash pods");
    for threads in [2usize, 8] {
        let parallel = run_at(threads);
        let tp = parallel
            .summary
            .telemetry
            .expect("telemetry summary missing");
        assert_eq!(ts.pod_crashes, tp.pod_crashes);
        assert_eq!(ts.retries, tp.retries);
        assert_eq!(ts.failed_requests, tp.failed_requests);
        assert_eq!(ts.fallback_solves, tp.fallback_solves);
        assert_eq!(serial.summary.total_requests, parallel.summary.total_requests);
        assert_eq!(serial.summary.failed, parallel.summary.failed);
        assert_eq!(serial.summary.shed, parallel.summary.shed);
        assert_eq!(
            serial.summary.slo_violation_rate,
            parallel.summary.slo_violation_rate
        );
        assert_eq!(serial.summary.core_seconds, parallel.summary.core_seconds);
        for (x, y) in serial.summary.services.iter().zip(&parallel.summary.services) {
            assert_summaries_identical(x, y);
        }
        for (a, b) in serial.per_service.iter().zip(&parallel.per_service) {
            assert_eq!(
                a.metrics.rows(a.duration_s),
                b.metrics.rows(b.duration_s),
                "interval rows diverge at {threads} threads"
            );
        }
    }
}

#[test]
fn recording_is_a_pure_observer() {
    // The ISSUE 10 invariant: the replay Recorder observes, it never
    // participates.  A recorded run must produce bit-identical summaries,
    // decision streams, and interval rows to an unrecorded run of the
    // same scenario — the record hooks read serial-boundary state the
    // stages already computed and draw no RNG — at both the serial
    // reference thread count and a parallel one, with the fault plane
    // armed (so the fault-draw hook is exercised too).
    let profiles = ProfileSet::paper_like();
    let mut config = Config::default();
    config.adapter.forecaster = "last_max".into();
    config.seed = 5;
    config.admission.enabled = true;
    config
        .fault
        .apply_spec("crash:0.004:60:300,slowstart:2,straggler:0.002:30:4,stall:0.05,reactions:on,retries:2")
        .expect("valid spec");
    let base = FleetScenario::synthetic_overload(2, 30.0, 420, 8, true, &config, &profiles);
    let dir = Path::new("/nonexistent");
    for threads in [1usize, 8] {
        let mut s = base.clone();
        s.solver_threads = threads;
        let plain = s.run(&FleetMode::Arbiter, dir);
        let (recorded, trace) = s.run_recorded(&FleetMode::Arbiter, dir);
        assert!(plain.summary.shed > 0, "the overload pin must actually shed");
        assert!(trace.ticks.len() > 1, "the recorder must actually record");
        assert!(
            trace.faults.iter().any(|f| !f.crashed.is_empty()),
            "the armed fault plane must actually draw"
        );
        assert_eq!(plain.summary.total_requests, recorded.summary.total_requests);
        assert_eq!(plain.summary.shed, recorded.summary.shed);
        assert_eq!(plain.summary.failed, recorded.summary.failed);
        assert_eq!(
            plain.summary.slo_violation_rate,
            recorded.summary.slo_violation_rate
        );
        assert_eq!(plain.summary.core_seconds, recorded.summary.core_seconds);
        for (x, y) in plain.summary.services.iter().zip(&recorded.summary.services) {
            assert_summaries_identical(x, y);
        }
        for (x, y) in plain.summary.tiers.iter().zip(&recorded.summary.tiers) {
            assert_eq!(x, y, "tier breakdowns diverge at {threads} threads");
        }
        for (a, b) in plain.per_service.iter().zip(&recorded.per_service) {
            assert_eq!(a.duration_s, b.duration_s);
            assert_eq!(a.decisions, b.decisions, "decision streams diverge");
            assert_eq!(
                a.metrics.rows(a.duration_s),
                b.metrics.rows(b.duration_s),
                "interval rows diverge at {threads} threads"
            );
        }
    }
}
