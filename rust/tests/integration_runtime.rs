//! Integration tests over the real PJRT runtime + AOT artifacts.
//!
//! These require `make artifacts`; every test self-skips (with a note)
//! when the artifacts directory is absent so `cargo test` stays green in
//! a fresh checkout.

use infadapter::forecaster::{Forecaster, LstmForecaster};
use infadapter::runtime::{load_weights_f32, Manifest, WorkerPool};
use std::path::PathBuf;
use std::sync::Arc;

fn artifacts() -> Option<PathBuf> {
    let dir = infadapter::runtime::artifacts_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        None
    }
}

#[test]
fn manifest_lists_the_paper_family() {
    let Some(dir) = artifacts() else { return };
    let m = Manifest::load(&dir).unwrap();
    let names: Vec<&str> = m.variants.iter().map(|v| v.name.as_str()).collect();
    for want in ["resnet18", "resnet34", "resnet50", "resnet101", "resnet152"] {
        assert!(names.contains(&want), "missing {want}");
    }
    // accuracy ladder ascends with depth
    let sorted = m.variants_by_accuracy();
    assert_eq!(sorted.first().unwrap().name, "resnet18");
    assert_eq!(sorted.last().unwrap().name, "resnet152");
}

#[test]
fn weights_npz_matches_manifest_counts() {
    let Some(dir) = artifacts() else { return };
    let m = Manifest::load(&dir).unwrap();
    let meta = m.variant("resnet18").unwrap();
    let weights = load_weights_f32(&meta.weights_path(&dir)).unwrap();
    assert_eq!(weights.len(), meta.num_weight_arrays);
    // names are sorted zero-padded indices => positional order is stable
    let names: Vec<&str> = weights.iter().map(|(n, _, _)| n.as_str()).collect();
    let mut sorted = names.clone();
    sorted.sort();
    assert_eq!(names, sorted);
    let total: usize = weights.iter().map(|(_, d, _)| d.len()).sum();
    assert_eq!(total as u64, meta.params);
}

#[test]
fn end_to_end_inference_is_deterministic_and_sane() {
    let Some(dir) = artifacts() else { return };
    let m = Manifest::load(&dir).unwrap();
    let meta = m.variant("resnet18").unwrap();
    let pool = WorkerPool::spawn(&dir, &m, meta, 1, 1).unwrap();
    let image = Arc::new(vec![0.5f32; m.input_shape(1).iter().product()]);
    let a = pool.infer_blocking(image.clone()).unwrap();
    let b = pool.infer_blocking(image.clone()).unwrap();
    assert_eq!(a.len(), m.num_classes);
    assert_eq!(a, b, "same input must give identical logits");
    assert!(a.iter().all(|x| x.is_finite()));
    // different input -> different logits
    let other = Arc::new(vec![-0.25f32; m.input_shape(1).iter().product()]);
    let c = pool.infer_blocking(other).unwrap();
    assert_ne!(a, c);
    pool.shutdown();
}

#[test]
fn pool_serves_concurrent_submissions() {
    let Some(dir) = artifacts() else { return };
    let m = Manifest::load(&dir).unwrap();
    let meta = m.variant("resnet18").unwrap();
    let pool = WorkerPool::spawn(&dir, &m, meta, 1, 2).unwrap();
    let image = Arc::new(vec![0.1f32; m.input_shape(1).iter().product()]);
    let (tx, rx) = std::sync::mpsc::channel();
    let n = 12;
    for _ in 0..n {
        let tx = tx.clone();
        pool.submit(image.clone(), move |result, _elapsed| {
            tx.send(result.is_ok()).unwrap();
        })
        .unwrap();
    }
    drop(tx);
    let oks: Vec<bool> = rx.iter().collect();
    assert_eq!(oks.len(), n);
    assert!(oks.iter().all(|&ok| ok));
    pool.shutdown();
}

#[test]
fn readiness_time_is_measured() {
    let Some(dir) = artifacts() else { return };
    let m = Manifest::load(&dir).unwrap();
    let meta = m.variant("resnet18").unwrap();
    let pool = WorkerPool::spawn(&dir, &m, meta, 1, 1).unwrap();
    // compile + weight upload takes real time (this is rt_m)
    assert!(pool.readiness.as_secs_f64() > 0.05);
    assert!(pool.readiness.as_secs_f64() < 120.0);
    pool.shutdown();
}

#[test]
fn lstm_forecaster_loads_and_reacts_to_load() {
    let Some(dir) = artifacts() else { return };
    let mut f = match LstmForecaster::load(&dir) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("skipping: forecaster artifact missing ({e:#})");
            return;
        }
    };
    for _ in 0..120 {
        f.observe(40.0);
    }
    let low = f.predict_max();
    for _ in 0..120 {
        f.observe(120.0);
    }
    let high = f.predict_max();
    assert!(low >= 40.0, "floor at observed peak, got {low}");
    assert!(high > low, "must react to rising load: {low} -> {high}");
    assert!(high >= 120.0 && high < 400.0, "sane range, got {high}");
}

#[test]
fn batched_artifacts_accept_batched_inputs() {
    let Some(dir) = artifacts() else { return };
    let m = Manifest::load(&dir).unwrap();
    let meta = m.variant("resnet50").unwrap();
    for &batch in meta.batch_sizes().iter().filter(|&&b| b <= 2) {
        let pool = WorkerPool::spawn(&dir, &m, meta, batch, 1).unwrap();
        let image = Arc::new(vec![0.3f32; m.input_shape(batch).iter().product()]);
        let out = pool.infer_blocking(image).unwrap();
        assert_eq!(out.len(), batch * m.num_classes);
        pool.shutdown();
    }
}
