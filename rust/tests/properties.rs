//! Property-based tests on coordinator invariants (hand-rolled generators:
//! the offline build has no proptest — `util::rng` drives the cases).

use infadapter::baselines::StaticPolicy;
use infadapter::config::{AdmissionConfig, BatchingConfig, Config, ObjectiveWeights};
use infadapter::dispatcher::{AdmissionGate, Dispatcher, Tier};
use infadapter::experiment::{PolicyKind, Scenario};
use infadapter::fleet::sim::service_seed;
use infadapter::fleet::{ArbiterEntry, CoreArbiter, FleetMode, FleetScenario};
use infadapter::metrics::rows_to_csv;
use infadapter::profiler::ProfileSet;
use infadapter::serving::sim::{SimConfig, SimEngine};
use infadapter::solver::{
    score, score_fast, value_curve_resolve, BranchBoundSolver, BruteForceSolver, Problem, Solver,
};
use infadapter::util::rng::Rng;
use infadapter::workload::{ArrivalProcess, Trace};
use std::collections::BTreeMap;

fn random_problem(rng: &mut Rng) -> Problem {
    let profiles = ProfileSet::paper_like();
    let lambda = rng.f64() * 200.0;
    let budget = 4 + rng.below(28);
    let beta = [0.0125, 0.05, 0.2][rng.below(3)];
    let mut current = BTreeMap::new();
    if rng.f64() < 0.5 {
        current.insert("resnet50".to_string(), 1 + rng.below(8));
    }
    Problem::from_profiles(
        &profiles,
        lambda,
        0.75,
        budget,
        ObjectiveWeights {
            alpha: 1.0,
            beta,
            gamma: 0.001,
        },
        &current,
    )
}

/// A fully randomized instance: synthetic profile family (random size,
/// accuracies, service times), random λ/budget/weights/SLO, random current
/// allocation, random batching config.
fn random_problem_general(rng: &mut Rng) -> Problem {
    let m = 1 + rng.below(6);
    let entries: Vec<(String, f64, f64, f64)> = (0..m)
        .map(|i| {
            (
                format!("v{i}"),
                50.0 + rng.f64() * 45.0,          // accuracy
                0.02 + rng.f64() * 0.3,           // service time
                1.0 + rng.f64() * 20.0,           // readiness
            )
        })
        .collect();
    let mut profiles = ProfileSet::from_service_times(&entries, 0.8 + rng.f64() * 0.2);
    for p in profiles.profiles.iter_mut() {
        p.batch_fixed_frac = rng.f64() * 0.9;
    }
    let budget = 1 + rng.below(24);
    let mut current = BTreeMap::new();
    for i in 0..m {
        if rng.f64() < 0.3 {
            current.insert(format!("v{i}"), 1 + rng.below(budget));
        }
    }
    let batching = BatchingConfig {
        max_batch: 1 + rng.below(12),
        max_wait_s: rng.f64() * 0.2,
    };
    Problem::from_profiles_batched(
        &profiles,
        rng.f64() * 400.0,
        0.1 + rng.f64() * 1.5, // SLO
        budget,
        ObjectiveWeights {
            alpha: rng.f64() * 2.0,
            beta: rng.f64() * 0.5,
            gamma: rng.f64() * 0.01,
        },
        &current,
        &batching,
    )
}

#[test]
fn prop_score_fast_matches_score() {
    // The enumeration hot path (`score_fast`) and the materializing path
    // (`score`) duplicate the greedy-fill logic; they must agree on
    // objective and feasibility for every core vector of every problem.
    let mut rng = Rng::seed_from_u64(108);
    for case in 0..300 {
        let p = if case % 2 == 0 {
            maybe_priced(random_problem(&mut rng), &mut rng)
        } else {
            maybe_priced(random_problem_general(&mut rng), &mut rng)
        };
        for _ in 0..16 {
            let cores: Vec<usize> = (0..p.variants.len())
                .map(|_| rng.below(p.budget + 1))
                .collect();
            let fast = score_fast(&p, &cores);
            let full = score(&p, &cores);
            match (fast, full) {
                (None, None) => {}
                (Some((obj, feasible)), Some(alloc)) => {
                    assert!(
                        (obj - alloc.objective).abs() < 1e-9,
                        "objective mismatch on {cores:?}: fast {obj} vs full {}",
                        alloc.objective
                    );
                    assert_eq!(
                        feasible, alloc.feasible,
                        "feasibility mismatch on {cores:?}"
                    );
                }
                (fast, full) => panic!(
                    "SLO-gate mismatch on {cores:?}: fast {:?} vs full {:?}",
                    fast.is_some(),
                    full.is_some()
                ),
            }
        }
    }
}

/// Randomly price shed traffic into a problem: a tier-weighted penalty
/// and an offered rate anywhere up to 1.25 × λ (the dominance caps must
/// widen to cover an offered load above the planning λ).  Roughly half
/// the cases stay unpriced so the PR 3/4 paths keep their coverage.
fn maybe_priced(mut p: Problem, rng: &mut Rng) -> Problem {
    if rng.f64() < 0.6 {
        p.shed_penalty = [0.25, 1.0, 4.0][rng.below(3)];
        p.offered_lambda = rng.f64() * p.lambda * 1.25;
    }
    p
}

#[test]
fn prop_solve_curve_matches_resolve_loop() {
    // The single-pass curve (bin best objective by cost, prefix-max) must
    // be pointwise equal to the old per-grant re-solve loop for both exact
    // solvers, monotone nondecreasing, deterministic, and unchanged by
    // warm-starting from any previous curve — including under randomized
    // shed pricing, which re-proves the B&B curve pruning (optimistic
    // shed charge + shed-pinned sweep cutoff) exact for the new term.
    let mut rng = Rng::seed_from_u64(109);
    for case in 0..30 {
        let p = if case % 2 == 0 {
            maybe_priced(random_problem(&mut rng), &mut rng)
        } else {
            maybe_priced(random_problem_general(&mut rng), &mut rng)
        };
        let check = |s: &dyn Solver, cap: usize| {
            let reference = value_curve_resolve(&p, s, cap);
            let curve = s.solve_curve(&p, cap);
            assert_eq!(curve.values().len(), cap + 1);
            for (g, (a, b)) in curve.values().iter().zip(&reference).enumerate() {
                assert!(
                    (a - b).abs() < 1e-9,
                    "case {case} {} g={g}/{cap}: curve {a} vs loop {b} (λ={}, B={})",
                    s.name(),
                    p.lambda,
                    p.budget
                );
            }
            for w in curve.values().windows(2) {
                assert!(w[1] >= w[0] - 1e-9, "curve must be nondecreasing");
            }
            // pure function of its inputs
            assert_eq!(curve, s.solve_curve(&p, cap), "case {case} {}", s.name());
            // warm starts change cost, never values
            let warm = s.solve_curve_seeded(&p, cap, Some(&curve));
            assert_eq!(
                warm.values(),
                curve.values(),
                "case {case} {} warm-seeded values drifted",
                s.name()
            );
        };
        // small caps for both exact solvers (the brute reference loop is
        // the expensive side)…
        let cap = rng.below(p.budget.min(16) + 1);
        check(&BruteForceSolver, cap);
        check(&BranchBoundSolver, cap);
        // …and periodically the whole budget for branch-and-bound, whose
        // reference loop prunes well enough to stay cheap
        if case % 5 == 0 {
            check(&BranchBoundSolver, p.budget);
        }
    }
}

#[test]
fn prop_priced_curve_matches_unpriced_at_zero() {
    // shed_penalty = 0 must reproduce the PR 3 curves pointwise — bit for
    // bit — no matter what offered rate rides along: an unpriced problem
    // ignores the offered load entirely (scorer guard + dominance caps).
    let mut rng = Rng::seed_from_u64(111);
    for case in 0..20 {
        let p = if case % 2 == 0 {
            random_problem(&mut rng)
        } else {
            random_problem_general(&mut rng)
        };
        let mut q = p.clone();
        q.shed_penalty = 0.0;
        q.offered_lambda = rng.f64() * 500.0;
        let cap = rng.below(p.budget.min(12) + 1);
        for s in [&BruteForceSolver as &dyn Solver, &BranchBoundSolver as &dyn Solver] {
            let a = s.solve_curve(&p, cap);
            let b = s.solve_curve(&q, cap);
            for (g, (x, y)) in a.values().iter().zip(b.values()).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "case {case} {} g={g}: zero-penalty curve drifted ({x} vs {y})",
                    s.name()
                );
            }
        }
    }
}

#[test]
fn prop_priced_value_curves_stay_monotone() {
    // v(g) stays nondecreasing in g under shed pricing for the exact
    // solvers: anything achievable inside grant g is achievable inside
    // g+1, and the priced objective of a fixed allocation does not
    // depend on the grant.
    let mut rng = Rng::seed_from_u64(112);
    for case in 0..25 {
        let base = if case % 2 == 0 {
            random_problem(&mut rng)
        } else {
            random_problem_general(&mut rng)
        };
        let mut p = base;
        p.shed_penalty = [0.25, 1.0, 4.0][rng.below(3)];
        p.offered_lambda = rng.f64() * p.lambda * 1.25;
        let cap = rng.below(p.budget.min(12) + 1);
        for s in [&BruteForceSolver as &dyn Solver, &BranchBoundSolver as &dyn Solver] {
            let curve = s.solve_curve(&p, cap);
            for (g, w) in curve.values().windows(2).enumerate() {
                assert!(
                    w[1] >= w[0] - 1e-9,
                    "case {case} {} g={g}: priced curve fell ({} -> {})",
                    s.name(),
                    w[0],
                    w[1]
                );
            }
        }
    }
}

#[test]
fn prop_score_fast_wide_problems_fall_back_to_score() {
    // Past 64 variants the u64 visited bitmask cannot cover the selection
    // loop; score_fast must fall back to the materializing path instead of
    // panicking (the seed code debug_assert!'d and corrupted in release).
    let mut rng = Rng::seed_from_u64(110);
    let entries: Vec<(String, f64, f64, f64)> = (0..70)
        .map(|i| {
            (
                format!("v{i}"),
                50.0 + rng.f64() * 45.0,
                0.02 + rng.f64() * 0.3,
                1.0 + rng.f64() * 20.0,
            )
        })
        .collect();
    let profiles = ProfileSet::from_service_times(&entries, 0.9);
    let p = Problem::from_profiles(
        &profiles,
        150.0,
        0.75,
        12,
        ObjectiveWeights::default(),
        &BTreeMap::new(),
    );
    for _ in 0..32 {
        let cores: Vec<usize> = (0..p.variants.len())
            .map(|_| if rng.f64() < 0.9 { 0 } else { 1 + rng.below(4) })
            .collect();
        let fast = score_fast(&p, &cores).expect("scoreable");
        let full = score(&p, &cores).expect("scoreable");
        assert!((fast.0 - full.objective).abs() < 1e-9);
        assert_eq!(fast.1, full.feasible);
    }
}

#[test]
fn prop_solver_never_exceeds_budget() {
    let mut rng = Rng::seed_from_u64(100);
    for _ in 0..60 {
        let p = random_problem(&mut rng);
        let a = BruteForceSolver.solve(&p).unwrap();
        assert!(a.total_cores() <= p.budget, "{a:?} vs budget {}", p.budget);
        assert_eq!(a.resource_cost, a.total_cores());
    }
}

#[test]
fn prop_solver_covers_load_whenever_possible() {
    let mut rng = Rng::seed_from_u64(101);
    for _ in 0..60 {
        let p = random_problem(&mut rng);
        let a = BruteForceSolver.solve(&p).unwrap();
        // if the best single-variant saturation can cover λ, result must be feasible
        let max_capacity: f64 = (0..p.variants.len())
            .map(|i| p.variants[i].throughput[p.budget])
            .fold(0.0, f64::max);
        if max_capacity >= p.lambda {
            assert!(a.feasible, "λ={} coverable but infeasible: {a:?}", p.lambda);
        }
        if a.feasible {
            assert!(a.capacity >= p.lambda - 1e-6);
            let quota_sum: f64 = a.assignments.values().map(|&(_, q)| q).sum();
            assert!((quota_sum - p.lambda).abs() < 1e-6, "quotas must sum to λ");
        }
    }
}

#[test]
fn prop_quotas_respect_per_variant_capacity() {
    let mut rng = Rng::seed_from_u64(102);
    let profiles = ProfileSet::paper_like();
    for _ in 0..60 {
        let p = random_problem(&mut rng);
        let a = BruteForceSolver.solve(&p).unwrap();
        for (name, &(cores, quota)) in &a.assignments {
            let th = profiles.get(name).unwrap().throughput(cores);
            assert!(quota <= th + 1e-9, "{name}: quota {quota} > th {th}");
        }
    }
}

#[test]
fn prop_branch_bound_equals_brute_force() {
    let mut rng = Rng::seed_from_u64(103);
    for _ in 0..25 {
        let p = random_problem(&mut rng);
        let bf = BruteForceSolver.solve(&p).unwrap();
        let bb = BranchBoundSolver.solve(&p).unwrap();
        assert!(
            (bf.objective - bb.objective).abs() < 1e-9,
            "bf={} bb={} (λ={}, B={})",
            bf.objective,
            bb.objective,
            p.lambda,
            p.budget
        );
    }
}

#[test]
fn prop_objective_monotone_in_budget() {
    let mut rng = Rng::seed_from_u64(104);
    let profiles = ProfileSet::paper_like();
    for _ in 0..20 {
        let lambda = rng.f64() * 150.0;
        let mut prev = f64::NEG_INFINITY;
        for budget in [6usize, 12, 20, 28] {
            let p = Problem::from_profiles(
                &profiles,
                lambda,
                0.75,
                budget,
                ObjectiveWeights::default(),
                &BTreeMap::new(),
            );
            let a = BruteForceSolver.solve(&p).unwrap();
            assert!(
                a.objective >= prev - 1e-9,
                "objective must not fall with budget: λ={lambda} B={budget}"
            );
            prev = a.objective;
        }
    }
}

#[test]
fn prop_accuracy_decreases_with_beta() {
    let profiles = ProfileSet::paper_like();
    for lambda in [30.0, 60.0, 90.0, 120.0] {
        let mut prev_acc = f64::INFINITY;
        for beta in [0.0125, 0.05, 0.2, 0.8] {
            let p = Problem::from_profiles(
                &profiles,
                lambda,
                0.75,
                20,
                ObjectiveWeights {
                    alpha: 1.0,
                    beta,
                    gamma: 0.001,
                },
                &BTreeMap::new(),
            );
            let a = BruteForceSolver.solve(&p).unwrap();
            assert!(
                a.average_accuracy <= prev_acc + 1e-9,
                "AA must not rise with β: λ={lambda} β={beta}"
            );
            prev_acc = a.average_accuracy;
        }
    }
}

#[test]
fn prop_dispatcher_distribution_tracks_weights() {
    let mut rng = Rng::seed_from_u64(105);
    for _ in 0..10 {
        let k = 2 + rng.below(4);
        let weights: Vec<(String, f64)> = (0..k)
            .map(|i| (format!("v{i}"), 1.0 + rng.f64() * 9.0))
            .collect();
        let d = Dispatcher::new();
        d.set_weights(&weights);
        let total: f64 = weights.iter().map(|(_, w)| w).sum();
        let n = 20_000;
        let mut counts: BTreeMap<String, usize> = BTreeMap::new();
        for _ in 0..n {
            *counts.entry(d.route().unwrap().to_string()).or_insert(0) += 1;
        }
        for (name, w) in &weights {
            let got = counts.get(name).copied().unwrap_or(0) as f64 / n as f64;
            let want = w / total;
            assert!(
                (got - want).abs() < 0.01,
                "{name}: got {got:.3}, want {want:.3}"
            );
        }
    }
}

#[test]
fn prop_arbiter_partition_bounded_floored_deterministic() {
    // The fleet arbiter's partition must (1) never exceed the global
    // budget, (2) respect every guaranteed-minimum floor, (3) lock
    // curve-less (fixed-budget) services at exactly their floor, (4) never
    // grant past a curve's cap, and (5) be a pure function of its inputs —
    // the same seed regenerates the same entries and the same partition.
    for case in 0..200u64 {
        let sub_seed = 10_000 + case;
        let gen = |rng: &mut Rng| -> (usize, Vec<ArbiterEntry>) {
            let n = 1 + rng.below(6);
            let budget = rng.below(64);
            let entries: Vec<ArbiterEntry> = (0..n)
                .map(|_| {
                    // per-service floor ≤ budget / n, so floors always fit
                    let floor = rng.below(budget / n + 1);
                    let has_curve = rng.f64() < 0.8;
                    let cap = floor + rng.below(40);
                    let mut level = 0.0f64;
                    let curve: Vec<f64> = (0..=cap)
                        .map(|_| {
                            // mostly-rising, occasionally dipping values:
                            // the arbiter must not assume monotonicity
                            level += rng.f64() * 2.0 - 0.2;
                            level
                        })
                        .collect();
                    ArbiterEntry {
                        priority: 0.1 + rng.f64() * 5.0,
                        // random strict tiers and burn signals: the
                        // invariants hold across the lexicographic
                        // pre-pass and the burn boost too
                        tier: rng.below(3) as u8,
                        burn: rng.f64() * 4.0,
                        floor,
                        curve: has_curve.then_some(curve),
                    }
                })
                .collect();
            (budget, entries)
        };
        let (budget, entries) = gen(&mut Rng::seed_from_u64(sub_seed));
        let boost = (case % 3) as f64; // 0 (off), 1, 2
        let arbiter = CoreArbiter::new(budget).with_burn_boost(boost);
        let grants = arbiter.partition(&entries);
        assert_eq!(grants.len(), entries.len());
        assert!(
            grants.iter().sum::<usize>() <= budget,
            "partition {grants:?} exceeds budget {budget}"
        );
        for (i, e) in entries.iter().enumerate() {
            assert!(grants[i] >= e.floor, "grant {} under floor {}", grants[i], e.floor);
            match &e.curve {
                None => assert_eq!(grants[i], e.floor, "fixed entry must hold its floor"),
                Some(c) => assert!(grants[i] < c.len(), "grant past the curve cap"),
            }
        }
        // same seed -> same entries -> same partition, and the call itself
        // is idempotent on identical inputs
        let (budget2, entries2) = gen(&mut Rng::seed_from_u64(sub_seed));
        assert_eq!(budget, budget2);
        let again = CoreArbiter::new(budget2)
            .with_burn_boost(boost)
            .partition(&entries2);
        assert_eq!(grants, again, "partition must be deterministic per seed");
        // (6) the O(B log N) heap water-fill must reproduce the reference
        // O(B·N) linear scan grant for grant, ties included
        assert_eq!(
            grants,
            arbiter.partition_scan(&entries),
            "heap fill diverged from the reference scan (case {case})"
        );
    }
}

/// Drive a gate with `seconds` of deterministic arrivals at `rps`, tiers
/// cycling through `pattern`; returns per-second (admitted, shed) counts
/// per tier.  Deterministic spacing makes "offered ≤ supply" exact — the
/// token bucket's own conformance definition — so the properties below
/// are sharp, not probabilistic.
fn drive_gate(
    gate: &mut AdmissionGate,
    rps: f64,
    seconds: usize,
    pattern: &[Tier],
) -> Vec<Vec<(u64, u64)>> {
    let tiers = *pattern.iter().max().unwrap() as usize + 1;
    let mut windows = vec![vec![(0u64, 0u64); tiers]; seconds];
    let n = (rps * seconds as f64).round() as usize;
    for i in 0..n {
        let t = (i + 1) as f64 / rps;
        let sec = (t.ceil() as usize - 1).min(seconds - 1);
        let tier = pattern[i % pattern.len()];
        if gate.admit(t, tier) {
            windows[sec][tier as usize].0 += 1;
        } else {
            windows[sec][tier as usize].1 += 1;
        }
    }
    windows
}

fn admission_cfg() -> AdmissionConfig {
    AdmissionConfig {
        enabled: true,
        burst_s: 1.0,
        slack: 1.0,
        ctl_window_s: 1.0,
    }
}

#[test]
fn prop_admission_never_sheds_under_capacity() {
    // (a) offered ≤ granted capacity -> zero sheds, for any supply,
    // utilization, and tier mix.
    for case in 0..100u64 {
        let mut rng = Rng::seed_from_u64(40_000 + case);
        let supply = 10.0 + rng.f64() * 190.0;
        let util = 0.3 + rng.f64() * 0.65; // ≤ 0.95: strictly conformant
        let pattern: Vec<Tier> = (0..1 + rng.below(4))
            .map(|_| rng.below(3) as u8)
            .collect();
        let mut gate = AdmissionGate::new(&admission_cfg(), 0, 2);
        gate.set_supply(0.0, supply);
        let windows = drive_gate(&mut gate, supply * util, 20, &pattern);
        let shed: u64 = windows.iter().flatten().map(|&(_, s)| s).sum();
        assert_eq!(
            shed, 0,
            "under-capacity shed (case {case}: supply {supply:.1}, util {util:.2})"
        );
    }
}

#[test]
fn prop_admission_shed_fraction_monotone_in_overload() {
    // (b) at a fixed supply, the shed fraction is monotone nondecreasing
    // in the offered load.
    for case in 0..50u64 {
        let mut rng = Rng::seed_from_u64(41_000 + case);
        let supply = 20.0 + rng.f64() * 180.0;
        let mut last = 0.0f64;
        for factor in [0.8, 1.0, 1.3, 1.8, 2.5, 4.0] {
            let mut gate = AdmissionGate::new(&admission_cfg(), 0, 0);
            gate.set_supply(0.0, supply);
            let windows = drive_gate(&mut gate, supply * factor, 30, &[0]);
            let (adm, shed) = windows
                .iter()
                .flatten()
                .fold((0u64, 0u64), |(a, s), &(x, y)| (a + x, s + y));
            let frac = shed as f64 / (adm + shed).max(1) as f64;
            assert!(
                frac >= last - 1e-9,
                "shed fraction not monotone (case {case}: {frac} < {last} at x{factor})"
            );
            last = frac;
        }
        assert!(last > 0.5, "4x overload must shed most (case {case}: {last})");
    }
}

#[test]
fn prop_admission_tiers_shed_lowest_first() {
    // (c) under sustained overload with tiers enabled, once the gate's
    // cutoff has adapted (≤ one control window per tier), a lower tier is
    // never served in an interval where a higher tier is being shed.
    for case in 0..50u64 {
        let mut rng = Rng::seed_from_u64(42_000 + case);
        let supply = 40.0 + rng.f64() * 160.0;
        // 2.4x–4x: the high tier alone stays strictly over supply, so the
        // gate is pressured in *every* control window and the cutoff
        // cannot flap (at ~2x the high tier exactly fits the supply and a
        // recovered cutoff could briefly readmit tier 1 mid-window)
        let factor = 2.4 + rng.f64() * 1.6;
        let mut gate = AdmissionGate::new(&admission_cfg(), 0, 1);
        gate.set_supply(0.0, supply);
        let windows = drive_gate(&mut gate, supply * factor, 30, &[0, 1]);
        // skip the adaptation transient: 2 control windows
        for (sec, w) in windows.iter().enumerate().skip(2) {
            let (t0_adm, t0_shed) = w[0];
            let (t1_adm, _) = w[1];
            if t0_shed > 0 {
                assert_eq!(
                    t1_adm, 0,
                    "tier 1 served while tier 0 shed (case {case}, second {sec}: \
                     t0 {t0_adm}+{t0_shed} shed, t1 admitted {t1_adm})"
                );
            }
        }
        // and the high tier keeps serving throughout
        let t0_total: u64 = windows.iter().skip(2).map(|w| w[0].0).sum();
        assert!(t0_total > 0, "tier 0 starved (case {case})");
    }
}

#[test]
fn prop_sim_conserves_requests() {
    // completed + dropped == arrivals, for any policy/seed/trace
    let profiles = ProfileSet::paper_like();
    let mut rng = Rng::seed_from_u64(106);
    for _ in 0..6 {
        let seed = rng.next_u64() % 1000;
        let base = 10.0 + rng.f64() * 60.0;
        let trace = Trace::bursty(base, base * 2.0, 240, seed);
        let expected = ArrivalProcess::poisson(&trace, seed.wrapping_add(1)).len() as u64;
        let sim = SimEngine::new(
            profiles.clone(),
            SimConfig {
                seed,
                ..Default::default()
            },
        );
        let mut policy = StaticPolicy::new("resnet50", 4);
        let res = sim.run(&mut policy, &trace);
        let s = res.metrics.summary("t", 240.0);
        assert_eq!(
            s.total_requests, expected,
            "request conservation violated (seed {seed})"
        );
    }
}

#[test]
fn prop_shed_conservation() {
    // For every fleet run — admission on/off, shed pricing on/off,
    // overload or staggered bursts, mixed-tier traffic, fault storms —
    // arrivals are conserved end to end: `arrivals == served + violated
    // + shed` for the whole run, per tier, and per metrics interval
    // (cross-checked against the raw regenerated arrival stream, not
    // the collector's own totals), and the Run/Fleet summaries equal
    // the sum of their interval rows.  `failed` (pod died under an
    // admitted request, fault plane) lives inside `violations` and the
    // interval bucket, disjoint from `dropped`.  Catches any double- or
    // under-count a pricing, admission, or fault change introduces
    // between the gate, the router, and the metrics pipeline.
    for case in 0..6u64 {
        let seed = 7_000 + case * 13;
        let mut config = Config::default();
        config.adapter.forecaster = "last_max".into();
        config.seed = seed;
        // matrix: {admission off (case 1) / on}, {shed pricing off/on},
        // {staggered (even cases) / simultaneous overload (odd cases)} —
        // case 3 is the full stack: overload + admission + pricing;
        // cases 4–5 re-run the stack under an armed fault storm
        // (reactions off, then on with retries), where crashes strand
        // admitted requests and conservation must still close the books
        config.admission.enabled = case != 1;
        config.fleet.shed_penalty = if case < 2 { 0.0 } else { 1.0 };
        if case >= 4 {
            config
                .fault
                .apply_spec("crash:0.004:30:180,slowstart:2,straggler:0.002:20:4,stall:0.05")
                .unwrap();
            config.fault.reactions = case == 5;
            config.fault.max_retries = 2;
        }
        let profiles = ProfileSet::paper_like();
        let mut scenario = if case % 2 == 1 {
            FleetScenario::synthetic_overload(2, 30.0, 240, 8, true, &config, &profiles)
        } else {
            FleetScenario::synthetic(2, 30.0, 240, 10, &config, &profiles)
        };
        // mixed-tier traffic on service 0 exercises the per-tier books
        scenario.services[0].trace = scenario.services[0]
            .trace
            .clone()
            .with_class_mix(vec![(0, 3.0), (1, 1.0)]);
        let out = scenario.run(&FleetMode::Arbiter, std::path::Path::new("/nonexistent"));

        let (mut sum_total, mut sum_shed, mut sum_dropped, mut sum_failed) =
            (0u64, 0u64, 0u64, 0u64);
        for (i, r) in out.per_service.iter().enumerate() {
            let s = &out.summary.services[i];
            // ground truth: regenerate this service's exact arrival stream
            let arrivals = ArrivalProcess::poisson(
                &scenario.services[i].trace,
                service_seed(seed, i).wrapping_add(1),
            );
            assert_eq!(
                s.total_requests,
                arrivals.len() as u64,
                "case {case} (seed {seed}) svc {i}: arrival conservation"
            );
            // whole-run conservation, from the per-tier books
            let served: u64 = s.tiers.iter().map(|t| t.served).sum();
            let violated: u64 = s.tiers.iter().map(|t| t.violations).sum();
            let shed: u64 = s.tiers.iter().map(|t| t.shed).sum();
            assert_eq!(
                served + violated + shed,
                s.total_requests,
                "case {case} svc {i}: served {served} + violated {violated} + shed {shed}"
            );
            assert_eq!(shed, s.shed, "case {case} svc {i}: tier shed books");
            let tier_failed: u64 = s.tiers.iter().map(|t| t.failed).sum();
            assert_eq!(tier_failed, s.failed, "case {case} svc {i}: tier failed books");
            for t in &s.tiers {
                assert_eq!(
                    t.served + t.violations + t.shed,
                    t.total,
                    "case {case} svc {i} tier {}: per-tier conservation",
                    t.tier
                );
                assert!(
                    t.dropped + t.failed <= t.violations,
                    "case {case} svc {i}: drops and failures are violations"
                );
            }
            // per-interval conservation vs the raw arrival stream, using
            // the collector's own bucketing formula so FP boundaries agree
            let rows = r.metrics.rows(r.duration_s);
            let bucket = r.metrics.bucket_s;
            for (b, row) in rows.iter().enumerate() {
                let expect = arrivals
                    .iter()
                    .filter(|&&t| (t / bucket) as usize == b)
                    .count() as u64;
                assert_eq!(
                    row.completed + row.dropped + row.failed + row.shed,
                    expect,
                    "case {case} svc {i} bucket {b}: interval conservation"
                );
                let by_tier: u64 = row.shed_by_tier.iter().map(|&(_, c)| c).sum();
                assert_eq!(by_tier, row.shed, "case {case} svc {i} bucket {b}: shed tiers");
            }
            // the summary equals the sum of its interval rows
            assert_eq!(rows.iter().map(|r| r.shed).sum::<u64>(), s.shed);
            assert_eq!(rows.iter().map(|r| r.dropped).sum::<u64>(), s.dropped);
            assert_eq!(rows.iter().map(|r| r.failed).sum::<u64>(), s.failed);
            assert_eq!(
                rows.iter()
                    .map(|r| r.completed + r.dropped + r.failed + r.shed)
                    .sum::<u64>(),
                s.total_requests,
                "case {case} svc {i}: rows must sum to the summary"
            );
            sum_total += s.total_requests;
            sum_shed += s.shed;
            sum_dropped += s.dropped;
            sum_failed += s.failed;
        }
        // the fleet aggregate equals the sum of its services
        assert_eq!(out.summary.total_requests, sum_total, "case {case}");
        assert_eq!(out.summary.shed, sum_shed, "case {case}");
        assert_eq!(out.summary.dropped, sum_dropped, "case {case}");
        assert_eq!(out.summary.failed, sum_failed, "case {case}");
        let tier_total: u64 = out.summary.tiers.iter().map(|t| t.total).sum();
        assert_eq!(tier_total, sum_total, "case {case}: merged tier books");
        // and the priced overload cases must actually exercise shedding
        if case == 3 {
            assert!(sum_shed > 0, "case {case}: the overload cell must shed");
        }
        // without an armed fault plane nothing may ever fail
        if case < 4 {
            assert_eq!(sum_failed, 0, "case {case}: failures need a fault plane");
        }
    }
}

#[test]
fn prop_fault_storm_replays_bit_identically() {
    // An armed fault storm is as deterministic as a fault-free run: the
    // same (engine seed → strided fault streams) replay produces the
    // exact same crashes, retries, fallbacks, and interval rows every
    // time.  The seed comes from `FAULT_SEED` when set (the CI chaos
    // matrix sweeps it) so each matrix cell pins a different storm.
    let seed: u64 = std::env::var("FAULT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(11);
    let mut config = Config::default();
    config.adapter.forecaster = "last_max".into();
    config.seed = 9_000 + seed;
    config.admission.enabled = true;
    config.telemetry.enabled = true;
    config
        .fault
        .apply_spec(
            "crash:0.004:40:160,slowstart:2,straggler:0.002:20:4,stall:0.05,\
             reactions:on,retries:2,backoff:0.2",
        )
        .unwrap();
    let profiles = ProfileSet::paper_like();
    let run = || {
        let scenario = FleetScenario::synthetic_overload(2, 30.0, 240, 8, true, &config, &profiles);
        scenario.run(&FleetMode::Arbiter, std::path::Path::new("/nonexistent"))
    };
    let a = run();
    let b = run();
    assert_eq!(a.summary.total_requests, b.summary.total_requests);
    assert_eq!(a.summary.shed, b.summary.shed);
    assert_eq!(a.summary.dropped, b.summary.dropped);
    assert_eq!(a.summary.failed, b.summary.failed);
    assert_eq!(a.per_service.len(), b.per_service.len());
    for (i, (ra, rb)) in a.per_service.iter().zip(b.per_service.iter()).enumerate() {
        // the CSV rows carry every interval counter — string equality is
        // a bit-identity check on the whole run
        assert_eq!(
            rows_to_csv(&ra.metrics.rows(ra.duration_s)),
            rows_to_csv(&rb.metrics.rows(rb.duration_s)),
            "svc {i}: fault storm did not replay identically (seed {seed})"
        );
    }
}

#[test]
fn prop_sim_accuracy_bounded_by_family() {
    let profiles = ProfileSet::paper_like();
    let mut rng = Rng::seed_from_u64(107);
    for kind in [
        PolicyKind::InfAdapter,
        PolicyKind::MsPlus,
        PolicyKind::Vpa("resnet50".into()),
    ] {
        let seed = rng.next_u64() % 1000;
        let mut config = Config::default();
        config.seed = seed;
        config.adapter.forecaster = "last_max".into();
        let s = Scenario::new(
            "prop",
            Trace::non_bursty(20.0, 50.0, 300, seed),
            config,
            profiles.clone(),
        );
        let out = s.run(&kind, std::path::Path::new("/nonexistent")).unwrap();
        assert!(out.summary.avg_accuracy >= 69.76 - 1e-6, "{kind:?}");
        assert!(out.summary.avg_accuracy <= 78.31 + 1e-6, "{kind:?}");
        assert!(out.summary.avg_accuracy_loss >= -1e-6);
    }
}

#[test]
fn prop_p2_quantile_matches_exact_sorted_sample() {
    // Cross-check the P² streaming estimator against the exact sorted
    // sample: (a) with at most 5 observations the estimate IS the exact
    // rank statistic (the marker array still holds the raw sample — this
    // pins the count == 5 boundary, which used to answer the median for
    // every q); (b) the estimate always stays inside the observed range;
    // (c) on a large smooth stream it lands near the exact quantile.
    use infadapter::monitoring::P2Quantile;

    fn exact(sorted: &[f64], q: f64) -> f64 {
        let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
        sorted[rank - 1]
    }

    let mut rng = Rng::seed_from_u64(109);
    for _ in 0..200 {
        let q = rng.f64();
        let n = 1 + rng.below(5); // 1..=5 observations: exact regime
        let mut est = P2Quantile::new(q);
        let mut sample = Vec::new();
        for _ in 0..n {
            let x = rng.f64() * 100.0;
            est.record(x);
            sample.push(x);
        }
        sample.sort_by(f64::total_cmp);
        assert_eq!(
            est.value(),
            Some(exact(&sample, q)),
            "n={n} q={q}: small-count estimate must be the exact rank statistic"
        );
    }

    for case in 0..20 {
        let q = [0.5, 0.9, 0.95, 0.99][case % 4];
        // Half the cases stress the converged regime (large n, accuracy
        // bound); half stress short streams (range bound only — P² makes
        // no accuracy promise right after the markers detach).
        let n = if case % 2 == 0 {
            20_000
        } else {
            6 + rng.below(3000)
        };
        let mut est = P2Quantile::new(q);
        let mut sample = Vec::new();
        for _ in 0..n {
            let x = rng.exp1();
            est.record(x);
            sample.push(x);
        }
        sample.sort_by(f64::total_cmp);
        let v = est.value().unwrap();
        assert!(
            (sample[0]..=sample[n - 1]).contains(&v),
            "n={n} q={q}: estimate {v} escaped the observed range"
        );
        if n >= 10_000 {
            let truth = exact(&sample, q);
            assert!(
                (v - truth).abs() / truth.max(1e-9) < 0.15,
                "n={n} q={q}: approx {v} vs exact {truth}"
            );
        }
    }
}
