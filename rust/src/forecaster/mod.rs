//! Workload forecasting (paper §5 "Load forecaster").
//!
//! The paper predicts the next-minute **max** request rate from the past 10
//! minutes with a 25-unit LSTM.  [`LstmForecaster`] runs the AOT-compiled
//! JAX LSTM through PJRT (trained at build time by `python/compile/aot.py`);
//! the classical forecasters are both fallbacks (no artifacts needed) and
//! ablation baselines.
//!
//! All forecasters share [`Forecaster`]: push observed per-second rates,
//! ask for the predicted max rate over the next horizon.

use crate::runtime::RuntimeHandle;
use anyhow::Result;
use std::collections::VecDeque;
use std::path::Path;

/// Common interface: observe per-second rates, predict next-horizon max.
pub trait Forecaster: Send {
    fn name(&self) -> &'static str;
    /// Record one observed per-second rate (oldest-first call order).
    fn observe(&mut self, rate: f64);
    /// Predicted max rate over the next horizon (requests/second).
    fn predict_max(&mut self) -> f64;
}

fn push_window(buf: &mut VecDeque<f64>, cap: usize, rate: f64) {
    if buf.len() == cap {
        buf.pop_front();
    }
    buf.push_back(rate.max(0.0));
}

// ---------------------------------------------------------------------------
// LSTM (AOT, PJRT)
// ---------------------------------------------------------------------------

/// The paper's LSTM forecaster, executed from the AOT HLO artifact.
pub struct LstmForecaster {
    runtime: RuntimeHandle,
    window: usize,
    rps_scale: f64,
    history: VecDeque<f64>,
}

impl LstmForecaster {
    /// Load `forecaster.hlo.txt` from the artifacts dir.
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let manifest = crate::runtime::Manifest::load(artifacts_dir)?;
        let meta = manifest
            .forecaster
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("manifest has no forecaster entry"))?;
        let runtime = RuntimeHandle::spawn_forecaster(artifacts_dir, meta.window)?;
        Ok(Self {
            runtime,
            window: meta.window,
            rps_scale: meta.rps_scale,
            history: VecDeque::with_capacity(meta.window),
        })
    }
}

impl Forecaster for LstmForecaster {
    fn name(&self) -> &'static str {
        "lstm"
    }

    fn observe(&mut self, rate: f64) {
        push_window(&mut self.history, self.window, rate);
    }

    fn predict_max(&mut self) -> f64 {
        // Left-pad with the oldest observed value until the window fills.
        let pad = self.history.front().copied().unwrap_or(0.0);
        let mut win = vec![(pad / self.rps_scale) as f32; self.window];
        let start = self.window - self.history.len();
        for (i, r) in self.history.iter().enumerate() {
            win[start + i] = (*r / self.rps_scale) as f32;
        }
        // De-normalize; never forecast below the recently observed peak.
        // The LSTM's value is *anticipating* load (ramps, recurring bursts);
        // a safe serving system must still cover what it has just seen —
        // under-prediction digs a queue backlog that never drains.
        let recent_peak = self
            .history
            .iter()
            .rev()
            .take(60)
            .cloned()
            .fold(0.0, f64::max);
        match self.runtime.predict(win) {
            Ok(pred) => (pred as f64 * self.rps_scale).max(0.0).max(recent_peak),
            Err(_) => recent_peak,
        }
    }
}

// ---------------------------------------------------------------------------
// Classical baselines
// ---------------------------------------------------------------------------

/// Max of the last `window` seconds, times a safety factor.
pub struct LastMaxForecaster {
    window: usize,
    safety: f64,
    history: VecDeque<f64>,
}

impl LastMaxForecaster {
    pub fn new(window: usize, safety: f64) -> Self {
        Self {
            window,
            safety,
            history: VecDeque::with_capacity(window),
        }
    }
}

impl Forecaster for LastMaxForecaster {
    fn name(&self) -> &'static str {
        "last_max"
    }

    fn observe(&mut self, rate: f64) {
        push_window(&mut self.history, self.window, rate);
    }

    fn predict_max(&mut self) -> f64 {
        self.history.iter().cloned().fold(0.0, f64::max) * self.safety
    }
}

/// Exponentially-weighted moving average plus k·stddev headroom.
pub struct MovingAverageForecaster {
    alpha: f64,
    k_sigma: f64,
    mean: f64,
    var: f64,
    initialized: bool,
}

impl MovingAverageForecaster {
    pub fn new(alpha: f64, k_sigma: f64) -> Self {
        Self {
            alpha,
            k_sigma,
            mean: 0.0,
            var: 0.0,
            initialized: false,
        }
    }
}

impl Forecaster for MovingAverageForecaster {
    fn name(&self) -> &'static str {
        "moving_average"
    }

    fn observe(&mut self, rate: f64) {
        if !self.initialized {
            self.mean = rate;
            self.var = 0.0;
            self.initialized = true;
            return;
        }
        let d = rate - self.mean;
        self.mean += self.alpha * d;
        self.var = (1.0 - self.alpha) * (self.var + self.alpha * d * d);
    }

    fn predict_max(&mut self) -> f64 {
        (self.mean + self.k_sigma * self.var.sqrt()).max(0.0)
    }
}

/// Holt's linear trend (double exponential smoothing), projected one
/// horizon ahead — catches ramps that last-max misses.
pub struct HoltForecaster {
    alpha: f64,
    beta: f64,
    horizon_s: f64,
    level: f64,
    trend: f64,
    initialized: bool,
    recent_max: VecDeque<f64>,
}

impl HoltForecaster {
    pub fn new(alpha: f64, beta: f64, horizon_s: f64) -> Self {
        Self {
            alpha,
            beta,
            horizon_s,
            level: 0.0,
            trend: 0.0,
            initialized: false,
            recent_max: VecDeque::with_capacity(60),
        }
    }
}

impl Forecaster for HoltForecaster {
    fn name(&self) -> &'static str {
        "holt"
    }

    fn observe(&mut self, rate: f64) {
        push_window(&mut self.recent_max, 60, rate);
        if !self.initialized {
            self.level = rate;
            self.trend = 0.0;
            self.initialized = true;
            return;
        }
        let prev_level = self.level;
        self.level = self.alpha * rate + (1.0 - self.alpha) * (self.level + self.trend);
        self.trend = self.beta * (self.level - prev_level) + (1.0 - self.beta) * self.trend;
    }

    fn predict_max(&mut self) -> f64 {
        let projected = self.level + self.trend.max(0.0) * self.horizon_s;
        let recent = self.recent_max.iter().cloned().fold(0.0, f64::max);
        projected.max(recent).max(0.0)
    }
}

/// Build a forecaster by config name; LSTM falls back to last-max when the
/// artifact is unavailable (returns the fallback's name via `name()`).
pub fn build(kind: &str, artifacts_dir: &Path, horizon_s: f64) -> Box<dyn Forecaster> {
    match kind {
        "lstm" => match LstmForecaster::load(artifacts_dir) {
            Ok(f) => Box::new(f),
            Err(e) => {
                eprintln!("[forecaster] LSTM unavailable ({e:#}); using last_max");
                Box::new(LastMaxForecaster::new(120, 1.1))
            }
        },
        "moving_average" => Box::new(MovingAverageForecaster::new(0.1, 3.0)),
        "holt" => Box::new(HoltForecaster::new(0.3, 0.1, horizon_s)),
        _ => Box::new(LastMaxForecaster::new(120, 1.1)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn last_max_tracks_peak_with_safety() {
        let mut f = LastMaxForecaster::new(10, 1.2);
        for r in [10.0, 50.0, 20.0] {
            f.observe(r);
        }
        assert!((f.predict_max() - 60.0).abs() < 1e-9);
    }

    #[test]
    fn last_max_window_forgets() {
        let mut f = LastMaxForecaster::new(3, 1.0);
        f.observe(100.0);
        for _ in 0..3 {
            f.observe(10.0);
        }
        assert!((f.predict_max() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn moving_average_converges_to_steady_rate() {
        let mut f = MovingAverageForecaster::new(0.2, 0.0);
        for _ in 0..200 {
            f.observe(40.0);
        }
        assert!((f.predict_max() - 40.0).abs() < 0.5);
    }

    #[test]
    fn holt_projects_a_ramp_above_current() {
        let mut f = HoltForecaster::new(0.5, 0.3, 30.0);
        for t in 0..60 {
            f.observe(10.0 + 2.0 * t as f64); // ramp 2 rps/s
        }
        let pred = f.predict_max();
        assert!(pred > 128.0, "pred {pred} should extrapolate past last obs");
    }

    #[test]
    fn build_falls_back_without_artifacts() {
        let dir = crate::util::testutil::TempDir::new();
        let mut f = build("lstm", dir.path(), 30.0);
        f.observe(25.0);
        assert!(f.predict_max() > 0.0);
    }
}
