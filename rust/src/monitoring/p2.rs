//! P² (Jain & Chlamtac 1985) streaming quantile estimator.
//!
//! O(1) memory and O(1) per-sample update: five markers track the target
//! quantile without storing the sample stream.  This is the serving hot
//! path's P99; its accuracy is pinned against the exact reservoir in tests.

/// Streaming estimator for a single quantile `q`.
#[derive(Debug, Clone)]
pub struct P2Quantile {
    q: f64,
    /// Marker heights (estimates of the quantile ladder).
    heights: [f64; 5],
    /// Marker positions (1-based ranks).
    pos: [f64; 5],
    /// Desired marker positions.
    desired: [f64; 5],
    /// Desired position increments per observation.
    incr: [f64; 5],
    count: usize,
}

impl P2Quantile {
    pub fn new(q: f64) -> Self {
        assert!((0.0..=1.0).contains(&q), "quantile must be in [0,1]");
        Self {
            q,
            heights: [0.0; 5],
            pos: [1.0, 2.0, 3.0, 4.0, 5.0],
            desired: [1.0, 1.0 + 2.0 * q, 1.0 + 4.0 * q, 3.0 + 2.0 * q, 5.0],
            incr: [0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0],
            count: 0,
        }
    }

    pub fn count(&self) -> usize {
        self.count
    }

    pub fn record(&mut self, x: f64) {
        if self.count < 5 {
            self.heights[self.count] = x;
            self.count += 1;
            if self.count == 5 {
                self.heights.sort_by(f64::total_cmp);
            }
            return;
        }
        self.count += 1;

        // Find the cell containing x; the extremes update the end markers.
        let k = if x < self.heights[0] {
            self.heights[0] = x;
            0
        } else if x >= self.heights[4] {
            self.heights[4] = x;
            3
        } else {
            let mut k = 0;
            for i in 0..4 {
                if x >= self.heights[i] && x < self.heights[i + 1] {
                    k = i;
                    break;
                }
            }
            k
        };

        for p in self.pos.iter_mut().skip(k + 1) {
            *p += 1.0;
        }
        for (d, inc) in self.desired.iter_mut().zip(self.incr.iter()) {
            *d += inc;
        }

        // Adjust interior markers via piecewise-parabolic interpolation.
        for i in 1..4 {
            let d = self.desired[i] - self.pos[i];
            let right_gap = self.pos[i + 1] - self.pos[i];
            let left_gap = self.pos[i - 1] - self.pos[i];
            if (d >= 1.0 && right_gap > 1.0) || (d <= -1.0 && left_gap < -1.0) {
                let sign = d.signum();
                let parabolic = self.parabolic(i, sign);
                let new_h = if self.heights[i - 1] < parabolic && parabolic < self.heights[i + 1] {
                    parabolic
                } else {
                    self.linear(i, sign)
                };
                self.heights[i] = new_h;
                self.pos[i] += sign;
            }
        }
    }

    fn parabolic(&self, i: usize, sign: f64) -> f64 {
        let (hm, h, hp) = (self.heights[i - 1], self.heights[i], self.heights[i + 1]);
        let (pm, p, pp) = (self.pos[i - 1], self.pos[i], self.pos[i + 1]);
        h + sign / (pp - pm)
            * ((p - pm + sign) * (hp - h) / (pp - p) + (pp - p - sign) * (h - hm) / (p - pm))
    }

    fn linear(&self, i: usize, sign: f64) -> f64 {
        let j = if sign > 0.0 { i + 1 } else { i - 1 };
        self.heights[i]
            + sign * (self.heights[j] - self.heights[i]) / (self.pos[j] - self.pos[i])
    }

    /// Current estimate; exact while at most 5 samples have arrived.
    ///
    /// The `<= 5` boundary matters: at exactly 5 observations the marker
    /// heights are still the raw sorted sample, and returning the middle
    /// marker (as the steady-state path does) would answer the median for
    /// *any* requested quantile — a p99 over 5 samples must be the max.
    pub fn value(&self) -> Option<f64> {
        if self.count == 0 {
            return None;
        }
        if self.count <= 5 {
            let mut v = self.heights[..self.count].to_vec();
            v.sort_by(f64::total_cmp);
            let rank = ((self.q * v.len() as f64).ceil() as usize).clamp(1, v.len());
            return Some(v[rank - 1]);
        }
        Some(self.heights[2])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn tracks_uniform_p99_within_tolerance() {
        let mut est = P2Quantile::new(0.99);
        let mut rng = Rng::seed_from_u64(1);
        for _ in 0..50_000 {
            est.record(rng.f64());
        }
        let v = est.value().unwrap();
        assert!((v - 0.99).abs() < 0.01, "estimate {v}");
    }

    #[test]
    fn tracks_exponential_p50() {
        let mut est = P2Quantile::new(0.5);
        let mut rng = Rng::seed_from_u64(2);
        for _ in 0..50_000 {
            est.record(rng.exp1()); // Exp(1), median ln 2
        }
        let v = est.value().unwrap();
        assert!((v - std::f64::consts::LN_2).abs() < 0.05, "estimate {v}");
    }

    #[test]
    fn agrees_with_exact_reservoir_on_latency_like_data() {
        use crate::monitoring::LatencyReservoir;
        let mut est = P2Quantile::new(0.99);
        let mut exact = LatencyReservoir::new(100_000);
        let mut rng = Rng::seed_from_u64(3);
        for _ in 0..30_000 {
            // lognormal-ish service latencies around 100ms
            let lat = 100.0 * (0.3 * rng.normal()).exp();
            est.record(lat);
            exact.record(lat);
        }
        let approx = est.value().unwrap();
        let truth = exact.quantile(0.99).unwrap();
        assert!(
            (approx - truth).abs() / truth < 0.08,
            "approx {approx} vs exact {truth}"
        );
    }

    #[test]
    fn small_counts_are_exact() {
        let mut est = P2Quantile::new(0.99);
        assert_eq!(est.value(), None);
        est.record(5.0);
        assert_eq!(est.value(), Some(5.0));
        est.record(1.0);
        est.record(9.0);
        assert_eq!(est.value(), Some(9.0));
    }

    #[test]
    fn fifth_observation_is_still_exact() {
        // Regression: at exactly 5 samples the estimator used to return the
        // median marker for every q.  A p99 over {1..5} must be 5, a p10
        // must be 1.
        let mut hi = P2Quantile::new(0.99);
        let mut lo = P2Quantile::new(0.10);
        for x in [3.0, 1.0, 5.0, 2.0, 4.0] {
            hi.record(x);
            lo.record(x);
        }
        assert_eq!(hi.value(), Some(5.0));
        assert_eq!(lo.value(), Some(1.0));
    }
}
