//! Exact windowed latency reservoir (experiment-grade percentiles).

/// Stores every latency sample in a bounded FIFO window and computes exact
/// percentiles over it.  Used for experiment reporting; the serving hot
/// path uses [`super::P2Quantile`] instead.
#[derive(Debug, Clone)]
pub struct LatencyReservoir {
    samples: std::collections::VecDeque<f64>,
    capacity: usize,
    total_count: u64,
}

impl LatencyReservoir {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self {
            samples: std::collections::VecDeque::with_capacity(capacity),
            capacity,
            total_count: 0,
        }
    }

    pub fn record(&mut self, latency: f64) {
        if self.samples.len() == self.capacity {
            self.samples.pop_front();
        }
        self.samples.push_back(latency);
        self.total_count += 1;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn total_count(&self) -> u64 {
        self.total_count
    }

    /// Exact percentile (nearest-rank on the sorted window), `q` in (0,1].
    pub fn quantile(&self, q: f64) -> Option<f64> {
        if self.samples.is_empty() {
            return None;
        }
        let mut v: Vec<f64> = self.samples.iter().cloned().collect();
        v.sort_by(f64::total_cmp);
        let rank = ((q * v.len() as f64).ceil() as usize).clamp(1, v.len());
        Some(v[rank - 1])
    }

    pub fn mean(&self) -> Option<f64> {
        if self.samples.is_empty() {
            None
        } else {
            Some(self.samples.iter().sum::<f64>() / self.samples.len() as f64)
        }
    }

    /// Fraction of windowed samples above `threshold`.
    pub fn violation_rate(&self, threshold: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().filter(|&&l| l > threshold).count() as f64
            / self.samples.len() as f64
    }

    pub fn clear(&mut self) {
        self.samples.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_percentiles() {
        let mut r = LatencyReservoir::new(1000);
        for i in 1..=100 {
            r.record(i as f64);
        }
        assert_eq!(r.quantile(0.5), Some(50.0));
        assert_eq!(r.quantile(0.99), Some(99.0));
        assert_eq!(r.quantile(1.0), Some(100.0));
        assert_eq!(r.mean(), Some(50.5));
    }

    #[test]
    fn window_evicts_oldest() {
        let mut r = LatencyReservoir::new(10);
        for i in 0..100 {
            r.record(i as f64);
        }
        assert_eq!(r.len(), 10);
        assert_eq!(r.quantile(1.0), Some(99.0));
        assert_eq!(r.quantile(0.1), Some(90.0));
        assert_eq!(r.total_count(), 100);
    }

    #[test]
    fn violation_rate_counts_exceedances() {
        let mut r = LatencyReservoir::new(100);
        for i in 1..=10 {
            r.record(i as f64 * 100.0); // 100..1000
        }
        assert!((r.violation_rate(750.0) - 0.3).abs() < 1e-12);
        assert_eq!(r.violation_rate(2000.0), 0.0);
    }

    #[test]
    fn empty_is_none() {
        let r = LatencyReservoir::new(10);
        assert_eq!(r.quantile(0.99), None);
        assert_eq!(r.mean(), None);
        assert_eq!(r.violation_rate(1.0), 0.0);
    }
}
