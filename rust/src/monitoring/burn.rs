//! SLO-burn-rate tracking: rolling violation rate against an error budget.
//!
//! SRE-style burn accounting for the fleet arbiter: every service declares
//! an *error budget* — the SLO-violation fraction it is allowed to run at
//! (e.g. 1%).  [`SloBurnMeter`] keeps a rolling window of per-interval
//! (violations, admitted) counts and reports the **burn rate**: the
//! windowed violation rate divided by the budget.  ≤ 1 means the service
//! is inside its budget; > 1 means it is actively burning — the arbiter
//! boosts the marginal utility of burning services so the water-fill
//! moves cores toward the fire (see [`crate::fleet::arbiter`]).

use std::collections::VecDeque;

/// Rolling (violations, admitted) window with burn-rate readout.
#[derive(Debug, Clone)]
pub struct SloBurnMeter {
    error_budget: f64,
    window: VecDeque<(u64, u64)>,
    cap: usize,
    sum_violations: u64,
    sum_admitted: u64,
}

impl SloBurnMeter {
    /// `error_budget` is the allowed violation fraction (clamped away from
    /// zero so the burn ratio stays finite); `window_intervals` is how
    /// many adaptation intervals the rolling rate covers.
    pub fn new(error_budget: f64, window_intervals: usize) -> Self {
        Self {
            error_budget: error_budget.max(1e-6),
            window: VecDeque::with_capacity(window_intervals.max(1)),
            cap: window_intervals.max(1),
            sum_violations: 0,
            sum_admitted: 0,
        }
    }

    /// Record one adaptation interval's (violations, admitted) counts.
    /// Shed requests are *not* violations and must not be counted here —
    /// shedding is the system keeping its promise to admitted traffic.
    pub fn observe(&mut self, violations: u64, admitted: u64) {
        if self.window.len() == self.cap {
            if let Some((v, a)) = self.window.pop_front() {
                self.sum_violations -= v;
                self.sum_admitted -= a;
            }
        }
        self.window.push_back((violations, admitted));
        self.sum_violations += violations;
        self.sum_admitted += admitted;
    }

    /// Windowed violation fraction (0 with no admitted traffic).
    pub fn violation_rate(&self) -> f64 {
        if self.sum_admitted == 0 {
            0.0
        } else {
            self.sum_violations as f64 / self.sum_admitted as f64
        }
    }

    /// Windowed violation rate over the error budget: ≤ 1 inside budget,
    /// > 1 burning, 0 before any traffic.
    pub fn burn_rate(&self) -> f64 {
        self.violation_rate() / self.error_budget
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_meter_reads_zero() {
        let m = SloBurnMeter::new(0.01, 4);
        assert_eq!(m.violation_rate(), 0.0);
        assert_eq!(m.burn_rate(), 0.0);
    }

    #[test]
    fn burn_rate_is_rate_over_budget() {
        let mut m = SloBurnMeter::new(0.01, 4);
        m.observe(2, 100); // 2% violations on a 1% budget
        assert!((m.violation_rate() - 0.02).abs() < 1e-12);
        assert!((m.burn_rate() - 2.0).abs() < 1e-9);
        m.observe(0, 100); // rolling: 2/200 = 1% -> burn 1.0
        assert!((m.burn_rate() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn window_evicts_old_intervals() {
        let mut m = SloBurnMeter::new(0.10, 2);
        m.observe(50, 100);
        m.observe(0, 100);
        m.observe(0, 100); // the 50-violation interval ages out
        assert_eq!(m.violation_rate(), 0.0);
        assert_eq!(m.burn_rate(), 0.0);
    }

    #[test]
    fn zero_budget_is_clamped_not_infinite() {
        let mut m = SloBurnMeter::new(0.0, 4);
        m.observe(1, 100);
        assert!(m.burn_rate().is_finite());
        assert!(m.burn_rate() > 1.0);
    }
}
