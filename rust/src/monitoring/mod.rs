//! Monitoring substrate: arrival-rate windows and latency percentiles.
//!
//! The paper's monitoring daemon "keeps statistics about the distribution of
//! request arrivals" and feeds per-second rates to the forecaster.  Here:
//! * [`RateWindow`] — ring buffer of per-second arrival counts,
//! * [`LatencyReservoir`] — exact windowed latencies (experiment reporting),
//! * [`P2Quantile`] — O(1)-per-sample streaming percentile estimator (the
//!   hot-path P99 used by the live dashboards; pinned against the exact
//!   reservoir in tests),
//! * [`SloBurnMeter`] — rolling SLO-violation rate against an error
//!   budget (the arbiter's burn-rate signal).

mod burn;
mod p2;
mod rate_window;
mod reservoir;

pub use burn::SloBurnMeter;
pub use p2::P2Quantile;
pub use rate_window::RateWindow;
pub use reservoir::LatencyReservoir;
