//! Per-second arrival counting over a sliding window.

/// Ring buffer of per-second request counts.
///
/// `record(t)` increments the bucket for virtual/wall time `t` (seconds);
/// `history()` returns the last `window` complete seconds, oldest first —
/// exactly the input the forecaster consumes.
#[derive(Debug, Clone)]
pub struct RateWindow {
    buckets: Vec<f64>,
    window: usize,
    /// Second index of the newest bucket written.
    head_sec: i64,
    started: bool,
}

impl RateWindow {
    pub fn new(window: usize) -> Self {
        assert!(window > 0, "window must be positive");
        Self {
            buckets: vec![0.0; window],
            window,
            head_sec: 0,
            started: false,
        }
    }

    fn advance_to(&mut self, sec: i64) {
        if !self.started {
            self.head_sec = sec;
            self.started = true;
            return;
        }
        while self.head_sec < sec {
            self.head_sec += 1;
            let idx = (self.head_sec.rem_euclid(self.window as i64)) as usize;
            self.buckets[idx] = 0.0;
        }
    }

    /// Record one arrival at time `t` (seconds). Out-of-order arrivals that
    /// fall inside the window are credited to their own bucket; older ones
    /// are dropped.
    pub fn record(&mut self, t: f64) {
        let sec = t.floor() as i64;
        if self.started && sec < self.head_sec - self.window as i64 + 1 {
            return; // too old for the window
        }
        if !self.started || sec > self.head_sec {
            self.advance_to(sec);
        }
        let idx = (sec.rem_euclid(self.window as i64)) as usize;
        self.buckets[idx] += 1.0;
    }

    /// Advance the clock without recording (quiet seconds must read 0).
    pub fn tick(&mut self, t: f64) {
        self.advance_to(t.floor() as i64);
    }

    /// Last `n` per-second rates ending at the current head, oldest first.
    /// Seconds before the first record read as 0.
    pub fn history(&self, n: usize) -> Vec<f64> {
        let n = n.min(self.window);
        let mut out = Vec::with_capacity(n);
        for back in (0..n).rev() {
            let sec = self.head_sec - back as i64;
            let idx = (sec.rem_euclid(self.window as i64)) as usize;
            if sec > self.head_sec - self.window as i64 {
                out.push(self.buckets[idx]);
            } else {
                out.push(0.0);
            }
        }
        out
    }

    /// Mean rate over the last `n` seconds.
    pub fn rate(&self, n: usize) -> f64 {
        let h = self.history(n);
        if h.is_empty() {
            0.0
        } else {
            h.iter().sum::<f64>() / h.len() as f64
        }
    }

    /// Max per-second rate over the last `n` seconds.
    pub fn peak(&self, n: usize) -> f64 {
        self.history(n).into_iter().fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_per_second() {
        let mut w = RateWindow::new(10);
        for i in 0..5 {
            w.record(0.1 * i as f64); // 5 arrivals in second 0
        }
        w.record(1.5);
        w.record(1.9);
        let h = w.history(2);
        assert_eq!(h, vec![5.0, 2.0]);
    }

    #[test]
    fn quiet_seconds_read_zero() {
        let mut w = RateWindow::new(10);
        w.record(0.5);
        w.tick(4.0);
        assert_eq!(w.history(5), vec![1.0, 0.0, 0.0, 0.0, 0.0]);
        assert!((w.rate(5) - 0.2).abs() < 1e-12);
    }

    #[test]
    fn window_wraps_and_evicts() {
        let mut w = RateWindow::new(3);
        for t in 0..6 {
            w.record(t as f64);
            w.record(t as f64 + 0.5);
        }
        // only the last 3 seconds survive
        assert_eq!(w.history(3), vec![2.0, 2.0, 2.0]);
        assert_eq!(w.history(5).len(), 3);
    }

    #[test]
    fn too_old_records_are_dropped() {
        let mut w = RateWindow::new(3);
        w.tick(10.0);
        w.record(2.0); // far in the past
        assert_eq!(w.history(3), vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn peak_reports_max() {
        let mut w = RateWindow::new(10);
        for _ in 0..7 {
            w.record(1.2);
        }
        w.record(2.1);
        assert_eq!(w.peak(5), 7.0);
    }
}
