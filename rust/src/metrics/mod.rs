//! Experiment metrics: per-interval timeseries and whole-run summaries.
//!
//! These are the quantities the paper's figures plot: accuracy loss (top
//! accuracy minus served weighted-average accuracy), cost (billed CPU
//! cores), and P99 latency, plus SLO-violation rates for the headline
//! claims ("reduces SLO violations up to 65%, cost up to 33%").
//!
//! With the admission-controlled request path, every request resolves to
//! one of four [`RequestOutcome`]s: **served** (completed within the
//! SLO), **violated** (completed late, or dropped inside the serving
//! path), **shed** (refused at the admission gate — an immediate
//! reject, deliberately *not* an SLO violation: shedding is the system
//! keeping its promise to the traffic it admitted), or **failed**
//! (admitted, but every serving attempt died with its pod under the
//! fault plane — counted inside the violation rate like a drop, and
//! additionally reported on its own so fault experiments can split
//! infrastructure deaths from latency misses).  Violation rates are
//! normalized by *admitted* requests; when nothing is shed this is
//! exactly the historical total-request denominator, so pre-admission
//! summaries are bit-identical.

use crate::dispatcher::Tier;
use crate::telemetry::TelemetrySummary;
use std::collections::BTreeMap;

/// How one request resolved (see the module docs for semantics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestOutcome {
    /// Completed within the SLO.
    Served,
    /// Admitted but completed late or dropped in the serving path.
    Violated,
    /// Refused at the admission gate; never entered a queue.
    Shed,
    /// Admitted, but every serving attempt died with its pod and the
    /// retry budget ran out (fault plane).  Counts inside the violation
    /// rate — it is admitted traffic the system lost.
    Failed,
}

/// One completed request.
#[derive(Debug, Clone, Copy)]
pub struct RequestRecord {
    pub arrival_s: f64,
    /// End-to-end latency in seconds. `f64::INFINITY` = dropped or shed.
    pub latency_s: f64,
    /// Accuracy metadata of the variant that served it.
    pub accuracy: f64,
    /// Priority tier the request carried (0 = most important).
    pub tier: Tier,
    /// Normalized against the collector's SLO at record time.
    pub outcome: RequestOutcome,
}

impl RequestRecord {
    /// A request that entered the serving path (latency `INFINITY` =
    /// dropped).  The collector judges served-vs-violated against its SLO
    /// when the record lands.
    pub fn new(arrival_s: f64, latency_s: f64, accuracy: f64, tier: Tier) -> Self {
        Self {
            arrival_s,
            latency_s,
            accuracy,
            tier,
            outcome: RequestOutcome::Violated, // normalized on record
        }
    }

    /// A request refused at the admission gate.
    pub fn shed(arrival_s: f64, tier: Tier) -> Self {
        Self {
            arrival_s,
            latency_s: f64::INFINITY,
            accuracy: 0.0,
            tier,
            outcome: RequestOutcome::Shed,
        }
    }

    /// A request abandoned because every serving attempt died with its
    /// pod (fault plane) and the retry budget ran out.
    pub fn failed(arrival_s: f64, tier: Tier) -> Self {
        Self {
            arrival_s,
            latency_s: f64::INFINITY,
            accuracy: 0.0,
            tier,
            outcome: RequestOutcome::Failed,
        }
    }

    /// No finite latency: dropped in the serving path or shed at the gate.
    pub fn dropped(&self) -> bool {
        !self.latency_s.is_finite()
    }
}

/// Per-tier outcome counts (one row of the tier breakdown).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TierStats {
    pub tier: Tier,
    /// All arrivals at this tier, including shed ones.
    pub total: u64,
    /// Refused at the admission gate.
    pub shed: u64,
    /// Admitted but dropped inside the serving path.
    pub dropped: u64,
    /// Admitted but abandoned after its serving pods died (fault plane);
    /// a subset of `violations`, disjoint from `dropped`.
    pub failed: u64,
    /// Admitted and violated (dropped + failed + completed late).
    pub violations: u64,
    /// Completed within the SLO.
    pub served: u64,
    /// `violations / (total - shed)` — violation rate of admitted traffic.
    pub slo_violation_rate: f64,
}

impl TierStats {
    fn finish(mut self) -> Self {
        let admitted = self.total - self.shed;
        self.slo_violation_rate = if admitted == 0 {
            0.0
        } else {
            self.violations as f64 / admitted as f64
        };
        self
    }
}

/// Merge per-service tier breakdowns (rates recomputed from counts).
fn merge_tiers<'a>(breakdowns: impl Iterator<Item = &'a [TierStats]>) -> Vec<TierStats> {
    let mut by_tier: BTreeMap<Tier, TierStats> = BTreeMap::new();
    for stats in breakdowns {
        for s in stats {
            let e = by_tier.entry(s.tier).or_insert(TierStats {
                tier: s.tier,
                ..Default::default()
            });
            e.total += s.total;
            e.shed += s.shed;
            e.dropped += s.dropped;
            e.failed += s.failed;
            e.violations += s.violations;
            e.served += s.served;
        }
    }
    by_tier.into_values().map(TierStats::finish).collect()
}

/// One row of the experiment timeseries (fixed-width buckets).
///
/// `PartialEq` is field-for-field (float `==`, no tolerance): the
/// parallel-fleet regression pin compares whole row streams bit-exactly
/// across `solver_threads` settings.
#[derive(Debug, Clone, PartialEq)]
pub struct IntervalRow {
    pub t_start: f64,
    /// Observed arrival rate (completed + dropped + shed), rps.
    pub observed_rps: f64,
    /// λ̂ the policy predicted for this interval (0 before first decision).
    pub predicted_rps: f64,
    /// Billed CPU cores (time-averaged over the bucket).
    pub cost_cores: f64,
    /// Served weighted-average accuracy.
    pub avg_accuracy: f64,
    /// Accuracy loss vs the most accurate variant.
    pub accuracy_loss: f64,
    pub p99_latency_s: f64,
    pub mean_latency_s: f64,
    /// Fraction of *admitted* requests in this bucket above the SLO
    /// (dropped count; shed do not).
    pub slo_violation_rate: f64,
    pub dropped: u64,
    /// Admitted requests whose serving pods died (fault plane) in this
    /// bucket; counted inside `slo_violation_rate`, not in `dropped`.
    pub failed: u64,
    pub completed: u64,
    /// Refused at the admission gate in this bucket.
    pub shed: u64,
    /// Per-tier shed counts in this bucket (empty when nothing shed).
    pub shed_by_tier: Vec<(Tier, u64)>,
}

/// Whole-run summary (one figure box/bar).
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub policy: String,
    /// All arrivals, including shed ones.
    pub total_requests: u64,
    /// Admitted requests dropped inside the serving path.
    pub dropped: u64,
    /// Admitted requests abandoned after their serving pods died (fault
    /// plane); disjoint from `dropped`, inside the violation rate.
    pub failed: u64,
    /// Requests refused at the admission gate.
    pub shed: u64,
    /// SLO violation fraction of *admitted* requests (dropped and failed
    /// requests count as violations; shed requests count in neither
    /// side).  With admission disabled this is the historical
    /// all-requests rate.
    pub slo_violation_rate: f64,
    /// Requests completed *within* the SLO per second of the run — the
    /// sustained useful throughput the batching experiments compare.
    pub goodput_rps: f64,
    /// Admitted arrivals per second (the door throughput; equals the
    /// offered rate with admission disabled).
    pub admitted_rps: f64,
    /// Goodput of the *admitted* stream: admitted requests completed
    /// within the SLO per second of the run.  Sheds never complete, so
    /// this equals `goodput_rps`; it is kept as its own field so
    /// admission on/off comparisons plot the (offered, admitted) pair
    /// explicitly.
    pub goodput_admitted_rps: f64,
    /// Request-weighted average accuracy over the run.
    pub avg_accuracy: f64,
    pub avg_accuracy_loss: f64,
    /// Time-averaged billed cores.
    pub avg_cost_cores: f64,
    /// Core-seconds integrated over the run.
    pub core_seconds: f64,
    pub p99_latency_s: f64,
    pub p50_latency_s: f64,
    pub mean_latency_s: f64,
    /// Per-tier outcome breakdown, lowest tier number first.
    pub tiers: Vec<TierStats>,
    /// Telemetry-plane scalars (`None` when the plane is disabled, so a
    /// telemetry-off summary is byte-for-byte the pre-telemetry one).
    pub telemetry: Option<TelemetrySummary>,
}

/// Aggregate of a multi-service fleet run: the per-service [`RunSummary`]s
/// plus cluster-wide rollups.  Requests are judged against their *own*
/// service's SLO, so the aggregate violation rate is the request-weighted
/// mean of the per-service rates; latency percentiles do not merge across
/// different SLOs, so the fleet reports the worst per-service P99 instead
/// (a fleet meets its SLOs only if every service does).
#[derive(Debug, Clone)]
pub struct FleetSummary {
    pub services: Vec<RunSummary>,
    pub total_requests: u64,
    pub dropped: u64,
    /// Requests abandoned after their serving pods died, fleet-wide.
    pub failed: u64,
    /// Requests refused at admission gates across the fleet.
    pub shed: u64,
    /// Admitted-request-weighted SLO-violation fraction across services.
    pub slo_violation_rate: f64,
    /// Sum of per-service goodput (each a rate over its *own* active
    /// window and its own SLO — per-service sustained useful throughput,
    /// not a cluster-horizon rate).
    pub goodput_rps: f64,
    /// Completed-request-weighted accuracy loss (each service relative to
    /// its own top variant).
    pub avg_accuracy_loss: f64,
    /// Cluster-wide time-averaged billed cores: summed core-seconds
    /// normalized by the fleet horizon (services whose traces end early
    /// stop billing, so summing per-window averages would over-report).
    pub avg_cost_cores: f64,
    pub core_seconds: f64,
    /// Worst per-service P99 latency.
    pub worst_p99_latency_s: f64,
    /// Fleet-wide per-tier breakdown (merged across services).
    pub tiers: Vec<TierStats>,
    /// Summed per-service telemetry scalars (`None` when no service
    /// carried any — i.e. the plane was disabled).
    pub telemetry: Option<TelemetrySummary>,
}

impl FleetSummary {
    /// Aggregate per-service summaries; `horizon_s` is the fleet-wide run
    /// length (max service duration) that cost is averaged over.
    pub fn from_services(services: Vec<RunSummary>, horizon_s: f64) -> Self {
        let total_requests: u64 = services.iter().map(|s| s.total_requests).sum();
        let dropped: u64 = services.iter().map(|s| s.dropped).sum();
        let failed: u64 = services.iter().map(|s| s.failed).sum();
        let shed: u64 = services.iter().map(|s| s.shed).sum();
        let admitted: u64 = services
            .iter()
            .map(|s| s.total_requests - s.shed)
            .sum();
        let completed: f64 = services
            .iter()
            .map(|s| (s.total_requests - s.shed - s.dropped - s.failed) as f64)
            .sum();
        let slo_violation_rate = services
            .iter()
            .map(|s| s.slo_violation_rate * (s.total_requests - s.shed) as f64)
            .sum::<f64>()
            / (admitted.max(1) as f64);
        let avg_accuracy_loss = services
            .iter()
            .map(|s| {
                s.avg_accuracy_loss
                    * (s.total_requests - s.shed - s.dropped - s.failed) as f64
            })
            .sum::<f64>()
            / completed.max(1.0);
        let core_seconds: f64 = services.iter().map(|s| s.core_seconds).sum();
        let tiers = merge_tiers(services.iter().map(|s| s.tiers.as_slice()));
        let telemetry = services
            .iter()
            .filter_map(|s| s.telemetry.as_ref())
            .fold(None, |acc: Option<TelemetrySummary>, t| {
                let mut sum = acc.unwrap_or_default();
                sum.absorb(t);
                Some(sum)
            });
        Self {
            total_requests,
            dropped,
            failed,
            shed,
            slo_violation_rate,
            goodput_rps: services.iter().map(|s| s.goodput_rps).sum(),
            avg_accuracy_loss,
            avg_cost_cores: core_seconds / horizon_s.max(1e-9),
            core_seconds,
            worst_p99_latency_s: services
                .iter()
                .map(|s| s.p99_latency_s)
                .fold(0.0, f64::max),
            tiers,
            telemetry,
            services,
        }
    }
}

/// Accumulates request records + cost samples into rows and a summary.
#[derive(Debug, Clone)]
pub struct MetricsCollector {
    pub bucket_s: f64,
    pub slo_s: f64,
    /// Accuracy of the most accurate variant (loss reference).
    pub top_accuracy: f64,
    records: Vec<RequestRecord>,
    /// (time, billed_cores) samples (stepwise-constant between samples).
    cost_samples: Vec<(f64, usize)>,
    /// (time, predicted λ) from policy decisions.
    predictions: Vec<(f64, f64)>,
    /// (time, variant, batch size) from policy decisions (batching audit).
    batch_decisions: Vec<(f64, String, usize)>,
    /// Running violation count of admitted requests (burn-rate signal).
    live_violations: u64,
    /// Running admitted count (burn-rate signal denominator).
    live_admitted: u64,
}

impl MetricsCollector {
    pub fn new(bucket_s: f64, slo_s: f64, top_accuracy: f64) -> Self {
        Self {
            bucket_s,
            slo_s,
            top_accuracy,
            records: Vec::new(),
            cost_samples: Vec::new(),
            predictions: Vec::new(),
            batch_decisions: Vec::new(),
            live_violations: 0,
            live_admitted: 0,
        }
    }

    /// Record one resolved request.  Non-shed records are normalized
    /// against the collector's SLO here, so `outcome` is authoritative on
    /// everything stored.
    pub fn record_request(&mut self, mut r: RequestRecord) {
        if !matches!(r.outcome, RequestOutcome::Shed | RequestOutcome::Failed) {
            r.outcome = if r.latency_s.is_finite() && r.latency_s <= self.slo_s {
                RequestOutcome::Served
            } else {
                RequestOutcome::Violated
            };
        }
        match r.outcome {
            RequestOutcome::Served => self.live_admitted += 1,
            RequestOutcome::Violated | RequestOutcome::Failed => {
                self.live_admitted += 1;
                self.live_violations += 1;
            }
            RequestOutcome::Shed => {}
        }
        self.records.push(r);
    }

    /// Running (violations, admitted) counts — the SLO-burn-rate feed the
    /// fleet engine snapshots every adaptation interval.
    pub fn live_counts(&self) -> (u64, u64) {
        (self.live_violations, self.live_admitted)
    }

    pub fn record_cost(&mut self, t: f64, billed_cores: usize) {
        self.cost_samples.push((t, billed_cores));
    }

    pub fn record_prediction(&mut self, t: f64, lambda_hat: f64) {
        self.predictions.push((t, lambda_hat));
    }

    /// Record a policy's chosen batch size for one variant.
    pub fn record_batch_decision(&mut self, t: f64, variant: &str, batch: usize) {
        self.batch_decisions.push((t, variant.to_string(), batch));
    }

    /// The per-variant batch-size decision log, in decision order.
    pub fn batch_decisions(&self) -> &[(f64, String, usize)] {
        &self.batch_decisions
    }

    fn cost_at(&self, t: f64) -> f64 {
        match self.cost_samples.iter().rev().find(|&&(ts, _)| ts <= t) {
            Some(&(_, c)) => c as f64,
            None => 0.0,
        }
    }

    fn prediction_at(&self, t: f64) -> f64 {
        match self.predictions.iter().rev().find(|&&(ts, _)| ts <= t) {
            Some(&(_, p)) => p,
            None => 0.0,
        }
    }

    /// Build the fixed-width timeseries over `[0, duration_s)`.
    pub fn rows(&self, duration_s: f64) -> Vec<IntervalRow> {
        let n_buckets = (duration_s / self.bucket_s).ceil() as usize;
        let mut buckets: Vec<Vec<&RequestRecord>> = vec![Vec::new(); n_buckets];
        for r in &self.records {
            let b = (r.arrival_s / self.bucket_s) as usize;
            if b < n_buckets {
                buckets[b].push(r);
            }
        }
        buckets
            .iter()
            .enumerate()
            .map(|(b, reqs)| {
                let t_start = b as f64 * self.bucket_s;
                let shed_recs: Vec<&&RequestRecord> = reqs
                    .iter()
                    .filter(|r| r.outcome == RequestOutcome::Shed)
                    .collect();
                let shed = shed_recs.len() as u64;
                let mut shed_by_tier: BTreeMap<Tier, u64> = BTreeMap::new();
                for r in &shed_recs {
                    *shed_by_tier.entry(r.tier).or_insert(0) += 1;
                }
                let completed: Vec<&&RequestRecord> =
                    reqs.iter().filter(|r| !r.dropped()).collect();
                let failed = reqs
                    .iter()
                    .filter(|r| r.outcome == RequestOutcome::Failed)
                    .count() as u64;
                let dropped = reqs.len() as u64 - completed.len() as u64 - shed - failed;
                let admitted = reqs.len() as u64 - shed;
                let mut lats: Vec<f64> = completed.iter().map(|r| r.latency_s).collect();
                lats.sort_by(f64::total_cmp);
                let q = |p: f64| -> f64 {
                    if lats.is_empty() {
                        0.0
                    } else {
                        let rank = ((p * lats.len() as f64).ceil() as usize).clamp(1, lats.len());
                        lats[rank - 1]
                    }
                };
                let avg_acc = if completed.is_empty() {
                    self.top_accuracy
                } else {
                    completed.iter().map(|r| r.accuracy).sum::<f64>() / completed.len() as f64
                };
                let violations = reqs
                    .iter()
                    .filter(|r| {
                        matches!(
                            r.outcome,
                            RequestOutcome::Violated | RequestOutcome::Failed
                        )
                    })
                    .count();
                // time-average cost via sub-sampling the step function
                let cost = (0..10)
                    .map(|i| self.cost_at(t_start + (i as f64 + 0.5) / 10.0 * self.bucket_s))
                    .sum::<f64>()
                    / 10.0;
                IntervalRow {
                    t_start,
                    observed_rps: reqs.len() as f64 / self.bucket_s,
                    predicted_rps: self.prediction_at(t_start),
                    cost_cores: cost,
                    avg_accuracy: avg_acc,
                    accuracy_loss: self.top_accuracy - avg_acc,
                    p99_latency_s: q(0.99),
                    mean_latency_s: if lats.is_empty() {
                        0.0
                    } else {
                        lats.iter().sum::<f64>() / lats.len() as f64
                    },
                    slo_violation_rate: if admitted == 0 {
                        0.0
                    } else {
                        violations as f64 / admitted as f64
                    },
                    dropped,
                    failed,
                    completed: completed.len() as u64,
                    shed,
                    shed_by_tier: shed_by_tier.into_iter().collect(),
                }
            })
            .collect()
    }

    /// Whole-run summary.
    pub fn summary(&self, policy: &str, duration_s: f64) -> RunSummary {
        let total = self.records.len() as u64;
        let shed = self
            .records
            .iter()
            .filter(|r| r.outcome == RequestOutcome::Shed)
            .count() as u64;
        let admitted = total - shed;
        let completed: Vec<&RequestRecord> =
            self.records.iter().filter(|r| !r.dropped()).collect();
        let failed = self
            .records
            .iter()
            .filter(|r| r.outcome == RequestOutcome::Failed)
            .count() as u64;
        let dropped = admitted - completed.len() as u64 - failed;
        let mut lats: Vec<f64> = completed.iter().map(|r| r.latency_s).collect();
        lats.sort_by(f64::total_cmp);
        let q = |p: f64| -> f64 {
            if lats.is_empty() {
                0.0
            } else {
                let rank = ((p * lats.len() as f64).ceil() as usize).clamp(1, lats.len());
                lats[rank - 1]
            }
        };
        let violations = self
            .records
            .iter()
            .filter(|r| {
                matches!(
                    r.outcome,
                    RequestOutcome::Violated | RequestOutcome::Failed
                )
            })
            .count();
        let avg_acc = if completed.is_empty() {
            0.0
        } else {
            completed.iter().map(|r| r.accuracy).sum::<f64>() / completed.len() as f64
        };
        // integrate the cost step function
        let mut core_seconds = 0.0;
        for w in self.cost_samples.windows(2) {
            core_seconds += w[0].1 as f64 * (w[1].0 - w[0].0);
        }
        if let Some(&(t_last, c_last)) = self.cost_samples.last() {
            core_seconds += c_last as f64 * (duration_s - t_last).max(0.0);
        }
        let within_slo = self
            .records
            .iter()
            .filter(|r| r.outcome == RequestOutcome::Served)
            .count();
        let mut tier_map: BTreeMap<Tier, TierStats> = BTreeMap::new();
        for r in &self.records {
            let e = tier_map.entry(r.tier).or_insert(TierStats {
                tier: r.tier,
                ..Default::default()
            });
            e.total += 1;
            match r.outcome {
                RequestOutcome::Shed => e.shed += 1,
                RequestOutcome::Served => e.served += 1,
                RequestOutcome::Violated => {
                    e.violations += 1;
                    if r.dropped() {
                        e.dropped += 1;
                    }
                }
                RequestOutcome::Failed => {
                    e.violations += 1;
                    e.failed += 1;
                }
            }
        }
        let tiers: Vec<TierStats> = tier_map.into_values().map(TierStats::finish).collect();
        // Offered-side and admitted-side goodput coincide by construction
        // (sheds never complete), so compute once and expose both names —
        // the invariant is documented on the fields.
        let goodput = within_slo as f64 / duration_s.max(1e-9);
        RunSummary {
            policy: policy.to_string(),
            total_requests: total,
            dropped,
            failed,
            shed,
            slo_violation_rate: if admitted == 0 {
                0.0
            } else {
                violations as f64 / admitted as f64
            },
            goodput_rps: goodput,
            admitted_rps: admitted as f64 / duration_s.max(1e-9),
            goodput_admitted_rps: goodput,
            avg_accuracy: avg_acc,
            avg_accuracy_loss: self.top_accuracy - avg_acc,
            avg_cost_cores: core_seconds / duration_s.max(1e-9),
            core_seconds,
            p99_latency_s: q(0.99),
            p50_latency_s: q(0.50),
            mean_latency_s: if lats.is_empty() {
                0.0
            } else {
                lats.iter().sum::<f64>() / lats.len() as f64
            },
            tiers,
            // attached by the fleet layer when the telemetry plane is on;
            // the collector itself never observes the data plane
            telemetry: None,
        }
    }

    pub fn requests(&self) -> &[RequestRecord] {
        &self.records
    }
}

/// Write rows as CSV (figure-regeneration output format).
pub fn rows_to_csv(rows: &[IntervalRow]) -> String {
    let mut out = String::from(
        "t,observed_rps,predicted_rps,cost_cores,avg_accuracy,accuracy_loss,\
         p99_latency_s,mean_latency_s,slo_violation_rate,dropped,failed,completed,shed\n",
    );
    for r in rows {
        out.push_str(&format!(
            "{:.0},{:.2},{:.2},{:.2},{:.3},{:.3},{:.4},{:.4},{:.4},{},{},{},{}\n",
            r.t_start,
            r.observed_rps,
            r.predicted_rps,
            r.cost_cores,
            r.avg_accuracy,
            r.accuracy_loss,
            r.p99_latency_s,
            r.mean_latency_s,
            r.slo_violation_rate,
            r.dropped,
            r.failed,
            r.completed,
            r.shed
        ));
    }
    out
}

/// Per-variant served-request share (experiment diagnostics).
pub fn served_share(records: &[RequestRecord]) -> BTreeMap<String, f64> {
    // accuracy identifies the variant uniquely in our family
    let mut counts: BTreeMap<String, u64> = BTreeMap::new();
    for r in records.iter().filter(|r| !r.dropped()) {
        *counts.entry(format!("{:.2}", r.accuracy)).or_insert(0) += 1;
    }
    let total: u64 = counts.values().sum();
    counts
        .into_iter()
        .map(|(k, v)| (k, v as f64 / total.max(1) as f64))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn collector() -> MetricsCollector {
        MetricsCollector::new(10.0, 0.75, 78.31)
    }

    #[test]
    fn summary_counts_violations_and_drops() {
        let mut m = collector();
        for i in 0..100 {
            m.record_request(RequestRecord::new(
                i as f64 * 0.1,
                if i < 90 { 0.1 } else { 1.0 },
                76.13,
                0,
            ));
        }
        m.record_request(RequestRecord::new(5.0, f64::INFINITY, 76.13, 0));
        let s = m.summary("test", 10.0);
        assert_eq!(s.total_requests, 101);
        assert_eq!(s.dropped, 1);
        assert_eq!(s.shed, 0);
        assert!((s.slo_violation_rate - 11.0 / 101.0).abs() < 1e-9);
        assert!((s.avg_accuracy - 76.13).abs() < 1e-9);
        assert!((s.avg_accuracy_loss - 2.18).abs() < 1e-9);
    }

    #[test]
    fn shed_requests_are_not_slo_violations() {
        let mut m = collector();
        // 8 served, 2 violated, 10 shed
        for i in 0..10 {
            m.record_request(RequestRecord::new(
                i as f64,
                if i < 8 { 0.1 } else { 2.0 },
                76.13,
                0,
            ));
        }
        for i in 0..10 {
            m.record_request(RequestRecord::shed(i as f64, 1));
        }
        let s = m.summary("t", 10.0);
        assert_eq!(s.total_requests, 20);
        assert_eq!(s.shed, 10);
        assert_eq!(s.dropped, 0);
        // violations normalized by the 10 admitted, not the 20 offered
        assert!((s.slo_violation_rate - 0.2).abs() < 1e-9, "{s:?}");
        assert!((s.admitted_rps - 1.0).abs() < 1e-9);
        assert!((s.goodput_admitted_rps - 0.8).abs() < 1e-9);
        // per-tier: tier 0 took the violations, tier 1 took the sheds
        assert_eq!(s.tiers.len(), 2);
        assert_eq!(s.tiers[0].tier, 0);
        assert_eq!(s.tiers[0].violations, 2);
        assert_eq!(s.tiers[0].shed, 0);
        assert!((s.tiers[0].slo_violation_rate - 0.2).abs() < 1e-9);
        assert_eq!(s.tiers[1].tier, 1);
        assert_eq!(s.tiers[1].shed, 10);
        assert_eq!(s.tiers[1].slo_violation_rate, 0.0);
    }

    #[test]
    fn failed_requests_count_as_violations_but_not_drops() {
        let mut m = collector();
        m.record_request(RequestRecord::new(0.0, 0.1, 76.13, 0)); // served
        m.record_request(RequestRecord::new(0.1, f64::INFINITY, 0.0, 0)); // dropped
        m.record_request(RequestRecord::failed(0.2, 0));
        m.record_request(RequestRecord::shed(0.3, 1));
        let s = m.summary("t", 10.0);
        assert_eq!(s.total_requests, 4);
        assert_eq!((s.dropped, s.failed, s.shed), (1, 1, 1));
        // drop + fail are 2 violations of the 3 admitted requests
        assert!((s.slo_violation_rate - 2.0 / 3.0).abs() < 1e-9, "{s:?}");
        assert_eq!(s.tiers[0].violations, 2);
        assert_eq!(s.tiers[0].dropped, 1);
        assert_eq!(s.tiers[0].failed, 1);
        assert_eq!(s.tiers[0].served, 1);
        // the burn meter sees failures as violations of admitted traffic
        assert_eq!(m.live_counts(), (2, 3));
        let rows = m.rows(10.0);
        assert_eq!(rows[0].failed, 1);
        assert_eq!(rows[0].dropped, 1);
        assert_eq!(rows[0].completed, 1);
        assert_eq!(rows[0].shed, 1);
        assert!((rows[0].slo_violation_rate - 2.0 / 3.0).abs() < 1e-9);
        // fleet rollup carries the failed count through
        let f = FleetSummary::from_services(vec![s], 10.0);
        assert_eq!(f.failed, 1);
        assert_eq!(f.dropped, 1);
    }

    #[test]
    fn live_counts_feed_the_burn_meter() {
        let mut m = collector();
        m.record_request(RequestRecord::new(0.0, 0.1, 76.13, 0)); // served
        m.record_request(RequestRecord::new(0.1, 2.0, 76.13, 0)); // violated
        m.record_request(RequestRecord::shed(0.2, 1)); // shed: neither
        let (v, a) = m.live_counts();
        assert_eq!((v, a), (1, 2));
    }

    #[test]
    fn cost_integration_is_stepwise() {
        let mut m = collector();
        m.record_cost(0.0, 10);
        m.record_cost(50.0, 20);
        let s = m.summary("test", 100.0);
        assert!((s.core_seconds - (10.0 * 50.0 + 20.0 * 50.0)).abs() < 1e-9);
        assert!((s.avg_cost_cores - 15.0).abs() < 1e-9);
    }

    #[test]
    fn rows_bucket_by_arrival_time() {
        let mut m = collector();
        for t in [1.0, 2.0, 11.0, 12.0, 13.0] {
            m.record_request(RequestRecord::new(t, 0.2, 69.76, 0));
        }
        m.record_request(RequestRecord::shed(3.0, 1));
        m.record_cost(0.0, 8);
        let rows = m.rows(20.0);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].completed, 2);
        assert_eq!(rows[0].shed, 1);
        assert_eq!(rows[0].shed_by_tier, vec![(1, 1)]);
        assert_eq!(rows[0].dropped, 0);
        assert_eq!(rows[1].completed, 3);
        assert_eq!(rows[1].shed, 0);
        assert!((rows[0].cost_cores - 8.0).abs() < 1e-9);
        assert!((rows[0].accuracy_loss - (78.31 - 69.76)).abs() < 1e-6);
    }

    #[test]
    fn goodput_counts_only_within_slo_completions() {
        let mut m = collector();
        for i in 0..100 {
            m.record_request(RequestRecord::new(
                i as f64 * 0.1,
                if i < 80 { 0.2 } else { 2.0 },
                76.13,
                0,
            ));
        }
        m.record_request(RequestRecord::new(1.0, f64::INFINITY, 0.0, 0));
        let s = m.summary("t", 10.0);
        // 80 of 101 finished within the 0.75 s SLO over 10 s
        assert!((s.goodput_rps - 8.0).abs() < 1e-9, "{}", s.goodput_rps);
    }

    #[test]
    fn batch_decisions_are_logged_in_order() {
        let mut m = collector();
        m.record_batch_decision(0.0, "resnet50", 4);
        m.record_batch_decision(30.0, "resnet50", 8);
        assert_eq!(m.batch_decisions().len(), 2);
        assert_eq!(m.batch_decisions()[1], (30.0, "resnet50".to_string(), 8));
    }

    #[test]
    fn fleet_summary_aggregates_request_weighted() {
        let mk = |total: u64, dropped: u64, viol: f64, loss: f64, cost: f64, p99: f64| {
            RunSummary {
                policy: "svc".into(),
                total_requests: total,
                dropped,
                failed: 0,
                shed: 0,
                slo_violation_rate: viol,
                goodput_rps: 10.0,
                admitted_rps: 10.0,
                goodput_admitted_rps: 10.0,
                avg_accuracy: 0.0,
                avg_accuracy_loss: loss,
                avg_cost_cores: cost,
                core_seconds: cost * 100.0,
                p99_latency_s: p99,
                p50_latency_s: 0.1,
                mean_latency_s: 0.1,
                tiers: Vec::new(),
                telemetry: None,
            }
        };
        let f = FleetSummary::from_services(
            vec![
                mk(300, 0, 0.10, 1.0, 6.0, 0.5),
                mk(100, 100, 0.30, 0.0, 2.0, 0.9),
            ],
            100.0,
        );
        assert_eq!(f.total_requests, 400);
        assert_eq!(f.dropped, 100);
        assert_eq!(f.shed, 0);
        // (0.10·300 + 0.30·100) / 400
        assert!((f.slo_violation_rate - 0.15).abs() < 1e-9);
        // loss weighted by completed requests only: (1.0·300 + 0.0·0)/300
        assert!((f.avg_accuracy_loss - 1.0).abs() < 1e-9);
        // (600 + 200) core-seconds over the 100 s horizon
        assert!((f.avg_cost_cores - 8.0).abs() < 1e-9);
        assert!((f.goodput_rps - 20.0).abs() < 1e-9);
        assert!((f.worst_p99_latency_s - 0.9).abs() < 1e-9);
        assert_eq!(f.services.len(), 2);
        // a service billed over a shorter window must not inflate the
        // cluster average: same core-seconds, longer horizon, lower avg
        let g = FleetSummary::from_services(
            vec![mk(300, 0, 0.0, 0.0, 4.0, 0.1), mk(100, 0, 0.0, 0.0, 4.0, 0.1)],
            400.0,
        );
        assert!((g.avg_cost_cores - 2.0).abs() < 1e-9);
    }

    #[test]
    fn fleet_summary_merges_tier_breakdowns() {
        let svc = |tiers: Vec<TierStats>, total: u64, shed: u64| RunSummary {
            policy: "svc".into(),
            total_requests: total,
            dropped: 0,
            failed: 0,
            shed,
            slo_violation_rate: 0.0,
            goodput_rps: 0.0,
            admitted_rps: 0.0,
            goodput_admitted_rps: 0.0,
            avg_accuracy: 0.0,
            avg_accuracy_loss: 0.0,
            avg_cost_cores: 0.0,
            core_seconds: 0.0,
            p99_latency_s: 0.0,
            p50_latency_s: 0.0,
            mean_latency_s: 0.0,
            tiers,
            telemetry: None,
        };
        let t = |tier: Tier, total: u64, shed: u64, violations: u64| TierStats {
            tier,
            total,
            shed,
            dropped: 0,
            failed: 0,
            violations,
            served: total - shed - violations,
            slo_violation_rate: 0.0,
        };
        let f = FleetSummary::from_services(
            vec![
                svc(vec![t(0, 100, 0, 10)], 100, 0),
                svc(vec![t(0, 50, 0, 0), t(1, 50, 40, 0)], 100, 40),
            ],
            100.0,
        );
        assert_eq!(f.shed, 40);
        assert_eq!(f.tiers.len(), 2);
        assert_eq!(f.tiers[0].tier, 0);
        assert_eq!(f.tiers[0].total, 150);
        assert_eq!(f.tiers[0].violations, 10);
        assert!((f.tiers[0].slo_violation_rate - 10.0 / 150.0).abs() < 1e-9);
        assert_eq!(f.tiers[1].shed, 40);
        assert_eq!(f.tiers[1].total, 50);
        assert_eq!(f.tiers[1].slo_violation_rate, 0.0);
    }

    #[test]
    fn p99_matches_exact_rank() {
        let mut m = collector();
        for i in 1..=200 {
            m.record_request(RequestRecord::new(0.5, i as f64 / 1000.0, 78.31, 0));
        }
        let s = m.summary("t", 10.0);
        assert!((s.p99_latency_s - 0.198).abs() < 1e-9);
        assert!((s.p50_latency_s - 0.100).abs() < 1e-9);
    }
}
