//! The InfAdapter ILP (paper Eq. 1) and its solvers.
//!
//! Decision variables: per variant m, an integer core count `n_m` and a
//! workload quota `λ_m`.  Objective: maximize
//!
//! ```text
//!   α·AA − (β·RC + γ·LC)
//!   AA = Σ (λ_m / λ)·acc_m     RC = Σ n_m      LC = max tc_m·rt_m
//! ```
//!
//! subject to aggregate stability `Σ th_m(n_m) ≥ λ`, per-variant stability
//! `λ_m ≤ th_m(n_m)`, the latency SLO `p_m(n_m) ≤ L` for active variants,
//! and the budget `Σ n_m ≤ B`.
//!
//! Key structural fact (DESIGN.md §5): **given a core vector, the optimal
//! quota split is greedy** — fill the most accurate active variants to
//! capacity first.  So the search space is core vectors only, each scored
//! in O(M); the paper notes its own solution enumerates all configurations.
//!
//! ## Batching
//!
//! With server-side batching enabled ([`Problem::from_profiles_batched`])
//! the decision gains a per-variant batch size `b_m ≤ max_batch`.  A second
//! structural fact keeps the search space unchanged: the batch amortization
//! gain is monotone in `b` and the objective depends on `b_m` only through
//! the capacity `th_m(n, b)` and the SLO constraint, so **for every
//! (variant, cores) pair the optimal batch is the largest SLO-feasible
//! one**, chosen independently per table entry.  The SLO accounting charges
//! the worst case end-to-end: `max_wait_s` of batch-formation wait (a pod
//! dispatches a partial batch after at most that long) plus the full
//! batched service time `s_m(b)` — a request may ride a batch that only
//! dispatched on timeout.  The chosen sizes surface as
//! [`VariantInput::batch`] / [`Allocation::batches`] and flow through
//! `Decision` into the serving engines; `max_batch = 1` reproduces the
//! unbatched tables bit-for-bit, so batching off is behaviourally identical
//! to the pre-batching solver.
//!
//! ## Shed pricing (admission-aware objective)
//!
//! With admission control the serving path refuses offered load beyond
//! the allocation's supply — lost goodput the plain objective never sees.
//! [`Problem::with_shed_pricing`] adds the term
//! `− shed_penalty · max(0, λ̂_offered − capacity)` to every score, where
//! `offered_lambda` is the *raw* predicted offered rate (the planning
//! `lambda` carries headroom on top) and `shed_penalty` is the
//! per-request lost-goodput price, tier-weighted by the caller.  The term
//! depends on a core vector only through its aggregate capacity, so all
//! the structural facts above survive: greedy quota fills stay optimal,
//! the per-(variant, cores) batch choice stays pointwise optimal, the
//! single-pass value curves stay exact (the branch-and-bound curve bound
//! charges the *optimistic* shed of each completion — see
//! [`BranchBoundSolver`]), and `shed_penalty = 0` (the default) skips the
//! term outright, keeping every score bit-identical to the unpriced
//! objective.
//!
//! Three solvers share the scoring code:
//! * [`BruteForceSolver`] — exact enumeration of all weak compositions
//!   (the paper's approach; with dominance pruning).
//! * [`BranchBoundSolver`] — exact, prunes with an accuracy upper bound
//!   (the paper's "scalability with ML" future-work axis, solved exactly).
//! * [`GreedySolver`] — fast heuristic baseline for the ablation bench.
//!
//! ## Value curves in one pass
//!
//! The fleet arbiter needs the *whole* per-budget value curve `v(g)`, not
//! one optimum.  The objective of a core vector depends on the budget only
//! through the feasibility bound `Σ n_m ≤ B`, so a single enumeration can
//! bin the best objective by resource cost `c = Σ n_m` and prefix-max the
//! bins into `v(g)` for every `g` at once ([`Solver::solve_curve`]) — the
//! exact solvers implement this natively instead of re-solving at each of
//! the `cap + 1` candidate grants ([`value_curve_resolve`], the old loop,
//! kept as the property-test reference).  [`Solver::solve_curve_seeded`]
//! additionally warm-starts the incumbent curve from a previous solve's
//! winner vectors, re-scored under the current problem so exactness is
//! preserved no matter how stale the seed is.

mod branch_bound;
mod brute;
mod greedy;

pub use branch_bound::BranchBoundSolver;
pub use brute::BruteForceSolver;
pub use greedy::GreedySolver;

use crate::config::{BatchingConfig, ObjectiveWeights};
use crate::profiler::ProfileSet;
use std::collections::BTreeMap;

/// One variant's inputs to the ILP.
#[derive(Debug, Clone)]
pub struct VariantInput {
    pub name: String,
    pub accuracy: f64,
    /// `th_m(n, b_n)` for n in 0..=budget (precomputed from the regression,
    /// at the per-n chosen batch size).
    pub throughput: Vec<f64>,
    /// Worst-case `p_m(n, b_n)` in seconds for n in 0..=budget, including
    /// batch-formation wait when `b_n > 1`.
    pub latency: Vec<f64>,
    /// Readiness time `rt_m`, seconds.
    pub readiness_s: f64,
    /// Cores currently allocated (0 = not loaded); drives `tc_m`.
    pub current_cores: usize,
    /// Chosen batch size per core count (all 1 when batching is disabled).
    pub batch: Vec<usize>,
}

/// The full problem instance.
#[derive(Debug, Clone)]
pub struct Problem {
    pub variants: Vec<VariantInput>,
    /// Predicted workload λ (requests/second).
    pub lambda: f64,
    /// Latency SLO L in seconds.
    pub slo_s: f64,
    /// CPU budget B.
    pub budget: usize,
    pub weights: ObjectiveWeights,
    /// Batch-size cap the tables were built with (1 = batching disabled).
    pub max_batch: usize,
    /// Batch-formation wait cap charged against the SLO when batching.
    pub max_wait_s: f64,
    /// Predicted *offered* rate λ̂ the shed pricing charges against —
    /// the raw forecast, while [`Self::lambda`] is the planning load
    /// (forecast × headroom, floored).  Equal to `lambda` when built
    /// without explicit shed pricing ([`Self::with_shed_pricing`]).
    pub offered_lambda: f64,
    /// Per-request lost-goodput price: the objective is charged
    /// `shed_penalty · max(0, offered_lambda − capacity)` — what the
    /// admission gate will shed at the door when the allocation's supply
    /// falls short of the offered load.  0 (the default) skips the term
    /// entirely, keeping every score bit-identical to the unpriced
    /// objective.
    pub shed_penalty: f64,
}

impl Problem {
    /// Build a problem from profiles with batching disabled (the paper's
    /// CPU setting; identical to [`Self::from_profiles_batched`] with
    /// `max_batch = 1`).
    pub fn from_profiles(
        profiles: &ProfileSet,
        lambda: f64,
        slo_s: f64,
        budget: usize,
        weights: ObjectiveWeights,
        current: &BTreeMap<String, usize>,
    ) -> Self {
        Self::from_profiles_batched(
            profiles,
            lambda,
            slo_s,
            budget,
            weights,
            current,
            &BatchingConfig::default(),
        )
    }

    /// Build a problem whose per-(variant, cores) tables additionally pick
    /// a server-side batch size: the largest `b ≤ max_batch` whose
    /// worst-case latency (formation wait + batched service) meets the SLO.
    /// The amortization gain is monotone in `b`, so that choice maximizes
    /// throughput — and therefore the Eq. 1 objective — pointwise, keeping
    /// the solvers' search space core vectors only.
    pub fn from_profiles_batched(
        profiles: &ProfileSet,
        lambda: f64,
        slo_s: f64,
        budget: usize,
        weights: ObjectiveWeights,
        current: &BTreeMap<String, usize>,
        batching: &BatchingConfig,
    ) -> Self {
        let max_batch = batching.max_batch.max(1);
        let variants = profiles
            .profiles
            .iter()
            .map(|p| {
                // Largest SLO-feasible batch; independent of the core count
                // (worst-case latency is formation wait + batched service,
                // neither depends on n).  b = 1 is kept even when itself
                // infeasible so `slo_ok` flags the variant just like the
                // unbatched tables.
                let mut best = 1usize;
                for b in 2..=max_batch {
                    if batching.max_wait_s + p.service_time_batch(b) <= slo_s {
                        best = b;
                    }
                }
                let formation = if best > 1 { batching.max_wait_s } else { 0.0 };
                let worst_latency = formation + p.service_time_batch(best);
                let mut throughput = Vec::with_capacity(budget + 1);
                let mut latency = Vec::with_capacity(budget + 1);
                let mut batch = Vec::with_capacity(budget + 1);
                for n in 0..=budget {
                    if n == 0 {
                        throughput.push(0.0);
                        latency.push(f64::INFINITY);
                        batch.push(1);
                        continue;
                    }
                    throughput.push(p.throughput_batched(n, best));
                    latency.push(worst_latency);
                    batch.push(best);
                }
                VariantInput {
                    name: p.name.clone(),
                    accuracy: p.accuracy,
                    throughput,
                    latency,
                    readiness_s: p.readiness_s,
                    current_cores: current.get(&p.name).copied().unwrap_or(0),
                    batch,
                }
            })
            .collect();
        Self {
            variants,
            lambda,
            slo_s,
            budget,
            weights,
            max_batch,
            max_wait_s: batching.max_wait_s,
            offered_lambda: lambda,
            shed_penalty: 0.0,
        }
    }

    /// Price shed traffic into the objective (builder style): every score
    /// is additionally charged `shed_penalty · max(0, offered − capacity)`
    /// — the lost goodput the admission gate would refuse at the door.
    /// The caller weights the per-request price by tier (class mix)
    /// before passing it in; see `fleet::shed_value_weight`.
    pub fn with_shed_pricing(mut self, offered_lambda: f64, shed_penalty: f64) -> Self {
        self.offered_lambda = offered_lambda.max(0.0);
        self.shed_penalty = shed_penalty.max(0.0);
        self
    }

    /// Max cores worth giving variant i: beyond the point where throughput
    /// already covers the demand, additional cores only add cost
    /// (dominance pruning).  The demand is the planning load λ — joined
    /// with the offered load when shed pricing is active, so capacity
    /// that still reduces priced shed is never pruned away; with the
    /// penalty at 0 the offered rate is ignored outright and the search
    /// space is the historical one, comparison for comparison.
    pub(crate) fn useful_max_cores(&self, i: usize) -> usize {
        let demand = if self.shed_penalty != 0.0 {
            self.lambda.max(self.offered_lambda)
        } else {
            self.lambda
        };
        let v = &self.variants[i];
        for n in 0..=self.budget {
            if v.throughput[n] >= demand {
                return n;
            }
        }
        self.budget
    }

    /// Is `n` cores on variant `i` SLO-feasible (n == 0 is always allowed)?
    pub(crate) fn slo_ok(&self, i: usize, n: usize) -> bool {
        n == 0 || self.variants[i].latency[n] <= self.slo_s
    }
}

/// A solved allocation.
#[derive(Debug, Clone, PartialEq)]
pub struct Allocation {
    /// variant name -> (cores, quota λ_m). Only active variants appear.
    pub assignments: BTreeMap<String, (usize, f64)>,
    /// variant name -> chosen server-side batch size (1 unless batching is
    /// enabled). Only active variants appear.
    pub batches: BTreeMap<String, usize>,
    pub objective: f64,
    /// Weighted average accuracy AA (percentage points).
    pub average_accuracy: f64,
    /// Resource cost RC = Σ n_m.
    pub resource_cost: usize,
    /// Loading cost LC = max tc_m · rt_m (seconds).
    pub loading_cost: f64,
    /// Aggregate capacity Σ th_m(n_m) at this allocation.
    pub capacity: f64,
    /// True if aggregate capacity covers λ.
    pub feasible: bool,
}

impl Allocation {
    pub fn cores_of(&self, name: &str) -> usize {
        self.assignments.get(name).map(|&(c, _)| c).unwrap_or(0)
    }

    pub fn quota_of(&self, name: &str) -> f64 {
        self.assignments.get(name).map(|&(_, q)| q).unwrap_or(0.0)
    }

    pub fn batch_of(&self, name: &str) -> usize {
        self.batches.get(name).copied().unwrap_or(1)
    }

    pub fn total_cores(&self) -> usize {
        self.assignments.values().map(|&(c, _)| c).sum()
    }

    /// Quota weights for the dispatcher, normalized to sum 1.  Names are
    /// borrowed — the decision path materializes owned strings only at
    /// the `Decision` boundary, not once per solve.
    pub fn quota_weights(&self) -> Vec<(&str, f64)> {
        let total: f64 = self.assignments.values().map(|&(_, q)| q).sum();
        self.assignments
            .iter()
            .filter(|(_, &(_, q))| q > 0.0)
            .map(|(n, &(_, q))| (n.as_str(), if total > 0.0 { q / total } else { 0.0 }))
            .collect()
    }
}

/// Allocation-free scoring: (objective, feasible) for a core vector, or
/// None if an active variant violates the SLO.  This is the enumeration
/// hot path — no heap traffic (see EXPERIMENTS.md §Perf).  Must agree with
/// [`score`] on every input (cross-checked by `prop_score_fast_matches_score`
/// in `tests/properties.rs`).
pub fn score_fast(problem: &Problem, cores: &[usize]) -> Option<(f64, bool)> {
    debug_assert_eq!(cores.len(), problem.variants.len());
    let m = cores.len();
    if m > 64 {
        // The selection scratch below is a u64 visited bitmask; wider
        // problems take the materializing path instead of panicking.
        return score(problem, cores).map(|a| (a.objective, a.feasible));
    }
    let mut capacity = 0.0;
    for (i, &n) in cores.iter().enumerate() {
        if !problem.slo_ok(i, n) {
            return None;
        }
        capacity += problem.variants[i].throughput[n];
    }
    // Greedy quota fill in descending accuracy (selection loop over a
    // bitmask of still-unfilled active variants; no sort, no stack array).
    let mut remaining = problem.lambda;
    let mut acc_weighted = 0.0;
    let mut active: u64 = 0;
    for (i, &n) in cores.iter().enumerate() {
        if n > 0 {
            active |= 1u64 << i;
        }
    }
    let mut best_active_acc: f64 = 0.0;
    let mut any_active = false;
    while active != 0 {
        let mut pick = usize::MAX;
        let mut pick_acc = 0.0f64;
        let mut rest = active;
        while rest != 0 {
            let i = rest.trailing_zeros() as usize;
            rest &= rest - 1;
            let acc = problem.variants[i].accuracy;
            if pick == usize::MAX || acc > pick_acc {
                pick = i;
                pick_acc = acc;
            }
        }
        active &= !(1u64 << pick);
        if !any_active {
            best_active_acc = problem.variants[pick].accuracy;
            any_active = true;
        }
        let q = remaining.min(problem.variants[pick].throughput[cores[pick]]);
        remaining -= q;
        acc_weighted += q * problem.variants[pick].accuracy;
    }
    let feasible = remaining <= 1e-9 && capacity >= problem.lambda - 1e-9;
    let average_accuracy = if problem.lambda > 0.0 {
        acc_weighted / problem.lambda
    } else if any_active {
        best_active_acc
    } else {
        0.0
    };
    let resource_cost: usize = cores.iter().sum();
    let mut loading_cost = 0.0f64;
    for (i, &n) in cores.iter().enumerate() {
        if n > 0 && problem.variants[i].current_cores == 0 {
            loading_cost = loading_cost.max(problem.variants[i].readiness_s);
        }
    }
    let w = problem.weights;
    let shortfall = (problem.lambda - capacity).max(0.0);
    let mut objective = w.alpha * average_accuracy
        - (w.beta * resource_cost as f64 + w.gamma * loading_cost)
        - if feasible { 0.0 } else { 1e3 + shortfall };
    // Shed pricing: charge the lost goodput the admission gate would
    // refuse at this capacity.  Guarded so the default (penalty 0) never
    // touches the objective's bit pattern.
    if problem.shed_penalty != 0.0 {
        objective -= problem.shed_penalty * (problem.offered_lambda - capacity).max(0.0);
    }
    Some((objective, feasible))
}

/// Score a core vector: greedy quota fill (most accurate first), then the
/// paper's objective.  Returns None if any active variant violates the SLO.
/// Materializes the full [`Allocation`] — use [`score_fast`] in search loops.
pub fn score(problem: &Problem, cores: &[usize]) -> Option<Allocation> {
    debug_assert_eq!(cores.len(), problem.variants.len());
    let mut capacity = 0.0;
    for (i, &n) in cores.iter().enumerate() {
        if !problem.slo_ok(i, n) {
            return None;
        }
        capacity += problem.variants[i].throughput[n];
    }
    // Greedy quota: most accurate active variants absorb load first.
    let mut order: Vec<usize> = (0..cores.len()).filter(|&i| cores[i] > 0).collect();
    order.sort_by(|&a, &b| {
        problem.variants[b]
            .accuracy
            .total_cmp(&problem.variants[a].accuracy)
    });
    let mut remaining = problem.lambda;
    let mut assignments = BTreeMap::new();
    let mut batches = BTreeMap::new();
    let mut acc_weighted = 0.0;
    for &i in &order {
        let v = &problem.variants[i];
        let q = remaining.min(v.throughput[cores[i]]);
        remaining -= q;
        acc_weighted += q * v.accuracy;
        assignments.insert(v.name.clone(), (cores[i], q));
        batches.insert(v.name.clone(), v.batch[cores[i]]);
    }
    let feasible = remaining <= 1e-9 && capacity >= problem.lambda - 1e-9;
    let average_accuracy = if problem.lambda > 0.0 {
        acc_weighted / problem.lambda
    } else {
        // No load: AA is the accuracy of the best active variant (serving
        // readiness), or 0 with nothing active.
        order
            .first()
            .map(|&i| problem.variants[i].accuracy)
            .unwrap_or(0.0)
    };
    let resource_cost: usize = cores.iter().sum();
    let loading_cost = cores
        .iter()
        .enumerate()
        .filter(|&(i, &n)| n > 0 && problem.variants[i].current_cores == 0)
        .map(|(i, _)| problem.variants[i].readiness_s)
        .fold(0.0, f64::max);
    let w = problem.weights;
    // Infeasible allocations are heavily penalized by their capacity gap so
    // the solver still returns the least-bad option when λ exceeds what the
    // budget can serve (the paper's "even the least accurate variant cannot
    // respond" regime).
    let shortfall = (problem.lambda - capacity).max(0.0);
    let mut objective = w.alpha * average_accuracy
        - (w.beta * resource_cost as f64 + w.gamma * loading_cost)
        - if feasible { 0.0 } else { 1e3 + shortfall };
    if problem.shed_penalty != 0.0 {
        objective -= problem.shed_penalty * (problem.offered_lambda - capacity).max(0.0);
    }
    Some(Allocation {
        assignments,
        batches,
        objective,
        average_accuracy,
        resource_cost,
        loading_cost,
        capacity,
        feasible,
    })
}

/// A whole value curve from one solve: `values()[g]` is the best
/// achievable objective when the core budget is capped at `g`, for
/// `g in 0..=cap`.  Alongside the values it carries the best core vector
/// found *at each exact resource cost* — achievable allocations that a
/// later solve on a near-identical problem can re-score to warm-start its
/// incumbent curve ([`Solver::solve_curve_seeded`]) without giving up
/// exactness.  Winners stay index-space core vectors (problem variant
/// order); names are materialized only at the [`Allocation`] boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct ValueCurve {
    /// `values[g]` = best objective over allocations costing ≤ g cores.
    values: Vec<f64>,
    /// `winners[c]` = best core vector of total cost exactly `c`, where
    /// the search recorded one (pruned-away costs stay `None`; the curve
    /// value at such a cost comes from a cheaper winner via prefix-max).
    winners: Vec<Option<Vec<usize>>>,
}

impl ValueCurve {
    pub(crate) fn from_parts(values: Vec<f64>, winners: Vec<Option<Vec<usize>>>) -> Self {
        debug_assert_eq!(values.len(), winners.len());
        Self { values, winners }
    }

    /// The curve of an unsolvable (empty) problem: `-inf` everywhere.
    pub(crate) fn unsolvable(cap: usize) -> Self {
        Self {
            values: vec![f64::NEG_INFINITY; cap + 1],
            winners: vec![None; cap + 1],
        }
    }

    /// Largest grant the curve covers (`values().len() - 1`).
    pub fn cap(&self) -> usize {
        self.values.len() - 1
    }

    pub fn values(&self) -> &[f64] {
        &self.values
    }

    pub fn into_values(self) -> Vec<f64> {
        self.values
    }

    pub fn winners(&self) -> &[Option<Vec<usize>>] {
        &self.winners
    }
}

/// Shared accumulator for curve-native solvers: bins the best objective by
/// exact resource cost and maintains the running prefix-max (`inc[c]` =
/// best objective at cost ≤ c), which doubles as the incumbent curve that
/// branch-and-bound prunes against.
pub(crate) struct CurveAcc {
    /// Best objective at *exact* cost c (winner bookkeeping).
    best_at: Vec<f64>,
    /// Prefix-max of `best_at`: the incumbent value curve.
    inc: Vec<f64>,
    winners: Vec<Option<Vec<usize>>>,
}

impl CurveAcc {
    pub(crate) fn new(cap: usize) -> Self {
        Self {
            best_at: vec![f64::NEG_INFINITY; cap + 1],
            inc: vec![f64::NEG_INFINITY; cap + 1],
            winners: vec![None; cap + 1],
        }
    }

    /// Record an achievable (cost, objective, core-vector) triple.
    pub(crate) fn offer(&mut self, cost: usize, objective: f64, cores: &[usize]) {
        if objective > self.best_at[cost] {
            self.best_at[cost] = objective;
            self.winners[cost] = Some(cores.to_vec());
        }
        for c in cost..self.inc.len() {
            if self.inc[c] < objective {
                self.inc[c] = objective;
            } else {
                break;
            }
        }
    }

    /// Incumbent curve value at grant `c` (what a new entry of cost `c`
    /// must strictly beat to matter).
    pub(crate) fn incumbent_at(&self, c: usize) -> f64 {
        self.inc[c.min(self.inc.len() - 1)]
    }

    pub(crate) fn finish(self) -> ValueCurve {
        ValueCurve::from_parts(self.inc, self.winners)
    }
}

/// Introspection counters from one curve solve — what the telemetry
/// plane surfaces per tick (B&B nodes visited, curve-aware prunes,
/// seeded-incumbent rescores).  Pure observation: solvers count work they
/// already do; the counters never feed back into any decision.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SolveStats {
    /// Search nodes visited (B&B recursion entries; 0 for heuristics).
    pub nodes_visited: u64,
    /// Subtrees cut by the curve-aware bound (`!promising` rejections).
    pub curve_prunes: u64,
    /// Warm-start winner vectors re-scored into the incumbent curve.
    pub seed_rescores: u64,
}

impl SolveStats {
    /// Accumulate another solve's counters (deterministic: plain sums).
    pub fn add(&mut self, other: SolveStats) {
        self.nodes_visited += other.nodes_visited;
        self.curve_prunes += other.curve_prunes;
        self.seed_rescores += other.seed_rescores;
    }
}

/// Common solver interface.
///
/// `Send` is a supertrait because boxed solvers ride inside policies that
/// cross shard-thread boundaries in the fleet's parallel solve stage
/// (`fleet::shard::parallel_zip`).  Every solver here is a stateless unit
/// struct, so the bound costs nothing.
pub trait Solver: Send {
    fn name(&self) -> &'static str;
    /// Best allocation for the problem; None only if the problem is empty.
    fn solve(&self, problem: &Problem) -> Option<Allocation>;

    /// The whole per-budget value curve in one call: `values()[g]` is the
    /// best objective achievable with the core budget capped at `g`, for
    /// `g in 0..=cap` (`cap ≤ problem.budget` so the per-variant tables
    /// cover every sub-budget).
    ///
    /// Default implementation: the per-grant re-solve loop (one `solve`
    /// per candidate budget), so heuristic solvers keep today's semantics
    /// verbatim.  The exact solvers override it with single-pass
    /// curve-native searches: the objective depends on the budget only
    /// through the feasibility bound, so one enumeration can bin the best
    /// objective by resource cost and prefix-max the bins into the curve
    /// (cross-checked against this loop by
    /// `prop_solve_curve_matches_resolve_loop`).
    fn solve_curve(&self, problem: &Problem, cap: usize) -> ValueCurve {
        debug_assert!(
            cap <= problem.budget,
            "curve cap {cap} exceeds the table budget {}",
            problem.budget
        );
        let mut acc = CurveAcc::new(cap);
        let mut values = Vec::with_capacity(cap + 1);
        let mut sub = problem.clone();
        for g in 0..=cap {
            sub.budget = g;
            match self.solve(&sub) {
                Some(a) => {
                    let cores: Vec<usize> = problem
                        .variants
                        .iter()
                        .map(|v| a.cores_of(&v.name))
                        .collect();
                    let cost: usize = cores.iter().sum();
                    if cost <= cap {
                        acc.offer(cost, a.objective, &cores);
                    }
                    values.push(a.objective);
                }
                None => values.push(f64::NEG_INFINITY),
            }
        }
        // Keep the loop's per-grant values verbatim (a heuristic solver's
        // curve need not be monotone); the accumulator only contributes
        // the achievable winner vectors for future warm starts.
        let winners = acc.finish().winners;
        ValueCurve::from_parts(values, winners)
    }

    /// [`Self::solve_curve`] with an optional warm start: `seed` is a
    /// previously solved curve whose winner vectors are *re-scored under
    /// this problem* to pre-load the incumbent curve — sound regardless of
    /// how stale the seed is, because only currently-achievable objectives
    /// enter the incumbent.  Solvers that cannot exploit a warm start
    /// (enumeration, heuristics) ignore it.
    fn solve_curve_seeded(
        &self,
        problem: &Problem,
        cap: usize,
        seed: Option<&ValueCurve>,
    ) -> ValueCurve {
        let _ = seed;
        self.solve_curve(problem, cap)
    }

    /// [`Self::solve_curve_seeded`] plus its [`SolveStats`] — the
    /// telemetry plane's entry point.  The returned curve MUST be
    /// identical to `solve_curve_seeded` on the same inputs (the stats
    /// are counters of work the solve already does, never a different
    /// algorithm).  Default: delegate and report zero stats, so
    /// heuristic solvers need no instrumentation.
    fn solve_curve_stats(
        &self,
        problem: &Problem,
        cap: usize,
        seed: Option<&ValueCurve>,
    ) -> (ValueCurve, SolveStats) {
        (self.solve_curve_seeded(problem, cap, seed), SolveStats::default())
    }
}

/// Per-budget value curve for the fleet arbiter (see
/// [`Solver::solve_curve`], which this delegates to — single-pass for the
/// exact solvers, the re-solve loop for heuristics).
pub fn value_curve(problem: &Problem, solver: &dyn Solver, cap: usize) -> Vec<f64> {
    solver.solve_curve(problem, cap).into_values()
}

/// The pre-curve-native reference: re-solves the same ILP once per
/// candidate grant `g in 0..=cap` — `N × (B+1)` branch-and-bound solves
/// per fleet tick, the quadratic decision path this module's single-pass
/// curves replace.  Kept as the ground truth for
/// `prop_solve_curve_matches_resolve_loop` and as the "old" side of the
/// `micro_hotpaths` value-curve comparison.
pub fn value_curve_resolve(problem: &Problem, solver: &dyn Solver, cap: usize) -> Vec<f64> {
    debug_assert!(
        cap <= problem.budget,
        "curve cap {cap} exceeds the table budget {}",
        problem.budget
    );
    let mut sub = problem.clone();
    (0..=cap)
        .map(|g| {
            sub.budget = g;
            solver
                .solve(&sub)
                .map(|a| a.objective)
                .unwrap_or(f64::NEG_INFINITY)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn problem(lambda: f64, budget: usize, beta: f64) -> Problem {
        let profiles = ProfileSet::paper_like();
        Problem::from_profiles(
            &profiles,
            lambda,
            0.75,
            budget,
            ObjectiveWeights {
                alpha: 1.0,
                beta,
                gamma: 0.001,
            },
            &BTreeMap::new(),
        )
    }

    pub(crate) fn problem_batched(
        lambda: f64,
        budget: usize,
        beta: f64,
        max_batch: usize,
    ) -> Problem {
        let profiles = ProfileSet::paper_like();
        Problem::from_profiles_batched(
            &profiles,
            lambda,
            0.75,
            budget,
            ObjectiveWeights {
                alpha: 1.0,
                beta,
                gamma: 0.001,
            },
            &BTreeMap::new(),
            &BatchingConfig {
                max_batch,
                max_wait_s: 0.05,
            },
        )
    }

    #[test]
    fn score_fills_most_accurate_first() {
        let p = problem(50.0, 20, 0.05);
        // resnet18 gets 2 cores (cap ~46), resnet152 gets 8 (cap ~49)
        let cores = vec![2, 0, 0, 0, 8];
        let alloc = score(&p, &cores).unwrap();
        assert!(alloc.feasible);
        // resnet152 (most accurate) absorbs to capacity first
        let q152 = alloc.quota_of("resnet152");
        let q18 = alloc.quota_of("resnet18");
        assert!(q152 > q18, "q152={q152} q18={q18}");
        assert!((q152 + q18 - 50.0).abs() < 1e-6);
    }

    #[test]
    fn score_flags_infeasible_capacity() {
        let p = problem(1000.0, 4, 0.05);
        let alloc = score(&p, &[4, 0, 0, 0, 0]).unwrap();
        assert!(!alloc.feasible);
        assert!(alloc.objective < -100.0);
    }

    #[test]
    fn quota_weights_normalize() {
        let p = problem(60.0, 20, 0.05);
        let alloc = score(&p, &[3, 0, 0, 0, 6]).unwrap();
        let w: f64 = alloc.quota_weights().iter().map(|(_, q)| q).sum();
        assert!((w - 1.0).abs() < 1e-9);
    }

    #[test]
    fn max_batch_one_reproduces_unbatched_tables_exactly() {
        let a = problem(60.0, 20, 0.05);
        let b = problem_batched(60.0, 20, 0.05, 1);
        for (va, vb) in a.variants.iter().zip(&b.variants) {
            assert_eq!(va.throughput, vb.throughput);
            assert_eq!(va.latency, vb.latency);
            assert!(vb.batch.iter().all(|&x| x == 1));
        }
    }

    #[test]
    fn batched_tables_cap_batch_at_the_slo() {
        let p = problem_batched(60.0, 20, 0.05, 8);
        let unb = problem(60.0, 20, 0.05);
        for (v, vu) in p.variants.iter().zip(&unb.variants) {
            for n in 1..=p.budget {
                assert!((1..=8).contains(&v.batch[n]), "{}: b={}", v.name, v.batch[n]);
                // the chosen batch is SLO-feasible (or batching was refused)
                assert!(v.batch[n] == 1 || v.latency[n] <= 0.75 + 1e-12, "{}", v.name);
                // batching never reduces capacity
                assert!(v.throughput[n] >= vu.throughput[n] - 1e-12);
            }
        }
        // fast variant takes the full batch; the slowest is capped by the
        // SLO: s(b) = 0.184·(0.5 + 0.5·b) + 0.05 wait ≤ 0.75 ⇒ b ≤ 6.
        assert_eq!(p.variants[0].name, "resnet18");
        assert_eq!(p.variants[0].batch[4], 8);
        assert_eq!(p.variants[4].name, "resnet152");
        assert_eq!(p.variants[4].batch[4], 6);
    }

    #[test]
    fn score_reports_chosen_batches() {
        let p = problem_batched(50.0, 20, 0.05, 8);
        let alloc = score(&p, &[2, 0, 0, 0, 8]).unwrap();
        assert_eq!(alloc.batch_of("resnet18"), 8);
        assert_eq!(alloc.batch_of("resnet152"), 6);
        // inactive variants report the neutral batch size
        assert_eq!(alloc.batch_of("resnet50"), 1);
        // unbatched problems report 1 everywhere
        let alloc1 = score(&problem(50.0, 20, 0.05), &[2, 0, 0, 0, 8]).unwrap();
        assert!(alloc1.batches.values().all(|&b| b == 1));
    }

    #[test]
    fn batching_weakly_improves_every_core_vector() {
        // per-vector capacity is pointwise ≥, so the score is too
        let unb = problem(120.0, 12, 0.05);
        let bat = problem_batched(120.0, 12, 0.05, 8);
        for cores in [
            vec![4, 0, 0, 0, 8],
            vec![0, 0, 12, 0, 0],
            vec![2, 2, 2, 2, 2],
            vec![5, 0, 0, 0, 0],
        ] {
            let (u, _) = score_fast(&unb, &cores).unwrap();
            let (b, _) = score_fast(&bat, &cores).unwrap();
            assert!(b >= u - 1e-9, "cores {cores:?}: batched {b} < unbatched {u}");
        }
    }

    #[test]
    fn value_curve_is_monotone_and_ends_at_the_full_solve() {
        let p = problem(75.0, 20, 0.05);
        let curve = value_curve(&p, &BruteForceSolver, 20);
        assert_eq!(curve.len(), 21);
        for w in curve.windows(2) {
            assert!(w[1] >= w[0] - 1e-9, "curve must be nondecreasing: {curve:?}");
        }
        let full = BruteForceSolver.solve(&p).unwrap();
        assert!((curve[20] - full.objective).abs() < 1e-9);
        // an infeasible prefix is strictly below the feasible tail
        assert!(curve[0] < curve[20]);
    }

    #[test]
    fn solve_curve_matches_the_resolve_loop_on_paper_problems() {
        for p in [problem(75.0, 20, 0.05), problem_batched(250.0, 16, 0.05, 8)] {
            for s in [&BruteForceSolver as &dyn Solver, &BranchBoundSolver as &dyn Solver] {
                let reference = value_curve_resolve(&p, s, p.budget);
                let curve = s.solve_curve(&p, p.budget);
                assert_eq!(curve.values().len(), reference.len());
                for (g, (a, b)) in curve.values().iter().zip(&reference).enumerate() {
                    assert!((a - b).abs() < 1e-9, "{} g={g}: {a} vs {b}", s.name());
                }
                // warm-starting from its own output changes nothing
                let warm = s.solve_curve_seeded(&p, p.budget, Some(&curve));
                assert_eq!(warm.values(), curve.values(), "{}", s.name());
            }
        }
    }

    #[test]
    fn value_curve_supports_sub_caps() {
        let p = problem(75.0, 20, 0.05);
        let short = value_curve(&p, &BranchBoundSolver, 8);
        let long = value_curve(&p, &BranchBoundSolver, 20);
        assert_eq!(short.len(), 9);
        for (a, b) in short.iter().zip(&long) {
            assert!((a - b).abs() < 1e-9, "shared prefix must agree");
        }
    }

    #[test]
    fn shed_pricing_charges_exactly_the_uncovered_offered_load() {
        let base = problem(300.0, 8, 0.05);
        let priced = base.clone().with_shed_pricing(260.0, 2.0);
        // 4 cores of resnet18 cover ~92 rps: shortfall vs the 260 rps
        // offered load is priced at 2.0 per rps on top of the unpriced
        // objective (which already carries the λ-infeasibility penalty).
        let cores = vec![4, 0, 0, 0, 0];
        let (u, uf) = score_fast(&base, &cores).unwrap();
        let (p, pf) = score_fast(&priced, &cores).unwrap();
        assert_eq!(uf, pf, "pricing must not change feasibility");
        let capacity = score(&base, &cores).unwrap().capacity;
        let expect = u - 2.0 * (260.0 - capacity).max(0.0);
        assert!((p - expect).abs() < 1e-9, "{p} vs {expect}");
        // a capacity at/above the offered load is never charged
        let covered = problem(50.0, 20, 0.05).with_shed_pricing(45.0, 2.0);
        let (a, _) = score_fast(&problem(50.0, 20, 0.05), &[4, 0, 0, 0, 0]).unwrap();
        let (b, _) = score_fast(&covered, &[4, 0, 0, 0, 0]).unwrap();
        assert_eq!(a.to_bits(), b.to_bits());
        // and score() agrees with score_fast() on priced problems
        let full = score(&priced, &cores).unwrap();
        assert!((full.objective - p).abs() < 1e-9);
    }

    #[test]
    fn zero_penalty_is_bit_identical_whatever_the_offered_rate() {
        let base = problem(120.0, 12, 0.05);
        let neutral = base.clone().with_shed_pricing(700.0, 0.0);
        for cores in [vec![4, 0, 0, 0, 8], vec![0, 0, 12, 0, 0], vec![1, 1, 1, 1, 1]] {
            let (a, af) = score_fast(&base, &cores).unwrap();
            let (b, bf) = score_fast(&neutral, &cores).unwrap();
            assert_eq!(a.to_bits(), b.to_bits(), "cores {cores:?}");
            assert_eq!(af, bf);
        }
        // dominance caps only widen when the offered load is priced in
        for i in 0..base.variants.len() {
            assert_eq!(base.useful_max_cores(i), neutral.useful_max_cores(i));
        }
    }

    #[test]
    fn priced_value_curves_stay_monotone_and_match_the_loop() {
        let p = problem(300.0, 12, 0.05).with_shed_pricing(272.0, 1.5);
        for s in [&BruteForceSolver as &dyn Solver, &BranchBoundSolver as &dyn Solver] {
            let reference = value_curve_resolve(&p, s, p.budget);
            let curve = s.solve_curve(&p, p.budget);
            for (g, (a, b)) in curve.values().iter().zip(&reference).enumerate() {
                assert!((a - b).abs() < 1e-9, "{} g={g}: {a} vs {b}", s.name());
            }
            for w in curve.values().windows(2) {
                assert!(w[1] >= w[0] - 1e-9, "{}: nondecreasing", s.name());
            }
        }
    }

    #[test]
    fn shed_pricing_prefers_higher_capacity_under_overload() {
        // At λ far past the 6-core capacity, the unpriced solver already
        // maximizes capacity via the infeasibility shortfall; a strictly
        // positive penalty must never choose *less* capacity, and the
        // solved objective falls by exactly the priced shed.
        let base = problem(400.0, 6, 0.05);
        let priced = base.clone().with_shed_pricing(360.0, 3.0);
        let a = BruteForceSolver.solve(&base).unwrap();
        let b = BruteForceSolver.solve(&priced).unwrap();
        assert!(b.capacity >= a.capacity - 1e-9, "{} vs {}", b.capacity, a.capacity);
        assert!(b.objective <= a.objective, "penalty only subtracts");
    }

    #[test]
    fn loading_cost_counts_only_new_variants() {
        let profiles = ProfileSet::paper_like();
        let mut current = BTreeMap::new();
        current.insert("resnet18".to_string(), 4);
        let p = Problem::from_profiles(
            &profiles,
            10.0,
            0.75,
            20,
            ObjectiveWeights::default(),
            &current,
        );
        // keeping resnet18 only: no loading cost
        let keep = score(&p, &[4, 0, 0, 0, 0]).unwrap();
        assert_eq!(keep.loading_cost, 0.0);
        // adding resnet152: pays its readiness time
        let add = score(&p, &[4, 0, 0, 0, 2]).unwrap();
        assert!((add.loading_cost - 16.0).abs() < 1e-9);
    }
}
