//! Greedy heuristic solver (ablation baseline).
//!
//! Marginal-utility greedy: starting from the cheapest feasible base, it
//! repeatedly moves one core to whichever variant improves the objective
//! most.  Fast (O(B·M) scores) but not exact — the ablation bench
//! (`micro_hotpaths` + EXPERIMENTS.md) quantifies the optimality gap that
//! justifies the paper's exact enumeration.

use super::{score, score_fast, Allocation, Problem, Solver};

#[derive(Debug, Default, Clone, Copy)]
pub struct GreedySolver;

impl Solver for GreedySolver {
    fn name(&self) -> &'static str {
        "greedy"
    }

    fn solve(&self, problem: &Problem) -> Option<Allocation> {
        if problem.variants.is_empty() {
            return None;
        }
        let m = problem.variants.len();
        let mut cores = vec![0usize; m];
        let (mut best_obj, mut best_feasible) = score_fast(problem, &cores)?;

        // Phase 1: reach feasibility by adding the core with the best
        // capacity-per-objective-loss until capacity covers λ.
        loop {
            if best_feasible || cores.iter().sum::<usize>() >= problem.budget {
                break;
            }
            let mut improved: Option<(usize, f64, bool)> = None;
            for i in 0..m {
                if cores.iter().sum::<usize>() >= problem.budget {
                    break;
                }
                cores[i] += 1;
                if problem.slo_ok(i, cores[i]) {
                    if let Some((obj, feas)) = score_fast(problem, &cores) {
                        if improved.as_ref().map_or(true, |&(_, b, _)| obj > b) {
                            improved = Some((i, obj, feas));
                        }
                    }
                }
                cores[i] -= 1;
            }
            match improved {
                Some((i, obj, feas)) => {
                    cores[i] += 1;
                    best_obj = obj;
                    best_feasible = feas;
                }
                None => break,
            }
        }

        // Phase 2: local moves — single-core add / remove / transfer while
        // the objective improves.
        let mut changed = true;
        while changed {
            changed = false;
            let mut candidate_obj = best_obj;
            let mut candidate_cores = cores.clone();
            for i in 0..m {
                for j in 0..m {
                    let mut trial = cores.clone();
                    if i == j {
                        // pure removal
                        if trial[i] == 0 {
                            continue;
                        }
                        trial[i] -= 1;
                    } else {
                        // transfer i -> j
                        if trial[i] == 0 || trial.iter().sum::<usize>() > problem.budget {
                            continue;
                        }
                        trial[i] -= 1;
                        trial[j] += 1;
                        if !problem.slo_ok(j, trial[j]) {
                            continue;
                        }
                    }
                    if let Some((obj, _)) = score_fast(problem, &trial) {
                        if obj > candidate_obj + 1e-12 {
                            candidate_obj = obj;
                            candidate_cores = trial;
                        }
                    }
                }
                // pure addition
                if cores.iter().sum::<usize>() < problem.budget {
                    let mut trial = cores.clone();
                    trial[i] += 1;
                    if problem.slo_ok(i, trial[i]) {
                        if let Some((obj, _)) = score_fast(problem, &trial) {
                            if obj > candidate_obj + 1e-12 {
                                candidate_obj = obj;
                                candidate_cores = trial;
                            }
                        }
                    }
                }
            }
            if candidate_obj > best_obj + 1e-12 {
                best_obj = candidate_obj;
                cores = candidate_cores;
                changed = true;
            }
        }
        let _ = best_feasible;
        score(problem, &cores)
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::problem;
    use super::super::BruteForceSolver;
    use super::*;

    #[test]
    fn reaches_feasibility_when_possible() {
        let p = problem(75.0, 20, 0.05);
        let alloc = GreedySolver.solve(&p).unwrap();
        assert!(alloc.feasible, "{alloc:?}");
        assert!(alloc.total_cores() <= 20);
    }

    #[test]
    fn batched_problems_stay_feasible_and_bounded() {
        use super::super::tests::problem_batched;
        for (lambda, budget) in [(75.0, 20), (200.0, 14)] {
            let p = problem_batched(lambda, budget, 0.05, 8);
            let g = GreedySolver.solve(&p).unwrap();
            let e = BruteForceSolver.solve(&p).unwrap();
            assert!(g.feasible, "λ={lambda} B={budget}: {g:?}");
            assert!(g.objective <= e.objective + 1e-9);
            assert!(
                e.objective - g.objective < 5.0,
                "gap {} at λ={lambda} B={budget}",
                e.objective - g.objective
            );
        }
    }

    #[test]
    fn gap_to_exact_is_bounded() {
        // Greedy may be suboptimal but should land within a few accuracy
        // points of the exact objective on paper-scale instances.
        for (lambda, budget) in [(75.0, 20), (40.0, 14), (100.0, 24)] {
            let p = problem(lambda, budget, 0.05);
            let g = GreedySolver.solve(&p).unwrap();
            let e = BruteForceSolver.solve(&p).unwrap();
            assert!(g.objective <= e.objective + 1e-9);
            assert!(
                e.objective - g.objective < 5.0,
                "gap {} at λ={lambda} B={budget}",
                e.objective - g.objective
            );
        }
    }
}
