//! Exact branch-and-bound solver (scales past brute force).
//!
//! Addresses the paper's §7 "Scalability with ML" concern exactly instead of
//! approximately: depth-first over variants (most accurate first), bounding
//! each partial assignment with an optimistic completion: the remaining load
//! is served at the highest possible accuracy, and the *cheapest possible*
//! number of additional cores (remaining capacity gap divided by the best
//! remaining per-core throughput) is still charged — a valid upper bound
//! that prunes aggressively at large budgets.

use super::{score, Allocation, Problem, Solver};

#[derive(Debug, Default, Clone, Copy)]
pub struct BranchBoundSolver;

struct Ctx<'a> {
    problem: &'a Problem,
    order: Vec<usize>,
    caps: Vec<usize>,
    max_acc: f64,
    /// Best throughput-per-core over all variants (bound ingredient).
    best_rate_per_core: f64,
    best: Option<(f64, Vec<usize>)>,
    visited: u64,
}

impl Solver for BranchBoundSolver {
    fn name(&self) -> &'static str {
        "branch_bound"
    }

    fn solve(&self, problem: &Problem) -> Option<Allocation> {
        if problem.variants.is_empty() {
            return None;
        }
        let m = problem.variants.len();
        // Visit most accurate variants first so good solutions surface early
        // and the bound tightens fast.
        let mut order: Vec<usize> = (0..m).collect();
        order.sort_by(|&a, &b| {
            problem.variants[b]
                .accuracy
                .total_cmp(&problem.variants[a].accuracy)
        });
        let caps: Vec<usize> = (0..m).map(|i| problem.useful_max_cores(i)).collect();
        let max_acc = problem
            .variants
            .iter()
            .map(|v| v.accuracy)
            .fold(0.0, f64::max);
        let best_rate_per_core = problem
            .variants
            .iter()
            .filter_map(|v| {
                if problem.budget >= 1 {
                    Some(v.throughput[1.min(problem.budget)])
                } else {
                    None
                }
            })
            .fold(0.0, f64::max)
            .max(1e-9);

        let mut ctx = Ctx {
            problem,
            order,
            caps,
            max_acc,
            best_rate_per_core,
            best: None,
            visited: 0,
        };
        dfs(&mut ctx, &mut vec![0usize; m], 0, problem.budget, 0.0, 0.0);
        ctx.best.and_then(|(_, cores)| score(problem, &cores))
    }
}

/// `filled`: λ already absorbable by decided variants (greedy order —
/// variants are decided in descending accuracy, which *is* the greedy fill
/// order); `acc_sum`: Σ quota·accuracy over that fill.
fn dfs(
    ctx: &mut Ctx,
    cores: &mut Vec<usize>,
    depth: usize,
    left: usize,
    filled: f64,
    acc_sum: f64,
) {
    ctx.visited += 1;
    if depth == ctx.order.len() {
        if let Some((objective, _)) = super::score_fast(ctx.problem, cores) {
            if ctx.best.as_ref().map_or(true, |(b, _)| objective > *b) {
                ctx.best = Some((objective, cores.clone()));
            }
        }
        return;
    }
    // Optimistic bound:
    //  * accuracy — the unabsorbed load can at best be served by the most
    //    accurate *remaining* variant (they are visited in descending
    //    accuracy, so that is order[depth]);
    //  * cost — at least the committed cores plus the cheapest completion
    //    that could close the capacity gap at the best per-core rate.
    let lambda = ctx.problem.lambda;
    let committed: usize = cores.iter().sum();
    let gap = (lambda - filled).max(0.0);
    let min_extra = ((gap / ctx.best_rate_per_core).ceil() as usize).min(left);
    let next_acc = ctx.problem.variants[ctx.order[depth]].accuracy;
    let opt_aa = if lambda > 0.0 {
        (acc_sum + gap * next_acc) / lambda
    } else {
        ctx.max_acc
    };
    let bound = ctx.problem.weights.alpha * opt_aa
        - ctx.problem.weights.beta * (committed + min_extra) as f64;
    if let Some((b, _)) = &ctx.best {
        if bound <= *b {
            return;
        }
    }
    let i = ctx.order[depth];
    let cap = ctx.caps[i].min(left);
    // Try larger allocations first: feasible (high-objective) solutions
    // appear sooner, tightening the bound.
    for n in (0..=cap).rev() {
        if !ctx.problem.slo_ok(i, n) {
            continue;
        }
        cores[i] = n;
        let q = (lambda - filled).max(0.0).min(ctx.problem.variants[i].throughput[n]);
        dfs(
            ctx,
            cores,
            depth + 1,
            left - n,
            filled + q,
            acc_sum + q * ctx.problem.variants[i].accuracy,
        );
    }
    cores[i] = 0;
}

#[cfg(test)]
mod tests {
    use super::super::tests::problem;
    use super::super::BruteForceSolver;
    use super::*;
    use crate::solver::Solver as _;

    #[test]
    fn agrees_with_brute_force_on_objective() {
        for (lambda, budget, beta) in [
            (75.0, 20, 0.05),
            (40.0, 14, 0.05),
            (75.0, 8, 0.2),
            (120.0, 24, 0.0125),
            (10.0, 4, 0.05),
            (0.0, 10, 0.05),
        ] {
            let p = problem(lambda, budget, beta);
            let bb = BranchBoundSolver.solve(&p).unwrap();
            let bf = BruteForceSolver.solve(&p).unwrap();
            assert!(
                (bb.objective - bf.objective).abs() < 1e-9,
                "λ={lambda} B={budget} β={beta}: bb={} bf={}",
                bb.objective,
                bf.objective
            );
        }
    }

    #[test]
    fn agrees_with_brute_force_on_batched_problems() {
        use super::super::tests::problem_batched;
        for (lambda, budget, max_batch) in [
            (75.0, 20, 4),
            (120.0, 14, 8),
            (250.0, 8, 8),
            (40.0, 10, 2),
        ] {
            let p = problem_batched(lambda, budget, 0.05, max_batch);
            let bb = BranchBoundSolver.solve(&p).unwrap();
            let bf = BruteForceSolver.solve(&p).unwrap();
            assert!(
                (bb.objective - bf.objective).abs() < 1e-9,
                "λ={lambda} B={budget} mb={max_batch}: bb={} bf={}",
                bb.objective,
                bf.objective
            );
        }
    }

    #[test]
    fn handles_large_budget_quickly() {
        let p = problem(400.0, 64, 0.05);
        let t0 = std::time::Instant::now();
        let alloc = BranchBoundSolver.solve(&p).unwrap();
        assert!(alloc.feasible);
        assert!(
            t0.elapsed().as_secs_f64() < 5.0,
            "took {:?}",
            t0.elapsed()
        );
    }
}
