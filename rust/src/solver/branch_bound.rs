//! Exact branch-and-bound solver (scales past brute force).
//!
//! Addresses the paper's §7 "Scalability with ML" concern exactly instead of
//! approximately: depth-first over variants (most accurate first), bounding
//! each partial assignment with an optimistic completion: the remaining load
//! is served at the highest possible accuracy, and the *cheapest possible*
//! number of additional cores (remaining capacity gap divided by the best
//! remaining per-core throughput) is still charged — a valid upper bound
//! that prunes aggressively at large budgets.
//!
//! ## Curve-aware search ([`Solver::solve_curve`])
//!
//! The single-optimum bound above is not enough for the single-pass value
//! curve: a partial assignment that cannot beat the global optimum may
//! still hold the best allocation at some *smaller* resource cost, which
//! the curve needs.  The curve search therefore prunes against the whole
//! incumbent curve: a node with `committed` cores and `left` spare is
//! expanded iff some completion cost `c ∈ [committed, committed + left]`
//! admits an optimistic objective above the incumbent `v(c)`.
//!
//! The per-cost bound is built from two suffix knapsacks, precomputed once
//! per solve over the not-yet-decided variants `order[d..]`:
//! `addmax[d][k]` (max capacity addable with ≤ k cores) and `accmax[d][k]`
//! (max accuracy-weighted capacity with ≤ k cores).  At completion cost
//! `c = committed + k` the bound charges
//! `α·(acc_sum + min(accmax, absorb·next_acc))/λ − β·c − γ·LC_partial`,
//! minus `1e3 + (gap − addmax)` while `addmax` cannot cover the remaining
//! gap (with the same 1e-9 feasibility tolerance the scorer uses — an
//! exactly-covered load must not be penalized for a 1-ulp residue).
//! `LC_partial` (max readiness among decided fresh variants) is a valid
//! loading-cost floor because LC is a max that only grows.  On priced
//! problems ([`Problem::with_shed_pricing`]) the bound additionally
//! charges the *optimistic* shed — the smallest possible priced
//! shortfall `shed_penalty · max(0, offered − (committed capacity +
//! addmax))` any completion at that cost could pay — which keeps it
//! admissible because real completions add at most `addmax` capacity.
//! The sweep stops once capacity covers the gap, the accuracy term
//! saturates, *and* (when pricing) the offered load is coverable so the
//! shed charge is pinned at zero: past that cost the bound only falls
//! while the incumbent only rises.  A node is pruned only when *no*
//! completion could improve the output curve at *any* cost, so exactness
//! is preserved.
//!
//! [`Solver::solve_curve_seeded`] warm-starts the incumbent curve from a
//! previous curve's winner vectors, **re-scored under the current
//! problem** — only currently-achievable objectives enter the incumbent,
//! so a stale seed can never corrupt the result; it can only prune.  On
//! steady-state fleet ticks (λ̂ wobble, same committed cores) the seeded
//! incumbent is already pointwise optimal and the search collapses.

use super::{score, Allocation, CurveAcc, Problem, SolveStats, Solver, ValueCurve};

#[derive(Debug, Default, Clone, Copy)]
pub struct BranchBoundSolver;

struct Ctx<'a> {
    problem: &'a Problem,
    order: Vec<usize>,
    caps: Vec<usize>,
    max_acc: f64,
    /// Best throughput-per-core over all variants (bound ingredient).
    best_rate_per_core: f64,
    best: Option<(f64, Vec<usize>)>,
    visited: u64,
}

/// Shared search preamble: visit order (most accurate first, so good
/// solutions surface early and the bound tightens fast), per-variant
/// dominance caps, and the optimistic-rate/accuracy bound ingredients.
fn prepare(problem: &Problem) -> (Vec<usize>, Vec<usize>, f64, f64) {
    let m = problem.variants.len();
    let mut order: Vec<usize> = (0..m).collect();
    order.sort_by(|&a, &b| {
        problem.variants[b]
            .accuracy
            .total_cmp(&problem.variants[a].accuracy)
    });
    let caps: Vec<usize> = (0..m).map(|i| problem.useful_max_cores(i)).collect();
    let max_acc = problem
        .variants
        .iter()
        .map(|v| v.accuracy)
        .fold(0.0, f64::max);
    let best_rate_per_core = problem
        .variants
        .iter()
        .filter_map(|v| {
            if problem.budget >= 1 {
                Some(v.throughput[1.min(problem.budget)])
            } else {
                None
            }
        })
        .fold(0.0, f64::max)
        .max(1e-9);
    (order, caps, max_acc, best_rate_per_core)
}

fn explore(problem: &Problem) -> Ctx<'_> {
    let (order, caps, max_acc, best_rate_per_core) = prepare(problem);
    let m = problem.variants.len();
    let mut ctx = Ctx {
        problem,
        order,
        caps,
        max_acc,
        best_rate_per_core,
        best: None,
        visited: 0,
    };
    dfs(&mut ctx, &mut vec![0usize; m], 0, problem.budget, 0.0, 0.0);
    ctx
}

impl Solver for BranchBoundSolver {
    fn name(&self) -> &'static str {
        "branch_bound"
    }

    fn solve(&self, problem: &Problem) -> Option<Allocation> {
        if problem.variants.is_empty() {
            return None;
        }
        explore(problem).best.and_then(|(_, cores)| score(problem, &cores))
    }

    fn solve_curve(&self, problem: &Problem, cap: usize) -> ValueCurve {
        self.solve_curve_seeded(problem, cap, None)
    }

    fn solve_curve_seeded(
        &self,
        problem: &Problem,
        cap: usize,
        seed: Option<&ValueCurve>,
    ) -> ValueCurve {
        self.curve_search(problem, cap, seed).0
    }

    fn solve_curve_stats(
        &self,
        problem: &Problem,
        cap: usize,
        seed: Option<&ValueCurve>,
    ) -> (ValueCurve, SolveStats) {
        self.curve_search(problem, cap, seed)
    }
}

impl BranchBoundSolver {
    fn curve_search(
        &self,
        problem: &Problem,
        cap: usize,
        seed: Option<&ValueCurve>,
    ) -> (ValueCurve, SolveStats) {
        debug_assert!(
            cap <= problem.budget,
            "curve cap {cap} exceeds the table budget {}",
            problem.budget
        );
        if problem.variants.is_empty() {
            return (ValueCurve::unsolvable(cap), SolveStats::default());
        }
        let m = problem.variants.len();
        let (order, caps, max_acc, _) = prepare(problem);
        // Suffix knapsacks over the not-yet-decided variants `order[d..]`
        // with ≤ k extra cores (a completion at depth d can only add cores
        // there): max addable capacity and max accuracy-weighted capacity.
        // O(m · cap · max_cores) once per solve, repaid many times over by
        // the per-cost pruning below.
        let mut addmax = vec![vec![0.0f64; cap + 1]; m + 1];
        let mut accmax = vec![vec![0.0f64; cap + 1]; m + 1];
        for d in (0..m).rev() {
            let i = order[d];
            let v = &problem.variants[i];
            let ci = caps[i].min(cap);
            for k in 0..=cap {
                let mut best_add = 0.0f64;
                let mut best_acc = 0.0f64;
                for n in 0..=ci.min(k) {
                    if !problem.slo_ok(i, n) {
                        continue;
                    }
                    let va = v.throughput[n] + addmax[d + 1][k - n];
                    let vw = v.accuracy * v.throughput[n] + accmax[d + 1][k - n];
                    if va > best_add {
                        best_add = va;
                    }
                    if vw > best_acc {
                        best_acc = vw;
                    }
                }
                addmax[d][k] = best_add;
                accmax[d][k] = best_acc;
            }
        }

        let mut acc = CurveAcc::new(cap);
        let mut seed_rescores = 0u64;
        if let Some(prev) = seed {
            for w in prev.winners().iter().flatten() {
                if w.len() != m {
                    continue;
                }
                let cost: usize = w.iter().sum();
                if cost > cap {
                    continue;
                }
                if let Some((objective, _feasible)) = super::score_fast(problem, w) {
                    acc.offer(cost, objective, w);
                    seed_rescores += 1;
                }
            }
        }
        let mut ctx = CurveCtx {
            problem,
            order,
            caps,
            max_acc,
            addmax,
            accmax,
            cap,
            acc,
            visited: 0,
            prunes: 0,
        };
        dfs_curve(&mut ctx, &mut vec![0usize; m], 0, cap, 0.0, 0.0, 0.0, 0.0);
        let stats = SolveStats {
            nodes_visited: ctx.visited,
            curve_prunes: ctx.prunes,
            seed_rescores,
        };
        (ctx.acc.finish(), stats)
    }

    /// Nodes the plain single-optimum solve visits (deterministic work
    /// proxy for perf tests and the `micro_hotpaths` bench).
    pub fn search_nodes(problem: &Problem) -> u64 {
        if problem.variants.is_empty() {
            return 0;
        }
        explore(problem).visited
    }

    /// Nodes the single-pass curve search visits, optionally warm-seeded.
    pub fn curve_search_nodes(problem: &Problem, cap: usize, seed: Option<&ValueCurve>) -> u64 {
        BranchBoundSolver.curve_search(problem, cap, seed).1.nodes_visited
    }
}

/// `filled`: λ already absorbable by decided variants (greedy order —
/// variants are decided in descending accuracy, which *is* the greedy fill
/// order); `acc_sum`: Σ quota·accuracy over that fill.
fn dfs(
    ctx: &mut Ctx,
    cores: &mut Vec<usize>,
    depth: usize,
    left: usize,
    filled: f64,
    acc_sum: f64,
) {
    ctx.visited += 1;
    if depth == ctx.order.len() {
        if let Some((objective, _)) = super::score_fast(ctx.problem, cores) {
            if ctx.best.as_ref().map_or(true, |(b, _)| objective > *b) {
                ctx.best = Some((objective, cores.clone()));
            }
        }
        return;
    }
    // Optimistic bound:
    //  * accuracy — the unabsorbed load can at best be served by the most
    //    accurate *remaining* variant (they are visited in descending
    //    accuracy, so that is order[depth]);
    //  * cost — at least the committed cores plus the cheapest completion
    //    that could close the capacity gap at the best per-core rate.
    // Loading cost, the infeasibility penalty, and the shed-pricing
    // charge are all nonpositive contributions omitted here, so the bound
    // stays an upper bound (admissible) on priced problems too — only
    // looser; the curve search below carries the tighter shed-aware form.
    let lambda = ctx.problem.lambda;
    let committed: usize = cores.iter().sum();
    let gap = (lambda - filled).max(0.0);
    let min_extra = ((gap / ctx.best_rate_per_core).ceil() as usize).min(left);
    let next_acc = ctx.problem.variants[ctx.order[depth]].accuracy;
    let opt_aa = if lambda > 0.0 {
        (acc_sum + gap * next_acc) / lambda
    } else {
        ctx.max_acc
    };
    let bound = ctx.problem.weights.alpha * opt_aa
        - ctx.problem.weights.beta * (committed + min_extra) as f64;
    if let Some((b, _)) = &ctx.best {
        if bound <= *b {
            return;
        }
    }
    let i = ctx.order[depth];
    let cap = ctx.caps[i].min(left);
    // Try larger allocations first: feasible (high-objective) solutions
    // appear sooner, tightening the bound.
    for n in (0..=cap).rev() {
        if !ctx.problem.slo_ok(i, n) {
            continue;
        }
        cores[i] = n;
        let q = (lambda - filled).max(0.0).min(ctx.problem.variants[i].throughput[n]);
        dfs(
            ctx,
            cores,
            depth + 1,
            left - n,
            filled + q,
            acc_sum + q * ctx.problem.variants[i].accuracy,
        );
    }
    cores[i] = 0;
}

struct CurveCtx<'a> {
    problem: &'a Problem,
    order: Vec<usize>,
    caps: Vec<usize>,
    max_acc: f64,
    /// `addmax[d][k]` = max capacity addable with ≤ k cores on `order[d..]`.
    addmax: Vec<Vec<f64>>,
    /// `accmax[d][k]` = max accuracy-weighted capacity with ≤ k cores.
    accmax: Vec<Vec<f64>>,
    cap: usize,
    acc: CurveAcc,
    visited: u64,
    /// Subtrees cut by the curve-aware bound (telemetry counter).
    prunes: u64,
}

/// Curve-aware DFS: same tree as [`dfs`], but every leaf is binned by its
/// exact cost and pruning tests the optimistic bound against the incumbent
/// curve at every reachable completion cost (see the module docs).
/// `lc_partial` is the loading cost already locked in by decided fresh
/// variants — a valid floor on any completion's LC.  `cap_committed` is
/// the raw capacity Σ th of decided variants (distinct from `filled`,
/// which is capped at λ): the shed-pricing bound needs it because the
/// priced shortfall is measured against the *offered* load, which
/// committed capacity beyond λ's absorption still reduces.
#[allow(clippy::too_many_arguments)]
fn dfs_curve(
    ctx: &mut CurveCtx,
    cores: &mut Vec<usize>,
    depth: usize,
    left: usize,
    filled: f64,
    acc_sum: f64,
    lc_partial: f64,
    cap_committed: f64,
) {
    ctx.visited += 1;
    let committed = ctx.cap - left;
    if depth == ctx.order.len() {
        if let Some((objective, _)) = super::score_fast(ctx.problem, cores) {
            ctx.acc.offer(committed, objective, cores);
        }
        return;
    }
    let lambda = ctx.problem.lambda;
    let gap = (lambda - filled).max(0.0);
    let next_acc = ctx.problem.variants[ctx.order[depth]].accuracy;
    let w = ctx.problem.weights;
    let w_shed = ctx.problem.shed_penalty;
    let offered = ctx.problem.offered_lambda;
    // Sweep candidate completion costs.  While `filled < λ` the decided
    // capacity is fully absorbed, so `gap` is exactly the remaining
    // capacity shortfall; `addmax` bounds how much k extra cores can close
    // and `accmax` bounds the accuracy weight they can add.  The 1e-9
    // slack mirrors the scorer's feasibility tolerance (a load covered up
    // to FP residue must not be charged the infeasibility penalty).
    let mut promising = false;
    for c in committed..=committed + left {
        let k = c - committed;
        let add = ctx.addmax[depth][k];
        let absorb = gap.min(add);
        let acc_add = ctx.accmax[depth][k].min(absorb * next_acc);
        let opt_aa = if lambda > 0.0 {
            (acc_sum + acc_add) / lambda
        } else {
            ctx.max_acc
        };
        let pen = if add >= gap - 1e-9 {
            0.0
        } else {
            1e3 + (gap - add)
        };
        // Optimistic (smallest admissible) shed charge: any completion
        // with k extra cores adds at most `add` capacity, so its priced
        // shortfall vs the offered load is at least this.
        let shed = if w_shed != 0.0 {
            w_shed * (offered - (cap_committed + add)).max(0.0)
        } else {
            0.0
        };
        let bound = w.alpha * opt_aa - w.beta * c as f64 - w.gamma * lc_partial - pen - shed;
        if bound > ctx.acc.incumbent_at(c) {
            promising = true;
            break;
        }
        // Past this cost the bound only falls (accuracy saturated at
        // gap·next_acc, infeasibility penalty gone, and — when pricing —
        // the offered load covered so the shed charge is pinned at 0,
        // the only term that could still have risen with k) while the
        // incumbent curve only rises — nothing further can flip the
        // decision.  The shed comparison is exact (no 1e-9 slack): the
        // scorer's shed term carries no feasibility tolerance, so an
        // early stop with an ε of shed still unpaid could out-prune it.
        if add >= gap - 1e-9
            && ctx.accmax[depth][k] >= gap * next_acc
            && (w_shed == 0.0 || cap_committed + add >= offered)
        {
            break;
        }
    }
    if !promising {
        ctx.prunes += 1;
        return;
    }
    let i = ctx.order[depth];
    let cap = ctx.caps[i].min(left);
    for n in (0..=cap).rev() {
        if !ctx.problem.slo_ok(i, n) {
            continue;
        }
        cores[i] = n;
        let th = ctx.problem.variants[i].throughput[n];
        let q = (lambda - filled).max(0.0).min(th);
        let lc_next = if n > 0 && ctx.problem.variants[i].current_cores == 0 {
            lc_partial.max(ctx.problem.variants[i].readiness_s)
        } else {
            lc_partial
        };
        let acc_gain = q * ctx.problem.variants[i].accuracy;
        dfs_curve(
            ctx,
            cores,
            depth + 1,
            left - n,
            filled + q,
            acc_sum + acc_gain,
            lc_next,
            cap_committed + th,
        );
    }
    cores[i] = 0;
}

#[cfg(test)]
mod tests {
    use super::super::tests::problem;
    use super::super::{value_curve_resolve, BruteForceSolver};
    use super::*;
    use crate::solver::Solver as _;

    #[test]
    fn agrees_with_brute_force_on_objective() {
        for (lambda, budget, beta) in [
            (75.0, 20, 0.05),
            (40.0, 14, 0.05),
            (75.0, 8, 0.2),
            (120.0, 24, 0.0125),
            (10.0, 4, 0.05),
            (0.0, 10, 0.05),
        ] {
            let p = problem(lambda, budget, beta);
            let bb = BranchBoundSolver.solve(&p).unwrap();
            let bf = BruteForceSolver.solve(&p).unwrap();
            assert!(
                (bb.objective - bf.objective).abs() < 1e-9,
                "λ={lambda} B={budget} β={beta}: bb={} bf={}",
                bb.objective,
                bf.objective
            );
        }
    }

    #[test]
    fn agrees_with_brute_force_on_batched_problems() {
        use super::super::tests::problem_batched;
        for (lambda, budget, max_batch) in [
            (75.0, 20, 4),
            (120.0, 14, 8),
            (250.0, 8, 8),
            (40.0, 10, 2),
        ] {
            let p = problem_batched(lambda, budget, 0.05, max_batch);
            let bb = BranchBoundSolver.solve(&p).unwrap();
            let bf = BruteForceSolver.solve(&p).unwrap();
            assert!(
                (bb.objective - bf.objective).abs() < 1e-9,
                "λ={lambda} B={budget} mb={max_batch}: bb={} bf={}",
                bb.objective,
                bf.objective
            );
        }
    }

    #[test]
    fn handles_large_budget_quickly() {
        let p = problem(400.0, 64, 0.05);
        let t0 = std::time::Instant::now();
        let alloc = BranchBoundSolver.solve(&p).unwrap();
        assert!(alloc.feasible);
        assert!(
            t0.elapsed().as_secs_f64() < 5.0,
            "took {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn curve_search_is_exact_at_the_acceptance_scale() {
        // The fig_fleet arbiter operating point: B=64, M=5.
        let p = problem(300.0, 64, 0.05);
        let reference = value_curve_resolve(&p, &BranchBoundSolver, 64);
        let curve = BranchBoundSolver.solve_curve(&p, 64);
        for (g, (a, b)) in curve.values().iter().zip(&reference).enumerate() {
            assert!((a - b).abs() < 1e-9, "g={g}: {a} vs {b}");
        }
    }

    #[test]
    fn single_pass_curve_is_an_order_of_magnitude_cheaper() {
        // Deterministic work proxy for the ≥10x wall-clock target at
        // B=64, M=5 (BENCH_solver.json carries the measured times): at
        // the large-budget stress point (λ=400, same as
        // `handles_large_budget_quickly`) the per-grant re-solve loop
        // explores ~10.4x the nodes of one curve-aware pass; assert with
        // headroom for FP-boundary drift in the table fits, and that a
        // warm-started steady-state pass prunes far further still.
        let p = problem(400.0, 64, 0.05);
        let loop_nodes: u64 = (0..=64)
            .map(|g| {
                let mut sub = p.clone();
                sub.budget = g;
                BranchBoundSolver::search_nodes(&sub)
            })
            .sum();
        let curve_nodes = BranchBoundSolver::curve_search_nodes(&p, 64, None);
        assert!(
            curve_nodes * 8 <= loop_nodes,
            "single pass {curve_nodes} nodes vs re-solve loop {loop_nodes}"
        );
        let seed = BranchBoundSolver.solve_curve(&p, 64);
        let warm_nodes = BranchBoundSolver::curve_search_nodes(&p, 64, Some(&seed));
        assert!(
            warm_nodes * 2 <= curve_nodes,
            "warm {warm_nodes} nodes should prune at least half of cold {curve_nodes}"
        );
    }

    #[test]
    fn priced_curve_search_stays_exact() {
        // Shed pricing adds a capacity-dependent term to every score; the
        // curve-aware pruning (optimistic shed in the bound, shed-pinned
        // sweep cutoff) must stay pointwise exact against the per-grant
        // re-solve loop, including at an offered rate *above* λ (the
        // dominance caps widen to cover it).
        for (lambda, offered, penalty, budget) in [
            (300.0, 272.0, 1.5, 24),
            (120.0, 119.0, 0.3, 16),
            (80.0, 180.0, 2.0, 12),
            (400.0, 360.0, 5.0, 20),
        ] {
            let p = problem(lambda, budget, 0.05).with_shed_pricing(offered, penalty);
            let reference = value_curve_resolve(&p, &BranchBoundSolver, budget);
            let curve = BranchBoundSolver.solve_curve(&p, budget);
            for (g, (a, b)) in curve.values().iter().zip(&reference).enumerate() {
                assert!(
                    (a - b).abs() < 1e-9,
                    "λ={lambda} offered={offered} pen={penalty} g={g}: {a} vs {b}"
                );
            }
            // brute force agrees on the full-budget optimum
            let bb = BranchBoundSolver.solve(&p).unwrap();
            let bf = BruteForceSolver.solve(&p).unwrap();
            assert!((bb.objective - bf.objective).abs() < 1e-9);
        }
    }

    #[test]
    fn stale_priced_seeds_never_corrupt_the_curve() {
        // Seeds solved under a *different* shed penalty are re-scored
        // under the current problem, so they can only prune, never drift
        // the values.
        let p = problem(250.0, 16, 0.05).with_shed_pricing(227.0, 2.0);
        let cold = BranchBoundSolver.solve_curve(&p, 16);
        for stale in [
            BranchBoundSolver.solve_curve(&problem(250.0, 16, 0.05), 16),
            BranchBoundSolver.solve_curve(
                &problem(40.0, 16, 0.2).with_shed_pricing(36.0, 0.1),
                16,
            ),
        ] {
            let warm = BranchBoundSolver.solve_curve_seeded(&p, 16, Some(&stale));
            assert_eq!(warm.values(), cold.values());
        }
    }

    #[test]
    fn stats_solve_returns_identical_curve_and_counts_work() {
        // `solve_curve_stats` must be the same algorithm as the plain
        // seeded solve — the counters observe work, never change it.
        let p = problem(300.0, 24, 0.05);
        let plain = BranchBoundSolver.solve_curve(&p, 24);
        let (curve, stats) = BranchBoundSolver.solve_curve_stats(&p, 24, None);
        assert_eq!(curve.values(), plain.values());
        assert!(stats.nodes_visited > 0);
        assert_eq!(stats.seed_rescores, 0);
        let (warm, wstats) = BranchBoundSolver.solve_curve_stats(&p, 24, Some(&plain));
        assert_eq!(warm.values(), curve.values());
        assert!(wstats.seed_rescores > 0, "seeded solve should re-score winners");
        assert!(wstats.nodes_visited <= stats.nodes_visited);
    }

    #[test]
    fn stale_seeds_never_corrupt_the_curve() {
        // Seed the λ=120 solve with curves from very different problems;
        // re-scoring makes any seed sound, so the values must match the
        // cold solve exactly.
        let p = problem(120.0, 24, 0.05);
        let cold = BranchBoundSolver.solve_curve(&p, 24);
        for stale in [
            BranchBoundSolver.solve_curve(&problem(10.0, 24, 0.05), 24),
            BranchBoundSolver.solve_curve(&problem(400.0, 64, 0.2), 40),
            ValueCurve::unsolvable(24),
        ] {
            let warm = BranchBoundSolver.solve_curve_seeded(&p, 24, Some(&stale));
            assert_eq!(warm.values(), cold.values());
        }
    }
}
