//! Exact brute-force enumeration over core vectors (the paper's solver).

use super::{score, Allocation, CurveAcc, Problem, Solver, ValueCurve};

/// Enumerates every weak composition of ≤ B cores over the variants, with
/// two prunings that keep exactness:
/// * per-variant cap at `useful_max_cores` — past the allocation whose
///   throughput already covers λ, more cores only add cost;
/// * SLO-infeasible per-variant allocations are skipped outright.
#[derive(Debug, Default, Clone, Copy)]
pub struct BruteForceSolver;

impl BruteForceSolver {
    /// Number of core vectors the enumeration will visit (diagnostics).
    pub fn search_space(problem: &Problem) -> u64 {
        fn count(problem: &Problem, i: usize, left: usize) -> u64 {
            if i == problem.variants.len() {
                return 1;
            }
            let cap = problem.useful_max_cores(i).min(left);
            (0..=cap)
                .filter(|&n| problem.slo_ok(i, n))
                .map(|n| count(problem, i + 1, left - n))
                .sum()
        }
        count(problem, 0, problem.budget)
    }
}

impl Solver for BruteForceSolver {
    fn name(&self) -> &'static str {
        "brute_force"
    }

    fn solve(&self, problem: &Problem) -> Option<Allocation> {
        if problem.variants.is_empty() {
            return None;
        }
        let m = problem.variants.len();
        let caps: Vec<usize> = (0..m).map(|i| problem.useful_max_cores(i)).collect();
        let mut cores = vec![0usize; m];
        // Search with the allocation-free scorer; materialize only the
        // winner (EXPERIMENTS.md §Perf: ~40x over scoring via Allocation).
        let mut best: Option<(f64, Vec<usize>)> = None;

        fn recurse(
            problem: &Problem,
            caps: &[usize],
            cores: &mut Vec<usize>,
            i: usize,
            left: usize,
            best: &mut Option<(f64, Vec<usize>)>,
        ) {
            if i == cores.len() {
                if let Some((objective, _feasible)) = super::score_fast(problem, cores) {
                    if best.as_ref().map_or(true, |(b, _)| objective > *b) {
                        *best = Some((objective, cores.clone()));
                    }
                }
                return;
            }
            let cap = caps[i].min(left);
            for n in 0..=cap {
                if !problem.slo_ok(i, n) {
                    continue;
                }
                cores[i] = n;
                recurse(problem, caps, cores, i + 1, left - n, best);
            }
            cores[i] = 0;
        }

        recurse(problem, &caps, &mut cores, 0, problem.budget, &mut best);
        best.and_then(|(_, cores)| score(problem, &cores))
    }

    /// Curve-native: the existing enumeration already visits every
    /// undominated core vector of cost ≤ `cap`; recording the best
    /// objective *per exact cost* during that one pass (instead of the
    /// single global best) and prefix-maxing the bins yields `v(g)` for
    /// every grant — `cap + 1` solves collapse into one enumeration.
    /// Shed pricing rides along for free: the leaf scorer charges it and
    /// the dominance caps already widen to the offered load when priced.
    fn solve_curve(&self, problem: &Problem, cap: usize) -> ValueCurve {
        debug_assert!(
            cap <= problem.budget,
            "curve cap {cap} exceeds the table budget {}",
            problem.budget
        );
        if problem.variants.is_empty() {
            return ValueCurve::unsolvable(cap);
        }
        let m = problem.variants.len();
        let caps: Vec<usize> = (0..m).map(|i| problem.useful_max_cores(i)).collect();
        let mut cores = vec![0usize; m];
        let mut acc = CurveAcc::new(cap);

        fn recurse(
            problem: &Problem,
            caps: &[usize],
            cores: &mut Vec<usize>,
            i: usize,
            left: usize,
            spent: usize,
            acc: &mut CurveAcc,
        ) {
            if i == cores.len() {
                if let Some((objective, _feasible)) = super::score_fast(problem, cores) {
                    acc.offer(spent, objective, cores);
                }
                return;
            }
            let cap = caps[i].min(left);
            for n in 0..=cap {
                if !problem.slo_ok(i, n) {
                    continue;
                }
                cores[i] = n;
                recurse(problem, caps, cores, i + 1, left - n, spent + n, acc);
            }
            cores[i] = 0;
        }

        recurse(problem, &caps, &mut cores, 0, cap, 0, &mut acc);
        acc.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::problem;
    use super::*;

    #[test]
    fn finds_feasible_allocation_under_budget() {
        let p = problem(75.0, 20, 0.05);
        let alloc = BruteForceSolver.solve(&p).unwrap();
        assert!(alloc.feasible, "{alloc:?}");
        assert!(alloc.total_cores() <= 20);
        assert!(alloc.capacity >= 75.0);
    }

    #[test]
    fn prefers_accurate_mix_when_cost_weight_is_low() {
        let lo = BruteForceSolver.solve(&problem(75.0, 20, 0.0125)).unwrap();
        let hi = BruteForceSolver.solve(&problem(75.0, 20, 0.2)).unwrap();
        assert!(
            lo.average_accuracy >= hi.average_accuracy,
            "lo {} hi {}",
            lo.average_accuracy,
            hi.average_accuracy
        );
        assert!(lo.resource_cost >= hi.resource_cost);
    }

    #[test]
    fn tight_budget_still_serves_with_cheap_variant() {
        // 4 cores can't host resnet152 capacity for 75 rps; brute force must
        // fall back to cheaper variants and stay feasible.
        let p = problem(75.0, 4, 0.05);
        let alloc = BruteForceSolver.solve(&p).unwrap();
        assert!(alloc.feasible, "{alloc:?}");
        assert!(alloc.cores_of("resnet18") > 0 || alloc.cores_of("resnet34") > 0);
    }

    #[test]
    fn overload_returns_least_bad_allocation() {
        // λ far beyond any capacity at the budget: still returns something,
        // flagged infeasible, using all cores on the highest-throughput mix.
        let p = problem(10_000.0, 8, 0.05);
        let alloc = BruteForceSolver.solve(&p).unwrap();
        assert!(!alloc.feasible);
        assert_eq!(alloc.total_cores(), 8);
    }

    #[test]
    fn batching_extends_feasible_load_at_equal_budget() {
        // λ=250 at B=8 exceeds every unbatched capacity (resnet18 peaks at
        // ~184 rps); with batch amortization the same 8 cores cover it.
        let unb = super::super::tests::problem(250.0, 8, 0.05);
        let bat = super::super::tests::problem_batched(250.0, 8, 0.05, 8);
        let a_unb = BruteForceSolver.solve(&unb).unwrap();
        let a_bat = BruteForceSolver.solve(&bat).unwrap();
        assert!(!a_unb.feasible, "{a_unb:?}");
        assert!(a_bat.feasible, "{a_bat:?}");
        assert!(a_bat.total_cores() <= 8);
        assert!(a_bat.batches.values().any(|&b| b > 1));
    }

    #[test]
    fn batching_never_hurts_the_objective() {
        for (lambda, budget) in [(40.0, 14), (75.0, 20), (120.0, 10), (200.0, 24)] {
            let unb = super::super::tests::problem(lambda, budget, 0.05);
            let bat = super::super::tests::problem_batched(lambda, budget, 0.05, 8);
            let a = BruteForceSolver.solve(&unb).unwrap();
            let b = BruteForceSolver.solve(&bat).unwrap();
            assert!(
                b.objective >= a.objective - 1e-9,
                "λ={lambda} B={budget}: batched {} < unbatched {}",
                b.objective,
                a.objective
            );
        }
    }

    #[test]
    fn search_space_is_pruned() {
        let p = problem(75.0, 20, 0.05);
        let space = BruteForceSolver::search_space(&p);
        // unpruned C(25,5) = 53130; pruning must cut it
        assert!(space < 53_130, "space {space}");
        assert!(space > 100);
    }

    #[test]
    fn zero_lambda_prefers_empty_or_minimal() {
        let p = problem(0.0, 20, 0.05);
        let alloc = BruteForceSolver.solve(&p).unwrap();
        assert!(alloc.feasible);
        // with no load, cost dominates: nothing (or almost nothing) allocated
        assert!(alloc.total_cores() <= 1);
    }
}
