//! Model-Switching+ (the paper's enhanced MS baseline).
//!
//! Original Model-Switching (Zhang et al., HotCloud'20) switches between
//! variants on a *fixed* resource budget.  The paper's MS+ adds predictive
//! allocation: at each step a *single* variant and its core count are
//! chosen by maximizing the same objective as InfAdapter (Eq. 1) restricted
//! to singleton variant sets — making MS+ the exact "one variant at a time"
//! ablation of InfAdapter.

use crate::config::ObjectiveWeights;
use crate::forecaster::Forecaster;
use crate::profiler::ProfileSet;
use crate::serving::{Decision, Policy};
use crate::solver::{score, Problem};
use std::collections::BTreeMap;

pub struct MsPlusPolicy {
    profiles: ProfileSet,
    forecaster: Box<dyn Forecaster>,
    weights: ObjectiveWeights,
    slo_s: f64,
    budget: usize,
    headroom: f64,
}

impl MsPlusPolicy {
    pub fn new(
        profiles: ProfileSet,
        forecaster: Box<dyn Forecaster>,
        weights: ObjectiveWeights,
        slo_s: f64,
        budget: usize,
        headroom: f64,
    ) -> Self {
        Self {
            profiles,
            forecaster,
            weights,
            slo_s,
            budget,
            headroom,
        }
    }
}

impl Policy for MsPlusPolicy {
    fn name(&self) -> String {
        "ms+".to_string()
    }

    fn decide(
        &mut self,
        _now: f64,
        rate_history: &[f64],
        committed: &BTreeMap<String, usize>,
    ) -> Decision {
        for &r in rate_history {
            self.forecaster.observe(r);
        }
        let lambda_hat = (self.forecaster.predict_max() * self.headroom).max(1.0);
        let problem = Problem::from_profiles(
            &self.profiles,
            lambda_hat,
            self.slo_s,
            self.budget,
            self.weights,
            committed,
        );
        // Enumerate singleton allocations: one variant, 1..=B cores.
        let m = problem.variants.len();
        let mut best: Option<(usize, usize, f64)> = None; // (variant, cores, objective)
        for i in 0..m {
            for n in 1..=problem.budget {
                let mut cores = vec![0usize; m];
                cores[i] = n;
                if let Some(alloc) = score(&problem, &cores) {
                    if best.map_or(true, |(_, _, obj)| alloc.objective > obj) {
                        best = Some((i, n, alloc.objective));
                    }
                }
            }
        }
        match best {
            Some((i, n, _)) => {
                let name = problem.variants[i].name.clone();
                // MS+ serves unbatched (batch 1), like the paper's baseline.
                Decision {
                    target: BTreeMap::from([(name.clone(), n)]),
                    quotas: vec![(name, 1.0)],
                    batches: BTreeMap::new(),
                    predicted_lambda: lambda_hat,
                    supply_rps: problem.variants[i].throughput[n],
                }
            }
            None => Decision {
                target: BTreeMap::new(),
                quotas: vec![],
                batches: BTreeMap::new(),
                predicted_lambda: lambda_hat,
                supply_rps: 0.0,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::forecaster::LastMaxForecaster;

    fn ms(budget: usize) -> MsPlusPolicy {
        MsPlusPolicy::new(
            ProfileSet::paper_like(),
            Box::new(LastMaxForecaster::new(120, 1.0)),
            ObjectiveWeights::default(),
            0.75,
            budget,
            1.1,
        )
    }

    #[test]
    fn selects_exactly_one_variant() {
        let mut p = ms(20);
        let d = p.decide(0.0, &vec![75.0; 60], &BTreeMap::new());
        assert_eq!(d.target.len(), 1);
        assert_eq!(d.quotas.len(), 1);
    }

    #[test]
    fn covers_load_when_budget_allows() {
        let mut p = ms(20);
        let d = p.decide(0.0, &vec![75.0; 60], &BTreeMap::new());
        let (variant, cores) = d.target.iter().next().unwrap();
        let profiles = ProfileSet::paper_like();
        assert!(
            profiles.get(variant).unwrap().throughput(*cores) >= d.predicted_lambda - 1e-9,
            "{variant} x{cores} can't cover {}",
            d.predicted_lambda
        );
    }

    #[test]
    fn downgrades_variant_under_tight_budget() {
        // At B=8 and 75 rps the most accurate variants can't keep up: MS+
        // must pick a cheaper variant (the paper's Figure 2 observation).
        let mut tight = ms(8);
        let d = tight.decide(0.0, &vec![75.0; 60], &BTreeMap::new());
        let (variant, _) = d.target.iter().next().unwrap();
        assert_ne!(variant, "resnet152");
        assert_ne!(variant, "resnet101");
    }
}
