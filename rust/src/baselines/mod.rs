//! Comparator policies from the paper's evaluation.
//!
//! * [`StaticPolicy`] — fixed variant + fixed cores (sanity baseline).
//! * [`VpaPolicy`] — the paper's "VPA+": Kubernetes Vertical Pod Autoscaler
//!   extended with create-before-remove and no lower-bound clamp, pinned to
//!   one variant (the paper runs VPA-18 / VPA-50 / VPA-152).
//! * [`MsPlusPolicy`] — Model-Switching+ (paper §5): exactly one active
//!   variant, chosen with the *same* objective as InfAdapter but restricted
//!   to singleton sets, with predictive allocation.

mod ms;
mod vpa;

pub use ms::MsPlusPolicy;
pub use vpa::VpaPolicy;

use crate::serving::{Decision, Policy};
use std::collections::BTreeMap;

/// Fixed variant and allocation; never adapts.  Optionally pins a
/// server-side batch size (the batching ablation baseline).
pub struct StaticPolicy {
    variant: String,
    cores: usize,
    batch: usize,
}

impl StaticPolicy {
    pub fn new(variant: &str, cores: usize) -> Self {
        Self::with_batch(variant, cores, 1)
    }

    /// Fixed allocation serving with batches of `batch` items.
    pub fn with_batch(variant: &str, cores: usize, batch: usize) -> Self {
        Self {
            variant: variant.to_string(),
            cores,
            batch: batch.max(1),
        }
    }
}

impl Policy for StaticPolicy {
    fn name(&self) -> String {
        if self.batch > 1 {
            format!("static-{}x{}b{}", self.variant, self.cores, self.batch)
        } else {
            format!("static-{}x{}", self.variant, self.cores)
        }
    }

    fn decide(
        &mut self,
        _now: f64,
        rate_history: &[f64],
        _committed: &BTreeMap<String, usize>,
    ) -> Decision {
        let observed = rate_history.iter().cloned().fold(0.0, f64::max);
        Decision {
            target: BTreeMap::from([(self.variant.clone(), self.cores)]),
            quotas: vec![(self.variant.clone(), 1.0)],
            batches: BTreeMap::from([(self.variant.clone(), self.batch)]),
            predicted_lambda: observed,
            supply_rps: 0.0, // static policy: no throughput model
        }
    }
}
