//! VPA+ — the paper's extended Kubernetes Vertical Pod Autoscaler.
//!
//! The built-in VPA recommends per-container CPU from a decaying histogram
//! of recent usage (Autopilot-style percentile targeting).  The paper's two
//! fixes, both reproduced here: (1) create-before-remove (the cluster
//! substrate implements it for every policy); (2) no lower-bound clamp so
//! scale-up is immediate.  VPA is *variant-blind*: it is always paired with
//! one fixed model (VPA-18 / VPA-50 / VPA-152 in the figures).

use crate::profiler::ProfileSet;
use crate::serving::{Decision, Policy};
use std::collections::BTreeMap;
use std::collections::VecDeque;

pub struct VpaPolicy {
    variant: String,
    profiles: ProfileSet,
    /// Target percentile of recent per-second usage (VPA default: P90).
    percentile: f64,
    /// Safety margin on the recommendation (VPA default: 15%).
    margin: f64,
    budget: usize,
    window: VecDeque<f64>,
    window_cap: usize,
}

impl VpaPolicy {
    pub fn new(variant: &str, profiles: ProfileSet, budget: usize) -> Self {
        Self {
            variant: variant.to_string(),
            profiles,
            percentile: 0.90,
            margin: 1.15,
            budget,
            window: VecDeque::new(),
            window_cap: 300,
        }
    }

    fn recommend_cores(&self) -> usize {
        if self.window.is_empty() {
            return 1;
        }
        let mut rates: Vec<f64> = self.window.iter().cloned().collect();
        rates.sort_by(f64::total_cmp);
        let rank =
            ((self.percentile * rates.len() as f64).ceil() as usize).clamp(1, rates.len());
        let demand_rps = rates[rank - 1] * self.margin;
        // invert the linear throughput model: cores s.t. th(n) >= demand
        let p = self.profiles.get(&self.variant).expect("known variant");
        let mut n = 1;
        while n < self.budget && p.throughput(n) < demand_rps {
            n += 1;
        }
        n
    }
}

impl Policy for VpaPolicy {
    fn name(&self) -> String {
        format!("vpa-{}", self.variant)
    }

    fn decide(
        &mut self,
        _now: f64,
        rate_history: &[f64],
        _committed: &BTreeMap<String, usize>,
    ) -> Decision {
        for &r in rate_history {
            if self.window.len() == self.window_cap {
                self.window.pop_front();
            }
            self.window.push_back(r);
        }
        let cores = self.recommend_cores();
        Decision {
            target: BTreeMap::from([(self.variant.clone(), cores)]),
            quotas: vec![(self.variant.clone(), 1.0)],
            batches: BTreeMap::new(),
            predicted_lambda: self
                .window
                .iter()
                .rev()
                .take(60)
                .cloned()
                .fold(0.0, f64::max),
            supply_rps: self
                .profiles
                .get(&self.variant)
                .map(|p| p.throughput(cores))
                .unwrap_or(0.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vpa() -> VpaPolicy {
        VpaPolicy::new("resnet50", ProfileSet::paper_like(), 32)
    }

    #[test]
    fn recommends_enough_cores_for_p90_demand() {
        let mut v = vpa();
        let d = v.decide(0.0, &vec![60.0; 120], &BTreeMap::new());
        let cores = d.target["resnet50"];
        let p = ProfileSet::paper_like();
        // must cover 60 rps * 1.15 margin
        assert!(
            p.get("resnet50").unwrap().throughput(cores) >= 60.0 * 1.15,
            "cores {cores}"
        );
    }

    #[test]
    fn scales_down_when_load_drops() {
        let mut v = vpa();
        let d_high = v.decide(0.0, &vec![80.0; 300], &BTreeMap::new());
        let d_low = v.decide(30.0, &vec![5.0; 300], &BTreeMap::new());
        assert!(d_low.target["resnet50"] < d_high.target["resnet50"]);
    }

    #[test]
    fn is_variant_blind() {
        let mut v = vpa();
        let d = v.decide(0.0, &vec![40.0; 60], &BTreeMap::new());
        assert_eq!(d.target.len(), 1);
        assert!(d.target.contains_key("resnet50"));
    }

    #[test]
    fn cold_start_recommends_one_core() {
        let mut v = vpa();
        let d = v.decide(0.0, &[], &BTreeMap::new());
        assert_eq!(d.target["resnet50"], 1);
    }
}
