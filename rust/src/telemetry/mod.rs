//! Fleet telemetry plane: counters, tick-stage profiling, and an
//! anomaly-triggered flight recorder — a **pure observer** of the fleet
//! data plane.
//!
//! The control loop this repo reproduces *reacts to what it observes*
//! (λ̂ forecasts, SLO burn, shed pressure), yet until this module the
//! engine could only report end-of-run summaries — nobody could see *why*
//! the arbiter moved cores at tick T or where a five-stage tick spends
//! its time.  The telemetry plane answers that without ever becoming part
//! of the loop:
//!
//! * [`Registry`] — named counters, gauges, and log-bucketed histograms
//!   ([`LogHistogram`]), exported as Prometheus text exposition
//!   ([`Registry::to_prometheus`], round-trippable through
//!   [`parse_exposition`]) and as a JSON snapshot ([`Registry::to_json`]).
//! * [`ShardTelemetry`] — per-[`ServiceShard`] counters recorded lock-free
//!   by whichever worker thread runs the shard (each shard's telemetry is
//!   its own disjoint state, exactly like the rest of the shard), fanned
//!   in by [`ShardTelemetry::merge`] strictly in service-index order.
//! * [`StageProfiler`] — wall-clock nanoseconds of each five-stage tick
//!   phase (observe → solve → arbitrate → apply → advance), plus a
//!   synthetic `dispatch` lap that carves worker-pool fan-out overhead
//!   out of the parallel stages.
//! * [`FlightRecorder`] — ring buffer of the last K [`TickTrace`] records
//!   (λ̂, offered load, grants, curve knees, decisions, gate supply) that
//!   marks a trip when the SLO-burn meter crosses 1 or the per-tick shed
//!   fraction exceeds a threshold, and dumps the window to JSON — the
//!   "why did it do that" artifact and the future record/replay substrate.
//!
//! **Determinism invariant.**  Telemetry on vs off is bit-identical in
//! every decision, event sequence, and summary field: every recorder is
//! guarded by the enabled flag, counters only *count* work the data plane
//! already does, and timing is observed but never consulted.  No branch
//! on the decision path reads a telemetry value.  Pinned by
//! `telemetry_on_is_bit_identical_to_off` in `tests/regression_pins.rs`
//! at `solver_threads ∈ {1, 8}`.
//!
//! [`ServiceShard`]: crate::fleet::ServiceShard

use crate::config::TelemetryConfig;
use crate::dispatcher::{NoRoute, Tier};
use crate::fleet::CurveCacheStats;
use crate::solver::SolveStats;
use crate::util::json::Value;
use std::collections::{BTreeMap, VecDeque};

/// The five tick stages in protocol order, plus the synthetic `dispatch`
/// lap that isolates worker-pool fan-out overhead from the parallel
/// stages it serves (indices into stage arrays).
pub const STAGES: [&str; 6] = ["observe", "solve", "arbitrate", "apply", "advance", "dispatch"];

/// Index of a stage name in [`STAGES`].
pub const STAGE_OBSERVE: usize = 0;
pub const STAGE_SOLVE: usize = 1;
pub const STAGE_ARBITRATE: usize = 2;
pub const STAGE_APPLY: usize = 3;
pub const STAGE_ADVANCE: usize = 4;
/// Thread-machinery overhead (pool wake + fan-in wait) carved out of the
/// parallel stages so `solve`/`apply`/`advance` histograms measure solver
/// and simulation work, not dispatch cost.
pub const STAGE_DISPATCH: usize = 5;

/// Power-of-two-bucketed histogram of `u64` samples (nanoseconds, counts,
/// …).  Bucket `b` holds values `v` with `2^(b-1) <= v < 2^b` (bucket 0
/// holds only zero), so 65 buckets cover the whole domain with no
/// configuration.  Merging is plain bucket-wise addition — deterministic
/// regardless of record order.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LogHistogram {
    counts: Vec<u64>,
    count: u64,
    sum: u64,
    max: u64,
}

fn bucket_of(v: u64) -> usize {
    (64 - v.leading_zeros()) as usize
}

impl LogHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, v: u64) {
        let b = bucket_of(v);
        if self.counts.len() <= b {
            self.counts.resize(b + 1, 0);
        }
        self.counts[b] += 1;
        self.count += 1;
        self.sum += v;
        self.max = self.max.max(v);
    }

    pub fn merge(&mut self, other: &LogHistogram) {
        if self.counts.len() < other.counts.len() {
            self.counts.resize(other.counts.len(), 0);
        }
        for (b, &c) in other.counts.iter().enumerate() {
            self.counts[b] += c;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> u64 {
        self.sum
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// `(inclusive upper bound, cumulative count)` per occupied bucket —
    /// the Prometheus `_bucket{le=...}` series.
    pub fn cumulative(&self) -> Vec<(u128, u64)> {
        let mut acc = 0u64;
        self.counts
            .iter()
            .enumerate()
            .map(|(b, &c)| {
                acc += c;
                ((1u128 << b) - 1, acc)
            })
            .collect()
    }
}

/// Named counters, gauges, and histograms with deterministic (sorted)
/// iteration order.  The fleet engine builds one per snapshot from the
/// merged telemetry state; nothing on the decision path ever reads it.
#[derive(Debug, Clone, Default)]
pub struct Registry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, LogHistogram>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter_add(&mut self, name: &str, v: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += v;
    }

    pub fn gauge_set(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    pub fn hist_record(&mut self, name: &str, v: u64) {
        self.histograms.entry(name.to_string()).or_default().record(v);
    }

    /// Install a pre-accumulated histogram under `name` (merged into any
    /// existing one).
    pub fn hist_merge(&mut self, name: &str, h: &LogHistogram) {
        self.histograms.entry(name.to_string()).or_default().merge(h);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    pub fn histogram(&self, name: &str) -> Option<&LogHistogram> {
        self.histograms.get(name)
    }

    /// Fold another registry in (sums counters, overwrites gauges, merges
    /// histograms).  Deterministic: BTreeMap order, plain addition.
    pub fn merge(&mut self, other: &Registry) {
        for (k, &v) in &other.counters {
            self.counter_add(k, v);
        }
        for (k, &v) in &other.gauges {
            self.gauges.insert(k.clone(), v);
        }
        for (k, h) in &other.histograms {
            self.hist_merge(k, h);
        }
    }

    /// Prometheus text exposition (format 0.0.4): `# TYPE` headers, one
    /// sample per line, histograms as cumulative `_bucket{le=...}` series
    /// plus `_sum` / `_count`.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.counters {
            out.push_str(&format!("# TYPE {k} counter\n{k} {v}\n"));
        }
        for (k, v) in &self.gauges {
            out.push_str(&format!("# TYPE {k} gauge\n{k} {v}\n"));
        }
        for (k, h) in &self.histograms {
            out.push_str(&format!("# TYPE {k} histogram\n"));
            for (le, c) in h.cumulative() {
                out.push_str(&format!("{k}_bucket{{le=\"{le}\"}} {c}\n"));
            }
            out.push_str(&format!("{k}_bucket{{le=\"+Inf\"}} {}\n", h.count()));
            out.push_str(&format!("{k}_sum {}\n", h.sum()));
            out.push_str(&format!("{k}_count {}\n", h.count()));
        }
        out
    }

    pub fn to_json(&self) -> Value {
        let counters = Value::Obj(
            self.counters
                .iter()
                .map(|(k, &v)| (k.clone(), Value::Num(v as f64)))
                .collect(),
        );
        let gauges = Value::Obj(
            self.gauges
                .iter()
                .map(|(k, &v)| (k.clone(), Value::Num(v)))
                .collect(),
        );
        let hists = Value::Obj(
            self.histograms
                .iter()
                .map(|(k, h)| {
                    (
                        k.clone(),
                        Value::obj(vec![
                            ("count", Value::Num(h.count() as f64)),
                            ("sum", Value::Num(h.sum() as f64)),
                            ("max", Value::Num(h.max() as f64)),
                            ("mean", Value::Num(h.mean())),
                        ]),
                    )
                })
                .collect(),
        );
        Value::obj(vec![
            ("counters", counters),
            ("gauges", gauges),
            ("histograms", hists),
        ])
    }
}

/// Parse a Prometheus text exposition back into `sample name -> value`
/// (labels kept verbatim as part of the name).  Used by the round-trip
/// test and by the CI smoke job to validate the exported artifact.
pub fn parse_exposition(text: &str) -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some((name, value)) = line.rsplit_once(' ') {
            if let Ok(v) = value.parse::<f64>() {
                out.insert(name.to_string(), v);
            }
        }
    }
    out
}

/// Index of the smallest grant whose curve value is within 1e-9 of the
/// curve's maximum — where the marginal value of more cores vanishes.
/// Diagnostics only (flight-recorder traces); the arbiter never reads it.
pub fn curve_knee(curve: &[f64]) -> usize {
    let max = curve.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    curve
        .iter()
        .position(|&v| v >= max - 1e-9)
        .unwrap_or(0)
}

/// Per-shard telemetry: request-path counters and worker-thread solve /
/// decide spans.  Owned by each [`crate::fleet::ServiceShard`] exactly
/// like the rest of its state — parallel stages record lock-free into
/// their own shard's instance, and the engine fans in with [`Self::merge`]
/// strictly in service-index order, so worker scheduling cannot reach the
/// merged values.  Every recorder early-returns when disabled.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ShardTelemetry {
    pub enabled: bool,
    /// Requests past the gate, indexed by tier.
    pub admit_by_tier: Vec<u64>,
    /// Requests refused at the gate, indexed by tier.
    pub shed_by_tier: Vec<u64>,
    /// Admitted requests the router had no weight table for.
    pub noroute_unconfigured: u64,
    /// Admitted requests whose weight table granted no capacity.
    pub noroute_nocapacity: u64,
    /// Σ batch-size targets over dispatched batches (capacity offered).
    pub batch_slots: u64,
    /// Σ members over dispatched batches (capacity used).
    pub batch_filled: u64,
    /// Per-worker wall-clock of this shard's curve solves, ns.
    pub solve_ns: LogHistogram,
    /// Per-worker wall-clock of this shard's decide calls, ns.
    pub decide_ns: LogHistogram,
    /// Knee of the most recent value curve (flight-trace scratch).
    pub last_curve_knee: usize,
    /// Ready pods killed by the fault plane.
    pub pod_crashes: u64,
    /// Σ cores of the crashed pods (capacity lost to the fault plane).
    pub crashed_cores: u64,
    /// Backends ejected from the smooth-WRR rotation by the health check.
    pub ejections: u64,
    /// Retry attempts scheduled for failure-stranded requests.
    pub retries: u64,
    /// Queued batches hedged away from a straggling pod.
    pub hedged_batches: u64,
    /// Requests that terminally failed (crash casualties past their retry
    /// or SLO budget).
    pub failed_requests: u64,
    /// Adapter ticks that reused the last-good decision on a solver stall.
    pub fallback_solves: u64,
}

fn bump_tier(v: &mut Vec<u64>, tier: Tier) {
    let i = tier as usize;
    if v.len() <= i {
        v.resize(i + 1, 0);
    }
    v[i] += 1;
}

impl ShardTelemetry {
    pub fn new(enabled: bool) -> Self {
        Self {
            enabled,
            ..Self::default()
        }
    }

    #[inline]
    pub fn record_admit(&mut self, tier: Tier) {
        if !self.enabled {
            return;
        }
        bump_tier(&mut self.admit_by_tier, tier);
    }

    #[inline]
    pub fn record_shed(&mut self, tier: Tier) {
        if !self.enabled {
            return;
        }
        bump_tier(&mut self.shed_by_tier, tier);
    }

    #[inline]
    pub fn record_noroute(&mut self, reason: NoRoute) {
        if !self.enabled {
            return;
        }
        match reason {
            NoRoute::Unconfigured => self.noroute_unconfigured += 1,
            NoRoute::NoCapacity => self.noroute_nocapacity += 1,
        }
    }

    #[inline]
    pub fn record_batch(&mut self, slots: usize, filled: usize) {
        if !self.enabled {
            return;
        }
        self.batch_slots += slots as u64;
        self.batch_filled += filled as u64;
    }

    #[inline]
    pub fn record_solve_ns(&mut self, ns: u64) {
        if !self.enabled {
            return;
        }
        self.solve_ns.record(ns);
    }

    #[inline]
    pub fn record_decide_ns(&mut self, ns: u64) {
        if !self.enabled {
            return;
        }
        self.decide_ns.record(ns);
    }

    #[inline]
    pub fn record_crash(&mut self, cores: usize) {
        if !self.enabled {
            return;
        }
        self.pod_crashes += 1;
        self.crashed_cores += cores as u64;
    }

    #[inline]
    pub fn record_ejection(&mut self) {
        if !self.enabled {
            return;
        }
        self.ejections += 1;
    }

    #[inline]
    pub fn record_retry(&mut self) {
        if !self.enabled {
            return;
        }
        self.retries += 1;
    }

    #[inline]
    pub fn record_hedge(&mut self) {
        if !self.enabled {
            return;
        }
        self.hedged_batches += 1;
    }

    #[inline]
    pub fn record_failed(&mut self) {
        if !self.enabled {
            return;
        }
        self.failed_requests += 1;
    }

    #[inline]
    pub fn record_fallback(&mut self) {
        if !self.enabled {
            return;
        }
        self.fallback_solves += 1;
    }

    pub fn admitted(&self) -> u64 {
        self.admit_by_tier.iter().sum()
    }

    pub fn shed(&self) -> u64 {
        self.shed_by_tier.iter().sum()
    }

    /// Mean fraction of dispatched batch slots actually filled (1.0 when
    /// batching never ran).
    pub fn batch_fill_ratio(&self) -> f64 {
        if self.batch_slots == 0 {
            1.0
        } else {
            self.batch_filled as f64 / self.batch_slots as f64
        }
    }

    /// Fan-in: fold another shard's counters in (called in service-index
    /// order; plain sums, so the merge is order-deterministic anyway).
    pub fn merge(&mut self, other: &ShardTelemetry) {
        self.enabled |= other.enabled;
        for (i, &v) in other.admit_by_tier.iter().enumerate() {
            if self.admit_by_tier.len() <= i {
                self.admit_by_tier.resize(i + 1, 0);
            }
            self.admit_by_tier[i] += v;
        }
        for (i, &v) in other.shed_by_tier.iter().enumerate() {
            if self.shed_by_tier.len() <= i {
                self.shed_by_tier.resize(i + 1, 0);
            }
            self.shed_by_tier[i] += v;
        }
        self.noroute_unconfigured += other.noroute_unconfigured;
        self.noroute_nocapacity += other.noroute_nocapacity;
        self.batch_slots += other.batch_slots;
        self.batch_filled += other.batch_filled;
        self.solve_ns.merge(&other.solve_ns);
        self.decide_ns.merge(&other.decide_ns);
        self.pod_crashes += other.pod_crashes;
        self.crashed_cores += other.crashed_cores;
        self.ejections += other.ejections;
        self.retries += other.retries;
        self.hedged_batches += other.hedged_batches;
        self.failed_requests += other.failed_requests;
        self.fallback_solves += other.fallback_solves;
    }
}

/// Wall-clock profile of the five tick stages plus the dispatch lap,
/// accumulated per adapter tick.  Timing is observed, never consulted —
/// the histograms exist only for export.
#[derive(Debug, Clone, Default)]
pub struct StageProfiler {
    hists: [LogHistogram; 6],
    /// The most recent tick's per-stage spans, ns (flight-trace scratch).
    pub last_ns: [u64; 6],
}

impl StageProfiler {
    pub fn record(&mut self, stage: usize, ns: u64) {
        self.hists[stage].record(ns);
        self.last_ns[stage] = ns;
    }

    pub fn hist(&self, stage: usize) -> &LogHistogram {
        &self.hists[stage]
    }

    pub fn mean_ns(&self) -> [u64; 6] {
        let mut out = [0u64; 6];
        for (o, h) in out.iter_mut().zip(&self.hists) {
            *o = h.mean().round() as u64;
        }
        out
    }
}

/// One service's row of a [`TickTrace`]: what it saw, what it asked for,
/// and what it was granted at one adapter boundary.
#[derive(Debug, Clone)]
pub struct ServiceTick {
    pub name: String,
    /// Forecast the curve was solved for.
    pub lambda_hat: f64,
    /// Raw offered rate the shed pricing was computed against.
    pub offered: f64,
    /// Arbiter core grant (`None`: service outside arbitration).
    pub grant: Option<usize>,
    /// Knee of the service's value curve (smallest grant at max value).
    pub curve_knee: usize,
    /// Σ cores of the decision's target allocation.
    pub target_cores: usize,
    /// Admission-gate supply at the boundary, rps.
    pub supply_rps: f64,
    /// Gate tier cutoff in force.
    pub gate_cutoff: Tier,
    /// Rolling SLO-burn rate (> 1 = burning).
    pub burn: f64,
    /// Cumulative curve-cache outcomes for this service.
    pub cache: CurveCacheStats,
    /// Cumulative solver introspection for this service.
    pub solve: SolveStats,
}

impl ServiceTick {
    fn to_json(&self) -> Value {
        Value::obj(vec![
            ("name", Value::Str(self.name.clone())),
            ("lambda_hat", Value::Num(self.lambda_hat)),
            ("offered", Value::Num(self.offered)),
            (
                "grant",
                match self.grant {
                    Some(g) => Value::Num(g as f64),
                    None => Value::Null,
                },
            ),
            ("curve_knee", Value::Num(self.curve_knee as f64)),
            ("target_cores", Value::Num(self.target_cores as f64)),
            ("supply_rps", Value::Num(self.supply_rps)),
            ("gate_cutoff", Value::Num(self.gate_cutoff as f64)),
            ("burn", Value::Num(self.burn)),
            ("cache_hits", Value::Num(self.cache.hits as f64)),
            ("cache_warm", Value::Num(self.cache.warm as f64)),
            ("cache_cold", Value::Num(self.cache.cold as f64)),
            ("solver_nodes", Value::Num(self.solve.nodes_visited as f64)),
            ("curve_prunes", Value::Num(self.solve.curve_prunes as f64)),
            ("seed_rescores", Value::Num(self.solve.seed_rescores as f64)),
        ])
    }
}

/// One adapter boundary's structured record: per-stage timings plus every
/// service's [`ServiceTick`] row.
#[derive(Debug, Clone)]
pub struct TickTrace {
    /// 1-based adapter-tick ordinal (the warm start is not traced).
    pub tick: u64,
    /// Virtual time of the boundary, seconds.
    pub t_s: f64,
    /// Wall-clock of each five-stage phase plus dispatch this tick, ns.
    pub stage_ns: [u64; 6],
    pub services: Vec<ServiceTick>,
}

impl TickTrace {
    pub fn to_json(&self) -> Value {
        let stages = Value::Obj(
            STAGES
                .iter()
                .zip(self.stage_ns)
                .map(|(&s, ns)| (format!("{s}_ns"), Value::Num(ns as f64)))
                .collect(),
        );
        Value::obj(vec![
            ("tick", Value::Num(self.tick as f64)),
            ("t_s", Value::Num(self.t_s)),
            ("stages", stages),
            (
                "services",
                Value::Arr(self.services.iter().map(|s| s.to_json()).collect()),
            ),
        ])
    }
}

/// Ring buffer of the last K [`TickTrace`]s with anomaly trips: when the
/// engine sees an SLO-burn meter cross 1 or a per-tick shed fraction past
/// the configured threshold, it marks a trip and the window around it can
/// be dumped to JSON — the "why did it do that" artifact.
#[derive(Debug, Clone)]
pub struct FlightRecorder {
    cap: usize,
    ring: VecDeque<TickTrace>,
    trips: Vec<(u64, String)>,
}

impl FlightRecorder {
    pub fn new(cap: usize) -> Self {
        Self {
            cap: cap.max(1),
            ring: VecDeque::new(),
            trips: Vec::new(),
        }
    }

    pub fn push(&mut self, trace: TickTrace) {
        if self.ring.len() == self.cap {
            self.ring.pop_front();
        }
        self.ring.push_back(trace);
    }

    /// Record an anomaly at `tick` (e.g. `"slo_burn"`, `"shed"`).
    pub fn trip(&mut self, tick: u64, reason: &str) {
        self.trips.push((tick, reason.to_string()));
    }

    pub fn tripped(&self) -> bool {
        !self.trips.is_empty()
    }

    pub fn trips(&self) -> &[(u64, String)] {
        &self.trips
    }

    pub fn ticks(&self) -> impl Iterator<Item = &TickTrace> {
        self.ring.iter()
    }

    /// The dump artifact: the retained tick window plus every trip.
    pub fn dump(&self) -> Value {
        Value::obj(vec![
            ("window", Value::Num(self.cap as f64)),
            (
                "trips",
                Value::Arr(
                    self.trips
                        .iter()
                        .map(|(t, r)| {
                            Value::obj(vec![
                                ("tick", Value::Num(*t as f64)),
                                ("reason", Value::Str(r.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "ticks",
                Value::Arr(self.ring.iter().map(|t| t.to_json()).collect()),
            ),
        ])
    }
}

/// Compact telemetry scalars attached to `RunSummary` / `FleetSummary`
/// (`None` when telemetry is disabled, so summaries stay bit-identical to
/// pre-telemetry runs).  Carries only deterministic counters — never
/// timings — so two telemetry-on runs of the same seed compare equal.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TelemetrySummary {
    pub admitted: u64,
    pub shed: u64,
    pub noroute_unconfigured: u64,
    pub noroute_nocapacity: u64,
    pub batch_slots: u64,
    pub batch_filled: u64,
    pub solver_nodes: u64,
    pub curve_prunes: u64,
    pub seed_rescores: u64,
    pub cache_hits: u64,
    pub cache_warm: u64,
    pub cache_cold: u64,
    pub arena_allocs: u64,
    pub arena_reuses: u64,
    /// Peak live requests in the shard's arena (pre-sizing validation).
    pub arena_high_water: u64,
    /// Peak live events in the shard's timer wheel.
    pub wheel_high_water: u64,
    /// Coarse-ring cascades the wheel performed (amortization check).
    pub wheel_cascades: u64,
    pub pod_crashes: u64,
    pub ejections: u64,
    pub retries: u64,
    pub hedged_batches: u64,
    pub failed_requests: u64,
    pub fallback_solves: u64,
}

impl TelemetrySummary {
    pub fn from_shard(
        shard: &ShardTelemetry,
        cache: CurveCacheStats,
        solve: SolveStats,
        arena_allocs: u64,
        arena_reuses: u64,
        arena_high_water: u64,
        wheel_high_water: u64,
        wheel_cascades: u64,
    ) -> Self {
        Self {
            admitted: shard.admitted(),
            shed: shard.shed(),
            noroute_unconfigured: shard.noroute_unconfigured,
            noroute_nocapacity: shard.noroute_nocapacity,
            batch_slots: shard.batch_slots,
            batch_filled: shard.batch_filled,
            solver_nodes: solve.nodes_visited,
            curve_prunes: solve.curve_prunes,
            seed_rescores: solve.seed_rescores,
            cache_hits: cache.hits,
            cache_warm: cache.warm,
            cache_cold: cache.cold,
            arena_allocs,
            arena_reuses,
            arena_high_water,
            wheel_high_water,
            wheel_cascades,
            pod_crashes: shard.pod_crashes,
            ejections: shard.ejections,
            retries: shard.retries,
            hedged_batches: shard.hedged_batches,
            failed_requests: shard.failed_requests,
            fallback_solves: shard.fallback_solves,
        }
    }

    /// Fleet aggregation: plain field-wise sums.
    pub fn absorb(&mut self, other: &TelemetrySummary) {
        self.admitted += other.admitted;
        self.shed += other.shed;
        self.noroute_unconfigured += other.noroute_unconfigured;
        self.noroute_nocapacity += other.noroute_nocapacity;
        self.batch_slots += other.batch_slots;
        self.batch_filled += other.batch_filled;
        self.solver_nodes += other.solver_nodes;
        self.curve_prunes += other.curve_prunes;
        self.seed_rescores += other.seed_rescores;
        self.cache_hits += other.cache_hits;
        self.cache_warm += other.cache_warm;
        self.cache_cold += other.cache_cold;
        self.arena_allocs += other.arena_allocs;
        self.arena_reuses += other.arena_reuses;
        // High-water marks are per-shard peaks, not flows: the fleet-level
        // figure is the worst shard, so fold with max rather than sum.
        self.arena_high_water = self.arena_high_water.max(other.arena_high_water);
        self.wheel_high_water = self.wheel_high_water.max(other.wheel_high_water);
        self.wheel_cascades += other.wheel_cascades;
        self.pod_crashes += other.pod_crashes;
        self.ejections += other.ejections;
        self.retries += other.retries;
        self.hedged_batches += other.hedged_batches;
        self.failed_requests += other.failed_requests;
        self.fallback_solves += other.fallback_solves;
    }

    pub fn batch_fill_ratio(&self) -> f64 {
        if self.batch_slots == 0 {
            1.0
        } else {
            self.batch_filled as f64 / self.batch_slots as f64
        }
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("admitted", Value::Num(self.admitted as f64)),
            ("shed", Value::Num(self.shed as f64)),
            (
                "noroute_unconfigured",
                Value::Num(self.noroute_unconfigured as f64),
            ),
            (
                "noroute_nocapacity",
                Value::Num(self.noroute_nocapacity as f64),
            ),
            ("batch_fill_ratio", Value::Num(self.batch_fill_ratio())),
            ("solver_nodes", Value::Num(self.solver_nodes as f64)),
            ("curve_prunes", Value::Num(self.curve_prunes as f64)),
            ("seed_rescores", Value::Num(self.seed_rescores as f64)),
            ("cache_hits", Value::Num(self.cache_hits as f64)),
            ("cache_warm", Value::Num(self.cache_warm as f64)),
            ("cache_cold", Value::Num(self.cache_cold as f64)),
            ("arena_allocs", Value::Num(self.arena_allocs as f64)),
            ("arena_reuses", Value::Num(self.arena_reuses as f64)),
            ("arena_high_water", Value::Num(self.arena_high_water as f64)),
            ("wheel_high_water", Value::Num(self.wheel_high_water as f64)),
            ("wheel_cascades", Value::Num(self.wheel_cascades as f64)),
            ("pod_crashes", Value::Num(self.pod_crashes as f64)),
            ("ejections", Value::Num(self.ejections as f64)),
            ("retries", Value::Num(self.retries as f64)),
            ("hedged_batches", Value::Num(self.hedged_batches as f64)),
            ("failed_requests", Value::Num(self.failed_requests as f64)),
            ("fallback_solves", Value::Num(self.fallback_solves as f64)),
        ])
    }
}

/// Engine-level telemetry for one fleet run: the stage profiler, the
/// flight recorder, and the merged shard / solver / cache state.  Built
/// by `FleetSimEngine::run_with_telemetry` when `SimConfig::telemetry`
/// enables it.
#[derive(Debug, Clone)]
pub struct FleetTelemetry {
    pub stages: StageProfiler,
    pub flight: FlightRecorder,
    /// Adapter boundaries traced (the warm start is not counted).
    pub ticks: u64,
    /// All shards' counters, merged in service-index order.
    pub shard: ShardTelemetry,
    pub cache: CurveCacheStats,
    pub solve: SolveStats,
    pub arena_allocs: u64,
    pub arena_reuses: u64,
    /// Peak live requests across all shards' arenas (max, not sum).
    pub arena_high_water: u64,
    /// Peak live events across all shards' timer wheels (max, not sum).
    pub wheel_high_water: u64,
    /// Σ coarse-ring cascades across all shards' timer wheels.
    pub wheel_cascades: u64,
    /// Worker-pool fan-outs performed by the engine this run.
    pub pool_dispatches: u64,
    /// Σ pool overhead (dispatch wall minus busiest worker), ns.
    pub pool_dispatch_ns: u64,
    /// Recovery-time-to-supply: seconds from a capacity-loss boundary to
    /// the first boundary where ready cores are back at the pre-loss level.
    pub recovery_s: LogHistogram,
    shed_trip_fraction: f64,
    prev_admitted: u64,
    prev_shed: u64,
    /// Open capacity-loss episode: `(t_s of the loss, ready-core target)`.
    recovering_since: Option<(f64, u64)>,
}

impl FleetTelemetry {
    pub fn new(cfg: &TelemetryConfig) -> Self {
        Self {
            stages: StageProfiler::default(),
            flight: FlightRecorder::new(cfg.flight_ticks),
            ticks: 0,
            shard: ShardTelemetry::new(true),
            cache: CurveCacheStats::default(),
            solve: SolveStats::default(),
            arena_allocs: 0,
            arena_reuses: 0,
            arena_high_water: 0,
            wheel_high_water: 0,
            wheel_cascades: 0,
            pool_dispatches: 0,
            pool_dispatch_ns: 0,
            recovery_s: LogHistogram::new(),
            shed_trip_fraction: cfg.shed_trip_fraction,
            prev_admitted: 0,
            prev_shed: 0,
            recovering_since: None,
        }
    }

    /// Fold one adapter boundary in: record the trace, and trip the flight
    /// recorder when any service is burning its SLO budget, the tick's
    /// shed fraction (from the admission gates' counter deltas) exceeds
    /// the threshold, or the fault plane killed more than 5% of capacity
    /// since the last boundary.  `lost_cores` is the Σ cores crashed since
    /// the previous tick; `ready_cores` the cluster's Ready cores now —
    /// together they drive the capacity-loss trip and the
    /// recovery-time-to-supply histogram.
    pub fn on_tick(
        &mut self,
        trace: TickTrace,
        gate_admitted: u64,
        gate_shed: u64,
        max_burn: f64,
        lost_cores: u64,
        ready_cores: u64,
    ) {
        self.ticks += 1;
        let tick = trace.tick;
        let t_s = trace.t_s;
        let d_admit = gate_admitted.saturating_sub(self.prev_admitted);
        let d_shed = gate_shed.saturating_sub(self.prev_shed);
        self.prev_admitted = gate_admitted;
        self.prev_shed = gate_shed;
        self.flight.push(trace);
        if max_burn > 1.0 {
            self.flight.trip(tick, "slo_burn");
        }
        let offered = d_admit + d_shed;
        if offered > 0 && d_shed as f64 / offered as f64 > self.shed_trip_fraction {
            self.flight.trip(tick, "shed");
        }
        if lost_cores > 0 {
            let pre_loss = ready_cores + lost_cores;
            if pre_loss > 0 && lost_cores as f64 / pre_loss as f64 > 0.05 {
                self.flight.trip(tick, "capacity_loss");
            }
            // extend an open episode to the (possibly higher) new target
            let target = match self.recovering_since {
                Some((t0, old)) => (t0, old.max(pre_loss)),
                None => (t_s, pre_loss),
            };
            self.recovering_since = Some(target);
        } else if let Some((t0, target)) = self.recovering_since {
            if ready_cores >= target {
                self.recovery_s.record((t_s - t0).max(0.0).round() as u64);
                self.recovering_since = None;
            }
        }
    }

    /// The exportable registry: every counter, gauge, and histogram the
    /// run accumulated.
    pub fn registry(&self) -> Registry {
        let mut r = Registry::new();
        r.counter_add("infadapter_ticks_total", self.ticks);
        r.counter_add("infadapter_admitted_total", self.shard.admitted());
        r.counter_add("infadapter_shed_total", self.shard.shed());
        for (i, &v) in self.shard.admit_by_tier.iter().enumerate() {
            r.counter_add(&format!("infadapter_tier{i}_admitted_total"), v);
        }
        for (i, &v) in self.shard.shed_by_tier.iter().enumerate() {
            r.counter_add(&format!("infadapter_tier{i}_shed_total"), v);
        }
        r.counter_add(
            "infadapter_noroute_unconfigured_total",
            self.shard.noroute_unconfigured,
        );
        r.counter_add(
            "infadapter_noroute_nocapacity_total",
            self.shard.noroute_nocapacity,
        );
        r.counter_add("infadapter_batch_slots_total", self.shard.batch_slots);
        r.counter_add("infadapter_batch_filled_total", self.shard.batch_filled);
        r.gauge_set(
            "infadapter_batch_fill_ratio",
            self.shard.batch_fill_ratio(),
        );
        r.counter_add("infadapter_solver_nodes_total", self.solve.nodes_visited);
        r.counter_add(
            "infadapter_solver_curve_prunes_total",
            self.solve.curve_prunes,
        );
        r.counter_add(
            "infadapter_solver_seed_rescores_total",
            self.solve.seed_rescores,
        );
        r.counter_add("infadapter_curve_cache_hits_total", self.cache.hits);
        r.counter_add("infadapter_curve_cache_warm_total", self.cache.warm);
        r.counter_add("infadapter_curve_cache_cold_total", self.cache.cold);
        r.counter_add("infadapter_arena_allocs_total", self.arena_allocs);
        r.counter_add("infadapter_arena_reuses_total", self.arena_reuses);
        r.gauge_set("infadapter_arena_high_water", self.arena_high_water as f64);
        r.gauge_set("infadapter_wheel_high_water", self.wheel_high_water as f64);
        r.counter_add("infadapter_wheel_cascades_total", self.wheel_cascades);
        r.counter_add("infadapter_pool_dispatches_total", self.pool_dispatches);
        r.counter_add("infadapter_pool_dispatch_ns_total", self.pool_dispatch_ns);
        r.counter_add("infadapter_pod_crashes_total", self.shard.pod_crashes);
        r.counter_add("infadapter_crashed_cores_total", self.shard.crashed_cores);
        r.counter_add("infadapter_ejections_total", self.shard.ejections);
        r.counter_add("infadapter_retries_total", self.shard.retries);
        r.counter_add("infadapter_hedged_batches_total", self.shard.hedged_batches);
        r.counter_add(
            "infadapter_failed_requests_total",
            self.shard.failed_requests,
        );
        r.counter_add(
            "infadapter_fallback_solves_total",
            self.shard.fallback_solves,
        );
        r.hist_merge("infadapter_recovery_s", &self.recovery_s);
        r.counter_add(
            "infadapter_flight_trips_total",
            self.flight.trips().len() as u64,
        );
        for (i, name) in STAGES.iter().enumerate() {
            r.hist_merge(&format!("infadapter_stage_{name}_ns"), self.stages.hist(i));
        }
        r.hist_merge("infadapter_shard_solve_ns", &self.shard.solve_ns);
        r.hist_merge("infadapter_shard_decide_ns", &self.shard.decide_ns);
        r
    }

    /// The JSON snapshot artifact: registry plus stage means and trips.
    pub fn snapshot_json(&self) -> Value {
        let stage_means = Value::Obj(
            STAGES
                .iter()
                .zip(self.stages.mean_ns())
                .map(|(&s, ns)| (format!("{s}_mean_ns"), Value::Num(ns as f64)))
                .collect(),
        );
        Value::obj(vec![
            ("registry", self.registry().to_json()),
            ("stage_means", stage_means),
            ("ticks", Value::Num(self.ticks as f64)),
            (
                "flight_trips",
                Value::Num(self.flight.trips().len() as f64),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_histogram_buckets_and_merges() {
        let mut h = LogHistogram::new();
        for v in [0u64, 1, 1, 2, 3, 1000] {
            h.record(v);
        }
        assert_eq!(h.count(), 6);
        assert_eq!(h.sum(), 1007);
        assert_eq!(h.max(), 1000);
        let mut other = LogHistogram::new();
        other.record(7);
        h.merge(&other);
        assert_eq!(h.count(), 7);
        assert_eq!(h.sum(), 1014);
        // cumulative counts are monotone and end at the total
        let cum = h.cumulative();
        assert!(cum.windows(2).all(|w| w[0].1 <= w[1].1));
        assert_eq!(cum.last().unwrap().1, 7);
    }

    #[test]
    fn merge_order_does_not_change_merged_state() {
        let mk = |vals: &[u64]| {
            let mut t = ShardTelemetry::new(true);
            for &v in vals {
                t.record_admit((v % 3) as Tier);
                t.record_solve_ns(v);
            }
            t.record_noroute(NoRoute::NoCapacity);
            t
        };
        let a = mk(&[1, 2, 3]);
        let b = mk(&[10, 20]);
        let mut ab = ShardTelemetry::new(true);
        ab.merge(&a);
        ab.merge(&b);
        let mut ba = ShardTelemetry::new(true);
        ba.merge(&b);
        ba.merge(&a);
        assert_eq!(ab, ba);
        assert_eq!(ab.admitted(), 5);
        assert_eq!(ab.noroute_nocapacity, 2);
    }

    #[test]
    fn flight_recorder_keeps_only_the_last_k_ticks() {
        let mut fr = FlightRecorder::new(3);
        for tick in 1..=5u64 {
            fr.push(TickTrace {
                tick,
                t_s: tick as f64 * 30.0,
                stage_ns: [0; 6],
                services: Vec::new(),
            });
        }
        let kept: Vec<u64> = fr.ticks().map(|t| t.tick).collect();
        assert_eq!(kept, vec![3, 4, 5]);
        assert!(!fr.tripped());
        fr.trip(5, "shed");
        assert!(fr.tripped());
        let dump = fr.dump();
        assert_eq!(dump.get("ticks").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(dump.get("trips").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn curve_knee_finds_the_smallest_max_grant() {
        assert_eq!(curve_knee(&[0.0, 1.0, 2.0, 2.0, 2.0]), 2);
        assert_eq!(curve_knee(&[5.0, 5.0]), 0);
        assert_eq!(curve_knee(&[]), 0);
    }

    #[test]
    fn prometheus_exposition_round_trips() {
        let mut r = Registry::new();
        r.counter_add("infadapter_admitted_total", 42);
        r.gauge_set("infadapter_batch_fill_ratio", 0.75);
        r.hist_record("infadapter_stage_solve_ns", 1500);
        r.hist_record("infadapter_stage_solve_ns", 90);
        let text = r.to_prometheus();
        let parsed = parse_exposition(&text);
        assert_eq!(parsed["infadapter_admitted_total"], 42.0);
        assert_eq!(parsed["infadapter_batch_fill_ratio"], 0.75);
        assert_eq!(parsed["infadapter_stage_solve_ns_count"], 2.0);
        assert_eq!(parsed["infadapter_stage_solve_ns_sum"], 1590.0);
        // the +Inf bucket is present and carries the full count
        assert_eq!(
            parsed["infadapter_stage_solve_ns_bucket{le=\"+Inf\"}"],
            2.0
        );
        // every non-comment line parsed into a sample
        let lines = text
            .lines()
            .filter(|l| !l.trim().is_empty() && !l.starts_with('#'))
            .count();
        assert_eq!(lines, parsed.len());
    }

    #[test]
    fn disabled_shard_telemetry_records_nothing() {
        let mut t = ShardTelemetry::new(false);
        t.record_admit(0);
        t.record_shed(1);
        t.record_noroute(NoRoute::Unconfigured);
        t.record_batch(8, 4);
        t.record_solve_ns(100);
        assert_eq!(t, ShardTelemetry::default());
    }
}
