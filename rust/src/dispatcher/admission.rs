//! Admission gate + the composed request path.
//!
//! The serving pipeline used to be "dispatch, then hope": when the
//! arbiter cannot grant a service enough cores for its λ̂, the queues blow
//! through *every* request's SLO.  [`AdmissionGate`] sheds the excess at
//! the door instead:
//!
//! * **Token bucket sized from supply.**  The bucket refills at the
//!   service's granted capacity — Σ per-variant `th_m(n, b)` over the
//!   committed allocation ([`crate::profiler::ProfileSet::supply_rps`]) —
//!   refreshed by the adapter each tick via [`AdmissionGate::set_supply`].
//!   An arrival that finds no token is refused with an explicit shed
//!   outcome (the client gets an immediate reject, not a blown SLO).
//! * **Lowest-tier-first shedding.**  Requests carry a [`Tier`]
//!   (0 = most important).  The gate keeps an adaptive *tier cutoff*:
//!   when a tier it intends to serve is shed for lack of tokens, the
//!   cutoff drops by one — the numerically highest admitted tier is
//!   excluded outright, reserving the whole token stream for the tiers
//!   above it.  When a control window passes with no pressure and spare
//!   tokens, the cutoff readmits one tier.  A *zero-supply blackout*
//!   (the service holds no capacity at all, e.g. an empty committed
//!   allocation) shuts the gate but is not tier pressure: the cutoff
//!   holds its ladder, so the gate reopens at the full tier range within
//!   one control window of the supply returning instead of crawling back
//!   one tier per quiet window.  Under *sustained* overload
//!   this converges to strict lowest-tier-first shedding within one
//!   control window per tier (see `prop_admission_tiers_shed_lowest_first`
//!   in `tests/properties.rs`); during transitions a lower tier may ride
//!   the residual burst for at most one window.
//!
//! The gate is pure bookkeeping: admitting everything (disabled, the
//! default) touches no RNG and no shared state, so the default request
//! path stays bit-identical to the pre-admission pipeline.

use super::{Dispatcher, NoRoute, Tier};
use crate::config::AdmissionConfig;
use std::sync::Arc;

/// Float-dust tolerance on the one-token admit threshold: refills
/// accumulate `Δt · rate` terms whose rounding error must never shed a
/// request that conformed to the supply exactly.
const ADMIT_EPS: f64 = 1e-9;

/// Token-bucket admission gate with an adaptive priority-tier cutoff.
#[derive(Debug, Clone)]
pub struct AdmissionGate {
    enabled: bool,
    /// Token refill rate: the granted supply, requests/second.
    rate_rps: f64,
    /// Bucket depth in seconds of supply.
    burst_s: f64,
    /// Multiplicative slack on the supply.
    slack: f64,
    /// Tier-cutoff adaptation cadence, seconds.
    ctl_window_s: f64,
    tokens: f64,
    last_s: f64,
    /// Whether the adapter has ever reported a supply.  An enabled gate
    /// with no supply signal (e.g. a policy without a throughput model on
    /// the real engine) admits everything rather than shedding blind —
    /// only a *reported* supply of 0 means "no capacity granted".
    supplied: bool,
    /// Numerically highest tier currently admitted.
    cutoff: Tier,
    /// Lowest tier this gate's traffic carries (cutoff floor).
    min_tier: Tier,
    /// Highest tier this gate ever admits (cutoff ceiling).
    max_tier: Tier,
    window_start_s: f64,
    /// A tier the gate intended to serve (≤ cutoff) was shed this window.
    pressured: bool,
    /// Lifetime admit count (diagnostics).
    pub admitted: u64,
    /// Lifetime shed count (diagnostics).
    pub shed: u64,
}

impl AdmissionGate {
    /// A gate that admits everything (the default request path).
    pub fn disabled() -> Self {
        Self::new(&AdmissionConfig::default(), 0, 0)
    }

    /// `min_tier..=max_tier` is the range of tiers this gate's traffic
    /// can actually carry; the adaptive cutoff moves inside it.  The
    /// floor matters: a service whose *lowest* arriving tier is 1 must
    /// never drop its cutoff to 0 — that would black out the service's
    /// only tier and oscillate between all-shed and burst-readmit
    /// windows instead of plain token-bucket shedding.
    pub fn new(cfg: &AdmissionConfig, min_tier: Tier, max_tier: Tier) -> Self {
        let max_tier = max_tier.max(min_tier);
        Self {
            enabled: cfg.enabled,
            rate_rps: 0.0,
            burst_s: cfg.burst_s,
            slack: cfg.slack,
            ctl_window_s: cfg.ctl_window_s,
            tokens: 0.0,
            last_s: 0.0,
            supplied: false,
            cutoff: max_tier,
            min_tier,
            max_tier,
            window_start_s: 0.0,
            pressured: false,
            admitted: 0,
            shed: 0,
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// The supply the bucket currently refills at, rps.
    pub fn supply_rps(&self) -> f64 {
        self.rate_rps
    }

    /// Numerically highest tier currently admitted (diagnostics).
    pub fn tier_cutoff(&self) -> Tier {
        self.cutoff
    }

    fn burst_tokens(&self) -> f64 {
        (self.rate_rps * self.slack * self.burst_s).max(1.0)
    }

    fn refill(&mut self, now_s: f64) {
        if now_s > self.last_s {
            self.tokens = (self.tokens + (now_s - self.last_s) * self.rate_rps * self.slack)
                .min(self.burst_tokens());
            self.last_s = now_s;
        }
    }

    /// Re-size the bucket from the service's granted capacity (the
    /// adapter calls this every tick with Σ `th_m(n, b)` of the committed
    /// allocation).  A supply of 0 means the service holds no capacity:
    /// the gate sheds everything until capacity returns.
    pub fn set_supply(&mut self, now_s: f64, supply_rps: f64) {
        self.refill(now_s);
        self.rate_rps = supply_rps.max(0.0);
        // a revoked supply empties the bucket outright — the burst floor
        // of one token must not let a zero-capacity service admit one
        // more request
        self.tokens = if self.rate_rps > 0.0 {
            self.tokens.min(self.burst_tokens())
        } else {
            0.0
        };
        self.supplied = true;
    }

    fn roll_windows(&mut self, now_s: f64) {
        // Spare-token level that counts as recovered: half the bucket,
        // but never above one token — a tiny supply caps its bucket at
        // exactly 1.0, and a strict `> 1.0` test would make readmission
        // unreachable there.
        let recovered = (self.burst_tokens() / 2.0).min(1.0);
        while now_s - self.window_start_s >= self.ctl_window_s {
            if self.pressured {
                // a tier we meant to serve was shed: exclude the lowest
                // admitted tier (never the gate's own floor tier) so its
                // tokens flow upward
                if self.cutoff > self.min_tier {
                    self.cutoff -= 1;
                }
            } else if self.tokens >= recovered && self.cutoff < self.max_tier {
                // a full quiet window with spare tokens: readmit one tier
                self.cutoff += 1;
            }
            self.pressured = false;
            self.window_start_s += self.ctl_window_s;
        }
    }

    /// Admit or shed one arrival at `now_s`.  O(1), no allocation, no RNG.
    pub fn admit(&mut self, now_s: f64, tier: Tier) -> bool {
        if !self.enabled || !self.supplied {
            return true;
        }
        self.refill(now_s);
        self.roll_windows(now_s);
        if tier > self.cutoff {
            // excluded tier: shed at the door without touching the bucket
            // (and without counting as pressure — door sheds are the
            // cutoff working, not the cutoff failing)
            self.shed += 1;
            return false;
        }
        if self.tokens + ADMIT_EPS >= 1.0 {
            self.tokens = (self.tokens - 1.0).max(0.0);
            self.admitted += 1;
            true
        } else {
            self.shed += 1;
            // A zero-supply blackout (service holds no capacity at all —
            // e.g. its committed allocation is empty) is not *tier*
            // pressure: there is no token stream for the cutoff to
            // reapportion, and dropping it would make recovery crawl
            // back one tier per quiet window after the supply returns.
            // The gate shuts outright and keeps its ladder, so it
            // reopens at the full tier range within one control window
            // of capacity coming back.
            if self.rate_rps > 0.0 {
                self.pressured = true;
            }
            false
        }
    }
}

/// Why one arrival left the request path the way it did.
#[derive(Debug, Clone, PartialEq)]
pub enum RouteOutcome {
    /// Admitted and routed to the named backend.
    Routed(Arc<str>),
    /// Refused at the admission gate (carries the request's tier).
    Shed(Tier),
    /// Admitted, but the router had nowhere to send it.
    Denied(NoRoute),
}

/// The unified request path: admission gate → priority tiers → smooth-WRR
/// quota routing.  Admitted traffic flows through the exact pre-existing
/// dispatcher; the gate only decides who gets to reach it.
#[derive(Debug, Clone)]
pub struct RequestPath {
    gate: AdmissionGate,
    dispatcher: Dispatcher,
}

impl RequestPath {
    pub fn new(gate: AdmissionGate) -> Self {
        Self {
            gate,
            dispatcher: Dispatcher::new(),
        }
    }

    pub fn dispatcher(&self) -> &Dispatcher {
        &self.dispatcher
    }

    pub fn gate(&self) -> &AdmissionGate {
        &self.gate
    }

    /// Admission step only — engines that need custom post-route pod
    /// placement call this, then route on [`Self::dispatcher`].
    pub fn admit(&mut self, now_s: f64, tier: Tier) -> bool {
        self.gate.admit(now_s, tier)
    }

    /// Refresh the gate's supply (adapter tick).
    pub fn set_supply(&mut self, now_s: f64, supply_rps: f64) {
        self.gate.set_supply(now_s, supply_rps);
    }

    /// Swap the router's quota table (adapter tick).
    pub fn set_weights(&self, weights: &[(String, f64)]) {
        self.dispatcher.set_weights(weights);
    }

    /// The whole pipeline for one arrival.  The clock reaches the router
    /// so health-checked routing (when armed) can schedule half-open
    /// probes; with health unset `try_route_at` is exactly `try_route`.
    pub fn handle(&mut self, now_s: f64, tier: Tier) -> RouteOutcome {
        if !self.gate.admit(now_s, tier) {
            return RouteOutcome::Shed(tier);
        }
        match self.dispatcher.try_route_at(now_s) {
            Ok(v) => RouteOutcome::Routed(v),
            Err(e) => RouteOutcome::Denied(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(burst_s: f64) -> AdmissionConfig {
        AdmissionConfig {
            enabled: true,
            burst_s,
            slack: 1.0,
            ctl_window_s: 1.0,
        }
    }

    /// Offer `seconds` of deterministic arrivals at `rps` with tiers
    /// cycling through `pattern`; returns (admitted, shed) per tier index.
    fn drive(gate: &mut AdmissionGate, rps: f64, seconds: f64, pattern: &[Tier]) -> Vec<(u64, u64)> {
        let max_tier = *pattern.iter().max().unwrap() as usize;
        let mut stats = vec![(0u64, 0u64); max_tier + 1];
        let n = (rps * seconds) as usize;
        for i in 0..n {
            let t = (i + 1) as f64 / rps;
            let tier = pattern[i % pattern.len()];
            if gate.admit(t, tier) {
                stats[tier as usize].0 += 1;
            } else {
                stats[tier as usize].1 += 1;
            }
        }
        stats
    }

    #[test]
    fn disabled_gate_admits_everything() {
        let mut g = AdmissionGate::disabled();
        assert!(!g.enabled());
        for i in 0..1000 {
            assert!(g.admit(i as f64 * 0.001, (i % 3) as Tier));
        }
        assert_eq!(g.shed, 0);
    }

    #[test]
    fn under_capacity_nothing_is_shed() {
        let mut g = AdmissionGate::new(&cfg(1.0), 0, 1);
        g.set_supply(0.0, 100.0);
        let stats = drive(&mut g, 80.0, 30.0, &[0, 1]);
        assert_eq!(stats[0].1 + stats[1].1, 0, "{stats:?}");
        assert_eq!(g.admitted, 80 * 30);
    }

    #[test]
    fn overload_sheds_roughly_the_excess() {
        let mut g = AdmissionGate::new(&cfg(1.0), 0, 0);
        g.set_supply(0.0, 100.0);
        let stats = drive(&mut g, 200.0, 30.0, &[0]);
        let shed_frac = stats[0].1 as f64 / (stats[0].0 + stats[0].1) as f64;
        // 2x overload: ~half shed (burst absorption gives a little slack)
        assert!((0.40..=0.55).contains(&shed_frac), "{shed_frac}");
    }

    #[test]
    fn zero_supply_sheds_everything() {
        let mut g = AdmissionGate::new(&cfg(1.0), 0, 0);
        g.set_supply(0.0, 0.0);
        let stats = drive(&mut g, 50.0, 5.0, &[0]);
        assert_eq!(stats[0].0, 0, "{stats:?}");
        // revoking a supply must also drain a previously full bucket —
        // not even the one-token burst floor may leak through
        let mut g = AdmissionGate::new(&cfg(1.0), 0, 0);
        g.set_supply(0.0, 100.0);
        let _ = drive(&mut g, 10.0, 5.0, &[0]); // bucket refills to burst
        g.set_supply(5.0, 0.0);
        assert!(!g.admit(5.1, 0), "revoked supply must shed immediately");
    }

    #[test]
    fn zero_supply_blackout_shuts_and_recovers_within_one_window() {
        // A gate whose service lost its whole committed allocation
        // (supply refreshed to 0) must SHUT — shedding every tier, unlike
        // the never-configured gate, which admits everything — without
        // burning its tier cutoff down: a blackout is not tier pressure,
        // and recovery must not crawl back one tier per quiet window.
        let mut g = AdmissionGate::new(&cfg(1.0), 0, 3);
        assert!(g.admit(0.05, 3), "an unconfigured enabled gate admits");
        g.set_supply(0.1, 100.0);
        assert!(g.admit(0.2, 3), "supplied and under capacity: admit");
        // the allocation is revoked: several control windows of blackout
        g.set_supply(1.0, 0.0);
        for i in 0..50 {
            let t = 1.0 + 0.1 * (i + 1) as f64;
            assert!(!g.admit(t, (i % 4) as Tier), "blackout must shed all tiers");
        }
        // the cutoff held its full ladder through the blackout...
        assert_eq!(g.tier_cutoff(), 3, "blackout must not burn the cutoff");
        // ...so one supply refresh reopens every tier within a single
        // control window — the lowest tier is admitted immediately
        g.set_supply(6.5, 100.0);
        assert!(g.admit(7.0, 3), "lowest tier must be admitted right away");
        assert!(g.admit(7.1, 0));
        assert_eq!(g.tier_cutoff(), 3);
    }

    #[test]
    fn genuine_overload_still_pressures_the_cutoff() {
        // The zero-supply carve-out must not weaken real tier adaptation:
        // a tiny-but-positive supply under overload still drops the
        // cutoff (the DAGOR path is unchanged whenever tokens exist).
        let mut g = AdmissionGate::new(&cfg(1.0), 0, 1);
        g.set_supply(0.0, 5.0);
        let _ = drive(&mut g, 50.0, 5.0, &[0, 1]);
        assert_eq!(g.tier_cutoff(), 0, "overload must still adapt the cutoff");
    }

    #[test]
    fn sustained_overload_converges_to_lowest_tier_first() {
        let mut g = AdmissionGate::new(&cfg(1.0), 0, 1);
        g.set_supply(0.0, 100.0);
        // 3x overload, tiers alternating: after the first control window
        // the cutoff excludes tier 1 entirely
        let _warmup = drive(&mut g, 300.0, 2.0, &[0, 1]);
        assert_eq!(g.tier_cutoff(), 0);
        let mut after = vec![(0u64, 0u64); 2];
        for i in 0..(300 * 10) {
            let t = 2.0 + (i + 1) as f64 / 300.0;
            let tier = (i % 2) as Tier;
            if g.admit(t, tier) {
                after[tier as usize].0 += 1;
            } else {
                after[tier as usize].1 += 1;
            }
        }
        assert_eq!(after[1].0, 0, "tier 1 must starve while tier 0 sheds: {after:?}");
        assert!(after[0].0 > 0, "tier 0 keeps serving: {after:?}");
        assert!(after[0].1 > 0, "tier 0 still sheds its own excess: {after:?}");
    }

    #[test]
    fn cutoff_recovers_when_pressure_lifts() {
        let mut g = AdmissionGate::new(&cfg(1.0), 0, 1);
        g.set_supply(0.0, 100.0);
        let _ = drive(&mut g, 300.0, 3.0, &[0, 1]);
        assert_eq!(g.tier_cutoff(), 0);
        // quiet spell: well under capacity for several control windows
        for i in 0..50 {
            let t = 3.0 + (i + 1) as f64 * 0.1;
            let _ = g.admit(t, 0);
        }
        assert_eq!(g.tier_cutoff(), 1, "tier 1 must be readmitted");
    }

    #[test]
    fn tiny_supply_can_still_readmit_a_tier() {
        // Regression: at supply ≤ 1/burst_s the bucket caps at exactly
        // 1.0 token, and a strict `tokens > 1.0` recovery test could
        // never pass — a single transient burst would exclude the lower
        // tier forever.
        let mut g = AdmissionGate::new(&cfg(1.0), 0, 1);
        g.set_supply(0.0, 0.8);
        // overload burst: drop the cutoff to 0
        for i in 0..20 {
            let _ = g.admit(0.1 * (i + 1) as f64, if i % 2 == 0 { 0 } else { 1 });
        }
        assert_eq!(g.tier_cutoff(), 0);
        // long quiet spell: the bucket refills to its (1-token) cap and
        // the excluded tier must come back
        for i in 0..10 {
            let _ = g.admit(2.0 + 10.0 * (i + 1) as f64, 0);
        }
        assert_eq!(g.tier_cutoff(), 1, "tiny-supply gate must recover");
    }

    #[test]
    fn cutoff_never_drops_below_the_gates_lowest_tier() {
        // Regression: a service whose only tier is 1 (ladder 1..=1) must
        // behave as a plain token bucket — the cutoff cannot black out
        // the service's whole stream.
        let mut g = AdmissionGate::new(&cfg(1.0), 1, 1);
        g.set_supply(0.0, 100.0);
        let stats = drive(&mut g, 300.0, 10.0, &[1]);
        assert_eq!(g.tier_cutoff(), 1);
        // plain 3x-overload bucket: ~1/3 admitted, the rest shed — no
        // all-shed blackout windows
        let frac = stats[1].0 as f64 / (stats[1].0 + stats[1].1) as f64;
        assert!((0.25..0.45).contains(&frac), "admitted fraction {frac}");
    }

    #[test]
    fn supply_refresh_rescales_the_bucket() {
        let mut g = AdmissionGate::new(&cfg(1.0), 0, 0);
        g.set_supply(0.0, 10.0);
        let _ = drive(&mut g, 40.0, 5.0, &[0]);
        let shed_small = g.shed;
        assert!(shed_small > 0);
        // the adapter grants 4x the capacity: the same offered load fits
        g.set_supply(5.0, 40.0);
        let before = g.shed;
        for i in 0..(40 * 5) {
            let t = 5.0 + (i + 1) as f64 / 40.0;
            let _ = g.admit(t, 0);
        }
        assert_eq!(g.shed, before, "no sheds at the refreshed supply");
    }

    #[test]
    fn request_path_composes_gate_and_router() {
        let mut path = RequestPath::new(AdmissionGate::new(&cfg(1.0), 0, 0));
        // admitted but unconfigured: Denied(Unconfigured)
        path.set_supply(0.0, 10.0);
        assert_eq!(
            path.handle(0.5, 0),
            RouteOutcome::Denied(NoRoute::Unconfigured)
        );
        path.set_weights(&[("resnet18".into(), 1.0)]);
        match path.handle(0.6, 0) {
            RouteOutcome::Routed(v) => assert_eq!(v.as_ref(), "resnet18"),
            other => panic!("expected a route, got {other:?}"),
        }
        // zero supply: shed before the router is consulted
        path.set_supply(1.0, 0.0);
        assert_eq!(path.handle(10.0, 2), RouteOutcome::Shed(2));
        // a zeroed quota table is NoCapacity, distinct from Unconfigured
        path.set_supply(10.0, 10.0);
        path.set_weights(&[]);
        assert_eq!(
            path.handle(10.5, 0),
            RouteOutcome::Denied(NoRoute::NoCapacity)
        );
    }
}
