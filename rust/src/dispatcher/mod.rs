//! Dispatcher: weighted round-robin load balancing over model variants.
//!
//! The paper's dispatcher "load balances the incoming workload among the
//! models based on the weighted round-robin algorithm using the models'
//! quota variable λ_m".  We implement *smooth* WRR (the nginx algorithm):
//! for integer-ish weights it emits the exact quota proportions with the
//! smoothest possible interleaving, avoiding the burst-to-one-backend
//! behaviour of naive WRR — which matters for per-variant queue depth.
//!
//! Weight tables are swapped atomically by the adapter; `route()` is the
//! request hot path (lock per call, O(#backends)).

use std::sync::{Arc, Mutex};

#[derive(Debug, Clone)]
struct Backend {
    name: String,
    weight: f64,
    current: f64,
}

/// Smooth weighted round-robin router.
#[derive(Debug, Clone)]
pub struct Dispatcher {
    inner: Arc<Mutex<Vec<Backend>>>,
}

impl Default for Dispatcher {
    fn default() -> Self {
        Self::new()
    }
}

impl Dispatcher {
    pub fn new() -> Self {
        Self {
            inner: Arc::new(Mutex::new(Vec::new())),
        }
    }

    /// Replace the weight table. `weights` are the per-variant quotas λ_m
    /// (any non-negative scale); zero/negative-weight backends are dropped.
    /// Existing smoothing state is kept for surviving backends so a quota
    /// update does not reset the interleaving.
    pub fn set_weights(&self, weights: &[(String, f64)]) {
        let mut inner = self.inner.lock().unwrap();
        let mut next: Vec<Backend> = Vec::with_capacity(weights.len());
        for (name, w) in weights {
            if *w <= 0.0 {
                continue;
            }
            let current = inner
                .iter()
                .find(|b| &b.name == name)
                .map(|b| b.current)
                .unwrap_or(0.0);
            next.push(Backend {
                name: name.clone(),
                weight: *w,
                current,
            });
        }
        *inner = next;
    }

    /// Pick the next backend (smooth WRR). None if no backend is active.
    pub fn route(&self) -> Option<String> {
        let mut inner = self.inner.lock().unwrap();
        if inner.is_empty() {
            return None;
        }
        let total: f64 = inner.iter().map(|b| b.weight).sum();
        for b in inner.iter_mut() {
            b.current += b.weight;
        }
        let best = inner
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.current.total_cmp(&b.1.current))
            .map(|(i, _)| i)
            .expect("non-empty");
        inner[best].current -= total;
        Some(inner[best].name.clone())
    }

    /// Current active backends and their weights (diagnostics).
    pub fn snapshot(&self) -> Vec<(String, f64)> {
        self.inner
            .lock().unwrap()
            .iter()
            .map(|b| (b.name.clone(), b.weight))
            .collect()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.lock().unwrap().is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    fn distribution(d: &Dispatcher, n: usize) -> HashMap<String, usize> {
        let mut counts = HashMap::new();
        for _ in 0..n {
            *counts.entry(d.route().unwrap()).or_insert(0) += 1;
        }
        counts
    }

    #[test]
    fn respects_quota_proportions() {
        let d = Dispatcher::new();
        d.set_weights(&[
            ("a".into(), 30.0),
            ("b".into(), 60.0),
            ("c".into(), 10.0),
        ]);
        let counts = distribution(&d, 10_000);
        assert!((counts["a"] as f64 / 10_000.0 - 0.3).abs() < 0.01);
        assert!((counts["b"] as f64 / 10_000.0 - 0.6).abs() < 0.01);
        assert!((counts["c"] as f64 / 10_000.0 - 0.1).abs() < 0.01);
    }

    #[test]
    fn smooth_interleaving_not_bursts() {
        let d = Dispatcher::new();
        d.set_weights(&[("a".into(), 5.0), ("b".into(), 1.0)]);
        // in any window of 6, b appears exactly once (smooth WRR property)
        let seq: Vec<String> = (0..60).map(|_| d.route().unwrap()).collect();
        for w in seq.chunks(6) {
            assert_eq!(w.iter().filter(|s| *s == "b").count(), 1, "{seq:?}");
        }
    }

    #[test]
    fn zero_weight_backends_are_dropped() {
        let d = Dispatcher::new();
        d.set_weights(&[("a".into(), 0.0), ("b".into(), 2.0)]);
        let counts = distribution(&d, 100);
        assert!(!counts.contains_key("a"));
        assert_eq!(counts["b"], 100);
    }

    #[test]
    fn empty_table_routes_none() {
        let d = Dispatcher::new();
        assert_eq!(d.route(), None);
        d.set_weights(&[]);
        assert_eq!(d.route(), None);
    }

    #[test]
    fn reweighting_preserves_smoothing_state() {
        let d = Dispatcher::new();
        d.set_weights(&[("a".into(), 1.0), ("b".into(), 1.0)]);
        let _ = d.route();
        d.set_weights(&[("a".into(), 1.0), ("b".into(), 1.0), ("c".into(), 1.0)]);
        let counts = distribution(&d, 3000);
        for v in ["a", "b", "c"] {
            assert!(
                (counts[v] as f64 / 3000.0 - 1.0 / 3.0).abs() < 0.02,
                "{counts:?}"
            );
        }
    }
}
