//! Dispatcher: the request path — admission gate, priority tiers, and
//! weighted round-robin load balancing over model variants.
//!
//! The paper's dispatcher "load balances the incoming workload among the
//! models based on the weighted round-robin algorithm using the models'
//! quota variable λ_m".  We implement *smooth* WRR (the nginx algorithm):
//! for integer-ish weights it emits the exact quota proportions with the
//! smoothest possible interleaving, avoiding the burst-to-one-backend
//! behaviour of naive WRR — which matters for per-variant queue depth.
//!
//! [`RequestPath`] (see [`admission`]) composes an [`AdmissionGate`] in
//! front of the router: a token bucket sized from the service's granted
//! capacity sheds excess arrivals at the door — lowest priority tier
//! first — instead of queueing them to death.
//!
//! Weight tables are swapped atomically by the adapter; `route()` is the
//! request hot path (lock per call, O(#backends)).  Backend names are
//! interned as `Arc<str>` so a route returns a reference-count bump, not
//! a fresh `String` allocation per request (see `micro_hotpaths`).

pub mod admission;

pub use admission::{AdmissionGate, RequestPath, RouteOutcome};

use std::sync::{Arc, Mutex};

/// Priority tier of a request or service; 0 is the most important.
pub type Tier = u8;

/// Why [`Dispatcher::try_route`] returned no backend: the two states were
/// previously conflated as `None`, but the admission gate (and operators)
/// need to tell "nothing was ever configured" apart from "a weight table
/// was set and granted no capacity".
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NoRoute {
    /// `set_weights` has never been called on this dispatcher.
    Unconfigured,
    /// A weight table was applied, but it granted no positive-weight
    /// backend (all quotas zero, or an empty table).
    NoCapacity,
}

#[derive(Debug, Clone)]
struct Backend {
    name: Arc<str>,
    weight: f64,
    current: f64,
}

#[derive(Debug)]
struct DispatcherState {
    backends: Vec<Backend>,
    /// Whether `set_weights` has ever been called (empty-vs-zeroed).
    configured: bool,
}

/// Smooth weighted round-robin router.
#[derive(Debug, Clone)]
pub struct Dispatcher {
    inner: Arc<Mutex<DispatcherState>>,
}

impl Default for Dispatcher {
    fn default() -> Self {
        Self::new()
    }
}

impl Dispatcher {
    pub fn new() -> Self {
        Self {
            inner: Arc::new(Mutex::new(DispatcherState {
                backends: Vec::new(),
                configured: false,
            })),
        }
    }

    /// Replace the weight table. `weights` are the per-variant quotas λ_m
    /// (any non-negative scale); zero/negative-weight backends are dropped.
    /// Existing smoothing state is kept for surviving backends so a quota
    /// update does not reset the interleaving — but carried credit is
    /// clamped to the *new* total weight.  Smooth WRR keeps every credit
    /// inside (−total, total), so re-setting unchanged weights (which the
    /// adapter does every tick) preserves state exactly; only a backend
    /// downweighted by orders of magnitude (e.g. 100 → 1 after a
    /// reallocation) gets its stale credit truncated — without the clamp
    /// it would monopolize the next tens of picks, bursting traffic at
    /// exactly the backend the policy just shrank.
    pub fn set_weights(&self, weights: &[(String, f64)]) {
        let mut inner = self.inner.lock().unwrap();
        let total: f64 = weights.iter().filter(|(_, w)| *w > 0.0).map(|(_, w)| w).sum();
        let mut next: Vec<Backend> = Vec::with_capacity(weights.len());
        for (name, w) in weights {
            if *w <= 0.0 {
                continue;
            }
            let existing = inner.backends.iter().find(|b| &*b.name == name.as_str());
            let current = existing
                .map(|b| b.current.clamp(-total, total))
                .unwrap_or(0.0);
            // keep the interned name alive across re-sets: the common
            // every-tick re-apply allocates nothing per surviving backend
            let interned = existing
                .map(|b| b.name.clone())
                .unwrap_or_else(|| Arc::from(name.as_str()));
            next.push(Backend {
                name: interned,
                weight: *w,
                current,
            });
        }
        inner.backends = next;
        inner.configured = true;
    }

    /// Pick the next backend (smooth WRR).  The returned name is interned:
    /// cloning it is a reference-count bump, not a string allocation.
    pub fn try_route(&self) -> Result<Arc<str>, NoRoute> {
        let mut inner = self.inner.lock().unwrap();
        if inner.backends.is_empty() {
            return Err(if inner.configured {
                NoRoute::NoCapacity
            } else {
                NoRoute::Unconfigured
            });
        }
        let total: f64 = inner.backends.iter().map(|b| b.weight).sum();
        for b in inner.backends.iter_mut() {
            b.current += b.weight;
        }
        let best = inner
            .backends
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.current.total_cmp(&b.1.current))
            .map(|(i, _)| i)
            .expect("non-empty");
        inner.backends[best].current -= total;
        Ok(inner.backends[best].name.clone())
    }

    /// [`Self::try_route`] without the reason (legacy callers that treat
    /// both empty states as "drop the request").
    pub fn route(&self) -> Option<Arc<str>> {
        self.try_route().ok()
    }

    /// Current active backends and their weights (diagnostics).
    pub fn snapshot(&self) -> Vec<(String, f64)> {
        self.inner
            .lock().unwrap()
            .backends
            .iter()
            .map(|b| (b.name.to_string(), b.weight))
            .collect()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.lock().unwrap().backends.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    fn distribution(d: &Dispatcher, n: usize) -> HashMap<String, usize> {
        let mut counts = HashMap::new();
        for _ in 0..n {
            *counts.entry(d.route().unwrap().to_string()).or_insert(0) += 1;
        }
        counts
    }

    #[test]
    fn respects_quota_proportions() {
        let d = Dispatcher::new();
        d.set_weights(&[
            ("a".into(), 30.0),
            ("b".into(), 60.0),
            ("c".into(), 10.0),
        ]);
        let counts = distribution(&d, 10_000);
        assert!((counts["a"] as f64 / 10_000.0 - 0.3).abs() < 0.01);
        assert!((counts["b"] as f64 / 10_000.0 - 0.6).abs() < 0.01);
        assert!((counts["c"] as f64 / 10_000.0 - 0.1).abs() < 0.01);
    }

    #[test]
    fn smooth_interleaving_not_bursts() {
        let d = Dispatcher::new();
        d.set_weights(&[("a".into(), 5.0), ("b".into(), 1.0)]);
        // in any window of 6, b appears exactly once (smooth WRR property)
        let seq: Vec<String> = (0..60).map(|_| d.route().unwrap().to_string()).collect();
        for w in seq.chunks(6) {
            assert_eq!(w.iter().filter(|s| *s == "b").count(), 1, "{seq:?}");
        }
    }

    #[test]
    fn zero_weight_backends_are_dropped() {
        let d = Dispatcher::new();
        d.set_weights(&[("a".into(), 0.0), ("b".into(), 2.0)]);
        let counts = distribution(&d, 100);
        assert!(!counts.contains_key("a"));
        assert_eq!(counts["b"], 100);
    }

    #[test]
    fn empty_table_routes_none() {
        let d = Dispatcher::new();
        assert_eq!(d.route(), None);
        d.set_weights(&[]);
        assert_eq!(d.route(), None);
    }

    #[test]
    fn unconfigured_and_zero_capacity_are_distinct() {
        // Regression: `route()` used to conflate "never configured" with
        // "configured but granted nothing" — both were `None`.
        let d = Dispatcher::new();
        assert_eq!(d.try_route(), Err(NoRoute::Unconfigured));
        // an all-zero table is a decision that grants no capacity
        d.set_weights(&[("a".into(), 0.0)]);
        assert_eq!(d.try_route(), Err(NoRoute::NoCapacity));
        // so is an explicitly empty one
        d.set_weights(&[]);
        assert_eq!(d.try_route(), Err(NoRoute::NoCapacity));
        // capacity granted again: routing resumes
        d.set_weights(&[("a".into(), 1.0)]);
        assert_eq!(d.try_route().unwrap().as_ref(), "a");
    }

    #[test]
    fn routed_names_are_interned_across_resets() {
        let d = Dispatcher::new();
        d.set_weights(&[("a".into(), 1.0)]);
        let first = d.route().unwrap();
        // the every-tick unchanged re-set must keep the same interned name
        d.set_weights(&[("a".into(), 1.0)]);
        let second = d.route().unwrap();
        assert!(Arc::ptr_eq(&first, &second));
    }

    #[test]
    fn downweighting_does_not_burst_to_the_shrunk_backend() {
        let d = Dispatcher::new();
        d.set_weights(&[("a".into(), 100.0), ("b".into(), 1.0)]);
        // After exactly 51 picks `a` sits on ~+50 credit (b just won); an
        // unclamped carry would let `a` monopolize the next ~50 picks even
        // at equal weights.
        for _ in 0..51 {
            let _ = d.route();
        }
        d.set_weights(&[("a".into(), 1.0), ("b".into(), 1.0)]);
        let next: Vec<String> = (0..20).map(|_| d.route().unwrap().to_string()).collect();
        let a_count = next.iter().filter(|s| *s == "a").count();
        assert!(
            (8..=12).contains(&a_count),
            "downweighted backend should serve ~half, got {a_count}/20: {next:?}"
        );
    }

    #[test]
    fn reapplying_same_weights_keeps_low_weight_backend_share() {
        // The adapter re-sets (often unchanged) quotas every tick; a
        // backend worth 1% must still get its ~1% even when the table is
        // re-applied far more often than it wins a pick.
        let d = Dispatcher::new();
        let table = [("a".to_string(), 100.0), ("b".to_string(), 1.0)];
        d.set_weights(&table);
        let mut b_count = 0;
        for _ in 0..101 {
            for _ in 0..10 {
                if d.route().unwrap().as_ref() == "b" {
                    b_count += 1;
                }
            }
            d.set_weights(&table); // unchanged re-set must keep credit
        }
        assert!(
            (5..=15).contains(&b_count),
            "b should keep ~1% of 1010 picks, got {b_count}"
        );
    }

    #[test]
    fn reweighting_preserves_smoothing_state() {
        let d = Dispatcher::new();
        d.set_weights(&[("a".into(), 1.0), ("b".into(), 1.0)]);
        let _ = d.route();
        d.set_weights(&[("a".into(), 1.0), ("b".into(), 1.0), ("c".into(), 1.0)]);
        let counts = distribution(&d, 3000);
        for v in ["a", "b", "c"] {
            assert!(
                (counts[v] as f64 / 3000.0 - 1.0 / 3.0).abs() < 0.02,
                "{counts:?}"
            );
        }
    }
}
