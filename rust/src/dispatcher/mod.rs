//! Dispatcher: the request path — admission gate, priority tiers, and
//! weighted round-robin load balancing over model variants.
//!
//! The paper's dispatcher "load balances the incoming workload among the
//! models based on the weighted round-robin algorithm using the models'
//! quota variable λ_m".  We implement *smooth* WRR (the nginx algorithm):
//! for integer-ish weights it emits the exact quota proportions with the
//! smoothest possible interleaving, avoiding the burst-to-one-backend
//! behaviour of naive WRR — which matters for per-variant queue depth.
//!
//! [`RequestPath`] (see [`admission`]) composes an [`AdmissionGate`] in
//! front of the router: a token bucket sized from the service's granted
//! capacity sheds excess arrivals at the door — lowest priority tier
//! first — instead of queueing them to death.
//!
//! Weight tables are swapped atomically by the adapter; `route()` is the
//! request hot path (lock per call, O(#backends)).  Backend names are
//! interned as `Arc<str>` so a route returns a reference-count bump, not
//! a fresh `String` allocation per request (see `micro_hotpaths`).

pub mod admission;

pub use admission::{AdmissionGate, RequestPath, RouteOutcome};

use std::sync::{Arc, Mutex};

/// Priority tier of a request or service; 0 is the most important.
pub type Tier = u8;

/// Why [`Dispatcher::try_route`] returned no backend: the two states were
/// previously conflated as `None`, but the admission gate (and operators)
/// need to tell "nothing was ever configured" apart from "a weight table
/// was set and granted no capacity".
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NoRoute {
    /// `set_weights` has never been called on this dispatcher.
    Unconfigured,
    /// A weight table was applied, but it granted no positive-weight
    /// backend (all quotas zero, or an empty table).
    NoCapacity,
}

/// Per-backend health for failure-aware routing: a backend is ejected
/// from the WRR rotation after `eject_after` *consecutive* routing
/// failures and readmitted through a single half-open probe request
/// once `probe_after_s` seconds of sit-out have elapsed.  `None` (the
/// default) keeps the dispatcher exactly on the pre-health path.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HealthPolicy {
    /// Consecutive failures before ejection (≥ 1).
    pub eject_after: u32,
    /// Sit-out before one probe request may readmit the backend.
    pub probe_after_s: f64,
}

#[derive(Debug, Clone)]
struct Backend {
    name: Arc<str>,
    weight: f64,
    current: f64,
    /// Consecutive failures since the last success (health only).
    fails: u32,
    /// When the backend was ejected; `None` = healthy.
    ejected_at: Option<f64>,
    /// A half-open probe request is in flight.
    probe_inflight: bool,
}

#[derive(Debug)]
struct DispatcherState {
    backends: Vec<Backend>,
    /// Whether `set_weights` has ever been called (empty-vs-zeroed).
    configured: bool,
    /// Health-checked routing; `None` = pre-health behaviour.
    health: Option<HealthPolicy>,
}

impl DispatcherState {
    /// Smooth WRR restricted to backends passing `eligible`; `None` when
    /// none do.  Ineligible backends neither earn nor spend credit, so an
    /// ejected backend's smoothing state is frozen while it sits out.
    fn pick(&mut self, eligible: impl Fn(&Backend) -> bool) -> Option<Arc<str>> {
        let total: f64 = self
            .backends
            .iter()
            .filter(|b| eligible(b))
            .map(|b| b.weight)
            .sum();
        for b in self.backends.iter_mut() {
            if eligible(b) {
                b.current += b.weight;
            }
        }
        let best = self
            .backends
            .iter()
            .enumerate()
            .filter(|(_, b)| eligible(b))
            .max_by(|a, b| a.1.current.total_cmp(&b.1.current))
            .map(|(i, _)| i)?;
        self.backends[best].current -= total;
        Some(self.backends[best].name.clone())
    }
}

/// Smooth weighted round-robin router.
#[derive(Debug, Clone)]
pub struct Dispatcher {
    inner: Arc<Mutex<DispatcherState>>,
}

impl Default for Dispatcher {
    fn default() -> Self {
        Self::new()
    }
}

impl Dispatcher {
    pub fn new() -> Self {
        Self {
            inner: Arc::new(Mutex::new(DispatcherState {
                backends: Vec::new(),
                configured: false,
                health: None,
            })),
        }
    }

    /// Replace the weight table. `weights` are the per-variant quotas λ_m
    /// (any non-negative scale); zero/negative-weight backends are dropped.
    /// Existing smoothing state is kept for surviving backends so a quota
    /// update does not reset the interleaving — but carried credit is
    /// clamped to the *new* total weight.  Smooth WRR keeps every credit
    /// inside (−total, total), so re-setting unchanged weights (which the
    /// adapter does every tick) preserves state exactly; only a backend
    /// downweighted by orders of magnitude (e.g. 100 → 1 after a
    /// reallocation) gets its stale credit truncated — without the clamp
    /// it would monopolize the next tens of picks, bursting traffic at
    /// exactly the backend the policy just shrank.
    pub fn set_weights(&self, weights: &[(String, f64)]) {
        let mut inner = self.inner.lock().unwrap();
        let total: f64 = weights.iter().filter(|(_, w)| *w > 0.0).map(|(_, w)| w).sum();
        let mut next: Vec<Backend> = Vec::with_capacity(weights.len());
        for (name, w) in weights {
            if *w <= 0.0 {
                continue;
            }
            let existing = inner.backends.iter().find(|b| &*b.name == name.as_str());
            let current = existing
                .map(|b| b.current.clamp(-total, total))
                .unwrap_or(0.0);
            // keep the interned name alive across re-sets: the common
            // every-tick re-apply allocates nothing per surviving backend
            let interned = existing
                .map(|b| b.name.clone())
                .unwrap_or_else(|| Arc::from(name.as_str()));
            // health state survives the every-tick weight re-apply: an
            // ejected backend stays ejected across quota updates
            let (fails, ejected_at, probe_inflight) = existing
                .map(|b| (b.fails, b.ejected_at, b.probe_inflight))
                .unwrap_or((0, None, false));
            next.push(Backend {
                name: interned,
                weight: *w,
                current,
                fails,
                ejected_at,
                probe_inflight,
            });
        }
        inner.backends = next;
        inner.configured = true;
    }

    /// Pick the next backend (smooth WRR).  The returned name is interned:
    /// cloning it is a reference-count bump, not a string allocation.
    ///
    /// Without a clock no half-open probe can ever come due; with health
    /// unset this is exactly the pre-health routing path.
    pub fn try_route(&self) -> Result<Arc<str>, NoRoute> {
        self.try_route_at(f64::NEG_INFINITY)
    }

    /// [`Self::try_route`] with a clock, enabling health-checked routing:
    /// a probe-eligible ejected backend (sit-out elapsed, no probe in
    /// flight) takes the request as its half-open probe; otherwise smooth
    /// WRR runs over the healthy backends only.  When every backend is
    /// ejected and no probe is due, the table granted capacity but none
    /// of it is healthy: [`NoRoute::NoCapacity`], never `Unconfigured`.
    pub fn try_route_at(&self, now: f64) -> Result<Arc<str>, NoRoute> {
        let mut inner = self.inner.lock().unwrap();
        if inner.backends.is_empty() {
            return Err(if inner.configured {
                NoRoute::NoCapacity
            } else {
                NoRoute::Unconfigured
            });
        }
        let Some(health) = inner.health else {
            return Ok(inner.pick(|_| true).expect("non-empty"));
        };
        // half-open probe: the longest-ejected eligible backend carries
        // this request (index breaks exact ties deterministically)
        let probe = inner
            .backends
            .iter()
            .enumerate()
            .filter(|(_, b)| {
                !b.probe_inflight
                    && matches!(b.ejected_at, Some(t) if now - t >= health.probe_after_s)
            })
            .min_by(|a, b| {
                let (ta, tb) = (a.1.ejected_at.unwrap(), b.1.ejected_at.unwrap());
                ta.total_cmp(&tb).then(a.0.cmp(&b.0))
            })
            .map(|(i, _)| i);
        if let Some(i) = probe {
            inner.backends[i].probe_inflight = true;
            return Ok(inner.backends[i].name.clone());
        }
        inner
            .pick(|b| b.ejected_at.is_none())
            .ok_or(NoRoute::NoCapacity)
    }

    /// Arm (or disarm) health-checked routing.  Disarming clears all
    /// ejection state so routing returns to the plain WRR path.
    pub fn set_health(&self, health: Option<HealthPolicy>) {
        let mut inner = self.inner.lock().unwrap();
        if health.is_none() {
            for b in inner.backends.iter_mut() {
                b.fails = 0;
                b.ejected_at = None;
                b.probe_inflight = false;
            }
        }
        inner.health = health;
    }

    /// Record a routing failure against `name`.  Returns `true` iff this
    /// failure *newly* ejects the backend (for telemetry).  A failure on
    /// an already-ejected backend — a failed half-open probe — restarts
    /// its sit-out instead.
    pub fn record_failure(&self, name: &str, now: f64) -> bool {
        let mut inner = self.inner.lock().unwrap();
        let Some(health) = inner.health else {
            return false;
        };
        let Some(b) = inner.backends.iter_mut().find(|b| &*b.name == name) else {
            return false;
        };
        if b.ejected_at.is_some() {
            b.ejected_at = Some(now);
            b.probe_inflight = false;
            return false;
        }
        b.fails += 1;
        if b.fails >= health.eject_after {
            b.ejected_at = Some(now);
            b.probe_inflight = false;
            true
        } else {
            false
        }
    }

    /// Record a success on `name`: resets the consecutive-failure streak
    /// and — if the backend was ejected (a half-open probe succeeded) —
    /// readmits it with its smooth-WRR credit reset to zero, so the
    /// recovered backend is not flooded by credit accumulated while out.
    pub fn record_success(&self, name: &str) {
        let mut inner = self.inner.lock().unwrap();
        if inner.health.is_none() {
            return;
        }
        let Some(b) = inner.backends.iter_mut().find(|b| &*b.name == name) else {
            return;
        };
        b.fails = 0;
        if b.ejected_at.is_some() {
            b.ejected_at = None;
            b.probe_inflight = false;
            b.current = 0.0;
        }
    }

    /// [`Self::try_route`] without the reason (legacy callers that treat
    /// both empty states as "drop the request").
    pub fn route(&self) -> Option<Arc<str>> {
        self.try_route().ok()
    }

    /// Current active backends and their weights (diagnostics).
    pub fn snapshot(&self) -> Vec<(String, f64)> {
        self.inner
            .lock().unwrap()
            .backends
            .iter()
            .map(|b| (b.name.to_string(), b.weight))
            .collect()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.lock().unwrap().backends.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    fn distribution(d: &Dispatcher, n: usize) -> HashMap<String, usize> {
        let mut counts = HashMap::new();
        for _ in 0..n {
            *counts.entry(d.route().unwrap().to_string()).or_insert(0) += 1;
        }
        counts
    }

    #[test]
    fn respects_quota_proportions() {
        let d = Dispatcher::new();
        d.set_weights(&[
            ("a".into(), 30.0),
            ("b".into(), 60.0),
            ("c".into(), 10.0),
        ]);
        let counts = distribution(&d, 10_000);
        assert!((counts["a"] as f64 / 10_000.0 - 0.3).abs() < 0.01);
        assert!((counts["b"] as f64 / 10_000.0 - 0.6).abs() < 0.01);
        assert!((counts["c"] as f64 / 10_000.0 - 0.1).abs() < 0.01);
    }

    #[test]
    fn smooth_interleaving_not_bursts() {
        let d = Dispatcher::new();
        d.set_weights(&[("a".into(), 5.0), ("b".into(), 1.0)]);
        // in any window of 6, b appears exactly once (smooth WRR property)
        let seq: Vec<String> = (0..60).map(|_| d.route().unwrap().to_string()).collect();
        for w in seq.chunks(6) {
            assert_eq!(w.iter().filter(|s| *s == "b").count(), 1, "{seq:?}");
        }
    }

    #[test]
    fn zero_weight_backends_are_dropped() {
        let d = Dispatcher::new();
        d.set_weights(&[("a".into(), 0.0), ("b".into(), 2.0)]);
        let counts = distribution(&d, 100);
        assert!(!counts.contains_key("a"));
        assert_eq!(counts["b"], 100);
    }

    #[test]
    fn empty_table_routes_none() {
        let d = Dispatcher::new();
        assert_eq!(d.route(), None);
        d.set_weights(&[]);
        assert_eq!(d.route(), None);
    }

    #[test]
    fn unconfigured_and_zero_capacity_are_distinct() {
        // Regression: `route()` used to conflate "never configured" with
        // "configured but granted nothing" — both were `None`.
        let d = Dispatcher::new();
        assert_eq!(d.try_route(), Err(NoRoute::Unconfigured));
        // an all-zero table is a decision that grants no capacity
        d.set_weights(&[("a".into(), 0.0)]);
        assert_eq!(d.try_route(), Err(NoRoute::NoCapacity));
        // so is an explicitly empty one
        d.set_weights(&[]);
        assert_eq!(d.try_route(), Err(NoRoute::NoCapacity));
        // capacity granted again: routing resumes
        d.set_weights(&[("a".into(), 1.0)]);
        assert_eq!(d.try_route().unwrap().as_ref(), "a");
    }

    #[test]
    fn routed_names_are_interned_across_resets() {
        let d = Dispatcher::new();
        d.set_weights(&[("a".into(), 1.0)]);
        let first = d.route().unwrap();
        // the every-tick unchanged re-set must keep the same interned name
        d.set_weights(&[("a".into(), 1.0)]);
        let second = d.route().unwrap();
        assert!(Arc::ptr_eq(&first, &second));
    }

    #[test]
    fn downweighting_does_not_burst_to_the_shrunk_backend() {
        let d = Dispatcher::new();
        d.set_weights(&[("a".into(), 100.0), ("b".into(), 1.0)]);
        // After exactly 51 picks `a` sits on ~+50 credit (b just won); an
        // unclamped carry would let `a` monopolize the next ~50 picks even
        // at equal weights.
        for _ in 0..51 {
            let _ = d.route();
        }
        d.set_weights(&[("a".into(), 1.0), ("b".into(), 1.0)]);
        let next: Vec<String> = (0..20).map(|_| d.route().unwrap().to_string()).collect();
        let a_count = next.iter().filter(|s| *s == "a").count();
        assert!(
            (8..=12).contains(&a_count),
            "downweighted backend should serve ~half, got {a_count}/20: {next:?}"
        );
    }

    #[test]
    fn reapplying_same_weights_keeps_low_weight_backend_share() {
        // The adapter re-sets (often unchanged) quotas every tick; a
        // backend worth 1% must still get its ~1% even when the table is
        // re-applied far more often than it wins a pick.
        let d = Dispatcher::new();
        let table = [("a".to_string(), 100.0), ("b".to_string(), 1.0)];
        d.set_weights(&table);
        let mut b_count = 0;
        for _ in 0..101 {
            for _ in 0..10 {
                if d.route().unwrap().as_ref() == "b" {
                    b_count += 1;
                }
            }
            d.set_weights(&table); // unchanged re-set must keep credit
        }
        assert!(
            (5..=15).contains(&b_count),
            "b should keep ~1% of 1010 picks, got {b_count}"
        );
    }

    #[test]
    fn all_ejected_is_no_capacity_not_unconfigured() {
        let d = Dispatcher::new();
        d.set_health(Some(HealthPolicy {
            eject_after: 2,
            probe_after_s: 5.0,
        }));
        d.set_weights(&[("a".into(), 1.0), ("b".into(), 1.0)]);
        assert!(!d.record_failure("a", 0.0), "first failure must not eject");
        assert!(d.record_failure("a", 0.0), "second consecutive failure ejects");
        // the survivor takes every pick while `a` sits out
        for _ in 0..8 {
            assert_eq!(d.try_route_at(1.0).unwrap().as_ref(), "b");
        }
        assert!(!d.record_failure("b", 1.0));
        assert!(d.record_failure("b", 1.0));
        // every backend ejected, no probe due: configured-but-unhealthy
        // must read as NoCapacity, never Unconfigured
        assert_eq!(d.try_route_at(2.0), Err(NoRoute::NoCapacity));
        // the weight re-apply the adapter does every tick must not
        // resurrect the ejected backends
        d.set_weights(&[("a".into(), 1.0), ("b".into(), 1.0)]);
        assert_eq!(d.try_route_at(2.0), Err(NoRoute::NoCapacity));
        // a success between failures resets the consecutive streak
        let d2 = Dispatcher::new();
        d2.set_health(Some(HealthPolicy {
            eject_after: 2,
            probe_after_s: 5.0,
        }));
        d2.set_weights(&[("a".into(), 1.0)]);
        assert!(!d2.record_failure("a", 0.0));
        d2.record_success("a");
        assert!(!d2.record_failure("a", 0.0), "success must reset the streak");
    }

    #[test]
    fn half_open_probe_readmits_after_the_sitout() {
        let d = Dispatcher::new();
        d.set_health(Some(HealthPolicy {
            eject_after: 1,
            probe_after_s: 5.0,
        }));
        d.set_weights(&[("a".into(), 1.0), ("b".into(), 1.0)]);
        assert!(d.record_failure("a", 10.0));
        // inside the sit-out everything lands on the healthy backend
        for _ in 0..4 {
            assert_eq!(d.try_route_at(12.0).unwrap().as_ref(), "b");
        }
        // past the window exactly one probe goes to `a` …
        assert_eq!(d.try_route_at(15.0).unwrap().as_ref(), "a");
        // … and while it is in flight the rotation stays healthy-only
        assert_eq!(d.try_route_at(15.0).unwrap().as_ref(), "b");
        // a failed probe restarts the sit-out (and is not a new ejection)
        assert!(!d.record_failure("a", 15.0));
        assert_eq!(d.try_route_at(16.0).unwrap().as_ref(), "b");
        assert_eq!(d.try_route_at(21.0).unwrap().as_ref(), "a");
        d.record_success("a");
        // readmitted: both serve again
        let picks: Vec<String> = (0..4)
            .map(|_| d.try_route_at(22.0).unwrap().to_string())
            .collect();
        assert!(picks.iter().any(|p| p == "a"), "{picks:?}");
        assert!(picks.iter().any(|p| p == "b"), "{picks:?}");
    }

    #[test]
    fn readmission_resets_wrr_credit() {
        // While ejected a backend neither earns nor spends credit, and on
        // readmission its credit restarts at zero — a recovered backend
        // must serve its fair share, not a make-up flood.
        let d = Dispatcher::new();
        d.set_health(Some(HealthPolicy {
            eject_after: 1,
            probe_after_s: 1.0,
        }));
        d.set_weights(&[("a".into(), 1.0), ("b".into(), 1.0)]);
        assert!(d.record_failure("a", 0.0));
        for _ in 0..100 {
            assert_eq!(d.try_route_at(0.5).unwrap().as_ref(), "b");
        }
        // probe + success readmits `a`
        assert_eq!(d.try_route_at(2.0).unwrap().as_ref(), "a");
        d.record_success("a");
        // equal weights from here on: `a` takes ~half, not a flood
        let picks: Vec<String> = (0..20)
            .map(|_| d.try_route_at(3.0).unwrap().to_string())
            .collect();
        let a_count = picks.iter().filter(|s| *s == "a").count();
        assert!(
            (9..=11).contains(&a_count),
            "readmitted backend got {a_count}/20: {picks:?}"
        );
    }

    #[test]
    fn health_off_routing_is_unchanged() {
        // With health unset the clocked route is exactly the plain path,
        // and failure/success records are inert.
        let a = Dispatcher::new();
        let b = Dispatcher::new();
        for d in [&a, &b] {
            d.set_weights(&[("x".into(), 3.0), ("y".into(), 1.0)]);
        }
        b.record_failure("x", 0.0);
        b.record_failure("x", 1.0);
        b.record_success("y");
        for t in 0..24 {
            assert_eq!(
                a.try_route().unwrap(),
                b.try_route_at(t as f64).unwrap(),
                "health-off routing diverged at pick {t}"
            );
        }
    }

    #[test]
    fn reweighting_preserves_smoothing_state() {
        let d = Dispatcher::new();
        d.set_weights(&[("a".into(), 1.0), ("b".into(), 1.0)]);
        let _ = d.route();
        d.set_weights(&[("a".into(), 1.0), ("b".into(), 1.0), ("c".into(), 1.0)]);
        let counts = distribution(&d, 3000);
        for v in ["a", "b", "c"] {
            assert!(
                (counts[v] as f64 / 3000.0 - 1.0 / 3.0).abs() < 0.02,
                "{counts:?}"
            );
        }
    }
}
