//! Virtual-time discrete-event serving simulator (single-service facade).
//!
//! Models the paper's testbed faithfully at the queueing level: each pod is
//! an M/G/n station — `cores` parallel servers (the TF-Serving inter-op
//! pool) with lognormal service times calibrated from real PJRT
//! measurements ([`crate::profiler::measure_real`]).  The cluster substrate
//! supplies readiness delays and create-before-remove; the dispatcher
//! supplies smooth-WRR routing; the policy is invoked on the same 30 s
//! cadence as the live system.
//!
//! The event loop itself lives in the multi-service fleet engine
//! ([`crate::fleet::sim::FleetSimEngine`]); [`SimEngine::run`] is the
//! single-service special case — one unprefixed service, no arbiter — so
//! the historical single-adapter behaviour (batch formation, rate
//! accounting, event order, RNG draw sequence) is exactly the fleet
//! engine's N = 1 path.  See the fleet module docs for the batching and
//! rate-accounting semantics, which are unchanged.

use super::{Decision, Policy};
use crate::config::{AdmissionConfig, FaultConfig, TelemetryConfig};
use crate::fleet::curve_cache::CurveCacheStats;
use crate::fleet::sim::{FleetPolicyRef, FleetService, FleetSimEngine};
use crate::metrics::MetricsCollector;
use crate::profiler::ProfileSet;
use crate::telemetry::TelemetrySummary;
use crate::workload::RateSeries;

/// Simulation parameters.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub slo_s: f64,
    pub adapter_interval_s: f64,
    pub node_cores: Vec<usize>,
    pub seed: u64,
    /// Metrics bucket width (figure x-resolution).
    pub bucket_s: f64,
    /// Drop requests that queued longer than this (paper clients time out).
    pub queue_timeout_s: f64,
    /// Batch-formation wait cap: a pod dispatches a partial batch once its
    /// oldest member has waited this long.  Irrelevant at batch size 1.
    pub batch_max_wait_s: f64,
    /// Request-path admission control (disabled by default: the gate
    /// admits everything and the run is bit-identical to the
    /// pre-admission engine).
    pub admission: AdmissionConfig,
    /// Worker threads for the fleet engine's parallel stages (advance,
    /// solve, decide), served by one persistent
    /// [`crate::util::pool::WorkerPool`] for the run's lifetime — workers
    /// park between stages, no per-stage spawns.  `0` = auto (available
    /// parallelism), `1` = the serial reference path (no pool, no threads).
    /// Never affects results — a parallel run is bit-identical to the
    /// serial one (pinned) — only wall-clock; the N = 1 single-service
    /// wrapper always runs serial and thread-free.
    pub solver_threads: usize,
    /// Telemetry plane (disabled by default: zero overhead, and an
    /// enabled run is bit-identical anyway — pinned by
    /// `telemetry_on_is_bit_identical_to_off`).
    pub telemetry: TelemetryConfig,
    /// Fault-injection plane + failure-aware reactions (disabled by
    /// default: no fault stream is drawn and the run is bit-identical to
    /// a fault-free build — pinned by `faults_off_is_bit_identical`).
    pub fault: FaultConfig,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            slo_s: 0.75,
            adapter_interval_s: 30.0,
            node_cores: vec![48, 48],
            seed: 0,
            bucket_s: 10.0,
            queue_timeout_s: 10.0,
            batch_max_wait_s: 0.05,
            admission: AdmissionConfig::default(),
            solver_threads: 0,
            telemetry: TelemetryConfig::default(),
            fault: FaultConfig::default(),
        }
    }
}

/// The single-service simulator.
pub struct SimEngine {
    pub config: SimConfig,
    profiles: ProfileSet,
}

/// Result of one simulated run (one service's stream).
pub struct SimResult {
    pub metrics: MetricsCollector,
    pub duration_s: f64,
    /// (t, decision) log for ablation inspection.
    pub decisions: Vec<(f64, Decision)>,
    /// Value-curve cache outcomes (nonzero only for arbitrated fleet
    /// services; the plain single-service path never solves curves).
    pub curve_cache: CurveCacheStats,
    /// Per-service telemetry scalars (`None` when the plane is disabled).
    pub telemetry: Option<TelemetrySummary>,
}

impl SimEngine {
    pub fn new(profiles: ProfileSet, config: SimConfig) -> Self {
        Self { config, profiles }
    }

    /// Run `policy` against `trace`. The initial decision (t=0) is applied
    /// with zero readiness (warm start, as in the paper's experiments).
    pub fn run(&self, policy: &mut dyn Policy, trace: &RateSeries) -> SimResult {
        let mut services = [FleetService {
            // empty name: unprefixed variant keys, the pre-fleet layout
            name: String::new(),
            trace,
            profiles: self.profiles.clone(),
            slo_s: self.config.slo_s,
            priority: 1.0,
            tier: 0,
            error_budget: 0.01,
            floor_cores: 0,
            policy: FleetPolicyRef::Plain(policy),
        }];
        FleetSimEngine::new(self.config.clone(), None)
            .run(&mut services)
            .pop()
            .expect("a single-service fleet returns exactly one result")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::StaticPolicy;
    use crate::workload::Trace;
    use std::collections::BTreeMap;

    fn engine(seed: u64) -> SimEngine {
        SimEngine::new(
            ProfileSet::paper_like(),
            SimConfig {
                seed,
                ..Default::default()
            },
        )
    }

    #[test]
    fn steady_load_under_capacity_meets_slo() {
        // resnet18 at 4 cores sustains ~92 rps in the model; offer 40.
        let mut policy = StaticPolicy::new("resnet18", 4);
        let res = engine(1).run(&mut policy, &Trace::steady(40.0, 120));
        let s = res.metrics.summary("static", 120.0);
        assert!(s.total_requests > 4000, "{s:?}");
        assert_eq!(s.dropped, 0);
        assert!(s.slo_violation_rate < 0.01, "{s:?}");
        assert!(s.p99_latency_s < 0.75, "{s:?}");
    }

    #[test]
    fn overload_violates_slo() {
        // resnet152 at 2 cores sustains ~12 rps; offer 60.
        let mut policy = StaticPolicy::new("resnet152", 2);
        let res = engine(2).run(&mut policy, &Trace::steady(60.0, 60));
        let s = res.metrics.summary("static", 60.0);
        assert!(
            s.slo_violation_rate > 0.3,
            "expected heavy violations, got {s:?}"
        );
    }

    #[test]
    fn served_accuracy_matches_variant() {
        let mut policy = StaticPolicy::new("resnet50", 4);
        let res = engine(3).run(&mut policy, &Trace::steady(20.0, 60));
        let s = res.metrics.summary("static", 60.0);
        assert!((s.avg_accuracy - 76.13).abs() < 1e-6);
        assert!((s.avg_accuracy_loss - (78.31 - 76.13)).abs() < 1e-6);
    }

    #[test]
    fn cost_tracks_allocation() {
        let mut policy = StaticPolicy::new("resnet18", 6);
        let res = engine(4).run(&mut policy, &Trace::steady(10.0, 100));
        let s = res.metrics.summary("static", 100.0);
        assert!((s.avg_cost_cores - 6.0).abs() < 0.5, "{s:?}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut p1 = StaticPolicy::new("resnet18", 4);
        let mut p2 = StaticPolicy::new("resnet18", 4);
        let r1 = engine(7).run(&mut p1, &Trace::steady(30.0, 60));
        let r2 = engine(7).run(&mut p2, &Trace::steady(30.0, 60));
        let s1 = r1.metrics.summary("a", 60.0);
        let s2 = r2.metrics.summary("b", 60.0);
        assert_eq!(s1.total_requests, s2.total_requests);
        assert_eq!(s1.p99_latency_s, s2.p99_latency_s);
    }

    #[test]
    fn deterministic_given_seed_with_batching() {
        let mut p1 = StaticPolicy::with_batch("resnet50", 4, 6);
        let mut p2 = StaticPolicy::with_batch("resnet50", 4, 6);
        let r1 = engine(7).run(&mut p1, &Trace::steady(30.0, 60));
        let r2 = engine(7).run(&mut p2, &Trace::steady(30.0, 60));
        let s1 = r1.metrics.summary("a", 60.0);
        let s2 = r2.metrics.summary("b", 60.0);
        assert_eq!(s1.total_requests, s2.total_requests);
        assert_eq!(s1.p99_latency_s, s2.p99_latency_s);
    }

    #[test]
    fn batched_pod_meets_slo_under_capacity() {
        // resnet50 at 4 cores, batch 6: formation ≤ 50 ms + batched service
        // s(b) stays well under the 750 ms SLO at 30 rps offered.
        let mut policy = StaticPolicy::with_batch("resnet50", 4, 6);
        let res = engine(11).run(&mut policy, &Trace::steady(30.0, 120));
        let s = res.metrics.summary("batched", 120.0);
        assert_eq!(s.dropped, 0, "{s:?}");
        assert!(s.p99_latency_s < 0.75, "{s:?}");
        assert!(s.slo_violation_rate < 0.01, "{s:?}");
        // batching adds formation wait: latency sits above the unbatched run
        let mut plain = StaticPolicy::new("resnet50", 4);
        let rp = engine(11).run(&mut plain, &Trace::steady(30.0, 120));
        let sp = rp.metrics.summary("plain", 120.0);
        assert!(s.mean_latency_s > sp.mean_latency_s, "{} vs {}", s.mean_latency_s, sp.mean_latency_s);
    }

    #[test]
    fn batching_increases_goodput_under_overload() {
        // resnet50 at 8 cores saturates near 80 rps unbatched; offered 120
        // rps the unbatched pod drowns while batch amortization (~1.7x
        // capacity) keeps the queue stable.
        let trace = Trace::steady(120.0, 180);
        let mut plain = StaticPolicy::new("resnet50", 8);
        let s1 = engine(9).run(&mut plain, &trace).metrics.summary("b1", 180.0);
        let mut batched = StaticPolicy::with_batch("resnet50", 8, 8);
        let sb = engine(9).run(&mut batched, &trace).metrics.summary("b8", 180.0);
        assert!(
            sb.goodput_rps > s1.goodput_rps * 1.2,
            "batched {} vs plain {}",
            sb.goodput_rps,
            s1.goodput_rps
        );
    }

    #[test]
    fn batch_decisions_are_surfaced_in_metrics() {
        let mut policy = StaticPolicy::with_batch("resnet50", 4, 6);
        let res = engine(12).run(&mut policy, &Trace::steady(20.0, 70));
        let log = res.metrics.batch_decisions();
        assert!(!log.is_empty());
        assert_eq!(log[0], (0.0, "resnet50".to_string(), 6));
        // unbatched runs log nothing
        let mut plain = StaticPolicy::new("resnet50", 4);
        let rp = engine(12).run(&mut plain, &Trace::steady(20.0, 70));
        assert!(rp.metrics.batch_decisions().is_empty());
    }

    /// Records the rate history each `decide` call observes.
    struct ProbePolicy {
        inner: StaticPolicy,
        windows: Vec<Vec<f64>>,
    }

    impl ProbePolicy {
        fn new(variant: &str, cores: usize) -> Self {
            Self {
                inner: StaticPolicy::new(variant, cores),
                windows: Vec::new(),
            }
        }
    }

    impl Policy for ProbePolicy {
        fn name(&self) -> String {
            "probe".into()
        }

        fn decide(
            &mut self,
            now: f64,
            rate_history: &[f64],
            committed: &BTreeMap<String, usize>,
        ) -> Decision {
            self.windows.push(rate_history.to_vec());
            self.inner.decide(now, rate_history, committed)
        }
    }

    #[test]
    fn first_adapter_tick_sees_thirty_rate_samples() {
        // 30 s of steady traffic before the first tick must surface as 30
        // per-second samples (the 31st second only starts at the tick).
        let mut probe = ProbePolicy::new("resnet18", 4);
        engine(5).run(&mut probe, &Trace::steady(40.0, 31));
        assert_eq!(probe.windows.len(), 2); // warm start + t=30 tick
        assert_eq!(probe.windows[1].len(), 30, "{:?}", probe.windows[1]);
    }

    #[test]
    fn fractional_adapter_tick_sees_the_partial_second() {
        // A tick at t=10.5 must see 10 whole seconds plus the in-progress
        // half second — previously the partial second was invisible — and
        // every sample must be normalized to a per-second rate: both the
        // partial sample and the post-tick remainder of that second sit
        // near the offered 200 rps, not near half of it.
        let eng = SimEngine::new(
            ProfileSet::paper_like(),
            SimConfig {
                seed: 6,
                adapter_interval_s: 10.5,
                ..Default::default()
            },
        );
        let mut probe = ProbePolicy::new("resnet18", 4);
        eng.run(&mut probe, &Trace::steady(200.0, 22));
        // warm start + ticks at 10.5 and 21.0
        assert_eq!(probe.windows.len(), 3);
        let w1 = &probe.windows[1];
        assert_eq!(w1.len(), 11, "{w1:?}");
        let partial = *w1.last().unwrap();
        assert!(partial > 150.0 && partial < 260.0, "partial sample {partial}");
        // second window starts with the [10.5, 11) remainder, normalized
        let w2 = &probe.windows[2];
        assert_eq!(w2.len(), 11, "{w2:?}");
        assert!(
            w2[0] > 150.0 && w2[0] < 260.0,
            "remainder sample {} must be span-normalized",
            w2[0]
        );
    }
}
