//! Virtual-time discrete-event serving simulator.
//!
//! Models the paper's testbed faithfully at the queueing level: each pod is
//! an M/G/n station — `cores` parallel servers (the TF-Serving inter-op
//! pool) with lognormal service times calibrated from real PJRT
//! measurements ([`crate::profiler::measure_real`]).  The cluster substrate
//! supplies readiness delays and create-before-remove; the dispatcher
//! supplies smooth-WRR routing; the policy is invoked on the same 30 s
//! cadence as the live system.
//!
//! ## Batch formation
//!
//! When a policy's [`Decision`] assigns a variant a batch size `b > 1`,
//! every pod of that variant forms batches at the queue head: arrivals
//! accumulate until either `b` requests are waiting or the oldest has
//! waited `batch_max_wait_s`, then the whole batch is dispatched as *one*
//! service draw occupying *one* core, with the batched mean service time
//! `s(b)` from the profile's amortization model
//! ([`crate::profiler::VariantProfile::service_time_batch`]).  A request's
//! recorded latency spans arrival → batch completion, so formation wait,
//! queueing, and the full batched service are all inside the SLO
//! accounting — matching the worst case the solver charges (`max_wait_s`
//! formation + `s(b)` service).  With `b = 1` (the default) a batch is a
//! single request dispatched immediately and no timeout events exist, so
//! the event and RNG-draw sequence is bit-identical to the pre-batching
//! engine.
//!
//! ## Rate accounting
//!
//! Arrivals are counted into per-second buckets; completed seconds are
//! flushed into the rate history the policy sees.  At every adapter tick
//! the counter is additionally flushed *up to `now`*: a tick at a
//! fractional time pushes the in-progress partial second as an
//! extrapolated per-second rate, so the just-observed load is never
//! invisible to the policy (previously it only surfaced when a later event
//! rolled the second counter forward).
//!
//! Event order: arrivals, completions, batch timeouts, cluster ticks
//! (1 s), adapter ticks.

use super::{Decision, Policy};
use crate::cluster::{Cluster, ClusterEvent};
use crate::dispatcher::Dispatcher;
use crate::metrics::{MetricsCollector, RequestRecord};
use crate::profiler::ProfileSet;
use crate::util::rng::Rng;
use crate::workload::{ArrivalProcess, RateSeries};
use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, HashMap, VecDeque};

/// Simulation parameters.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub slo_s: f64,
    pub adapter_interval_s: f64,
    pub node_cores: Vec<usize>,
    pub seed: u64,
    /// Metrics bucket width (figure x-resolution).
    pub bucket_s: f64,
    /// Drop requests that queued longer than this (paper clients time out).
    pub queue_timeout_s: f64,
    /// Batch-formation wait cap: a pod dispatches a partial batch once its
    /// oldest member has waited this long.  Irrelevant at batch size 1.
    pub batch_max_wait_s: f64,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            slo_s: 0.75,
            adapter_interval_s: 30.0,
            node_cores: vec![48, 48],
            seed: 0,
            bucket_s: 10.0,
            queue_timeout_s: 10.0,
            batch_max_wait_s: 0.05,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum EventKind {
    Arrival(usize),
    /// One batched service draw finishing; `batch` indexes the batch table.
    Completion { pod_id: u64, batch: usize },
    /// Formation wait expired for the batch a pod opened at `forming_seq`.
    BatchTimeout { pod_id: u64, forming_seq: u64 },
    ClusterTick,
    AdapterTick,
}

#[derive(Debug, Clone, Copy)]
struct Event {
    t: f64,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t.total_cmp(&other.t).then(self.seq.cmp(&other.seq))
    }
}

fn push_event(heap: &mut BinaryHeap<Reverse<Event>>, seq: &mut u64, t: f64, kind: EventKind) {
    *seq += 1;
    heap.push(Reverse(Event { t, seq: *seq, kind }));
}

/// Shortest window a rate sample may be normalized over.  Caps the
/// extrapolation factor at 4x: an adapter tick at t = 30.001 must not turn
/// one arrival in a 1 ms sliver into a 1000 rps sample (a max-picking
/// forecaster would seize on it).  Windows shorter than this merge into
/// the neighbouring sample instead.
const MIN_RATE_SAMPLE_SPAN_S: f64 = 0.25;

struct PodSim {
    variant: String,
    cores: usize,
    busy: usize,
    /// Formed batches (ids into the batch table) awaiting a free core.
    queue: VecDeque<usize>,
    /// Requests accumulating toward the next batch (ids).
    forming: Vec<usize>,
    /// Bumped on every dispatch; stale `BatchTimeout` events don't match.
    forming_seq: u64,
    /// Current batch-size target for this pod's variant (1 = no batching).
    max_batch: usize,
    /// Requests waiting at this pod (forming + members of queued batches);
    /// kept as a counter so routing comparisons stay O(1).
    waiting: usize,
}

impl PodSim {
    /// Waiting + in-service requests normalized by cores — the
    /// least-loaded routing metric.
    fn load(&self) -> f64 {
        (self.busy + self.waiting) as f64 / self.cores.max(1) as f64
    }
}

struct RequestSim {
    arrival: f64,
    accuracy: f64,
}

/// The simulator.
pub struct SimEngine {
    pub config: SimConfig,
    profiles: ProfileSet,
}

/// Result of one simulated run.
pub struct SimResult {
    pub metrics: MetricsCollector,
    pub duration_s: f64,
    /// (t, decision) log for ablation inspection.
    pub decisions: Vec<(f64, Decision)>,
}

impl SimEngine {
    pub fn new(profiles: ProfileSet, config: SimConfig) -> Self {
        Self { config, profiles }
    }

    /// Draw one service time for a batch of `batch` requests on a variant
    /// (lognormal around the amortized mean; `batch = 1` is the plain
    /// measured service time).
    fn sample_service_batch(&self, variant: &str, batch: usize, rng: &mut Rng) -> f64 {
        let p = self.profiles.get(variant).expect("unknown variant");
        rng.lognormal_mean(p.service_time_batch(batch), p.service_sigma.max(1e-6))
    }

    /// Run `policy` against `trace`. The initial decision (t=0) is applied
    /// with zero readiness (warm start, as in the paper's experiments).
    pub fn run(&self, policy: &mut dyn Policy, trace: &RateSeries) -> SimResult {
        let cfg = &self.config;
        let duration = trace.duration_s() as f64;
        let mut rng = Rng::seed_from_u64(cfg.seed);
        let arrivals = ArrivalProcess::poisson(trace, cfg.seed.wrapping_add(1));

        let top_acc = self
            .profiles
            .profiles
            .iter()
            .map(|p| p.accuracy)
            .fold(0.0, f64::max);
        let mut metrics = MetricsCollector::new(cfg.bucket_s, cfg.slo_s, top_acc);
        let mut cluster = Cluster::new(&cfg.node_cores);
        let dispatcher = Dispatcher::new();
        let mut decisions: Vec<(f64, Decision)> = Vec::new();

        // --- Warm start: decide at t=0 and make pods ready instantly.
        let first_rate = trace.rates.first().copied().unwrap_or(0.0);
        let d0 = policy.decide(0.0, &[first_rate], &BTreeMap::new());
        cluster.apply(&d0.target, 0.0, |_| 0.0);
        cluster.tick(0.0);
        dispatcher.set_weights(&d0.quotas);
        metrics.record_prediction(0.0, d0.predicted_lambda);
        metrics.record_cost(0.0, cluster.billed_cores());
        // Per-variant batch-size targets in force (new pods inherit them).
        let mut current_batches: BTreeMap<String, usize> = d0
            .target
            .keys()
            .map(|v| (v.clone(), d0.batch_of(v)))
            .collect();
        for (v, &b) in current_batches.iter().filter(|&(_, &b)| b > 1) {
            metrics.record_batch_decision(0.0, v, b);
        }
        decisions.push((0.0, d0));

        // --- Event queue.
        let mut heap: BinaryHeap<Reverse<Event>> = BinaryHeap::new();
        let mut seq = 0u64;
        for (i, &t) in arrivals.iter().enumerate() {
            push_event(&mut heap, &mut seq, t, EventKind::Arrival(i));
        }
        let mut t_next = 1.0;
        while t_next < duration {
            push_event(&mut heap, &mut seq, t_next, EventKind::ClusterTick);
            t_next += 1.0;
        }
        let mut t_adapt = cfg.adapter_interval_s;
        while t_adapt < duration {
            push_event(&mut heap, &mut seq, t_adapt, EventKind::AdapterTick);
            t_adapt += cfg.adapter_interval_s;
        }

        // --- State.
        let mut pods: HashMap<u64, PodSim> = HashMap::new();
        for p in cluster.pods() {
            pods.insert(
                p.id,
                PodSim {
                    variant: p.variant.clone(),
                    cores: p.cores,
                    busy: 0,
                    queue: VecDeque::new(),
                    forming: Vec::new(),
                    forming_seq: 0,
                    max_batch: current_batches.get(&p.variant).copied().unwrap_or(1),
                    waiting: 0,
                },
            );
        }
        let mut requests: Vec<RequestSim> = Vec::with_capacity(arrivals.len());
        // batch id -> member request ids (set at dispatch, pruned of
        // timed-out members at service start)
        let mut batches: Vec<Vec<usize>> = Vec::new();
        let mut rate_history: Vec<f64> = Vec::new();
        let mut arrivals_this_second = 0u64;
        let mut last_whole_second = 0u64;
        // Start of the window `arrivals_this_second` covers; advances with
        // the per-second roll and with partial flushes at adapter ticks so
        // every sample is normalized by the span it actually observed.
        let mut counter_since = 0.0f64;

        let acc_of = |profiles: &ProfileSet, v: &str| -> f64 {
            profiles.get(v).map(|p| p.accuracy).unwrap_or(0.0)
        };

        // --- Main loop.  Arrivals and ticks all fall inside [0, duration);
        // completions may land past the end and are drained so every
        // request is accounted for (conservation invariant).
        while let Some(Reverse(ev)) = heap.pop() {
            let now = ev.t;
            // roll the per-second arrival counter (the division is by
            // exactly 1.0 — a bit-exact no-op — unless an adapter tick
            // partially flushed this second; a sliver left by a flush just
            // before the boundary merges into the next second's sample)
            let sec = now as u64;
            while last_whole_second < sec {
                let boundary = (last_whole_second + 1) as f64;
                let span = boundary - counter_since;
                if span >= MIN_RATE_SAMPLE_SPAN_S {
                    rate_history.push(arrivals_this_second as f64 / span);
                    arrivals_this_second = 0;
                    counter_since = boundary;
                }
                last_whole_second += 1;
            }

            match ev.kind {
                EventKind::Arrival(_) => {
                    arrivals_this_second += 1;
                    let rid = requests.len();
                    // Route: dispatcher picks the variant; least-loaded
                    // ready pod of that variant takes the request.
                    let variant = dispatcher.route();
                    let pod_id = variant.as_deref().and_then(|v| {
                        pick_pod(&cluster, &pods, v).or_else(|| any_pod(&cluster, &pods))
                    });
                    let Some(pid) = pod_id else {
                        requests.push(RequestSim {
                            arrival: now,
                            accuracy: 0.0,
                        });
                        metrics.record_request(RequestRecord {
                            arrival_s: now,
                            latency_s: f64::INFINITY,
                            accuracy: 0.0,
                        });
                        continue;
                    };
                    let accuracy = acc_of(&self.profiles, &pods[&pid].variant);
                    requests.push(RequestSim {
                        arrival: now,
                        accuracy,
                    });
                    self.enqueue_request(
                        pid,
                        rid,
                        now,
                        &mut pods,
                        &mut batches,
                        &mut heap,
                        &mut seq,
                        &mut rng,
                    );
                }
                EventKind::Completion { pod_id, batch } => {
                    for &rid in &batches[batch] {
                        let r = &requests[rid];
                        metrics.record_request(RequestRecord {
                            arrival_s: r.arrival,
                            latency_s: now - r.arrival,
                            accuracy: r.accuracy,
                        });
                    }
                    if let Some(pod) = pods.get_mut(&pod_id) {
                        pod.busy = pod.busy.saturating_sub(1);
                        // Start the next formed batch, dropping members
                        // that queued past the client timeout.
                        while let Some(bid) = pod.queue.pop_front() {
                            pod.waiting = pod.waiting.saturating_sub(batches[bid].len());
                            let mut live = Vec::with_capacity(batches[bid].len());
                            for &rid in &batches[bid] {
                                let waited = now - requests[rid].arrival;
                                if waited > self.config.queue_timeout_s {
                                    metrics.record_request(RequestRecord {
                                        arrival_s: requests[rid].arrival,
                                        latency_s: f64::INFINITY,
                                        accuracy: requests[rid].accuracy,
                                    });
                                } else {
                                    live.push(rid);
                                }
                            }
                            if live.is_empty() {
                                continue;
                            }
                            pod.busy += 1;
                            let st =
                                self.sample_service_batch(&pod.variant, live.len(), &mut rng);
                            batches[bid] = live;
                            push_event(
                                &mut heap,
                                &mut seq,
                                now + st,
                                EventKind::Completion { pod_id, batch: bid },
                            );
                            break;
                        }
                    }
                }
                EventKind::BatchTimeout { pod_id, forming_seq } => {
                    if let Some(pod) = pods.get_mut(&pod_id) {
                        if pod.forming_seq == forming_seq && !pod.forming.is_empty() {
                            let items = std::mem::take(&mut pod.forming);
                            pod.forming_seq += 1;
                            self.dispatch_batch(
                                pod,
                                pod_id,
                                items,
                                now,
                                &mut batches,
                                &mut heap,
                                &mut seq,
                                &mut rng,
                            );
                        }
                    }
                }
                EventKind::ClusterTick => {
                    for event in cluster.tick(now) {
                        match event {
                            ClusterEvent::PodReady { pod_id, variant } => {
                                let cores = cluster
                                    .pods()
                                    .iter()
                                    .find(|p| p.id == pod_id)
                                    .map(|p| p.cores)
                                    .unwrap_or(0);
                                let max_batch =
                                    current_batches.get(&variant).copied().unwrap_or(1);
                                pods.insert(
                                    pod_id,
                                    PodSim {
                                        variant,
                                        cores,
                                        busy: 0,
                                        queue: VecDeque::new(),
                                        forming: Vec::new(),
                                        forming_seq: 0,
                                        max_batch,
                                        waiting: 0,
                                    },
                                );
                            }
                            ClusterEvent::PodRemoved { pod_id, .. } => {
                                // Re-route still-waiting requests (queued
                                // batches and the forming buffer).
                                if let Some(mut dead) = pods.remove(&pod_id) {
                                    let mut orphans: Vec<usize> = Vec::new();
                                    for bid in dead.queue.drain(..) {
                                        orphans.append(&mut batches[bid]);
                                    }
                                    orphans.append(&mut dead.forming);
                                    for rid in orphans {
                                        if let Some(target) = dispatcher
                                            .route()
                                            .and_then(|v| pick_pod(&cluster, &pods, &v))
                                            .or_else(|| any_pod(&cluster, &pods))
                                        {
                                            requests[rid].accuracy =
                                                acc_of(&self.profiles, &pods[&target].variant);
                                            self.enqueue_request(
                                                target,
                                                rid,
                                                now,
                                                &mut pods,
                                                &mut batches,
                                                &mut heap,
                                                &mut seq,
                                                &mut rng,
                                            );
                                        } else {
                                            metrics.record_request(RequestRecord {
                                                arrival_s: requests[rid].arrival,
                                                latency_s: f64::INFINITY,
                                                accuracy: requests[rid].accuracy,
                                            });
                                        }
                                    }
                                }
                            }
                        }
                    }
                    metrics.record_cost(now, cluster.billed_cores());
                }
                EventKind::AdapterTick => {
                    // Flush the arrival counter up to `now` so the policy
                    // sees the in-progress partial second (normalized to a
                    // per-second rate); integer tick times flush nothing
                    // extra because the roll above already caught up.  The
                    // remainder of the second is then normalized by its own
                    // span at the next roll via `counter_since`.  Slivers
                    // below the minimum span stay in the counter rather
                    // than become wildly extrapolated samples.
                    let span = now - counter_since;
                    if span >= MIN_RATE_SAMPLE_SPAN_S {
                        rate_history.push(arrivals_this_second as f64 / span);
                        arrivals_this_second = 0;
                        counter_since = now;
                    }
                    let committed = cluster.committed_allocation();
                    let decision = policy.decide(now, &rate_history, &committed);
                    rate_history.clear();
                    let profiles = &self.profiles;
                    cluster.apply(&decision.target, now, |v| {
                        profiles.get(v).map(|p| p.readiness_s).unwrap_or(10.0)
                    });
                    dispatcher.set_weights(&decision.quotas);
                    // Propagate batch-size targets to live and future pods;
                    // a shrunk target can complete a forming batch.  Visit
                    // pods in id order — HashMap iteration order would make
                    // the RNG draw sequence nondeterministic across runs.
                    current_batches = decision
                        .target
                        .keys()
                        .map(|v| (v.clone(), decision.batch_of(v)))
                        .collect();
                    let mut pod_ids: Vec<u64> = pods.keys().copied().collect();
                    pod_ids.sort_unstable();
                    for pid in pod_ids {
                        let pod = pods.get_mut(&pid).expect("listed pod");
                        let mb = current_batches.get(&pod.variant).copied().unwrap_or(1);
                        if mb != pod.max_batch {
                            pod.max_batch = mb;
                            if pod.forming.len() >= mb {
                                let items = std::mem::take(&mut pod.forming);
                                pod.forming_seq += 1;
                                self.dispatch_batch(
                                    pod,
                                    pid,
                                    items,
                                    now,
                                    &mut batches,
                                    &mut heap,
                                    &mut seq,
                                    &mut rng,
                                );
                            }
                        }
                    }
                    for (v, &b) in current_batches.iter().filter(|&(_, &b)| b > 1) {
                        metrics.record_batch_decision(now, v, b);
                    }
                    metrics.record_prediction(now, decision.predicted_lambda);
                    metrics.record_cost(now, cluster.billed_cores());
                    decisions.push((now, decision));
                }
            }
        }

        SimResult {
            metrics,
            duration_s: duration,
            decisions,
        }
    }

    /// Add one routed request to a pod: it joins the forming batch, which
    /// dispatches when full (immediately at `max_batch = 1`); opening a
    /// fresh batch arms the formation timeout.
    #[allow(clippy::too_many_arguments)]
    fn enqueue_request(
        &self,
        pod_id: u64,
        rid: usize,
        now: f64,
        pods: &mut HashMap<u64, PodSim>,
        batches: &mut Vec<Vec<usize>>,
        heap: &mut BinaryHeap<Reverse<Event>>,
        seq: &mut u64,
        rng: &mut Rng,
    ) {
        let pod = pods.get_mut(&pod_id).expect("routed to unknown pod");
        pod.forming.push(rid);
        pod.waiting += 1;
        if pod.forming.len() >= pod.max_batch {
            let items = std::mem::take(&mut pod.forming);
            pod.forming_seq += 1;
            self.dispatch_batch(pod, pod_id, items, now, batches, heap, seq, rng);
        } else if pod.forming.len() == 1 {
            push_event(
                heap,
                seq,
                now + self.config.batch_max_wait_s,
                EventKind::BatchTimeout {
                    pod_id,
                    forming_seq: pod.forming_seq,
                },
            );
        }
    }

    /// Hand a formed batch to the pod: one service draw on a free core, or
    /// the formed-batch queue when all cores are busy.
    #[allow(clippy::too_many_arguments)]
    fn dispatch_batch(
        &self,
        pod: &mut PodSim,
        pod_id: u64,
        items: Vec<usize>,
        now: f64,
        batches: &mut Vec<Vec<usize>>,
        heap: &mut BinaryHeap<Reverse<Event>>,
        seq: &mut u64,
        rng: &mut Rng,
    ) {
        let bid = batches.len();
        batches.push(items);
        if pod.busy < pod.cores {
            pod.busy += 1;
            pod.waiting = pod.waiting.saturating_sub(batches[bid].len());
            let st = self.sample_service_batch(&pod.variant, batches[bid].len(), rng);
            push_event(heap, seq, now + st, EventKind::Completion { pod_id, batch: bid });
        } else {
            pod.queue.push_back(bid);
        }
    }
}

/// Least-loaded ready pod of a variant (waiting requests normalized by
/// cores).
fn pick_pod(cluster: &Cluster, pods: &HashMap<u64, PodSim>, variant: &str) -> Option<u64> {
    cluster
        .ready_pods_of(variant)
        .iter()
        .filter_map(|p| pods.get(&p.id).map(|ps| (p.id, ps)))
        .min_by(|a, b| a.1.load().total_cmp(&b.1.load()))
        .map(|(id, _)| id)
}

/// Any ready pod at all (fallback when the chosen variant has none yet).
fn any_pod(cluster: &Cluster, pods: &HashMap<u64, PodSim>) -> Option<u64> {
    cluster
        .pods()
        .iter()
        .filter(|p| p.is_ready() && pods.contains_key(&p.id))
        .map(|p| p.id)
        .min_by(|a, b| pods[a].load().total_cmp(&pods[b].load()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::StaticPolicy;
    use crate::workload::Trace;

    fn engine(seed: u64) -> SimEngine {
        SimEngine::new(
            ProfileSet::paper_like(),
            SimConfig {
                seed,
                ..Default::default()
            },
        )
    }

    #[test]
    fn steady_load_under_capacity_meets_slo() {
        // resnet18 at 4 cores sustains ~92 rps in the model; offer 40.
        let mut policy = StaticPolicy::new("resnet18", 4);
        let res = engine(1).run(&mut policy, &Trace::steady(40.0, 120));
        let s = res.metrics.summary("static", 120.0);
        assert!(s.total_requests > 4000, "{s:?}");
        assert_eq!(s.dropped, 0);
        assert!(s.slo_violation_rate < 0.01, "{s:?}");
        assert!(s.p99_latency_s < 0.75, "{s:?}");
    }

    #[test]
    fn overload_violates_slo() {
        // resnet152 at 2 cores sustains ~12 rps; offer 60.
        let mut policy = StaticPolicy::new("resnet152", 2);
        let res = engine(2).run(&mut policy, &Trace::steady(60.0, 60));
        let s = res.metrics.summary("static", 60.0);
        assert!(
            s.slo_violation_rate > 0.3,
            "expected heavy violations, got {s:?}"
        );
    }

    #[test]
    fn served_accuracy_matches_variant() {
        let mut policy = StaticPolicy::new("resnet50", 4);
        let res = engine(3).run(&mut policy, &Trace::steady(20.0, 60));
        let s = res.metrics.summary("static", 60.0);
        assert!((s.avg_accuracy - 76.13).abs() < 1e-6);
        assert!((s.avg_accuracy_loss - (78.31 - 76.13)).abs() < 1e-6);
    }

    #[test]
    fn cost_tracks_allocation() {
        let mut policy = StaticPolicy::new("resnet18", 6);
        let res = engine(4).run(&mut policy, &Trace::steady(10.0, 100));
        let s = res.metrics.summary("static", 100.0);
        assert!((s.avg_cost_cores - 6.0).abs() < 0.5, "{s:?}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut p1 = StaticPolicy::new("resnet18", 4);
        let mut p2 = StaticPolicy::new("resnet18", 4);
        let r1 = engine(7).run(&mut p1, &Trace::steady(30.0, 60));
        let r2 = engine(7).run(&mut p2, &Trace::steady(30.0, 60));
        let s1 = r1.metrics.summary("a", 60.0);
        let s2 = r2.metrics.summary("b", 60.0);
        assert_eq!(s1.total_requests, s2.total_requests);
        assert_eq!(s1.p99_latency_s, s2.p99_latency_s);
    }

    #[test]
    fn deterministic_given_seed_with_batching() {
        let mut p1 = StaticPolicy::with_batch("resnet50", 4, 6);
        let mut p2 = StaticPolicy::with_batch("resnet50", 4, 6);
        let r1 = engine(7).run(&mut p1, &Trace::steady(30.0, 60));
        let r2 = engine(7).run(&mut p2, &Trace::steady(30.0, 60));
        let s1 = r1.metrics.summary("a", 60.0);
        let s2 = r2.metrics.summary("b", 60.0);
        assert_eq!(s1.total_requests, s2.total_requests);
        assert_eq!(s1.p99_latency_s, s2.p99_latency_s);
    }

    #[test]
    fn batched_pod_meets_slo_under_capacity() {
        // resnet50 at 4 cores, batch 6: formation ≤ 50 ms + batched service
        // s(b) stays well under the 750 ms SLO at 30 rps offered.
        let mut policy = StaticPolicy::with_batch("resnet50", 4, 6);
        let res = engine(11).run(&mut policy, &Trace::steady(30.0, 120));
        let s = res.metrics.summary("batched", 120.0);
        assert_eq!(s.dropped, 0, "{s:?}");
        assert!(s.p99_latency_s < 0.75, "{s:?}");
        assert!(s.slo_violation_rate < 0.01, "{s:?}");
        // batching adds formation wait: latency sits above the unbatched run
        let mut plain = StaticPolicy::new("resnet50", 4);
        let rp = engine(11).run(&mut plain, &Trace::steady(30.0, 120));
        let sp = rp.metrics.summary("plain", 120.0);
        assert!(s.mean_latency_s > sp.mean_latency_s, "{} vs {}", s.mean_latency_s, sp.mean_latency_s);
    }

    #[test]
    fn batching_increases_goodput_under_overload() {
        // resnet50 at 8 cores saturates near 80 rps unbatched; offered 120
        // rps the unbatched pod drowns while batch amortization (~1.7x
        // capacity) keeps the queue stable.
        let trace = Trace::steady(120.0, 180);
        let mut plain = StaticPolicy::new("resnet50", 8);
        let s1 = engine(9).run(&mut plain, &trace).metrics.summary("b1", 180.0);
        let mut batched = StaticPolicy::with_batch("resnet50", 8, 8);
        let sb = engine(9).run(&mut batched, &trace).metrics.summary("b8", 180.0);
        assert!(
            sb.goodput_rps > s1.goodput_rps * 1.2,
            "batched {} vs plain {}",
            sb.goodput_rps,
            s1.goodput_rps
        );
    }

    #[test]
    fn batch_decisions_are_surfaced_in_metrics() {
        let mut policy = StaticPolicy::with_batch("resnet50", 4, 6);
        let res = engine(12).run(&mut policy, &Trace::steady(20.0, 70));
        let log = res.metrics.batch_decisions();
        assert!(!log.is_empty());
        assert_eq!(log[0], (0.0, "resnet50".to_string(), 6));
        // unbatched runs log nothing
        let mut plain = StaticPolicy::new("resnet50", 4);
        let rp = engine(12).run(&mut plain, &Trace::steady(20.0, 70));
        assert!(rp.metrics.batch_decisions().is_empty());
    }

    /// Records the rate history each `decide` call observes.
    struct ProbePolicy {
        inner: StaticPolicy,
        windows: Vec<Vec<f64>>,
    }

    impl ProbePolicy {
        fn new(variant: &str, cores: usize) -> Self {
            Self {
                inner: StaticPolicy::new(variant, cores),
                windows: Vec::new(),
            }
        }
    }

    impl Policy for ProbePolicy {
        fn name(&self) -> String {
            "probe".into()
        }

        fn decide(
            &mut self,
            now: f64,
            rate_history: &[f64],
            committed: &BTreeMap<String, usize>,
        ) -> Decision {
            self.windows.push(rate_history.to_vec());
            self.inner.decide(now, rate_history, committed)
        }
    }

    #[test]
    fn first_adapter_tick_sees_thirty_rate_samples() {
        // 30 s of steady traffic before the first tick must surface as 30
        // per-second samples (the 31st second only starts at the tick).
        let mut probe = ProbePolicy::new("resnet18", 4);
        engine(5).run(&mut probe, &Trace::steady(40.0, 31));
        assert_eq!(probe.windows.len(), 2); // warm start + t=30 tick
        assert_eq!(probe.windows[1].len(), 30, "{:?}", probe.windows[1]);
    }

    #[test]
    fn fractional_adapter_tick_sees_the_partial_second() {
        // A tick at t=10.5 must see 10 whole seconds plus the in-progress
        // half second — previously the partial second was invisible — and
        // every sample must be normalized to a per-second rate: both the
        // partial sample and the post-tick remainder of that second sit
        // near the offered 200 rps, not near half of it.
        let eng = SimEngine::new(
            ProfileSet::paper_like(),
            SimConfig {
                seed: 6,
                adapter_interval_s: 10.5,
                ..Default::default()
            },
        );
        let mut probe = ProbePolicy::new("resnet18", 4);
        eng.run(&mut probe, &Trace::steady(200.0, 22));
        // warm start + ticks at 10.5 and 21.0
        assert_eq!(probe.windows.len(), 3);
        let w1 = &probe.windows[1];
        assert_eq!(w1.len(), 11, "{w1:?}");
        let partial = *w1.last().unwrap();
        assert!(partial > 150.0 && partial < 260.0, "partial sample {partial}");
        // second window starts with the [10.5, 11) remainder, normalized
        let w2 = &probe.windows[2];
        assert_eq!(w2.len(), 11, "{w2:?}");
        assert!(
            w2[0] > 150.0 && w2[0] < 260.0,
            "remainder sample {} must be span-normalized",
            w2[0]
        );
    }
}
