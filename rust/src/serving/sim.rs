//! Virtual-time discrete-event serving simulator.
//!
//! Models the paper's testbed faithfully at the queueing level: each pod is
//! an M/G/n station — `cores` parallel servers (the TF-Serving inter-op
//! pool) with lognormal service times calibrated from real PJRT
//! measurements ([`crate::profiler::measure_real`]).  The cluster substrate
//! supplies readiness delays and create-before-remove; the dispatcher
//! supplies smooth-WRR routing; the policy is invoked on the same 30 s
//! cadence as the live system.
//!
//! Event order: arrivals, completions, cluster ticks (1 s), adapter ticks.

use super::{Decision, Policy};
use crate::cluster::{Cluster, ClusterEvent};
use crate::dispatcher::Dispatcher;
use crate::metrics::{MetricsCollector, RequestRecord};
use crate::profiler::ProfileSet;
use crate::util::rng::Rng;
use crate::workload::{ArrivalProcess, RateSeries};
use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, HashMap, VecDeque};

/// Simulation parameters.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub slo_s: f64,
    pub adapter_interval_s: f64,
    pub node_cores: Vec<usize>,
    pub seed: u64,
    /// Metrics bucket width (figure x-resolution).
    pub bucket_s: f64,
    /// Drop requests that queued longer than this (paper clients time out).
    pub queue_timeout_s: f64,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            slo_s: 0.75,
            adapter_interval_s: 30.0,
            node_cores: vec![48, 48],
            seed: 0,
            bucket_s: 10.0,
            queue_timeout_s: 10.0,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum EventKind {
    Arrival(usize),
    Completion { pod_id: u64, req: usize },
    ClusterTick,
    AdapterTick,
}

#[derive(Debug, Clone, Copy)]
struct Event {
    t: f64,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t.total_cmp(&other.t).then(self.seq.cmp(&other.seq))
    }
}

struct PodSim {
    variant: String,
    cores: usize,
    busy: usize,
    queue: VecDeque<usize>, // request ids
    alive: bool,
}

struct RequestSim {
    arrival: f64,
    accuracy: f64,
}

/// The simulator.
pub struct SimEngine {
    pub config: SimConfig,
    profiles: ProfileSet,
}

/// Result of one simulated run.
pub struct SimResult {
    pub metrics: MetricsCollector,
    pub duration_s: f64,
    /// (t, decision) log for ablation inspection.
    pub decisions: Vec<(f64, Decision)>,
}

impl SimEngine {
    pub fn new(profiles: ProfileSet, config: SimConfig) -> Self {
        Self { config, profiles }
    }

    /// Draw one service time for a variant (lognormal, measured mean).
    fn sample_service(&self, variant: &str, rng: &mut Rng) -> f64 {
        let p = self.profiles.get(variant).expect("unknown variant");
        rng.lognormal_mean(p.service_time_s, p.service_sigma.max(1e-6))
    }

    /// Run `policy` against `trace`. The initial decision (t=0) is applied
    /// with zero readiness (warm start, as in the paper's experiments).
    pub fn run(&self, policy: &mut dyn Policy, trace: &RateSeries) -> SimResult {
        let cfg = &self.config;
        let duration = trace.duration_s() as f64;
        let mut rng = Rng::seed_from_u64(cfg.seed);
        let arrivals = ArrivalProcess::poisson(trace, cfg.seed.wrapping_add(1));

        let top_acc = self
            .profiles
            .profiles
            .iter()
            .map(|p| p.accuracy)
            .fold(0.0, f64::max);
        let mut metrics = MetricsCollector::new(cfg.bucket_s, cfg.slo_s, top_acc);
        let mut cluster = Cluster::new(&cfg.node_cores);
        let dispatcher = Dispatcher::new();
        let mut decisions: Vec<(f64, Decision)> = Vec::new();

        // --- Warm start: decide at t=0 and make pods ready instantly.
        let first_rate = trace.rates.first().copied().unwrap_or(0.0);
        let d0 = policy.decide(0.0, &[first_rate], &BTreeMap::new());
        cluster.apply(&d0.target, 0.0, |_| 0.0);
        cluster.tick(0.0);
        dispatcher.set_weights(&d0.quotas);
        metrics.record_prediction(0.0, d0.predicted_lambda);
        metrics.record_cost(0.0, cluster.billed_cores());
        decisions.push((0.0, d0));

        // --- Event queue.
        let mut heap: BinaryHeap<Reverse<Event>> = BinaryHeap::new();
        let mut seq = 0u64;
        let push = |heap: &mut BinaryHeap<Reverse<Event>>, seq: &mut u64, t: f64, kind: EventKind| {
            *seq += 1;
            heap.push(Reverse(Event { t, seq: *seq, kind }));
        };
        for (i, &t) in arrivals.iter().enumerate() {
            push(&mut heap, &mut seq, t, EventKind::Arrival(i));
        }
        let mut t_next = 1.0;
        while t_next < duration {
            push(&mut heap, &mut seq, t_next, EventKind::ClusterTick);
            t_next += 1.0;
        }
        let mut t_adapt = cfg.adapter_interval_s;
        while t_adapt < duration {
            push(&mut heap, &mut seq, t_adapt, EventKind::AdapterTick);
            t_adapt += cfg.adapter_interval_s;
        }

        // --- State.
        let mut pods: HashMap<u64, PodSim> = HashMap::new();
        for p in cluster.pods() {
            pods.insert(
                p.id,
                PodSim {
                    variant: p.variant.clone(),
                    cores: p.cores,
                    busy: 0,
                    queue: VecDeque::new(),
                    alive: true,
                },
            );
        }
        let mut requests: Vec<RequestSim> = Vec::with_capacity(arrivals.len());
        let mut rate_history: Vec<f64> = Vec::new();
        let mut arrivals_this_second = 0u64;
        let mut last_whole_second = 0u64;

        let acc_of = |profiles: &ProfileSet, v: &str| -> f64 {
            profiles.get(v).map(|p| p.accuracy).unwrap_or(0.0)
        };

        // --- Main loop.  Arrivals and ticks all fall inside [0, duration);
        // completions may land past the end and are drained so every
        // request is accounted for (conservation invariant).
        while let Some(Reverse(ev)) = heap.pop() {
            let now = ev.t;
            // roll the per-second arrival counter
            let sec = now as u64;
            while last_whole_second < sec {
                rate_history.push(arrivals_this_second as f64);
                arrivals_this_second = 0;
                last_whole_second += 1;
            }

            match ev.kind {
                EventKind::Arrival(_) => {
                    arrivals_this_second += 1;
                    let rid = requests.len();
                    // Route: dispatcher picks the variant; least-loaded
                    // ready pod of that variant takes the request.
                    let variant = dispatcher.route();
                    let pod_id = variant.as_deref().and_then(|v| {
                        pick_pod(&cluster, &pods, v).or_else(|| any_pod(&cluster, &pods))
                    });
                    let Some(pid) = pod_id else {
                        requests.push(RequestSim {
                            arrival: now,
                            accuracy: 0.0,
                        });
                        metrics.record_request(RequestRecord {
                            arrival_s: now,
                            latency_s: f64::INFINITY,
                            accuracy: 0.0,
                        });
                        continue;
                    };
                    let pod = pods.get_mut(&pid).expect("routed to unknown pod");
                    requests.push(RequestSim {
                        arrival: now,
                        accuracy: acc_of(&self.profiles, &pod.variant),
                    });
                    if pod.busy < pod.cores {
                        pod.busy += 1;
                        let st = self.sample_service(&pod.variant, &mut rng);
                        push(
                            &mut heap,
                            &mut seq,
                            now + st,
                            EventKind::Completion { pod_id: pid, req: rid },
                        );
                    } else {
                        pod.queue.push_back(rid);
                    }
                }
                EventKind::Completion { pod_id, req } => {
                    let r = &requests[req];
                    metrics.record_request(RequestRecord {
                        arrival_s: r.arrival,
                        latency_s: now - r.arrival,
                        accuracy: r.accuracy,
                    });
                    if let Some(pod) = pods.get_mut(&pod_id) {
                        pod.busy = pod.busy.saturating_sub(1);
                        // Start the next queued request, dropping timeouts.
                        while let Some(next) = pod.queue.pop_front() {
                            let waited = now - requests[next].arrival;
                            if waited > self.config.queue_timeout_s {
                                metrics.record_request(RequestRecord {
                                    arrival_s: requests[next].arrival,
                                    latency_s: f64::INFINITY,
                                    accuracy: requests[next].accuracy,
                                });
                                continue;
                            }
                            pod.busy += 1;
                            let st = self.sample_service(&pod.variant, &mut rng);
                            push(
                                &mut heap,
                                &mut seq,
                                now + st,
                                EventKind::Completion {
                                    pod_id,
                                    req: next,
                                },
                            );
                            break;
                        }
                    }
                }
                EventKind::ClusterTick => {
                    for event in cluster.tick(now) {
                        match event {
                            ClusterEvent::PodReady { pod_id, variant } => {
                                let cores = cluster
                                    .pods()
                                    .iter()
                                    .find(|p| p.id == pod_id)
                                    .map(|p| p.cores)
                                    .unwrap_or(0);
                                pods.insert(
                                    pod_id,
                                    PodSim {
                                        variant,
                                        cores,
                                        busy: 0,
                                        queue: VecDeque::new(),
                                        alive: true,
                                    },
                                );
                            }
                            ClusterEvent::PodRemoved { pod_id, .. } => {
                                // Re-route any still-queued requests.
                                if let Some(mut dead) = pods.remove(&pod_id) {
                                    dead.alive = false;
                                    let orphans: Vec<usize> = dead.queue.drain(..).collect();
                                    for rid in orphans {
                                        if let Some(target) = dispatcher
                                            .route()
                                            .and_then(|v| pick_pod(&cluster, &pods, &v))
                                            .or_else(|| any_pod(&cluster, &pods))
                                        {
                                            let pod =
                                                pods.get_mut(&target).expect("alive pod");
                                            requests[rid].accuracy =
                                                acc_of(&self.profiles, &pod.variant);
                                            if pod.busy < pod.cores {
                                                pod.busy += 1;
                                                let st = self.sample_service(&pod.variant, &mut rng);
                                                push(
                                                    &mut heap,
                                                    &mut seq,
                                                    now + st,
                                                    EventKind::Completion {
                                                        pod_id: target,
                                                        req: rid,
                                                    },
                                                );
                                            } else {
                                                pod.queue.push_back(rid);
                                            }
                                        } else {
                                            metrics.record_request(RequestRecord {
                                                arrival_s: requests[rid].arrival,
                                                latency_s: f64::INFINITY,
                                                accuracy: requests[rid].accuracy,
                                            });
                                        }
                                    }
                                }
                            }
                        }
                    }
                    metrics.record_cost(now, cluster.billed_cores());
                }
                EventKind::AdapterTick => {
                    let committed = cluster.committed_allocation();
                    let decision = policy.decide(now, &rate_history, &committed);
                    rate_history.clear();
                    let profiles = &self.profiles;
                    cluster.apply(&decision.target, now, |v| {
                        profiles.get(v).map(|p| p.readiness_s).unwrap_or(10.0)
                    });
                    dispatcher.set_weights(&decision.quotas);
                    metrics.record_prediction(now, decision.predicted_lambda);
                    metrics.record_cost(now, cluster.billed_cores());
                    decisions.push((now, decision));
                }
            }
        }

        SimResult {
            metrics,
            duration_s: duration,
            decisions,
        }
    }
}

/// Least-loaded ready pod of a variant (queue+busy normalized by cores).
fn pick_pod(cluster: &Cluster, pods: &HashMap<u64, PodSim>, variant: &str) -> Option<u64> {
    cluster
        .ready_pods_of(variant)
        .iter()
        .filter_map(|p| pods.get(&p.id).map(|ps| (p.id, ps)))
        .min_by(|a, b| {
            let load_a = (a.1.busy + a.1.queue.len()) as f64 / a.1.cores.max(1) as f64;
            let load_b = (b.1.busy + b.1.queue.len()) as f64 / b.1.cores.max(1) as f64;
            load_a.total_cmp(&load_b)
        })
        .map(|(id, _)| id)
}

/// Any ready pod at all (fallback when the chosen variant has none yet).
fn any_pod(cluster: &Cluster, pods: &HashMap<u64, PodSim>) -> Option<u64> {
    cluster
        .pods()
        .iter()
        .filter(|p| p.is_ready() && pods.contains_key(&p.id))
        .map(|p| p.id)
        .min_by(|a, b| {
            let pa = &pods[a];
            let pb = &pods[b];
            let la = (pa.busy + pa.queue.len()) as f64 / pa.cores.max(1) as f64;
            let lb = (pb.busy + pb.queue.len()) as f64 / pb.cores.max(1) as f64;
            la.total_cmp(&lb)
        })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::StaticPolicy;
    use crate::workload::Trace;

    fn engine(seed: u64) -> SimEngine {
        SimEngine::new(
            ProfileSet::paper_like(),
            SimConfig {
                seed,
                ..Default::default()
            },
        )
    }

    #[test]
    fn steady_load_under_capacity_meets_slo() {
        // resnet18 at 4 cores sustains ~92 rps in the model; offer 40.
        let mut policy = StaticPolicy::new("resnet18", 4);
        let res = engine(1).run(&mut policy, &Trace::steady(40.0, 120));
        let s = res.metrics.summary("static", 120.0);
        assert!(s.total_requests > 4000, "{s:?}");
        assert_eq!(s.dropped, 0);
        assert!(s.slo_violation_rate < 0.01, "{s:?}");
        assert!(s.p99_latency_s < 0.75, "{s:?}");
    }

    #[test]
    fn overload_violates_slo() {
        // resnet152 at 2 cores sustains ~12 rps; offer 60.
        let mut policy = StaticPolicy::new("resnet152", 2);
        let res = engine(2).run(&mut policy, &Trace::steady(60.0, 60));
        let s = res.metrics.summary("static", 60.0);
        assert!(
            s.slo_violation_rate > 0.3,
            "expected heavy violations, got {s:?}"
        );
    }

    #[test]
    fn served_accuracy_matches_variant() {
        let mut policy = StaticPolicy::new("resnet50", 4);
        let res = engine(3).run(&mut policy, &Trace::steady(20.0, 60));
        let s = res.metrics.summary("static", 60.0);
        assert!((s.avg_accuracy - 76.13).abs() < 1e-6);
        assert!((s.avg_accuracy_loss - (78.31 - 76.13)).abs() < 1e-6);
    }

    #[test]
    fn cost_tracks_allocation() {
        let mut policy = StaticPolicy::new("resnet18", 6);
        let res = engine(4).run(&mut policy, &Trace::steady(10.0, 100));
        let s = res.metrics.summary("static", 100.0);
        assert!((s.avg_cost_cores - 6.0).abs() < 0.5, "{s:?}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut p1 = StaticPolicy::new("resnet18", 4);
        let mut p2 = StaticPolicy::new("resnet18", 4);
        let r1 = engine(7).run(&mut p1, &Trace::steady(30.0, 60));
        let r2 = engine(7).run(&mut p2, &Trace::steady(30.0, 60));
        let s1 = r1.metrics.summary("a", 60.0);
        let s2 = r2.metrics.summary("b", 60.0);
        assert_eq!(s1.total_requests, s2.total_requests);
        assert_eq!(s1.p99_latency_s, s2.p99_latency_s);
    }
}
