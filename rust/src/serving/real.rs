//! Real serving engine: live PJRT execution of the AOT artifacts.
//!
//! Wall-clock, thread-driven: an open-loop arrival generator replays a
//! trace, the dispatcher routes each request to a variant, and the
//! variant's [`WorkerPool`](crate::runtime::WorkerPool) executes the actual
//! compiled ResNet on the CPU PJRT client.  The adapter loop re-plans on
//! the configured cadence; allocation changes spawn replacement pools
//! (create-before-remove — the old pool keeps serving while the new one
//! compiles, and compile time is the *measured* readiness cost rt_m).
//!
//! This engine is the end-to-end proof that the three layers compose with
//! Python absent; the figure-scale experiments use the virtual-time
//! simulator (see DESIGN.md §4 for the 1-core-host substitution).

use crate::config::AdmissionConfig;
use crate::dispatcher::{AdmissionGate, Dispatcher};
use crate::metrics::{MetricsCollector, RequestRecord};
use crate::monitoring::RateWindow;
use crate::runtime::{Manifest, WorkerPool};
use crate::serving::Policy;
use crate::workload::{ArrivalProcess, RateSeries};
use anyhow::{Context, Result};
use std::collections::{BTreeMap, HashMap};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

/// Real-engine parameters.
#[derive(Debug, Clone)]
pub struct RealConfig {
    pub slo_s: f64,
    pub adapter_interval_s: f64,
    /// Default serving batch size (the paper disables batching on CPU: 1).
    /// A policy's `Decision::batches` overrides it per variant when the
    /// manifest has an executable compiled at that batch size.
    pub batch: usize,
    /// Seed for the arrival process.
    pub seed: u64,
    /// Cap on per-variant worker counts (host protection).
    pub max_workers_per_variant: usize,
    /// Request-path admission control (disabled by default).  The live
    /// gate runs single-tier and is sized from each decision's
    /// `supply_rps` (Σ th_m of the decided allocation).
    pub admission: AdmissionConfig,
}

impl Default for RealConfig {
    fn default() -> Self {
        Self {
            slo_s: 0.75,
            adapter_interval_s: 10.0,
            batch: 1,
            seed: 0,
            max_workers_per_variant: 4,
            admission: AdmissionConfig::default(),
        }
    }
}

/// Live serving system state.
pub struct RealEngine {
    artifacts_dir: PathBuf,
    manifest: Arc<Manifest>,
    pub config: RealConfig,
    pools: Arc<RwLock<HashMap<String, Arc<WorkerPool>>>>,
    dispatcher: Dispatcher,
    rate_window: Arc<Mutex<RateWindow>>,
    /// Variants with a replacement pool currently compiling (suppresses
    /// duplicate builders while one is in flight).
    building: Arc<Mutex<std::collections::HashSet<String>>>,
    /// The allocation the policy currently wants (drives deferred removal).
    desired: Arc<Mutex<BTreeMap<String, usize>>>,
    /// The quota table the policy currently wants (intersected with the
    /// pools that actually exist before reaching the dispatcher).
    desired_quotas: Arc<Mutex<Vec<(String, f64)>>>,
    /// Per-variant batch sizes the policy currently wants (from
    /// `Decision::batches`; absent = the config default).
    desired_batches: Arc<Mutex<BTreeMap<String, usize>>>,
}

impl RealEngine {
    pub fn new(artifacts_dir: PathBuf, config: RealConfig) -> Result<Self> {
        let manifest = Arc::new(Manifest::load(&artifacts_dir)?);
        Ok(Self {
            artifacts_dir,
            manifest,
            config,
            pools: Arc::new(RwLock::new(HashMap::new())),
            dispatcher: Dispatcher::new(),
            rate_window: Arc::new(Mutex::new(RateWindow::new(600))),
            building: Arc::new(Mutex::new(std::collections::HashSet::new())),
            desired: Arc::new(Mutex::new(BTreeMap::new())),
            desired_quotas: Arc::new(Mutex::new(Vec::new())),
            desired_batches: Arc::new(Mutex::new(BTreeMap::new())),
        })
    }

    /// Push the desired quota table to the dispatcher, restricted to pools
    /// that exist *now*; while a transition is compiling, surviving pools
    /// keep serving (create-before-remove at the routing layer).
    fn refresh_dispatcher(
        pools: &RwLock<HashMap<String, Arc<WorkerPool>>>,
        dispatcher: &Dispatcher,
        desired_quotas: &Mutex<Vec<(String, f64)>>,
    ) {
        let available = pools.read().unwrap();
        let desired = desired_quotas.lock().unwrap();
        let mut weights: Vec<(String, f64)> = desired
            .iter()
            .filter(|(v, _)| available.contains_key(v))
            .cloned()
            .collect();
        if weights.is_empty() {
            // Nothing the policy asked for is ready yet: serve with what
            // exists rather than black-holing requests.
            weights = available.keys().map(|v| (v.clone(), 1.0)).collect();
        }
        dispatcher.set_weights(&weights);
    }

    /// Remove pools not in the desired allocation — but only once every
    /// desired variant has a ready pool (the paper's create-before-remove).
    fn reconcile_removals(
        pools: &RwLock<HashMap<String, Arc<WorkerPool>>>,
        desired: &Mutex<BTreeMap<String, usize>>,
    ) {
        let want = desired.lock().unwrap().clone();
        let mut pools = pools.write().unwrap();
        let all_ready = want
            .iter()
            .filter(|(_, &c)| c > 0)
            .all(|(v, _)| pools.contains_key(v));
        if all_ready {
            pools.retain(|v, _| want.get(v).copied().unwrap_or(0) > 0);
        }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Current committed allocation (variant -> worker count).
    pub fn committed(&self) -> BTreeMap<String, usize> {
        self.pools
            .read()
            .unwrap()
            .iter()
            .map(|(v, p)| (v.clone(), p.size))
            .collect()
    }

    /// Apply a target allocation **without blocking the serving path**:
    /// replacement pools compile on builder threads and are swapped in when
    /// Ready (create-before-remove — the old pool keeps serving; compile
    /// time is the measured readiness cost rt_m).  Synchronous only when
    /// `wait` is true (warm start / tests).
    pub fn apply(&self, target: &BTreeMap<String, usize>, wait: bool) -> Result<()> {
        *self.desired.lock().unwrap() = target.clone();
        let current = self.committed();
        let current_batches: BTreeMap<String, usize> = self
            .pools
            .read()
            .unwrap()
            .iter()
            .map(|(v, p)| (v.clone(), p.batch))
            .collect();
        let wanted_batches = self.desired_batches.lock().unwrap().clone();
        for (variant, &cores) in target {
            if cores == 0 {
                continue;
            }
            let meta = self.manifest.variant(variant)?.clone();
            // Serve at the policy's chosen batch size when an executable
            // was exported for it; otherwise fall back to the default.
            let want = wanted_batches
                .get(variant)
                .copied()
                .unwrap_or(self.config.batch)
                .max(1);
            let batch = if meta.hlo.contains_key(&want) {
                want
            } else {
                self.config.batch
            };
            let workers = cores.clamp(1, self.config.max_workers_per_variant);
            if current.get(variant) == Some(&workers)
                && current_batches.get(variant) == Some(&batch)
            {
                continue;
            }
            {
                let mut building = self.building.lock().unwrap();
                if building.contains(variant) {
                    continue; // a replacement is already compiling
                }
                building.insert(variant.clone());
            }
            let dir = self.artifacts_dir.clone();
            let manifest = self.manifest.clone();
            let pools = self.pools.clone();
            let building = self.building.clone();
            let variant_name = variant.clone();
            let desired = self.desired.clone();
            let desired_quotas = self.desired_quotas.clone();
            let dispatcher = self.dispatcher.clone();
            let builder = move || {
                let built = WorkerPool::spawn(&dir, &manifest, &meta, batch, workers);
                match built {
                    Ok(pool) => {
                        pools
                            .write()
                            .unwrap()
                            .insert(variant_name.clone(), Arc::new(pool));
                    }
                    Err(e) => eprintln!("[real] pool build failed for {variant_name}: {e:#}"),
                }
                building.lock().unwrap().remove(&variant_name);
                // The replacement is Ready: now (and only now) retire pools
                // the policy no longer wants, and re-point the dispatcher.
                Self::reconcile_removals(&pools, &desired);
                Self::refresh_dispatcher(&pools, &dispatcher, &desired_quotas);
            };
            if wait {
                builder();
            } else {
                std::thread::Builder::new()
                    .name(format!("pool-builder-{variant}"))
                    .spawn(builder)
                    .context("spawning pool builder")?;
            }
        }
        // Removals are deferred until replacements are Ready
        // (create-before-remove); when nothing is building this applies
        // scale-downs immediately.
        Self::reconcile_removals(&self.pools, &self.desired);
        Ok(())
    }

    pub fn set_quotas(&self, quotas: &[(String, f64)]) {
        *self.desired_quotas.lock().unwrap() = quotas.to_vec();
        Self::refresh_dispatcher(&self.pools, &self.dispatcher, &self.desired_quotas);
    }

    /// Serve `trace` (wall-clock) under `policy`. Returns collected metrics.
    ///
    /// The initial decision is applied before the clock starts (warm start).
    pub fn serve(&self, policy: &mut dyn Policy, trace: &RateSeries) -> Result<MetricsCollector> {
        let top_acc = self
            .manifest
            .variants
            .iter()
            .map(|v| v.accuracy)
            .fold(0.0, f64::max);
        let metrics = Arc::new(Mutex::new(MetricsCollector::new(
            10.0,
            self.config.slo_s,
            top_acc,
        )));
        let acc_by_variant: HashMap<String, f64> = self
            .manifest
            .variants
            .iter()
            .map(|v| (v.name.clone(), v.accuracy))
            .collect();

        // Warm start.  The live engine composes the gate with its
        // long-lived `self.dispatcher` directly rather than through
        // `RequestPath`: the dispatcher is shared with pool-builder
        // threads (create-before-remove re-points it on swap-in), while
        // the gate is exclusively the serve loop's — bundling them would
        // force a lock around the gate for no concurrency gain.  The
        // composition order (admit, then route) matches
        // `RequestPath::handle`, which the virtual-time engines use.
        let mut gate = AdmissionGate::new(&self.config.admission, 0, 0);
        let first_rate = trace.rates.first().copied().unwrap_or(0.0);
        let d0 = policy.decide(0.0, &[first_rate], &BTreeMap::new());
        if d0.supply_rps > 0.0 {
            gate.set_supply(0.0, d0.supply_rps);
        }
        *self.desired_batches.lock().unwrap() = d0.batches.clone();
        self.apply(&d0.target, true)?; // warm start: block until ready
        self.set_quotas(&d0.quotas);
        {
            let mut m = metrics.lock().unwrap();
            m.record_prediction(0.0, d0.predicted_lambda);
            m.record_cost(0.0, self.committed().values().sum());
        }

        let arrivals = ArrivalProcess::poisson(trace, self.config.seed);
        let started = Instant::now();
        // Input buffers per batch size: pools of different variants may
        // serve different compiled batch shapes.
        let mut image_cache: HashMap<usize, Arc<Vec<f32>>> = HashMap::new();
        let inflight = Arc::new(AtomicUsize::new(0));
        let mut next_adapt = self.config.adapter_interval_s;
        let duration = trace.duration_s() as f64;

        for &t_arr in &arrivals {
            // Adapter ticks interleaved with arrivals.
            while next_adapt <= t_arr && next_adapt < duration {
                wait_until(started, next_adapt);
                self.adapter_tick(policy, next_adapt, &metrics, &mut gate)?;
                next_adapt += self.config.adapter_interval_s;
            }
            wait_until(started, t_arr);
            let now_s = started.elapsed().as_secs_f64();
            self.rate_window.lock().unwrap().record(now_s);

            // Admission: shed excess offered load at the door (an
            // immediate reject) instead of queueing it past the SLO.
            if !gate.admit(now_s, 0) {
                metrics
                    .lock()
                    .unwrap()
                    .record_request(RequestRecord::shed(now_s, 0));
                continue;
            }
            let variant = match self.dispatcher.route() {
                Some(v) => v,
                None => {
                    metrics.lock().unwrap().record_request(RequestRecord::new(
                        now_s,
                        f64::INFINITY,
                        0.0,
                        0,
                    ));
                    continue;
                }
            };
            let pool = self.pools.read().unwrap().get(&*variant).cloned();
            let Some(pool) = pool else {
                metrics.lock().unwrap().record_request(RequestRecord::new(
                    now_s,
                    f64::INFINITY,
                    0.0,
                    0,
                ));
                continue;
            };
            let metrics_cb = metrics.clone();
            let accuracy = acc_by_variant.get(&*variant).copied().unwrap_or(0.0);
            let inflight_cb = inflight.clone();
            let image = image_cache
                .entry(pool.batch)
                .or_insert_with(|| {
                    Arc::new(vec![
                        0.5f32;
                        self.manifest.input_shape(pool.batch).iter().product()
                    ])
                })
                .clone();
            inflight.fetch_add(1, Ordering::SeqCst);
            let submitted = pool.submit(image, move |result, elapsed| {
                metrics_cb.lock().unwrap().record_request(RequestRecord::new(
                    now_s,
                    if result.is_ok() {
                        elapsed.as_secs_f64()
                    } else {
                        f64::INFINITY
                    },
                    accuracy,
                    0,
                ));
                inflight_cb.fetch_sub(1, Ordering::SeqCst);
            });
            if submitted.is_err() {
                inflight.fetch_sub(1, Ordering::SeqCst);
                metrics.lock().unwrap().record_request(RequestRecord::new(
                    now_s,
                    f64::INFINITY,
                    accuracy,
                    0,
                ));
            }
        }
        // Remaining adapter ticks until the trace ends, then drain.
        while next_adapt < duration {
            wait_until(started, next_adapt);
            self.adapter_tick(policy, next_adapt, &metrics, &mut gate)?;
            next_adapt += self.config.adapter_interval_s;
        }
        let drain_deadline = Instant::now() + Duration::from_secs(60);
        while inflight.load(Ordering::SeqCst) > 0 && Instant::now() < drain_deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        let collector = metrics.lock().unwrap().clone();
        Ok(collector)
    }

    fn adapter_tick(
        &self,
        policy: &mut dyn Policy,
        now: f64,
        metrics: &Arc<Mutex<MetricsCollector>>,
        gate: &mut AdmissionGate,
    ) -> Result<()> {
        let history = {
            let w = self.rate_window.lock().unwrap();
            w.history(self.config.adapter_interval_s.ceil() as usize)
        };
        let committed = self.committed();
        let d = policy.decide(now, &history, &committed);
        if d.supply_rps > 0.0 {
            gate.set_supply(now, d.supply_rps);
        }
        *self.desired_batches.lock().unwrap() = d.batches.clone();
        self.apply(&d.target, false)?; // non-blocking: builders swap in when ready
        self.set_quotas(&d.quotas);
        let mut m = metrics.lock().unwrap();
        m.record_prediction(now, d.predicted_lambda);
        m.record_cost(now, self.committed().values().sum());
        Ok(())
    }
}

fn wait_until(started: Instant, t: f64) {
    let target = Duration::from_secs_f64(t);
    let elapsed = started.elapsed();
    if target > elapsed {
        std::thread::sleep(target - elapsed);
    }
}
