//! Serving engines and the policy interface.
//!
//! Two engines share the coordinator logic (cluster, dispatcher, policies):
//! * [`sim::SimEngine`] — virtual-time discrete-event simulation of the
//!   paper's testbed (M/G/n pods calibrated by measured service times);
//!   regenerates all figures in seconds instead of 20 wall-clock minutes.
//! * [`real::RealEngine`] — live PJRT execution: every request runs the
//!   actual AOT-compiled variant on the CPU client through worker pools.
//!
//! A [`Policy`] is the adaptation brain invoked every interval: InfAdapter,
//! MS+, VPA+, or a static allocation (see [`crate::baselines`] and
//! [`crate::adapter`]).

pub mod real;
pub mod sim;

use std::collections::BTreeMap;

/// What a policy wants the cluster to look like.
#[derive(Debug, Clone, PartialEq)]
pub struct Decision {
    /// variant -> cores; absent or 0 means scale to zero.
    pub target: BTreeMap<String, usize>,
    /// Dispatcher weights λ_m (any non-negative scale).
    pub quotas: Vec<(String, f64)>,
    /// variant -> server-side batch size the variant's pods should form;
    /// absent means 1 (no batching).
    pub batches: BTreeMap<String, usize>,
    /// λ̂ the policy planned for (reporting).
    pub predicted_lambda: f64,
    /// Aggregate sustainable throughput Σ th_m(n, b) of the decided
    /// allocation, rps — what the real engine sizes its admission gate
    /// from (the sim engines recompute supply from the *committed*
    /// allocation instead).  0 = unknown (policy has no throughput
    /// model); the gate then keeps its previous supply.
    pub supply_rps: f64,
}

impl Decision {
    /// Batch size for a variant (1 when the policy did not set one).
    pub fn batch_of(&self, variant: &str) -> usize {
        self.batches.get(variant).copied().unwrap_or(1).max(1)
    }
}

/// Adaptation policy, invoked once per adapter interval.
pub trait Policy: Send {
    fn name(&self) -> String;

    /// `rate_history`: per-second observed arrival rates since the previous
    /// call (oldest first).  `committed`: the cluster's current committed
    /// allocation (variant -> cores), i.e. what is already loaded.
    fn decide(
        &mut self,
        now: f64,
        rate_history: &[f64],
        committed: &BTreeMap<String, usize>,
    ) -> Decision;
}
