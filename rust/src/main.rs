//! InfAdapter CLI — the Layer-3 leader entrypoint.
//!
//! Subcommands:
//! * `info`     — show artifacts/manifest and measured profiles.
//! * `profile`  — measure variant service/readiness times on the real PJRT
//!                engine and write `artifacts/profiles.json`.
//! * `solve`    — one-shot ILP solve for a given λ / budget / β.
//! * `simulate` — run a policy vs a trace on the virtual-time engine.
//! * `fleet`    — multi-service serving on one shared cluster (core
//!                arbitration vs static splits).
//! * `serve`    — live serving of a trace on the real PJRT engine.
//!
//! Flag parsing is hand-rolled (`--flag value` / `--flag=value`): the
//! offline build has no clap.

use anyhow::{bail, Context, Result};
use infadapter::config::Config;
use infadapter::experiment::{self, PolicyKind, Scenario};
use infadapter::fleet::{print_fleet, FleetMode, FleetScenario};
use infadapter::profiler::{self, ProfileSet};
use infadapter::runtime::Manifest;
use infadapter::serving::real::{RealConfig, RealEngine};
use infadapter::solver::{BranchBoundSolver, BruteForceSolver, GreedySolver, Problem, Solver};
use infadapter::util::json::Value;
use infadapter::workload::{RateSeries, Trace};
use std::collections::BTreeMap;
use std::path::PathBuf;

const USAGE: &str = "\
infadapter — SLO-, accuracy- and cost-aware inference serving (EuroMLSys'23 reproduction)

USAGE: infadapter [--artifacts DIR] [--config FILE.json] <command> [flags]

COMMANDS:
  info                               show manifest + measured profiles
  profile  [--iters N] [--variants a,b]
                                     measure real service/readiness times
  solve    --lambda RPS [--budget B] [--beta X] [--max-batch N]
           [--solver brute|bnb|greedy]
                                     one-shot ILP solve (also prints the
                                     admission-gate supply Σ th_m(n, b))
  simulate [--trace T] [--policy P] [--seconds N] [--base RPS] [--out CSV]
           [--admission on|off]
                                     virtual-time experiment
  fleet    [--services N] [--mode M] [--seconds N] [--base RPS] [--budget B]
           [--admission on|off] [--burn-boost F] [--shed-penalty F]
           [--solver-threads K] [--tiers 0,1,..] [--overload on]
           [--faults SPEC] [--out PREFIX] [--telemetry PREFIX]
           [--record FILE] [--replay FILE]
                                     multi-service serving on one shared
                                     cluster (config.fleet when present,
                                     else N synthetic services with
                                     interleaved bursts; --overload makes
                                     every service burst simultaneously —
                                     the admission/tier experiment;
                                     --shed-penalty prices shed traffic
                                     into the per-service ILPs so the
                                     arbiter trades cores against
                                     shedding explicitly;
                                     --solver-threads K bounds the
                                     parallel curve-solve stage: 0 = auto,
                                     1 = serial reference — results are
                                     bit-identical at every K;
                                     --telemetry PREFIX enables the
                                     telemetry plane and writes
                                     PREFIX.json / PREFIX.prom /
                                     PREFIX_flight.json — decisions stay
                                     bit-identical to a telemetry-off run;
                                     --faults SPEC arms the deterministic
                                     fault plane: comma-separated clauses
                                     crash:RATE[:START[:END]] |
                                     slowstart:F |
                                     straggler:RATE[:WINDOW[:MULT]] |
                                     stall:RATE | reactions:on|off |
                                     retries:N | backoff:S | eject:N |
                                     probe:S | hedge:on|off — same seed
                                     replays the same faults at any
                                     --solver-threads;
                                     --record FILE captures the run —
                                     arrival streams, every per-tick
                                     decision record, fault draws — into
                                     a versioned trace (.json readable,
                                     any other extension compact binary;
                                     a bare prefix gets .replay.json);
                                     --replay FILE re-drives the engine
                                     from a recorded trace's embedded
                                     scenario and fails with \"expected
                                     Decision X at tick T, got Y\" on the
                                     first differing field — add
                                     --solver-threads K to check
                                     cross-thread determinism)
  serve    [--trace T] [--policy P] [--seconds N] [--base RPS] [--interval S]
                                     live serving on the real PJRT engine

  traces:   bursty | non-bursty | twitter | steady:<rps>
            | csv:<path>[:scale=<k>][:loop=<seconds>]
            | burst:<start_s>:<len_s>[:<peak_rps>]
            (csv reads `t,rps` or one-column files and an optional
            `# tiers: 0:7,1:3` class-mix directive; Trace::to_csv writes
            full-precision rates, so export -> csv: round-trips exactly)
  policies: infadapter | ms+ | vpa:<variant> | static:<variant>:<cores>
  fleet modes: arbiter | even | vpa:<variant>
  tiers: 0 is the most important; the arbiter honors tiers before weights
         and the admission gate sheds the highest tier numbers first
";

/// `--flag value` / `--flag=value` parser.
struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut flags = BTreeMap::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else {
                    let v = argv
                        .get(i + 1)
                        .with_context(|| format!("--{name} needs a value"))?;
                    flags.insert(name.to_string(), v.clone());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Self { flags, positional })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?}")),
            None => Ok(default),
        }
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?}")),
            None => Ok(default),
        }
    }
}

fn parse_trace(spec: &str, base: f64, seconds: usize, seed: u64) -> Result<RateSeries> {
    Trace::from_spec(spec, base, seconds, seed)
}

fn parse_onoff(v: &str) -> Result<bool> {
    match v {
        "on" | "true" | "1" => Ok(true),
        "off" | "false" | "0" => Ok(false),
        other => bail!("expected on|off, got {other}"),
    }
}

fn parse_policy(spec: &str) -> Result<PolicyKind> {
    Ok(match spec {
        "infadapter" => PolicyKind::InfAdapter,
        "ms+" | "ms" => PolicyKind::MsPlus,
        other => {
            if let Some(v) = other.strip_prefix("vpa:") {
                PolicyKind::Vpa(v.to_string())
            } else if let Some(rest) = other.strip_prefix("static:") {
                let (v, c) = rest
                    .split_once(':')
                    .context("static:<variant>:<cores>")?;
                PolicyKind::Static(v.to_string(), c.parse()?)
            } else {
                bail!("unknown policy {other} (see `infadapter` usage)")
            }
        }
    })
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" {
        print!("{USAGE}");
        return Ok(());
    }
    let args = Args::parse(&argv)?;
    let command = args
        .positional
        .first()
        .map(String::as_str)
        .context("missing command")?;

    let artifacts = args
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(infadapter::runtime::artifacts_dir);
    let mut config = match args.get("config") {
        Some(p) => Config::load(std::path::Path::new(p))?,
        None => Config::default(),
    };
    // Global overrides shared by simulate/fleet.
    if let Some(v) = args.get("admission") {
        config.admission.enabled = parse_onoff(v)?;
    }
    if let Some(v) = args.get("burn-boost") {
        config.fleet.burn_boost = v.parse().with_context(|| format!("--burn-boost {v:?}"))?;
    }
    if let Some(v) = args.get("shed-penalty") {
        config.fleet.shed_penalty = v
            .parse()
            .with_context(|| format!("--shed-penalty {v:?}"))?;
    }
    if args.get("solver-threads").is_some() && command != "fleet" {
        bail!("--solver-threads only applies to the fleet command");
    }
    if args.get("telemetry").is_some() && command != "fleet" {
        bail!("--telemetry only applies to the fleet command");
    }
    for flag in ["record", "replay"] {
        if args.get(flag).is_some() && command != "fleet" {
            bail!("--{flag} only applies to the fleet command");
        }
    }
    if let Some(spec) = args.get("faults") {
        if command != "fleet" {
            bail!("--faults only applies to the fleet command");
        }
        config.fault.apply_spec(spec)?;
    }
    config.validate()?;

    match command {
        "info" => {
            let manifest = Manifest::load(&artifacts)?;
            println!("artifacts: {}", artifacts.display());
            println!(
                "input: {}x{}x3, {} classes",
                manifest.input_hw, manifest.input_hw, manifest.num_classes
            );
            println!(
                "{:<12} {:>8} {:>12} {:>12} {:>10}",
                "variant", "top-1", "params", "flops", "batches"
            );
            for v in &manifest.variants {
                println!(
                    "{:<12} {:>8.2} {:>12} {:>12} {:>10?}",
                    v.name,
                    v.accuracy,
                    v.params,
                    v.flops,
                    v.batch_sizes()
                );
            }
            if let Some(f) = &manifest.forecaster {
                println!(
                    "forecaster: LSTM({}) window={}s horizon={}s train-loss={:.5}",
                    f.units, f.window, f.horizon, f.final_train_loss
                );
            }
            if let Ok(p) = ProfileSet::load(&artifacts.join("profiles.json")) {
                println!("\nmeasured profiles:");
                for v in &p.profiles {
                    println!(
                        "  {:<12} service={:.1}ms readiness={:.2}s th(8)={:.1}rps r2={:.4}",
                        v.name,
                        v.service_time_s * 1000.0,
                        v.readiness_s,
                        v.throughput(8),
                        v.throughput_model.r_squared
                    );
                }
            }
        }
        "profile" => {
            let manifest = Manifest::load(&artifacts)?;
            let iters = args.get_usize("iters", 20)?;
            let filter: Option<Vec<String>> = args
                .get("variants")
                .map(|v| v.split(',').map(str::to_string).collect());
            let set = profiler::measure_real(&artifacts, &manifest, iters, filter.as_deref())?;
            let out = artifacts.join("profiles.json");
            set.save(&out)?;
            println!("wrote {}", out.display());
            for p in &set.profiles {
                println!(
                    "  {:<12} service={:.1}ms readiness={:.2}s",
                    p.name,
                    p.service_time_s * 1000.0,
                    p.readiness_s
                );
            }
        }
        "solve" => {
            let lambda = args.get_f64("lambda", f64::NAN)?;
            anyhow::ensure!(lambda.is_finite(), "--lambda is required");
            let budget = args.get_usize("budget", config.cluster.budget)?;
            let beta = args.get_f64("beta", config.weights.beta)?;
            let profiles = experiment::load_or_default_profiles(&artifacts);
            let mut weights = config.weights;
            weights.beta = beta;
            let mut batching = config.batching;
            batching.max_batch = args.get_usize("max-batch", batching.max_batch)?;
            let problem = Problem::from_profiles_batched(
                &profiles,
                lambda,
                config.slo.latency_ms / 1000.0,
                budget,
                weights,
                &BTreeMap::new(),
                &batching,
            );
            let s: Box<dyn Solver> = match args.get("solver").unwrap_or("brute") {
                "bnb" => Box::new(BranchBoundSolver),
                "greedy" => Box::new(GreedySolver),
                _ => Box::new(BruteForceSolver),
            };
            let t0 = std::time::Instant::now();
            let alloc = s.solve(&problem).context("no allocation")?;
            println!(
                "solved in {:?} (search space {})",
                t0.elapsed(),
                BruteForceSolver::search_space(&problem)
            );
            println!(
                "objective={:.3} AA={:.3} RC={} LC={:.1}s feasible={}",
                alloc.objective,
                alloc.average_accuracy,
                alloc.resource_cost,
                alloc.loading_cost,
                alloc.feasible
            );
            println!(
                "admission-gate supply: {:.1} rps (Σ th_m(n, b) of this allocation)",
                alloc.capacity
            );
            for (v, (c, q)) in &alloc.assignments {
                println!(
                    "  {v:<12} cores={c:<3} quota={q:.1} rps batch={}",
                    alloc.batch_of(v)
                );
            }
        }
        "simulate" => {
            let seconds = args.get_usize("seconds", 1200)?;
            let base = args.get_f64("base", 40.0)?;
            let series = parse_trace(args.get("trace").unwrap_or("bursty"), base, seconds, config.seed)?;
            let kind = parse_policy(args.get("policy").unwrap_or("infadapter"))?;
            let profiles = experiment::load_or_default_profiles(&artifacts);
            let scenario = Scenario::new("cli", series, config.clone(), profiles);
            let result = scenario.run(&kind, &artifacts)?;
            experiment::print_summaries("simulate", std::slice::from_ref(&result));
            if let Some(path) = args.get("out") {
                std::fs::write(path, result.to_csv())?;
                println!("rows -> {path}");
            }
        }
        "fleet" => {
            if let Some(path) = args.get("replay") {
                anyhow::ensure!(
                    args.get("record").is_none(),
                    "--record and --replay are mutually exclusive"
                );
                let mut replayer = infadapter::replay::Replayer::load(std::path::Path::new(path))?;
                // The one knob worth overriding on replay: thread count is
                // required to be result-neutral, so replaying a
                // single-threaded recording at K threads *is* the
                // cross-thread determinism check.
                if let Some(k) = args.get("solver-threads") {
                    replayer.trace.scenario.solver_threads =
                        k.parse().with_context(|| format!("--solver-threads {k:?}"))?;
                }
                let report = replayer.replay(&artifacts)?;
                print_fleet(&format!("replay: {path}"), &report.output);
                if report.divergences.is_empty() {
                    println!("replay OK: {} ticks, 0 divergences", report.ticks);
                    return Ok(());
                }
                for d in report.divergences.iter().take(5) {
                    eprintln!("divergence: {d}");
                }
                if report.divergences.len() > 5 {
                    eprintln!("… and {} more", report.divergences.len() - 5);
                }
                bail!(
                    "replay diverged: {} divergence(s) over {} ticks",
                    report.divergences.len(),
                    report.ticks
                );
            }
            let seconds = args.get_usize("seconds", 1200)?;
            let base = args.get_f64("base", 30.0)?;
            config.fleet.solver_threads =
                args.get_usize("solver-threads", config.fleet.solver_threads)?;
            let profiles = experiment::load_or_default_profiles(&artifacts);
            let mut scenario = if !config.fleet.services.is_empty() {
                anyhow::ensure!(
                    args.get("services").is_none()
                        && args.get("budget").is_none()
                        && args.get("base").is_none()
                        && args.get("tiers").is_none()
                        && args.get("overload").is_none(),
                    "--services/--budget/--base/--tiers/--overload conflict with \
                     the config file's fleet section; edit config.fleet or drop \
                     the flags"
                );
                FleetScenario::from_config(&config, &profiles, seconds)?
            } else {
                let n = args.get_usize("services", 2)?;
                anyhow::ensure!(n >= 1, "--services must be at least 1");
                let budget = args.get_usize("budget", config.cluster.budget)?;
                let mut scenario = if args
                    .get("overload")
                    .map(parse_onoff)
                    .transpose()?
                    .unwrap_or(false)
                {
                    FleetScenario::synthetic_overload(
                        n, base, seconds, budget, false, &config, &profiles,
                    )
                } else {
                    FleetScenario::synthetic(n, base, seconds, budget, &config, &profiles)
                };
                if let Some(spec) = args.get("tiers") {
                    let tiers: Vec<u8> = spec
                        .split(',')
                        .map(|t| t.trim().parse().with_context(|| format!("--tiers {spec:?}")))
                        .collect::<Result<Vec<_>>>()?;
                    anyhow::ensure!(
                        tiers.len() == scenario.services.len(),
                        "--tiers needs one tier per service ({} given, {} services)",
                        tiers.len(),
                        scenario.services.len()
                    );
                    for (svc, t) in scenario.services.iter_mut().zip(tiers) {
                        svc.tier = t;
                    }
                }
                scenario
            };
            if args.get("telemetry").is_some() {
                scenario.telemetry.enabled = true;
            }
            let mode = FleetMode::from_spec(args.get("mode").unwrap_or("arbiter"))?;
            let out = match args.get("record") {
                Some(spec) => {
                    let (out, trace) = scenario.run_recorded(&mode, &artifacts);
                    let path = if spec.ends_with(".json") || spec.ends_with(".bin") {
                        PathBuf::from(spec)
                    } else {
                        PathBuf::from(format!("{spec}.replay.json"))
                    };
                    trace.save(&path)?;
                    println!(
                        "trace -> {} ({} ticks, {} fault draws)",
                        path.display(),
                        trace.ticks.len(),
                        trace.faults.len()
                    );
                    out
                }
                None => scenario.run(&mode, &artifacts),
            };
            print_fleet(
                &format!(
                    "fleet: {} services, budget {}",
                    scenario.services.len(),
                    scenario.global_budget
                ),
                &out,
            );
            if let Some(prefix) = args.get("out") {
                for (r, s) in out.per_service.iter().zip(&scenario.services) {
                    let path = format!("{prefix}_{}.csv", s.name);
                    std::fs::write(
                        &path,
                        infadapter::metrics::rows_to_csv(&r.metrics.rows(r.duration_s)),
                    )?;
                    println!("rows -> {path}");
                }
            }
            if let Some(prefix) = args.get("telemetry") {
                let ft = out
                    .telemetry
                    .as_ref()
                    .context("telemetry enabled but no snapshot produced")?;
                let snap = format!("{prefix}.json");
                std::fs::write(&snap, ft.snapshot_json().to_string_pretty())?;
                let prom = format!("{prefix}.prom");
                std::fs::write(&prom, ft.registry().to_prometheus())?;
                let flight = format!("{prefix}_flight.json");
                std::fs::write(&flight, ft.flight.dump().to_string_pretty())?;
                println!("telemetry -> {snap} + {prom} + {flight}");
            }
        }
        "serve" => {
            let seconds = args.get_usize("seconds", 120)?;
            let base = args.get_f64("base", 4.0)?;
            let interval = args.get_f64("interval", 10.0)?;
            let series = parse_trace(args.get("trace").unwrap_or("bursty"), base, seconds, config.seed)?;
            let kind = parse_policy(args.get("policy").unwrap_or("infadapter"))?;
            let profiles = experiment::load_or_default_profiles(&artifacts);
            let scenario = Scenario::new("serve", series.clone(), config.clone(), profiles);
            let mut policy_obj = scenario.build_policy(&kind, &artifacts);
            let engine = RealEngine::new(
                artifacts.clone(),
                RealConfig {
                    slo_s: config.slo.latency_ms / 1000.0,
                    adapter_interval_s: interval,
                    ..Default::default()
                },
            )?;
            let metrics = engine.serve(policy_obj.as_mut(), &series)?;
            let summary = metrics.summary(&kind.label(), seconds as f64);
            println!("{}", summary_json(&summary).to_string_pretty());
        }
        other => {
            print!("{USAGE}");
            bail!("unknown command {other}");
        }
    }
    Ok(())
}

fn summary_json(s: &infadapter::metrics::RunSummary) -> Value {
    Value::obj(vec![
        ("policy", Value::Str(s.policy.clone())),
        ("total_requests", Value::Num(s.total_requests as f64)),
        ("dropped", Value::Num(s.dropped as f64)),
        ("slo_violation_rate", Value::Num(s.slo_violation_rate)),
        ("avg_accuracy", Value::Num(s.avg_accuracy)),
        ("avg_accuracy_loss", Value::Num(s.avg_accuracy_loss)),
        ("avg_cost_cores", Value::Num(s.avg_cost_cores)),
        ("p99_latency_s", Value::Num(s.p99_latency_s)),
        ("p50_latency_s", Value::Num(s.p50_latency_s)),
        ("mean_latency_s", Value::Num(s.mean_latency_s)),
    ])
}
