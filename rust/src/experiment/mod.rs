//! Experiment harness: run a policy against a trace and produce the rows
//! the paper's figures plot.
//!
//! Each figure bench builds a [`Scenario`], runs it through the simulation
//! engine (calibrated by real PJRT measurements when artifacts exist, or
//! the paper-like profile set otherwise) and prints/persists the series.

use crate::baselines::{MsPlusPolicy, StaticPolicy, VpaPolicy};
use crate::adapter::InfAdapterPolicy;
use crate::config::Config;
use crate::forecaster;
use crate::metrics::{IntervalRow, RunSummary};
use crate::profiler::ProfileSet;
use crate::serving::sim::{SimConfig, SimEngine, SimResult};
use crate::serving::Policy;
use crate::solver::BranchBoundSolver;
use crate::workload::RateSeries;
use anyhow::Result;
use std::path::Path;

/// Which policy to instantiate for a run.
#[derive(Debug, Clone, PartialEq)]
pub enum PolicyKind {
    InfAdapter,
    MsPlus,
    /// VPA pinned to the named variant.
    Vpa(String),
    Static(String, usize),
}

impl PolicyKind {
    pub fn label(&self) -> String {
        match self {
            PolicyKind::InfAdapter => "InfAdapter".into(),
            PolicyKind::MsPlus => "MS+".into(),
            PolicyKind::Vpa(v) => format!("VPA-{}", v.trim_start_matches("resnet")),
            PolicyKind::Static(v, c) => format!("Static-{v}x{c}"),
        }
    }
}

/// A fully-specified experiment.
#[derive(Clone)]
pub struct Scenario {
    pub name: String,
    pub trace: RateSeries,
    pub config: Config,
    pub profiles: ProfileSet,
}

impl Scenario {
    pub fn new(name: &str, trace: RateSeries, config: Config, profiles: ProfileSet) -> Self {
        Self {
            name: name.to_string(),
            trace,
            config,
            profiles,
        }
    }

    /// Build the policy object for `kind` under this scenario's config.
    pub fn build_policy(&self, kind: &PolicyKind, artifacts_dir: &Path) -> Box<dyn Policy> {
        let c = &self.config;
        match kind {
            PolicyKind::InfAdapter => Box::new(
                InfAdapterPolicy::new(
                    self.profiles.clone(),
                    forecaster::build(&c.adapter.forecaster, artifacts_dir, c.adapter.interval_s),
                    // exact and ~700x faster than brute force (see §Perf)
                    Box::new(BranchBoundSolver),
                    c.weights,
                    c.slo.latency_ms / 1000.0,
                    c.cluster.budget,
                    c.adapter.headroom,
                )
                .with_batching(c.batching),
            ),
            PolicyKind::MsPlus => Box::new(MsPlusPolicy::new(
                self.profiles.clone(),
                forecaster::build(&c.adapter.forecaster, artifacts_dir, c.adapter.interval_s),
                c.weights,
                c.slo.latency_ms / 1000.0,
                c.cluster.budget,
                c.adapter.headroom,
            )),
            PolicyKind::Vpa(variant) => Box::new(VpaPolicy::new(
                variant,
                self.profiles.clone(),
                c.cluster.budget,
            )),
            PolicyKind::Static(variant, cores) => Box::new(StaticPolicy::new(variant, *cores)),
        }
    }

    /// Run one policy through the simulator.
    pub fn run(&self, kind: &PolicyKind, artifacts_dir: &Path) -> Result<RunOutput> {
        let mut policy = self.build_policy(kind, artifacts_dir);
        let sim = SimEngine::new(
            self.profiles.clone(),
            SimConfig {
                slo_s: self.config.slo.latency_ms / 1000.0,
                adapter_interval_s: self.config.adapter.interval_s,
                node_cores: self.config.cluster.node_cores.clone(),
                seed: self.config.seed,
                bucket_s: 10.0,
                queue_timeout_s: 10.0,
                batch_max_wait_s: self.config.batching.max_wait_s,
                admission: self.config.admission,
                solver_threads: self.config.fleet.solver_threads,
                telemetry: self.config.telemetry,
                fault: self.config.fault,
            },
        );
        let result: SimResult = sim.run(policy.as_mut(), &self.trace);
        let label = kind.label();
        let rows = result.metrics.rows(result.duration_s);
        let summary = result.metrics.summary(&label, result.duration_s);
        Ok(RunOutput {
            label,
            rows,
            summary,
        })
    }

    /// Run a set of policies (one figure's worth of lines).
    pub fn compare(&self, kinds: &[PolicyKind], artifacts_dir: &Path) -> Result<Vec<RunOutput>> {
        kinds.iter().map(|k| self.run(k, artifacts_dir)).collect()
    }
}

/// One (policy, scenario) result.
#[derive(Debug, Clone)]
pub struct RunOutput {
    pub label: String,
    pub rows: Vec<IntervalRow>,
    pub summary: RunSummary,
}

impl RunOutput {
    pub fn to_csv(&self) -> String {
        crate::metrics::rows_to_csv(&self.rows)
    }
}

/// The standard comparison set of the paper's figures 5/7/8/9/10.
pub fn paper_policy_set() -> Vec<PolicyKind> {
    vec![
        PolicyKind::InfAdapter,
        PolicyKind::MsPlus,
        PolicyKind::Vpa("resnet18".into()),
        PolicyKind::Vpa("resnet50".into()),
        PolicyKind::Vpa("resnet152".into()),
    ]
}

/// Pretty-print a summary table (the benches' terminal output).
pub fn print_summaries(title: &str, outs: &[RunOutput]) {
    println!("\n== {title} ==");
    println!(
        "{:<14} {:>9} {:>8} {:>10} {:>10} {:>10} {:>9}",
        "policy", "requests", "SLOviol%", "acc.loss", "cost(avg)", "P99(ms)", "dropped"
    );
    for o in outs {
        let s = &o.summary;
        println!(
            "{:<14} {:>9} {:>8.2} {:>10.3} {:>10.2} {:>10.0} {:>9}",
            o.label,
            s.total_requests,
            s.slo_violation_rate * 100.0,
            s.avg_accuracy_loss,
            s.avg_cost_cores,
            s.p99_latency_s * 1000.0,
            s.dropped
        );
    }
}

/// Saturation search parameters: binary-search the highest offered steady
/// load a (variant, cores) pod sustains within the SLO (the paper's
/// Figure 1 measurement procedure).  One parameterized probe backs every
/// caller — [`find_saturation`] (Figures 1/2), the batching ablation
/// ([`find_saturation_batched`], Figure 4), and the fleet bench's
/// per-SLO capacity context (`benches/fig_fleet.rs`) — so the procedure
/// cannot drift between experiments.
#[derive(Debug, Clone)]
pub struct SaturationProbe {
    pub slo_s: f64,
    /// Server-side batch size pinned for the whole probe (1 = unbatched).
    pub batch: usize,
    pub seed: u64,
    /// Steady-load seconds per attempt.
    pub duration_s: usize,
    /// Bisection width, rps.
    pub resolution_rps: f64,
}

impl Default for SaturationProbe {
    fn default() -> Self {
        Self {
            slo_s: 0.75,
            batch: 1,
            seed: 0,
            duration_s: 90,
            resolution_rps: 0.5,
        }
    }
}

impl SaturationProbe {
    /// Highest steady rps the pod sustains with zero drops and P99 within
    /// the SLO (exponential bracket, then bisection).
    pub fn measure(&self, profiles: &ProfileSet, variant: &str, cores: usize) -> f64 {
        use crate::baselines::StaticPolicy;
        use crate::workload::Trace;
        let attempt = |rps: f64| -> bool {
            if rps <= 0.0 {
                return true;
            }
            let sim = SimEngine::new(
                profiles.clone(),
                SimConfig {
                    slo_s: self.slo_s,
                    adapter_interval_s: 1e9, // static: never adapt
                    node_cores: vec![cores.max(48)],
                    seed: self.seed,
                    bucket_s: 10.0,
                    queue_timeout_s: 10.0,
                    batch_max_wait_s: 0.05,
                    admission: Default::default(),
                    solver_threads: 0,
                    telemetry: Default::default(),
                    fault: Default::default(),
                },
            );
            let mut policy = StaticPolicy::with_batch(variant, cores, self.batch);
            let res = sim.run(&mut policy, &Trace::steady(rps, self.duration_s));
            let s = res.metrics.summary("sat", self.duration_s as f64);
            s.dropped == 0 && s.p99_latency_s <= self.slo_s
        };
        let mut lo = 0.0f64;
        let mut hi = 4.0f64;
        while attempt(hi) && hi < 100_000.0 {
            lo = hi;
            hi *= 2.0;
        }
        while hi - lo > self.resolution_rps {
            let mid = (lo + hi) / 2.0;
            if attempt(mid) {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo
    }
}

/// Measured sustained throughput of one (variant, cores) pod under the
/// SLO (thin wrapper over [`SaturationProbe`]).
pub fn find_saturation(
    profiles: &ProfileSet,
    variant: &str,
    cores: usize,
    slo_s: f64,
    seed: u64,
) -> f64 {
    SaturationProbe {
        slo_s,
        seed,
        ..Default::default()
    }
    .measure(profiles, variant, cores)
}

/// [`find_saturation`] with server-side batching pinned at `batch` — the
/// Figure 4 batching-vs-no-batching measurement at equal core budgets.
pub fn find_saturation_batched(
    profiles: &ProfileSet,
    variant: &str,
    cores: usize,
    batch: usize,
    slo_s: f64,
    seed: u64,
) -> f64 {
    SaturationProbe {
        slo_s,
        batch,
        seed,
        ..Default::default()
    }
    .measure(profiles, variant, cores)
}

/// Load measured profiles if `profiles.json` exists next to the artifacts,
/// else fall back to the paper-like calibration (CI without artifacts).
pub fn load_or_default_profiles(artifacts_dir: &Path) -> ProfileSet {
    let path = artifacts_dir.join("profiles.json");
    match ProfileSet::load(&path) {
        Ok(p) => p,
        Err(_) => ProfileSet::paper_like(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Trace;

    fn scenario(trace: RateSeries) -> Scenario {
        Scenario::new(
            "test",
            trace,
            Config::default(),
            ProfileSet::paper_like(),
        )
    }

    #[test]
    fn infadapter_beats_vpa152_on_violations_under_burst() {
        let s = scenario(Trace::bursty(40.0, 100.0, 600, 11));
        let dir = std::path::Path::new("/nonexistent");
        let inf = s.run(&PolicyKind::InfAdapter, dir).unwrap();
        let vpa152 = s.run(&PolicyKind::Vpa("resnet152".into()), dir).unwrap();
        assert!(
            inf.summary.slo_violation_rate <= vpa152.summary.slo_violation_rate + 0.02,
            "inf {} vs vpa152 {}",
            inf.summary.slo_violation_rate,
            vpa152.summary.slo_violation_rate
        );
    }

    #[test]
    fn infadapter_more_accurate_than_vpa18() {
        let s = scenario(Trace::non_bursty(20.0, 60.0, 600, 12));
        let dir = std::path::Path::new("/nonexistent");
        let inf = s.run(&PolicyKind::InfAdapter, dir).unwrap();
        let vpa18 = s.run(&PolicyKind::Vpa("resnet18".into()), dir).unwrap();
        assert!(
            inf.summary.avg_accuracy_loss < vpa18.summary.avg_accuracy_loss,
            "inf {} vs vpa18 {}",
            inf.summary.avg_accuracy_loss,
            vpa18.summary.avg_accuracy_loss
        );
    }

    #[test]
    fn infadapter_accuracy_at_least_msplus() {
        let s = scenario(Trace::bursty(40.0, 100.0, 600, 13));
        let dir = std::path::Path::new("/nonexistent");
        let inf = s.run(&PolicyKind::InfAdapter, dir).unwrap();
        let ms = s.run(&PolicyKind::MsPlus, dir).unwrap();
        assert!(
            inf.summary.avg_accuracy_loss <= ms.summary.avg_accuracy_loss + 0.3,
            "inf {} vs ms {}",
            inf.summary.avg_accuracy_loss,
            ms.summary.avg_accuracy_loss
        );
    }

    #[test]
    fn batching_config_reaches_infadapter_and_lifts_goodput_under_pressure() {
        // Budget 8 cannot cover 220 rps unbatched (resnet18 peaks ~184);
        // with batching the same budget sustains it.
        let profiles = ProfileSet::paper_like();
        let dir = std::path::Path::new("/nonexistent");
        let mut config = Config::default();
        config.cluster.budget = 8;
        config.adapter.forecaster = "last_max".into();
        let trace = Trace::steady(220.0, 300);
        let plain = Scenario::new("plain", trace.clone(), config.clone(), profiles.clone())
            .run(&PolicyKind::InfAdapter, dir)
            .unwrap();
        config.batching.max_batch = 8;
        let batched = Scenario::new("batched", trace, config, profiles)
            .run(&PolicyKind::InfAdapter, dir)
            .unwrap();
        assert!(
            batched.summary.goodput_rps > plain.summary.goodput_rps * 1.2,
            "batched {} vs plain {}",
            batched.summary.goodput_rps,
            plain.summary.goodput_rps
        );
    }

    #[test]
    fn saturation_probe_backs_the_wrappers() {
        let profiles = ProfileSet::paper_like();
        let a = find_saturation(&profiles, "resnet50", 4, 0.75, 3);
        let b = SaturationProbe {
            slo_s: 0.75,
            seed: 3,
            ..Default::default()
        }
        .measure(&profiles, "resnet50", 4);
        assert_eq!(a, b, "wrapper must be the probe verbatim");
        // resnet50@4 models ~40 rps capacity; saturation sits below it
        assert!(a > 20.0 && a < 60.0, "{a}");
        // a tighter SLO can only lower the measured saturation
        let tight = SaturationProbe {
            slo_s: 0.3,
            seed: 3,
            ..Default::default()
        }
        .measure(&profiles, "resnet50", 4);
        assert!(tight <= a + 1e-9, "tight {tight} vs {a}");
    }

    #[test]
    fn rows_cover_the_whole_trace() {
        let s = scenario(Trace::steady(30.0, 300));
        let out = s
            .run(&PolicyKind::Static("resnet18".into(), 4), std::path::Path::new("/nonexistent"))
            .unwrap();
        assert_eq!(out.rows.len(), 30); // 300s / 10s buckets
        assert!(out.rows.iter().all(|r| r.observed_rps > 0.0));
    }
}
