//! The InfAdapter policy: forecast → solve → enforce (paper §4 "Adapter").
//!
//! Every adaptation interval the adapter (1) feeds the observed per-second
//! arrival rates to the forecaster, (2) predicts the next-interval max
//! workload λ̂, (3) solves the ILP of Eq. 1 for the best variant set + core
//! allocation given the current cluster state (loading costs are relative
//! to what is already loaded), and (4) emits the target allocation, the
//! per-variant quotas λ_m for the dispatcher, and — when batching is
//! enabled — the per-variant batch sizes for the serving pods.

use crate::config::{BatchingConfig, ObjectiveWeights};
use crate::forecaster::Forecaster;
use crate::profiler::ProfileSet;
use crate::serving::{Decision, Policy};
use crate::solver::{Allocation, Problem, SolveStats, Solver, ValueCurve};
use std::collections::BTreeMap;

/// The paper's system, as a [`Policy`].
pub struct InfAdapterPolicy {
    pub profiles: ProfileSet,
    pub forecaster: Box<dyn Forecaster>,
    // `Solver: Send` is a supertrait, so the box is Send without an
    // explicit `+ Send` here.
    pub solver: Box<dyn Solver>,
    pub weights: ObjectiveWeights,
    pub slo_s: f64,
    pub budget: usize,
    /// Multiplicative headroom on λ̂ (absorbs forecast error).
    pub headroom: f64,
    /// Floor on λ̂ so the system never scales to zero capacity.
    pub min_lambda: f64,
    /// Hysteresis: keep the current allocation unless the newly solved
    /// objective beats it by more than this (suppresses churn — every
    /// reallocation pays a readiness window at reduced capacity).
    pub hysteresis: f64,
    /// Server-side batching knobs (default: disabled, `max_batch = 1`).
    pub batching: BatchingConfig,
    /// Per-request lost-goodput price the ILP charges on offered load its
    /// capacity cannot cover (`shed_penalty · max(0, λ̂_offered −
    /// capacity)`); already tier-weighted by the fleet layer (see
    /// `fleet::shed_value_weight`).  0 (the default) disables the term
    /// and keeps every solve bit-identical to the unpriced objective.
    pub shed_penalty: f64,
    /// Raw predicted offered rate from the last
    /// [`Self::observe_and_predict`] (pre-headroom, pre-floor) — what the
    /// shed pricing charges against.
    last_offered: f64,
    last_allocation: Option<Allocation>,
}

impl InfAdapterPolicy {
    pub fn new(
        profiles: ProfileSet,
        forecaster: Box<dyn Forecaster>,
        solver: Box<dyn Solver>,
        weights: ObjectiveWeights,
        slo_s: f64,
        budget: usize,
        headroom: f64,
    ) -> Self {
        Self {
            profiles,
            forecaster,
            solver,
            weights,
            slo_s,
            budget,
            headroom,
            min_lambda: 1.0,
            hysteresis: 0.5,
            batching: BatchingConfig::default(),
            shed_penalty: 0.0,
            last_offered: 0.0,
            last_allocation: None,
        }
    }

    /// Enable server-side batching (builder style).
    pub fn with_batching(mut self, batching: BatchingConfig) -> Self {
        self.batching = batching;
        self
    }

    /// Price shed traffic into every solve (builder style); the penalty
    /// is the per-request lost-goodput price, tier-weighted by the caller.
    pub fn with_shed_pricing(mut self, shed_penalty: f64) -> Self {
        self.shed_penalty = shed_penalty.max(0.0);
        self
    }

    /// Raw predicted offered rate from the last observation (diagnostics
    /// and the `CurveCache` key).
    pub fn last_offered(&self) -> f64 {
        self.last_offered
    }

    /// Last solved allocation (diagnostics / benches).
    pub fn last_allocation(&self) -> Option<&Allocation> {
        self.last_allocation.as_ref()
    }

    /// First half of [`Policy::decide`]: feed the observed rates to the
    /// forecaster and return the planned-for workload λ̂ (headroom and
    /// floor applied).  Split out so the fleet layer can learn λ̂, ask for
    /// a value curve, and only then commit to a budget — calling this once
    /// followed by [`Self::decide_with_lambda`] is exactly `decide`.
    pub fn observe_and_predict(&mut self, rate_history: &[f64]) -> f64 {
        for &r in rate_history {
            self.forecaster.observe(r);
        }
        let raw = self.forecaster.predict_max();
        // The raw forecast is the *offered* rate shed pricing charges
        // against; the returned planning λ̂ adds headroom and the floor.
        self.last_offered = raw.max(0.0);
        (raw * self.headroom).max(self.min_lambda)
    }

    /// Build the ILP instance for one solve: profiles + batching as
    /// before, with shed pricing injected when a penalty is configured
    /// (the guard keeps unpriced problems bit-identical to PR 4).
    fn build_problem(
        &self,
        lambda_hat: f64,
        committed: &BTreeMap<String, usize>,
        budget: usize,
    ) -> Problem {
        let problem = Problem::from_profiles_batched(
            &self.profiles,
            lambda_hat,
            self.slo_s,
            budget,
            self.weights,
            committed,
            &self.batching,
        );
        if self.shed_penalty != 0.0 {
            problem.with_shed_pricing(self.last_offered, self.shed_penalty)
        } else {
            problem
        }
    }

    /// Best-objective value curve over candidate core grants `0..=cap` —
    /// what the fleet arbiter asks this service for.  Pure solver work: it
    /// touches neither the forecaster nor any RNG, so it may run between
    /// [`Self::observe_and_predict`] and [`Self::decide_with_lambda`]
    /// without perturbing the decision sequence.  One single-pass
    /// [`Solver::solve_curve`] replaces the old per-grant re-solve loop.
    /// With shed pricing configured the curve is priced against
    /// [`Self::last_offered`] (set by the preceding observation), so a
    /// shedding service's marginal utility rises in the *same tick* the
    /// forecast sees the overload — before its burn meter trips.
    pub fn value_curve(
        &self,
        lambda_hat: f64,
        committed: &BTreeMap<String, usize>,
        cap: usize,
    ) -> Vec<f64> {
        self.value_curve_seeded(lambda_hat, committed, cap, None)
            .into_values()
    }

    /// [`Self::value_curve`] in full ([`ValueCurve`], incl. the per-cost
    /// winner vectors) with an optional warm start from a previous tick's
    /// curve — what the fleet layer's `CurveCache` calls.  Exactness does
    /// not depend on the seed (winners are re-scored under the current
    /// problem), so the values are identical whether or not a seed is
    /// supplied.
    pub fn value_curve_seeded(
        &self,
        lambda_hat: f64,
        committed: &BTreeMap<String, usize>,
        cap: usize,
        seed: Option<&ValueCurve>,
    ) -> ValueCurve {
        let problem = self.build_problem(lambda_hat, committed, cap);
        self.solver.solve_curve_seeded(&problem, cap, seed)
    }

    /// [`Self::value_curve_seeded`] plus the solver's [`SolveStats`] — the
    /// telemetry plane's entry point.  The curve is identical to the
    /// unstated variant on the same inputs; the stats only count work the
    /// solve already does.
    pub fn value_curve_seeded_stats(
        &self,
        lambda_hat: f64,
        committed: &BTreeMap<String, usize>,
        cap: usize,
        seed: Option<&ValueCurve>,
    ) -> (ValueCurve, SolveStats) {
        let problem = self.build_problem(lambda_hat, committed, cap);
        self.solver.solve_curve_stats(&problem, cap, seed)
    }

    /// Second half of [`Policy::decide`]: solve for the best variant set
    /// and allocation inside `self.budget` given a λ̂ already produced by
    /// [`Self::observe_and_predict`].
    pub fn decide_with_lambda(
        &mut self,
        lambda_hat: f64,
        committed: &BTreeMap<String, usize>,
    ) -> Decision {
        let problem = self.build_problem(lambda_hat, committed, self.budget);
        let mut allocation = self
            .solver
            .solve(&problem)
            .expect("solver returned no allocation");
        // Hysteresis: if what is already running is feasible for λ̂ and the
        // solved optimum is only marginally better, keep the current
        // allocation — a reallocation serves at reduced capacity for a full
        // readiness window.
        if !committed.is_empty() {
            let current_cores: Vec<usize> = problem
                .variants
                .iter()
                .map(|v| committed.get(&v.name).copied().unwrap_or(0))
                .collect();
            if current_cores.iter().sum::<usize>() <= problem.budget {
                if let Some(current) = crate::solver::score(&problem, &current_cores) {
                    if current.feasible
                        && allocation.objective - current.objective < self.hysteresis
                    {
                        allocation = current;
                    }
                }
            }
        }
        let target: BTreeMap<String, usize> = allocation
            .assignments
            .iter()
            .filter(|(_, &(c, _))| c > 0)
            .map(|(v, &(c, _))| (v.clone(), c))
            .collect();
        // quota_weights borrows; owned names materialize only here, at the
        // Decision boundary.
        let quotas: Vec<(String, f64)> = allocation
            .quota_weights()
            .into_iter()
            .map(|(v, q)| (v.to_string(), q))
            .collect();
        let batches: BTreeMap<String, usize> = allocation
            .batches
            .iter()
            .filter(|(_, &b)| b > 1)
            .map(|(v, &b)| (v.clone(), b))
            .collect();
        let decision = Decision {
            target,
            quotas,
            batches,
            predicted_lambda: lambda_hat,
            // Σ th_m(n, b) of the decided allocation: the admission
            // gate's supply signal on the real engine.
            supply_rps: allocation.capacity,
        };
        self.last_allocation = Some(allocation);
        decision
    }
}

impl Policy for InfAdapterPolicy {
    fn name(&self) -> String {
        format!("infadapter[{}]", self.solver.name())
    }

    fn decide(
        &mut self,
        _now: f64,
        rate_history: &[f64],
        committed: &BTreeMap<String, usize>,
    ) -> Decision {
        let lambda_hat = self.observe_and_predict(rate_history);
        self.decide_with_lambda(lambda_hat, committed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::forecaster::LastMaxForecaster;
    use crate::solver::BruteForceSolver;

    fn policy(beta: f64, budget: usize) -> InfAdapterPolicy {
        InfAdapterPolicy::new(
            ProfileSet::paper_like(),
            Box::new(LastMaxForecaster::new(120, 1.0)),
            Box::new(BruteForceSolver),
            ObjectiveWeights {
                alpha: 1.0,
                beta,
                gamma: 0.001,
            },
            0.75,
            budget,
            1.1,
        )
    }

    #[test]
    fn covers_predicted_load() {
        let mut p = policy(0.05, 20);
        let history = vec![70.0; 60];
        let d = p.decide(0.0, &history, &BTreeMap::new());
        assert!(d.predicted_lambda >= 70.0);
        let alloc = p.last_allocation().unwrap();
        assert!(alloc.feasible);
        assert!(alloc.capacity >= d.predicted_lambda - 1e-9);
        assert!(!d.quotas.is_empty());
    }

    #[test]
    fn scales_down_after_a_spike_passes() {
        let mut p = policy(0.05, 20);
        let spike = vec![100.0; 60];
        let d1 = p.decide(0.0, &spike, &BTreeMap::new());
        let cores_spike: usize = d1.target.values().sum();
        // 150 quiet seconds push the spike out of the 120s window
        let committed = d1.target.clone();
        let mut d2 = None;
        for i in 0..3 {
            d2 = Some(p.decide(
                30.0 * (i + 1) as f64,
                &vec![10.0; 60],
                &committed,
            ));
        }
        let cores_quiet: usize = d2.unwrap().target.values().sum();
        assert!(
            cores_quiet < cores_spike,
            "quiet {cores_quiet} !< spike {cores_spike}"
        );
    }

    #[test]
    fn uses_multiple_variants_at_moderate_budget() {
        // The paper's Figure 2 observation: a mixed set beats one variant.
        let mut p = policy(0.05, 14);
        let d = p.decide(0.0, &vec![75.0; 60], &BTreeMap::new());
        // with 14 cores and 75 rps (plus headroom), a single top variant
        // can't cover the load: the solver must mix
        assert!(
            d.target.len() >= 2,
            "expected a variant set, got {:?}",
            d.target
        );
    }

    #[test]
    fn batching_extends_coverage_and_surfaces_batch_sizes() {
        // 250 rps (×1.1 headroom) exceeds every unbatched capacity at B=8…
        let mut plain = policy(0.05, 8);
        let d_plain = plain.decide(0.0, &vec![250.0; 60], &BTreeMap::new());
        assert!(d_plain.batches.is_empty());
        assert!(!plain.last_allocation().unwrap().feasible);
        // …but the batched solver covers it on the same budget.
        let mut batched = policy(0.05, 8).with_batching(BatchingConfig {
            max_batch: 8,
            max_wait_s: 0.05,
        });
        let d = batched.decide(0.0, &vec![250.0; 60], &BTreeMap::new());
        let alloc = batched.last_allocation().unwrap();
        assert!(alloc.feasible, "{alloc:?}");
        assert!(
            d.batches.values().any(|&b| b > 1),
            "expected batch sizes in {:?}",
            d.batches
        );
        for (v, &b) in &d.batches {
            assert!(d.target.contains_key(v));
            assert_eq!(d.batch_of(v), b);
        }
    }

    #[test]
    fn split_decide_matches_decide_exactly() {
        // The fleet path runs observe_and_predict + decide_with_lambda with
        // value-curve solves in between; it must reproduce decide()
        // verbatim, or single-service fleet runs stop being bit-identical.
        let mut whole = policy(0.05, 20);
        let mut split = policy(0.05, 20);
        let history = vec![70.0; 60];
        let committed = BTreeMap::from([("resnet18".to_string(), 4)]);
        let d1 = whole.decide(0.0, &history, &committed);
        let lambda = split.observe_and_predict(&history);
        let _curves_are_pure = split.value_curve(lambda, &committed, 20);
        let d2 = split.decide_with_lambda(lambda, &committed);
        assert_eq!(d1.predicted_lambda, d2.predicted_lambda);
        assert_eq!(d1.target, d2.target);
        assert_eq!(d1.quotas, d2.quotas);
        assert_eq!(d1.batches, d2.batches);
    }

    #[test]
    fn value_curve_is_monotone_and_consistent_with_the_solve() {
        let p = policy(0.05, 20);
        let curve = p.value_curve(77.0, &BTreeMap::new(), 20);
        assert_eq!(curve.len(), 21);
        for w in curve.windows(2) {
            assert!(w[1] >= w[0] - 1e-9, "nondecreasing: {curve:?}");
        }
        let mut solver_view = policy(0.05, 20);
        solver_view.decide_with_lambda(77.0, &BTreeMap::new());
        let best = solver_view.last_allocation().unwrap().objective;
        assert!((curve[20] - best).abs() < 1e-9);
    }

    #[test]
    fn shed_pricing_steepens_the_overloaded_value_curve() {
        // An overloaded service (λ̂ far beyond what 8 cores can carry):
        // the priced curve must sit below the unpriced one wherever
        // capacity falls short of the offered load, with the *gap
        // shrinking* as the grant grows — that widening marginal is what
        // pulls arbiter cores toward shedding services.
        let mut plain = policy(0.05, 8);
        let mut priced = policy(0.05, 8).with_shed_pricing(2.0);
        let history = vec![400.0; 60];
        let l1 = plain.observe_and_predict(&history);
        let l2 = priced.observe_and_predict(&history);
        assert_eq!(l1, l2, "pricing must not perturb the forecast");
        assert!((priced.last_offered() - 400.0).abs() < 1e-9);
        let u = plain.value_curve(l1, &BTreeMap::new(), 8);
        let p = priced.value_curve(l2, &BTreeMap::new(), 8);
        // priced ≤ unpriced pointwise; strictly below while shedding
        for (g, (&a, &b)) in p.iter().zip(&u).enumerate() {
            assert!(a <= b + 1e-9, "g={g}: priced {a} above unpriced {b}");
        }
        assert!(p[1] < u[1] - 1.0, "shedding grant must be priced down");
        // the priced marginal at low grants exceeds the unpriced one
        assert!(
            (p[2] - p[1]) > (u[2] - u[1]) + 1e-9,
            "priced marginal {} !> unpriced {}",
            p[2] - p[1],
            u[2] - u[1]
        );
        // penalty 0 reproduces the unpriced curve bit-for-bit
        let mut zero = policy(0.05, 8).with_shed_pricing(0.0);
        let l0 = zero.observe_and_predict(&history);
        assert_eq!(zero.value_curve(l0, &BTreeMap::new(), 8), u);
    }

    #[test]
    fn priced_split_decide_matches_decide_exactly() {
        // The fleet protocol (observe → curve → decide) must stay an
        // exact factoring of decide() when shed pricing is on.
        let mut whole = policy(0.05, 12).with_shed_pricing(1.5);
        let mut split = policy(0.05, 12).with_shed_pricing(1.5);
        let history = vec![250.0; 60];
        let committed = BTreeMap::from([("resnet18".to_string(), 4)]);
        let d1 = whole.decide(0.0, &history, &committed);
        let lambda = split.observe_and_predict(&history);
        let _ = split.value_curve(lambda, &committed, 12);
        let d2 = split.decide_with_lambda(lambda, &committed);
        assert_eq!(d1.predicted_lambda, d2.predicted_lambda);
        assert_eq!(d1.target, d2.target);
        assert_eq!(d1.quotas, d2.quotas);
        assert_eq!(d1.supply_rps, d2.supply_rps);
    }

    #[test]
    fn never_returns_empty_capacity_under_load() {
        let mut p = policy(0.2, 8);
        let d = p.decide(0.0, &vec![5.0; 30], &BTreeMap::new());
        assert!(!d.target.is_empty());
        let total: usize = d.target.values().sum();
        assert!(total >= 1);
    }
}
