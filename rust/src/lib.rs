//! # InfAdapter — SLO-, accuracy-, and cost-aware inference serving
//!
//! Reproduction of *"Reconciling High Accuracy, Cost-Efficiency, and Low
//! Latency of Inference Serving Systems"* (Salmani et al., EuroMLSys '23).
//!
//! InfAdapter proactively selects a **set** of model variants together with
//! their per-variant CPU allocations by solving an ILP every adaptation
//! interval, maximizing `α·AA − (β·RC + γ·LC)` subject to latency-SLO and
//! budget constraints, then load-balances requests across the active
//! variants with weighted round-robin.
//!
//! Architecture (see `DESIGN.md`):
//! * [`runtime`] — PJRT bridge; loads AOT HLO artifacts produced by
//!   `python/compile/aot.py` (jax + Pallas). Python never runs at serve time.
//! * [`workload`] — trace generators and arrival processes.
//! * [`monitoring`] — arrival-rate windows and latency percentile tracking.
//! * [`profiler`] — variant profiling + linear-regression throughput models.
//! * [`forecaster`] — AOT LSTM + classical baselines.
//! * [`solver`] — the ILP: brute-force, branch & bound, greedy; whole
//!   per-budget value curves from one single-pass solve; optional shed
//!   pricing charges the offered load an allocation cannot cover.
//! * [`dispatcher`] — the admission-controlled request path: a
//!   token-bucket gate sized from granted capacity (sheds overload at the
//!   door, lowest priority tier first) in front of weighted round-robin
//!   over per-variant quotas.
//! * [`cluster`] — simulated Kubernetes substrate (pods, readiness,
//!   create-before-remove).
//! * [`serving`] — backend engines: real (PJRT worker pools) and simulated
//!   (virtual-time M/G/n queues calibrated by real measurements).
//! * [`adapter`] — the control loop: monitor → forecast → solve → enforce.
//! * [`fleet`] — multi-service layer, sharded: each service's event loop
//!   (a `util::sched::TimerWheel` calendar queue with heap-exact pop
//!   order), RNG, gate, dispatcher, pods view, request-state arena, and
//!   metrics live in a `fleet::shard::ServiceShard`; the orchestrator
//!   drives an explicit five-stage tick protocol (observe → solve ∥ →
//!   arbitrate → apply ∥ → advance ∥, parallel stages fanned out over one
//!   persistent `util::pool::WorkerPool` — zero spawns per tick —
//!   bit-identical to the serial path at every `solver_threads`).  The
//!   top-level core arbiter re-partitions the global budget every
//!   interval by heap water-filling on priority-weighted marginal utility
//!   (per-service ILP value curves, cached and warm-started across
//!   ticks), honoring strict priority tiers lexicographically, boosting
//!   services burning their SLO error budget, and — with shed pricing on
//!   — trading cores against tier-weighted shedding within the tick that
//!   forecasts it.
//! * [`telemetry`] — the observability plane: a registry of counters /
//!   gauges / log-bucketed histograms with per-shard lock-free recording
//!   and deterministic index-order fan-in, a tick-stage profiler (the
//!   five stages plus a pool-dispatch overhead lap),
//!   solver/request-path introspection counters, and an
//!   anomaly-triggered flight recorder (last K `TickTrace`s, dumped to
//!   JSON on SLO-burn or shed trips).  Zero-overhead when disabled and
//!   bit-identical on vs off — a pure observer of the decision path.
//! * [`fault`] — the deterministic fault plane: per-service seeded
//!   streams inject pod crashes (with slow-start respawns), stragglers,
//!   and solver stalls; the failure-aware reactions — health-checked
//!   routing with retries and hedging, immediate gate refresh on
//!   capacity loss, and last-good-decision solver fallback — keep the
//!   serving path graceful when capacity disappears mid-flight.  Off by
//!   default and bit-identical off ↔ absent.
//! * [`replay`] — deterministic record/replay: a `Recorder` captures the
//!   per-service arrival streams and every per-tick decision record
//!   (λ̂, offered, grant, allocation/batches/quotas, gate supply, tier
//!   cutoff, fault draws) into a versioned trace file (JSON or CBOR-style
//!   binary by extension); a `Replayer` re-drives the fleet engine from
//!   the embedded scenario and reports the first differing decision field
//!   per tick.  Recording hooks sit only at the serial tick boundaries,
//!   so recording is a pure observer and traces replay bit-identically
//!   across `solver_threads`.
//! * [`baselines`] — VPA+ and Model-Switching+ comparators.
//! * [`experiment`] — scenario harness regenerating the paper's figures.

pub mod adapter;
pub mod baselines;
pub mod cluster;
pub mod config;
pub mod dispatcher;
pub mod experiment;
pub mod fault;
pub mod fleet;
pub mod forecaster;
pub mod metrics;
pub mod monitoring;
pub mod profiler;
pub mod replay;
pub mod runtime;
pub mod serving;
pub mod solver;
pub mod telemetry;
pub mod util;
pub mod workload;

pub use config::Config;
