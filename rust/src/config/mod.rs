//! Typed configuration for the whole system.
//!
//! Everything an experiment or the server needs is in [`Config`]; it loads
//! from JSON (`--config file.json`) with field-level defaults so a partial
//! file only overrides what it names.  `Config::default()` reproduces the
//! paper's main setting: 750 ms P99 SLO, 20-core budget, α=1, β=0.05,
//! γ=0.001 (normalized), 30 s adaptation interval, batching disabled.
//!
//! Batching knobs ([`BatchingConfig`]): `max_batch` caps the server-side
//! batch size the solver may choose per variant (1 = disabled, the paper's
//! CPU setting) and `max_wait_s` caps how long a pod's batcher waits to
//! fill a batch before dispatching it partially filled.  The solver charges
//! `max_wait_s` as worst-case batch-formation latency on top of the batched
//! service time when checking the SLO, so every chosen batch size is
//! SLO-feasible by construction.

use crate::util::json::{parse, Value};
use anyhow::{Context, Result};
use std::path::Path;

/// Objective weights `α·AA − (β·RC + γ·LC)` (paper Eq. 1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ObjectiveWeights {
    /// Weight on weighted-average accuracy (percentage points).
    pub alpha: f64,
    /// Weight on resource cost (CPU cores).
    pub beta: f64,
    /// Weight on loading cost (seconds of max readiness time).
    pub gamma: f64,
}

impl Default for ObjectiveWeights {
    fn default() -> Self {
        Self {
            alpha: 1.0,
            beta: 0.05,
            gamma: 0.001,
        }
    }
}

/// Latency SLO definition.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Slo {
    /// The latency bound, milliseconds.
    pub latency_ms: f64,
    /// Which percentile the bound applies to (0..1), paper uses P99.
    pub percentile: f64,
}

impl Default for Slo {
    fn default() -> Self {
        Self {
            latency_ms: 750.0,
            percentile: 0.99,
        }
    }
}

/// Adapter (control-loop) parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct AdapterConfig {
    /// Seconds between adaptation decisions (paper: 30).
    pub interval_s: f64,
    /// Forecaster kind: "lstm" | "moving_average" | "last_max" | "holt".
    pub forecaster: String,
    /// History window (seconds) fed to the forecaster.
    pub history_window_s: usize,
    /// Multiplicative headroom applied to the predicted rate.
    pub headroom: f64,
}

impl Default for AdapterConfig {
    fn default() -> Self {
        Self {
            interval_s: 30.0,
            forecaster: "lstm".into(),
            history_window_s: 120,
            headroom: 1.1,
        }
    }
}

/// Server-side batching parameters (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchingConfig {
    /// Largest batch the solver may pick per variant; 1 disables batching.
    pub max_batch: usize,
    /// Batch-formation wait cap, seconds: a pod dispatches a partial batch
    /// after waiting this long for it to fill.  Also the worst-case
    /// formation latency the solver charges against the SLO.
    pub max_wait_s: f64,
}

impl Default for BatchingConfig {
    fn default() -> Self {
        Self {
            max_batch: 1,
            max_wait_s: 0.05,
        }
    }
}

/// Cluster / budget parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// Total CPU core budget B.
    pub budget: usize,
    /// Node core capacities (the paper's testbed: two 48-core machines).
    pub node_cores: Vec<usize>,
    /// Default readiness time (s) when no measurement is available.
    pub default_readiness_s: f64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            budget: 20,
            node_cores: vec![48, 48],
            default_readiness_s: 10.0,
        }
    }
}

/// Top-level configuration.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    pub weights: ObjectiveWeights,
    pub slo: Slo,
    pub adapter: AdapterConfig,
    pub cluster: ClusterConfig,
    pub batching: BatchingConfig,
    /// Variants eligible for selection; empty = all in the manifest.
    pub variants: Vec<String>,
    /// Random seed for workloads and service-time noise.
    pub seed: u64,
}

// ---- JSON (de)serialization -------------------------------------------------

fn f64_or(v: &Value, key: &str, default: f64) -> Result<f64> {
    match v.get(key) {
        Some(x) => x.as_f64(),
        None => Ok(default),
    }
}

fn usize_or(v: &Value, key: &str, default: usize) -> Result<usize> {
    match v.get(key) {
        Some(x) => x.as_usize(),
        None => Ok(default),
    }
}

fn str_or(v: &Value, key: &str, default: &str) -> Result<String> {
    match v.get(key) {
        Some(x) => Ok(x.as_str()?.to_string()),
        None => Ok(default.to_string()),
    }
}

impl Config {
    pub fn from_json(v: &Value) -> Result<Self> {
        let d = Config::default();
        let weights = match v.get("weights") {
            Some(w) => ObjectiveWeights {
                alpha: f64_or(w, "alpha", d.weights.alpha)?,
                beta: f64_or(w, "beta", d.weights.beta)?,
                gamma: f64_or(w, "gamma", d.weights.gamma)?,
            },
            None => d.weights,
        };
        let slo = match v.get("slo") {
            Some(s) => Slo {
                latency_ms: f64_or(s, "latency_ms", d.slo.latency_ms)?,
                percentile: f64_or(s, "percentile", d.slo.percentile)?,
            },
            None => d.slo,
        };
        let adapter = match v.get("adapter") {
            Some(a) => AdapterConfig {
                interval_s: f64_or(a, "interval_s", d.adapter.interval_s)?,
                forecaster: str_or(a, "forecaster", &d.adapter.forecaster)?,
                history_window_s: usize_or(a, "history_window_s", d.adapter.history_window_s)?,
                headroom: f64_or(a, "headroom", d.adapter.headroom)?,
            },
            None => d.adapter,
        };
        let cluster = match v.get("cluster") {
            Some(c) => ClusterConfig {
                budget: usize_or(c, "budget", d.cluster.budget)?,
                node_cores: match c.get("node_cores") {
                    Some(nc) => nc
                        .as_arr()?
                        .iter()
                        .map(|x| x.as_usize())
                        .collect::<Result<Vec<_>>>()?,
                    None => d.cluster.node_cores.clone(),
                },
                default_readiness_s: f64_or(
                    c,
                    "default_readiness_s",
                    d.cluster.default_readiness_s,
                )?,
            },
            None => d.cluster,
        };
        let batching = match v.get("batching") {
            Some(b) => BatchingConfig {
                max_batch: usize_or(b, "max_batch", d.batching.max_batch)?,
                max_wait_s: f64_or(b, "max_wait_s", d.batching.max_wait_s)?,
            },
            None => d.batching,
        };
        let variants = match v.get("variants") {
            Some(vs) => vs
                .as_arr()?
                .iter()
                .map(|x| Ok(x.as_str()?.to_string()))
                .collect::<Result<Vec<_>>>()?,
            None => Vec::new(),
        };
        Ok(Self {
            weights,
            slo,
            adapter,
            cluster,
            batching,
            variants,
            seed: v.get("seed").map(|s| s.as_u64()).transpose()?.unwrap_or(0),
        })
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            (
                "weights",
                Value::obj(vec![
                    ("alpha", Value::Num(self.weights.alpha)),
                    ("beta", Value::Num(self.weights.beta)),
                    ("gamma", Value::Num(self.weights.gamma)),
                ]),
            ),
            (
                "slo",
                Value::obj(vec![
                    ("latency_ms", Value::Num(self.slo.latency_ms)),
                    ("percentile", Value::Num(self.slo.percentile)),
                ]),
            ),
            (
                "adapter",
                Value::obj(vec![
                    ("interval_s", Value::Num(self.adapter.interval_s)),
                    ("forecaster", Value::Str(self.adapter.forecaster.clone())),
                    (
                        "history_window_s",
                        Value::Num(self.adapter.history_window_s as f64),
                    ),
                    ("headroom", Value::Num(self.adapter.headroom)),
                ]),
            ),
            (
                "cluster",
                Value::obj(vec![
                    ("budget", Value::Num(self.cluster.budget as f64)),
                    (
                        "node_cores",
                        Value::Arr(
                            self.cluster
                                .node_cores
                                .iter()
                                .map(|&c| Value::Num(c as f64))
                                .collect(),
                        ),
                    ),
                    (
                        "default_readiness_s",
                        Value::Num(self.cluster.default_readiness_s),
                    ),
                ]),
            ),
            (
                "batching",
                Value::obj(vec![
                    ("max_batch", Value::Num(self.batching.max_batch as f64)),
                    ("max_wait_s", Value::Num(self.batching.max_wait_s)),
                ]),
            ),
            (
                "variants",
                Value::Arr(self.variants.iter().map(|v| Value::Str(v.clone())).collect()),
            ),
            ("seed", Value::Num(self.seed as f64)),
        ])
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path:?}"))?;
        Self::from_json(&parse(&text).with_context(|| format!("parsing config {path:?}"))?)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())
            .with_context(|| format!("writing config {path:?}"))
    }

    /// Validate invariants that would otherwise surface deep in a run.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.slo.latency_ms > 0.0, "SLO latency must be positive");
        anyhow::ensure!(
            self.slo.percentile > 0.0 && self.slo.percentile < 1.0,
            "SLO percentile must be in (0, 1)"
        );
        anyhow::ensure!(self.cluster.budget > 0, "budget must be positive");
        anyhow::ensure!(
            self.adapter.interval_s > 0.0,
            "adapter interval must be positive"
        );
        anyhow::ensure!(self.adapter.headroom >= 1.0, "headroom must be >= 1");
        anyhow::ensure!(self.batching.max_batch >= 1, "max_batch must be >= 1");
        anyhow::ensure!(
            self.batching.max_wait_s >= 0.0,
            "batch max_wait_s must be non-negative"
        );
        anyhow::ensure!(
            self.batching.max_batch == 1
                || self.batching.max_wait_s < self.slo.latency_ms / 1000.0,
            "batch max_wait_s {} must stay under the SLO {} s",
            self.batching.max_wait_s,
            self.slo.latency_ms / 1000.0
        );
        anyhow::ensure!(
            self.weights.alpha >= 0.0 && self.weights.beta >= 0.0 && self.weights.gamma >= 0.0,
            "objective weights must be non-negative"
        );
        let node_total: usize = self.cluster.node_cores.iter().sum();
        anyhow::ensure!(
            self.cluster.budget <= node_total,
            "budget {} exceeds total node capacity {}",
            self.cluster.budget,
            node_total
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid_and_matches_paper() {
        let c = Config::default();
        c.validate().unwrap();
        assert_eq!(c.slo.latency_ms, 750.0);
        assert_eq!(c.slo.percentile, 0.99);
        assert_eq!(c.adapter.interval_s, 30.0);
        assert_eq!(c.weights.beta, 0.05);
        assert_eq!(c.cluster.budget, 20);
    }

    #[test]
    fn default_batching_is_disabled() {
        let c = Config::default();
        assert_eq!(c.batching.max_batch, 1);
        assert!(c.batching.max_wait_s > 0.0);
        // enabling batching with a wait at/over the SLO is rejected
        let mut c = Config::default();
        c.batching.max_batch = 8;
        c.batching.max_wait_s = 1.0;
        assert!(c.validate().is_err());
        c.batching.max_wait_s = 0.1;
        c.validate().unwrap();
    }

    #[test]
    fn roundtrips_through_json() {
        let mut c = Config::default();
        c.variants = vec!["resnet18".into(), "resnet50".into()];
        c.batching.max_batch = 4;
        c.seed = 7;
        let text = c.to_json().to_string_pretty();
        let back = Config::from_json(&parse(&text).unwrap()).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn partial_json_uses_defaults() {
        let v = parse(r#"{"weights":{"alpha":2.0,"beta":0.2}}"#).unwrap();
        let c = Config::from_json(&v).unwrap();
        assert_eq!(c.weights.alpha, 2.0);
        assert_eq!(c.weights.beta, 0.2);
        assert_eq!(c.weights.gamma, 0.001); // default
        assert_eq!(c.slo.latency_ms, 750.0);
    }

    #[test]
    fn file_roundtrip() {
        let dir = crate::util::testutil::TempDir::new();
        let path = dir.path().join("config.json");
        let c = Config::default();
        c.save(&path).unwrap();
        assert_eq!(Config::load(&path).unwrap(), c);
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut c = Config::default();
        c.slo.percentile = 1.5;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.cluster.budget = 0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.cluster.budget = 1000;
        assert!(c.validate().is_err());
    }
}
