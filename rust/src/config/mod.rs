//! Typed configuration for the whole system.
//!
//! Everything an experiment or the server needs is in [`Config`]; it loads
//! from JSON (`--config file.json`) with field-level defaults so a partial
//! file only overrides what it names.  `Config::default()` reproduces the
//! paper's main setting: 750 ms P99 SLO, 20-core budget, α=1, β=0.05,
//! γ=0.001 (normalized), 30 s adaptation interval, batching disabled.
//!
//! Batching knobs ([`BatchingConfig`]): `max_batch` caps the server-side
//! batch size the solver may choose per variant (1 = disabled, the paper's
//! CPU setting) and `max_wait_s` caps how long a pod's batcher waits to
//! fill a batch before dispatching it partially filled.  The solver charges
//! `max_wait_s` as worst-case batch-formation latency on top of the batched
//! service time when checking the SLO, so every chosen batch size is
//! SLO-feasible by construction.

use crate::util::json::{parse, Value};
use anyhow::{Context, Result};
use std::path::Path;

/// Objective weights `α·AA − (β·RC + γ·LC)` (paper Eq. 1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ObjectiveWeights {
    /// Weight on weighted-average accuracy (percentage points).
    pub alpha: f64,
    /// Weight on resource cost (CPU cores).
    pub beta: f64,
    /// Weight on loading cost (seconds of max readiness time).
    pub gamma: f64,
}

impl Default for ObjectiveWeights {
    fn default() -> Self {
        Self {
            alpha: 1.0,
            beta: 0.05,
            gamma: 0.001,
        }
    }
}

/// Latency SLO definition.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Slo {
    /// The latency bound, milliseconds.
    pub latency_ms: f64,
    /// Which percentile the bound applies to (0..1), paper uses P99.
    pub percentile: f64,
}

impl Default for Slo {
    fn default() -> Self {
        Self {
            latency_ms: 750.0,
            percentile: 0.99,
        }
    }
}

/// Adapter (control-loop) parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct AdapterConfig {
    /// Seconds between adaptation decisions (paper: 30).
    pub interval_s: f64,
    /// Forecaster kind: "lstm" | "moving_average" | "last_max" | "holt".
    pub forecaster: String,
    /// History window (seconds) fed to the forecaster.
    pub history_window_s: usize,
    /// Multiplicative headroom applied to the predicted rate.
    pub headroom: f64,
}

impl Default for AdapterConfig {
    fn default() -> Self {
        Self {
            interval_s: 30.0,
            forecaster: "lstm".into(),
            history_window_s: 120,
            headroom: 1.1,
        }
    }
}

/// Admission-control knobs for the request path's token-bucket gate
/// (see [`crate::dispatcher::AdmissionGate`]).
///
/// The gate refills at the service's *granted supply* — Σ per-variant
/// `th_m(n, b)` of the committed allocation, refreshed by the adapter
/// every tick — and sheds arrivals that find no token, so overload is
/// refused at the door instead of queueing every request past its SLO.
/// With multiple priority tiers the gate sheds the numerically highest
/// (least important) tiers first via an adaptive tier cutoff.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdmissionConfig {
    /// Master switch; disabled (the default) keeps the request path
    /// bit-identical to the pre-admission pipeline.
    pub enabled: bool,
    /// Bucket depth in seconds of supply — how much burst the gate
    /// absorbs before shedding.
    pub burst_s: f64,
    /// Multiplicative slack on the measured supply before shedding
    /// (1.0 = shed exactly past capacity).
    pub slack: f64,
    /// Cadence (seconds) of the tier-cutoff adaptation: one tier is
    /// dropped or readmitted at most once per window.
    pub ctl_window_s: f64,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            burst_s: 1.0,
            slack: 1.0,
            ctl_window_s: 1.0,
        }
    }
}

/// Telemetry plane (see [`crate::telemetry`]): counters, tick-stage
/// profiling, and the anomaly-triggered flight recorder.  A pure observer
/// — enabling it never changes a decision, event sequence, or summary
/// field (pinned in `tests/regression_pins.rs`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TelemetryConfig {
    /// Master switch; disabled (the default) records nothing and skips
    /// every timing call.
    pub enabled: bool,
    /// Tick window the flight recorder retains (last K adapter ticks).
    pub flight_ticks: usize,
    /// Per-tick shed fraction (shed / offered, from the admission gates'
    /// counter deltas) above which the flight recorder marks a trip.
    pub shed_trip_fraction: f64,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            flight_ticks: 16,
            shed_trip_fraction: 0.2,
        }
    }
}

/// Fault-injection plane + failure-aware reactions (see [`crate::fault`]).
///
/// Everything defaults to **off**: with `enabled = false` (or all rates at
/// zero) no fault RNG stream is ever drawn and the run is bit-identical to
/// a fault-free build (pinned in `tests/regression_pins.rs`).  Faults are
/// drawn from per-service SplitMix64-strided streams — the same discipline
/// as arrivals — so any fault run replays exactly from its seed, at every
/// `solver_threads` count.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultConfig {
    /// Master switch; off (the default) skips every fault draw.
    pub enabled: bool,
    /// Per-Ready-pod, per-second crash probability (Bernoulli at each
    /// cluster boundary).  A crashed pod fails its in-flight requests and
    /// respawns as Pending with the variant's loading cost — the
    /// VPA-restart dynamic the paper measures.
    pub crash_rate: f64,
    /// Crash window start, seconds (crashes only inside the window).
    pub crash_start_s: f64,
    /// Crash window end, seconds.  Defaults to effectively-forever (1e9,
    /// exact in f64 so the config round-trips through JSON).
    pub crash_end_s: f64,
    /// Readiness multiplier on crash respawns (slow starts): the
    /// replacement pod's loading time is `readiness × slow_start_factor`.
    pub slow_start_factor: f64,
    /// Per-Ready-pod, per-second straggler-onset probability.
    pub straggler_rate: f64,
    /// Service-time multiplier a straggling pod applies to every batch it
    /// serves while the straggle window is open.
    pub straggler_mult: f64,
    /// How long a straggle episode lasts, seconds.
    pub straggler_window_s: f64,
    /// Per-adapter-tick probability that a service's curve solve stalls;
    /// with reactions on the tick falls back to the last-good decision
    /// (`SolveOutcome::Fallback`) instead of re-solving.
    pub stall_rate: f64,
    /// Master switch for the failure-aware reactions (health-checked
    /// routing, retries, hedging, gate refresh on capacity loss, solver
    /// fallback).  Off = faults are injected but the serving path reacts
    /// exactly as the pre-fault pipeline did (the reactions-off baseline
    /// `fig_fleet` Part D measures against).
    pub reactions: bool,
    /// Retry budget per request after a pod failure strands it.
    pub max_retries: u32,
    /// Base retry backoff, seconds; attempt k waits `backoff × 2^k`,
    /// charged against the request's remaining SLO budget — a retry that
    /// cannot make the deadline fails immediately instead of wasting a
    /// slot.
    pub retry_backoff_s: f64,
    /// Consecutive routing failures before the dispatcher ejects a
    /// backend from the smooth-WRR rotation.
    pub eject_after: u32,
    /// Seconds an ejected backend sits out before one half-open probe
    /// request may readmit it.
    pub probe_after_s: f64,
    /// Hedge queued work away from a straggling pod when the straggle is
    /// detected (in-service batches finish where they are).
    pub hedge: bool,
}

impl Default for FaultConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            crash_rate: 0.0,
            crash_start_s: 0.0,
            crash_end_s: 1e9,
            slow_start_factor: 1.0,
            straggler_rate: 0.0,
            straggler_mult: 1.0,
            straggler_window_s: 30.0,
            stall_rate: 0.0,
            reactions: false,
            max_retries: 1,
            retry_backoff_s: 0.05,
            eject_after: 3,
            probe_after_s: 5.0,
            hedge: true,
        }
    }
}

/// Server-side batching parameters (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchingConfig {
    /// Largest batch the solver may pick per variant; 1 disables batching.
    pub max_batch: usize,
    /// Batch-formation wait cap, seconds: a pod dispatches a partial batch
    /// after waiting this long for it to fill.  Also the worst-case
    /// formation latency the solver charges against the SLO.
    pub max_wait_s: f64,
}

impl Default for BatchingConfig {
    fn default() -> Self {
        Self {
            max_batch: 1,
            max_wait_s: 0.05,
        }
    }
}

/// Cluster / budget parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// Total CPU core budget B.
    pub budget: usize,
    /// Node core capacities (the paper's testbed: two 48-core machines).
    pub node_cores: Vec<usize>,
    /// Default readiness time (s) when no measurement is available.
    pub default_readiness_s: f64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            budget: 20,
            node_cores: vec![48, 48],
            default_readiness_s: 10.0,
        }
    }
}

/// One service of a multi-service fleet ([`FleetConfig`]).
#[derive(Debug, Clone, PartialEq)]
pub struct FleetServiceConfig {
    /// Service name; namespaces its pods on the shared cluster.  Must be
    /// non-empty, unique within the fleet, and slash-free.
    pub name: String,
    /// Arbitration weight (> 0): higher claims marginal cores first.
    pub priority: f64,
    /// Strict priority tier (0 = most important).  Tiers outrank weights:
    /// the arbiter grants tier-0 marginal utility lexicographically before
    /// any tier-1 weight, and the admission gate sheds the numerically
    /// highest tiers first under overload.
    pub tier: u8,
    /// Allowed SLO-violation fraction: the denominator of the service's
    /// SLO-burn-rate signal (rolling violation rate / budget) that boosts
    /// its marginal utility in the arbiter while it is burning.
    pub error_budget: f64,
    /// Optional per-request class mix `[(tier, weight)]` applied to the
    /// service's trace; empty = every request at the service `tier`.
    pub class_mix: Vec<(u8, f64)>,
    /// Guaranteed-minimum core grant.
    pub floor_cores: usize,
    /// Per-service latency SLO, milliseconds.
    pub slo_latency_ms: f64,
    /// Trace spec (the CLI grammar: `bursty | non-bursty | twitter |
    /// steady:<rps> | csv:<path> | burst:<start_s>:<len_s>[:<peak_rps>]`).
    pub trace: String,
    /// Base arrival rate the trace generator scales from.
    pub base_rps: f64,
}

impl Default for FleetServiceConfig {
    fn default() -> Self {
        Self {
            name: String::new(),
            priority: 1.0,
            tier: 0,
            error_budget: 0.01,
            class_mix: Vec::new(),
            floor_cores: 0,
            slo_latency_ms: 750.0,
            trace: "bursty".into(),
            base_rps: 30.0,
        }
    }
}

/// Multi-service fleet: N independent services (each its own SLO, trace,
/// and policy instance) sharing one cluster, with the core arbiter
/// re-partitioning `global_budget` across them every adaptation interval.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FleetConfig {
    /// Shared core budget the arbiter partitions; 0 = use `cluster.budget`.
    pub global_budget: usize,
    /// Strength of the arbiter's SLO-burn boost: a service burning its
    /// error budget has its marginal utility multiplied by up to
    /// `1 + burn_boost × overshoot` (overshoot clamped).  0 (default)
    /// disables the boost and keeps partitions bit-identical to the
    /// burn-unaware arbiter.
    pub burn_boost: f64,
    /// Per-request lost-goodput price the per-service ILPs charge on
    /// offered load their capacity cannot cover (admission-aware value
    /// curves): each service's effective penalty is this price weighted
    /// by its traffic's tier mix (`fleet::shed_value_weight`), so the
    /// arbiter trades cores against shedding explicitly — high-value
    /// shed costs more than best-effort shed.  0 (default) disables the
    /// pricing and keeps every solve bit-identical to the unpriced
    /// objective.
    pub shed_penalty: f64,
    /// Worker threads for the fleet engine's parallel stages (advance,
    /// curve solve, decide), backed by one persistent worker pool per run
    /// — workers park between stages, no per-stage spawns.  0 (default) =
    /// auto — one worker per available core; 1 = the serial reference
    /// path (no pool).  The count never changes results (parallel runs
    /// are bit-identical to serial, pinned by
    /// `parallel_fleet_is_bit_identical_to_serial`), only wall-clock.
    pub solver_threads: usize,
    /// Empty = fleet serving disabled (single-service mode).
    pub services: Vec<FleetServiceConfig>,
}

impl FleetConfig {
    /// The budget the arbiter actually partitions.
    pub fn resolved_budget(&self, cluster: &ClusterConfig) -> usize {
        if self.global_budget > 0 {
            self.global_budget
        } else {
            cluster.budget
        }
    }
}

/// Top-level configuration.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    pub weights: ObjectiveWeights,
    pub slo: Slo,
    pub adapter: AdapterConfig,
    pub cluster: ClusterConfig,
    pub batching: BatchingConfig,
    /// Request-path admission control (disabled by default).
    pub admission: AdmissionConfig,
    /// Telemetry plane (disabled by default).
    pub telemetry: TelemetryConfig,
    /// Fault injection + failure-aware reactions (disabled by default).
    pub fault: FaultConfig,
    /// Multi-service fleet definition (empty services = disabled).
    pub fleet: FleetConfig,
    /// Variants eligible for selection; empty = all in the manifest.
    pub variants: Vec<String>,
    /// Random seed for workloads and service-time noise.
    pub seed: u64,
}

// ---- JSON (de)serialization -------------------------------------------------

fn f64_or(v: &Value, key: &str, default: f64) -> Result<f64> {
    match v.get(key) {
        Some(x) => x.as_f64(),
        None => Ok(default),
    }
}

fn usize_or(v: &Value, key: &str, default: usize) -> Result<usize> {
    match v.get(key) {
        Some(x) => x.as_usize(),
        None => Ok(default),
    }
}

fn str_or(v: &Value, key: &str, default: &str) -> Result<String> {
    match v.get(key) {
        Some(x) => Ok(x.as_str()?.to_string()),
        None => Ok(default.to_string()),
    }
}

impl Config {
    pub fn from_json(v: &Value) -> Result<Self> {
        let d = Config::default();
        let weights = match v.get("weights") {
            Some(w) => ObjectiveWeights {
                alpha: f64_or(w, "alpha", d.weights.alpha)?,
                beta: f64_or(w, "beta", d.weights.beta)?,
                gamma: f64_or(w, "gamma", d.weights.gamma)?,
            },
            None => d.weights,
        };
        let slo = match v.get("slo") {
            Some(s) => Slo {
                latency_ms: f64_or(s, "latency_ms", d.slo.latency_ms)?,
                percentile: f64_or(s, "percentile", d.slo.percentile)?,
            },
            None => d.slo,
        };
        let adapter = match v.get("adapter") {
            Some(a) => AdapterConfig {
                interval_s: f64_or(a, "interval_s", d.adapter.interval_s)?,
                forecaster: str_or(a, "forecaster", &d.adapter.forecaster)?,
                history_window_s: usize_or(a, "history_window_s", d.adapter.history_window_s)?,
                headroom: f64_or(a, "headroom", d.adapter.headroom)?,
            },
            None => d.adapter,
        };
        let cluster = match v.get("cluster") {
            Some(c) => ClusterConfig {
                budget: usize_or(c, "budget", d.cluster.budget)?,
                node_cores: match c.get("node_cores") {
                    Some(nc) => nc
                        .as_arr()?
                        .iter()
                        .map(|x| x.as_usize())
                        .collect::<Result<Vec<_>>>()?,
                    None => d.cluster.node_cores.clone(),
                },
                default_readiness_s: f64_or(
                    c,
                    "default_readiness_s",
                    d.cluster.default_readiness_s,
                )?,
            },
            None => d.cluster,
        };
        let batching = match v.get("batching") {
            Some(b) => BatchingConfig {
                max_batch: usize_or(b, "max_batch", d.batching.max_batch)?,
                max_wait_s: f64_or(b, "max_wait_s", d.batching.max_wait_s)?,
            },
            None => d.batching,
        };
        let admission = match v.get("admission") {
            Some(a) => AdmissionConfig {
                enabled: match a.get("enabled") {
                    Some(x) => x.as_bool()?,
                    None => d.admission.enabled,
                },
                burst_s: f64_or(a, "burst_s", d.admission.burst_s)?,
                slack: f64_or(a, "slack", d.admission.slack)?,
                ctl_window_s: f64_or(a, "ctl_window_s", d.admission.ctl_window_s)?,
            },
            None => d.admission,
        };
        let telemetry = match v.get("telemetry") {
            Some(t) => TelemetryConfig {
                enabled: match t.get("enabled") {
                    Some(x) => x.as_bool()?,
                    None => d.telemetry.enabled,
                },
                flight_ticks: usize_or(t, "flight_ticks", d.telemetry.flight_ticks)?,
                shed_trip_fraction: f64_or(
                    t,
                    "shed_trip_fraction",
                    d.telemetry.shed_trip_fraction,
                )?,
            },
            None => d.telemetry,
        };
        let fault = match v.get("fault") {
            Some(f) => FaultConfig {
                enabled: match f.get("enabled") {
                    Some(x) => x.as_bool()?,
                    None => d.fault.enabled,
                },
                crash_rate: f64_or(f, "crash_rate", d.fault.crash_rate)?,
                crash_start_s: f64_or(f, "crash_start_s", d.fault.crash_start_s)?,
                crash_end_s: f64_or(f, "crash_end_s", d.fault.crash_end_s)?,
                slow_start_factor: f64_or(f, "slow_start_factor", d.fault.slow_start_factor)?,
                straggler_rate: f64_or(f, "straggler_rate", d.fault.straggler_rate)?,
                straggler_mult: f64_or(f, "straggler_mult", d.fault.straggler_mult)?,
                straggler_window_s: f64_or(
                    f,
                    "straggler_window_s",
                    d.fault.straggler_window_s,
                )?,
                stall_rate: f64_or(f, "stall_rate", d.fault.stall_rate)?,
                reactions: match f.get("reactions") {
                    Some(x) => x.as_bool()?,
                    None => d.fault.reactions,
                },
                max_retries: usize_or(f, "max_retries", d.fault.max_retries as usize)?
                    .try_into()
                    .map_err(|_| anyhow::anyhow!("fault max_retries must fit in u32"))?,
                retry_backoff_s: f64_or(f, "retry_backoff_s", d.fault.retry_backoff_s)?,
                eject_after: usize_or(f, "eject_after", d.fault.eject_after as usize)?
                    .try_into()
                    .map_err(|_| anyhow::anyhow!("fault eject_after must fit in u32"))?,
                probe_after_s: f64_or(f, "probe_after_s", d.fault.probe_after_s)?,
                hedge: match f.get("hedge") {
                    Some(x) => x.as_bool()?,
                    None => d.fault.hedge,
                },
            },
            None => d.fault,
        };
        let fleet = match v.get("fleet") {
            Some(f) => FleetConfig {
                global_budget: usize_or(f, "global_budget", 0)?,
                burn_boost: f64_or(f, "burn_boost", 0.0)?,
                shed_penalty: f64_or(f, "shed_penalty", 0.0)?,
                solver_threads: usize_or(f, "solver_threads", 0)?,
                services: match f.get("services") {
                    Some(svcs) => svcs
                        .as_arr()?
                        .iter()
                        .map(|s| -> Result<FleetServiceConfig> {
                            let d = FleetServiceConfig::default();
                            Ok(FleetServiceConfig {
                                name: str_or(s, "name", &d.name)?,
                                priority: f64_or(s, "priority", d.priority)?,
                                tier: usize_or(s, "tier", d.tier as usize)?
                                    .try_into()
                                    .map_err(|_| anyhow::anyhow!("tier must fit in u8"))?,
                                error_budget: f64_or(s, "error_budget", d.error_budget)?,
                                class_mix: match s.get("class_mix") {
                                    Some(m) => m
                                        .as_arr()?
                                        .iter()
                                        .map(|pair| -> Result<(u8, f64)> {
                                            let p = pair.as_arr()?;
                                            anyhow::ensure!(
                                                p.len() == 2,
                                                "class_mix entries are [tier, weight] pairs"
                                            );
                                            let tier: u8 = p[0]
                                                .as_usize()?
                                                .try_into()
                                                .map_err(|_| {
                                                    anyhow::anyhow!("class_mix tier must fit in u8")
                                                })?;
                                            Ok((tier, p[1].as_f64()?))
                                        })
                                        .collect::<Result<Vec<_>>>()?,
                                    None => Vec::new(),
                                },
                                floor_cores: usize_or(s, "floor_cores", d.floor_cores)?,
                                slo_latency_ms: f64_or(s, "slo_latency_ms", d.slo_latency_ms)?,
                                trace: str_or(s, "trace", &d.trace)?,
                                base_rps: f64_or(s, "base_rps", d.base_rps)?,
                            })
                        })
                        .collect::<Result<Vec<_>>>()?,
                    None => Vec::new(),
                },
            },
            None => FleetConfig::default(),
        };
        let variants = match v.get("variants") {
            Some(vs) => vs
                .as_arr()?
                .iter()
                .map(|x| Ok(x.as_str()?.to_string()))
                .collect::<Result<Vec<_>>>()?,
            None => Vec::new(),
        };
        Ok(Self {
            weights,
            slo,
            adapter,
            cluster,
            batching,
            admission,
            telemetry,
            fault,
            fleet,
            variants,
            seed: v.get("seed").map(|s| s.as_u64()).transpose()?.unwrap_or(0),
        })
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            (
                "weights",
                Value::obj(vec![
                    ("alpha", Value::Num(self.weights.alpha)),
                    ("beta", Value::Num(self.weights.beta)),
                    ("gamma", Value::Num(self.weights.gamma)),
                ]),
            ),
            (
                "slo",
                Value::obj(vec![
                    ("latency_ms", Value::Num(self.slo.latency_ms)),
                    ("percentile", Value::Num(self.slo.percentile)),
                ]),
            ),
            (
                "adapter",
                Value::obj(vec![
                    ("interval_s", Value::Num(self.adapter.interval_s)),
                    ("forecaster", Value::Str(self.adapter.forecaster.clone())),
                    (
                        "history_window_s",
                        Value::Num(self.adapter.history_window_s as f64),
                    ),
                    ("headroom", Value::Num(self.adapter.headroom)),
                ]),
            ),
            (
                "cluster",
                Value::obj(vec![
                    ("budget", Value::Num(self.cluster.budget as f64)),
                    (
                        "node_cores",
                        Value::Arr(
                            self.cluster
                                .node_cores
                                .iter()
                                .map(|&c| Value::Num(c as f64))
                                .collect(),
                        ),
                    ),
                    (
                        "default_readiness_s",
                        Value::Num(self.cluster.default_readiness_s),
                    ),
                ]),
            ),
            (
                "batching",
                Value::obj(vec![
                    ("max_batch", Value::Num(self.batching.max_batch as f64)),
                    ("max_wait_s", Value::Num(self.batching.max_wait_s)),
                ]),
            ),
            (
                "admission",
                Value::obj(vec![
                    ("enabled", Value::Bool(self.admission.enabled)),
                    ("burst_s", Value::Num(self.admission.burst_s)),
                    ("slack", Value::Num(self.admission.slack)),
                    ("ctl_window_s", Value::Num(self.admission.ctl_window_s)),
                ]),
            ),
            (
                "telemetry",
                Value::obj(vec![
                    ("enabled", Value::Bool(self.telemetry.enabled)),
                    (
                        "flight_ticks",
                        Value::Num(self.telemetry.flight_ticks as f64),
                    ),
                    (
                        "shed_trip_fraction",
                        Value::Num(self.telemetry.shed_trip_fraction),
                    ),
                ]),
            ),
            (
                "fault",
                Value::obj(vec![
                    ("enabled", Value::Bool(self.fault.enabled)),
                    ("crash_rate", Value::Num(self.fault.crash_rate)),
                    ("crash_start_s", Value::Num(self.fault.crash_start_s)),
                    ("crash_end_s", Value::Num(self.fault.crash_end_s)),
                    (
                        "slow_start_factor",
                        Value::Num(self.fault.slow_start_factor),
                    ),
                    ("straggler_rate", Value::Num(self.fault.straggler_rate)),
                    ("straggler_mult", Value::Num(self.fault.straggler_mult)),
                    (
                        "straggler_window_s",
                        Value::Num(self.fault.straggler_window_s),
                    ),
                    ("stall_rate", Value::Num(self.fault.stall_rate)),
                    ("reactions", Value::Bool(self.fault.reactions)),
                    ("max_retries", Value::Num(self.fault.max_retries as f64)),
                    ("retry_backoff_s", Value::Num(self.fault.retry_backoff_s)),
                    ("eject_after", Value::Num(self.fault.eject_after as f64)),
                    ("probe_after_s", Value::Num(self.fault.probe_after_s)),
                    ("hedge", Value::Bool(self.fault.hedge)),
                ]),
            ),
            (
                "fleet",
                Value::obj(vec![
                    (
                        "global_budget",
                        Value::Num(self.fleet.global_budget as f64),
                    ),
                    ("burn_boost", Value::Num(self.fleet.burn_boost)),
                    ("shed_penalty", Value::Num(self.fleet.shed_penalty)),
                    (
                        "solver_threads",
                        Value::Num(self.fleet.solver_threads as f64),
                    ),
                    (
                        "services",
                        Value::Arr(
                            self.fleet
                                .services
                                .iter()
                                .map(|s| {
                                    Value::obj(vec![
                                        ("name", Value::Str(s.name.clone())),
                                        ("priority", Value::Num(s.priority)),
                                        ("tier", Value::Num(s.tier as f64)),
                                        ("error_budget", Value::Num(s.error_budget)),
                                        (
                                            "class_mix",
                                            Value::Arr(
                                                s.class_mix
                                                    .iter()
                                                    .map(|&(t, w)| {
                                                        Value::Arr(vec![
                                                            Value::Num(t as f64),
                                                            Value::Num(w),
                                                        ])
                                                    })
                                                    .collect(),
                                            ),
                                        ),
                                        (
                                            "floor_cores",
                                            Value::Num(s.floor_cores as f64),
                                        ),
                                        (
                                            "slo_latency_ms",
                                            Value::Num(s.slo_latency_ms),
                                        ),
                                        ("trace", Value::Str(s.trace.clone())),
                                        ("base_rps", Value::Num(s.base_rps)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ]),
            ),
            (
                "variants",
                Value::Arr(self.variants.iter().map(|v| Value::Str(v.clone())).collect()),
            ),
            ("seed", Value::Num(self.seed as f64)),
        ])
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path:?}"))?;
        Self::from_json(&parse(&text).with_context(|| format!("parsing config {path:?}"))?)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())
            .with_context(|| format!("writing config {path:?}"))
    }

    /// Validate invariants that would otherwise surface deep in a run.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.slo.latency_ms > 0.0, "SLO latency must be positive");
        anyhow::ensure!(
            self.slo.percentile > 0.0 && self.slo.percentile < 1.0,
            "SLO percentile must be in (0, 1)"
        );
        anyhow::ensure!(self.cluster.budget > 0, "budget must be positive");
        anyhow::ensure!(
            self.adapter.interval_s > 0.0,
            "adapter interval must be positive"
        );
        anyhow::ensure!(self.adapter.headroom >= 1.0, "headroom must be >= 1");
        anyhow::ensure!(self.batching.max_batch >= 1, "max_batch must be >= 1");
        anyhow::ensure!(
            self.batching.max_wait_s >= 0.0,
            "batch max_wait_s must be non-negative"
        );
        anyhow::ensure!(
            self.batching.max_batch == 1
                || self.batching.max_wait_s < self.slo.latency_ms / 1000.0,
            "batch max_wait_s {} must stay under the SLO {} s",
            self.batching.max_wait_s,
            self.slo.latency_ms / 1000.0
        );
        anyhow::ensure!(
            self.weights.alpha >= 0.0 && self.weights.beta >= 0.0 && self.weights.gamma >= 0.0,
            "objective weights must be non-negative"
        );
        anyhow::ensure!(
            self.admission.burst_s > 0.0,
            "admission burst_s must be positive"
        );
        anyhow::ensure!(
            self.admission.slack > 0.0,
            "admission slack must be positive"
        );
        anyhow::ensure!(
            self.admission.ctl_window_s > 0.0,
            "admission ctl_window_s must be positive"
        );
        anyhow::ensure!(
            self.telemetry.flight_ticks >= 1,
            "telemetry flight_ticks must be at least 1"
        );
        anyhow::ensure!(
            self.telemetry.shed_trip_fraction > 0.0 && self.telemetry.shed_trip_fraction <= 1.0,
            "telemetry shed_trip_fraction must be in (0, 1]"
        );
        let rate_ok = |r: f64| r.is_finite() && (0.0..=1.0).contains(&r);
        anyhow::ensure!(
            rate_ok(self.fault.crash_rate),
            "fault crash_rate must be a probability in [0, 1]"
        );
        anyhow::ensure!(
            rate_ok(self.fault.straggler_rate),
            "fault straggler_rate must be a probability in [0, 1]"
        );
        anyhow::ensure!(
            rate_ok(self.fault.stall_rate),
            "fault stall_rate must be a probability in [0, 1]"
        );
        anyhow::ensure!(
            self.fault.crash_start_s.is_finite() && self.fault.crash_start_s >= 0.0,
            "fault crash_start_s must be finite and non-negative"
        );
        anyhow::ensure!(
            self.fault.crash_end_s.is_finite() && self.fault.crash_end_s >= self.fault.crash_start_s,
            "fault crash_end_s must be finite and at or after crash_start_s"
        );
        anyhow::ensure!(
            self.fault.slow_start_factor.is_finite() && self.fault.slow_start_factor >= 1.0,
            "fault slow_start_factor must be >= 1"
        );
        anyhow::ensure!(
            self.fault.straggler_mult.is_finite() && self.fault.straggler_mult >= 1.0,
            "fault straggler_mult must be >= 1"
        );
        anyhow::ensure!(
            self.fault.straggler_window_s.is_finite() && self.fault.straggler_window_s >= 0.0,
            "fault straggler_window_s must be finite and non-negative"
        );
        anyhow::ensure!(
            self.fault.max_retries <= 16,
            "fault max_retries must be at most 16"
        );
        anyhow::ensure!(
            self.fault.retry_backoff_s.is_finite() && self.fault.retry_backoff_s >= 0.0,
            "fault retry_backoff_s must be finite and non-negative"
        );
        anyhow::ensure!(
            self.fault.eject_after >= 1,
            "fault eject_after must be at least 1"
        );
        anyhow::ensure!(
            self.fault.probe_after_s.is_finite() && self.fault.probe_after_s > 0.0,
            "fault probe_after_s must be finite and positive"
        );
        // validated outside the fleet-services block: the CLI can set it
        // on synthetic fleets whose `services` list is empty
        anyhow::ensure!(
            self.fleet.burn_boost >= 0.0,
            "fleet burn_boost must be non-negative"
        );
        anyhow::ensure!(
            self.fleet.shed_penalty >= 0.0 && self.fleet.shed_penalty.is_finite(),
            "fleet shed_penalty must be finite and non-negative"
        );
        let node_total: usize = self.cluster.node_cores.iter().sum();
        anyhow::ensure!(
            self.cluster.budget <= node_total,
            "budget {} exceeds total node capacity {}",
            self.cluster.budget,
            node_total
        );
        if !self.fleet.services.is_empty() {
            let global = self.fleet.resolved_budget(&self.cluster);
            anyhow::ensure!(
                global <= node_total,
                "fleet global budget {global} exceeds total node capacity {node_total}"
            );
            let mut names: Vec<&str> =
                self.fleet.services.iter().map(|s| s.name.as_str()).collect();
            anyhow::ensure!(
                names.iter().all(|n| !n.is_empty() && !n.contains('/')),
                "fleet service names must be non-empty and slash-free"
            );
            names.sort_unstable();
            names.dedup();
            anyhow::ensure!(
                names.len() == self.fleet.services.len(),
                "fleet service names must be unique"
            );
            let floors: usize = self.fleet.services.iter().map(|s| s.floor_cores).sum();
            anyhow::ensure!(
                floors <= global,
                "fleet floors {floors} exceed the global budget {global}"
            );
            for s in &self.fleet.services {
                anyhow::ensure!(
                    s.priority > 0.0,
                    "fleet service {} needs a positive priority",
                    s.name
                );
                anyhow::ensure!(
                    s.error_budget > 0.0 && s.error_budget <= 1.0,
                    "fleet service {} needs an error budget in (0, 1]",
                    s.name
                );
                anyhow::ensure!(
                    s.class_mix.iter().all(|&(_, w)| w > 0.0),
                    "fleet service {} class_mix weights must be positive",
                    s.name
                );
                anyhow::ensure!(
                    s.slo_latency_ms > 0.0,
                    "fleet service {} needs a positive SLO",
                    s.name
                );
                anyhow::ensure!(
                    s.base_rps >= 0.0,
                    "fleet service {} has a negative base rate",
                    s.name
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid_and_matches_paper() {
        let c = Config::default();
        c.validate().unwrap();
        assert_eq!(c.slo.latency_ms, 750.0);
        assert_eq!(c.slo.percentile, 0.99);
        assert_eq!(c.adapter.interval_s, 30.0);
        assert_eq!(c.weights.beta, 0.05);
        assert_eq!(c.cluster.budget, 20);
    }

    #[test]
    fn default_batching_is_disabled() {
        let c = Config::default();
        assert_eq!(c.batching.max_batch, 1);
        assert!(c.batching.max_wait_s > 0.0);
        // enabling batching with a wait at/over the SLO is rejected
        let mut c = Config::default();
        c.batching.max_batch = 8;
        c.batching.max_wait_s = 1.0;
        assert!(c.validate().is_err());
        c.batching.max_wait_s = 0.1;
        c.validate().unwrap();
    }

    #[test]
    fn roundtrips_through_json() {
        let mut c = Config::default();
        c.variants = vec!["resnet18".into(), "resnet50".into()];
        c.batching.max_batch = 4;
        c.seed = 7;
        c.fleet.global_budget = 24;
        c.fleet.burn_boost = 1.5;
        c.fleet.shed_penalty = 0.75;
        c.fleet.solver_threads = 4;
        c.admission = AdmissionConfig {
            enabled: true,
            burst_s: 2.0,
            slack: 1.1,
            ctl_window_s: 0.5,
        };
        c.telemetry = TelemetryConfig {
            enabled: true,
            flight_ticks: 8,
            shed_trip_fraction: 0.5,
        };
        c.fault = FaultConfig {
            enabled: true,
            crash_rate: 0.004,
            crash_start_s: 60.0,
            crash_end_s: 180.0,
            slow_start_factor: 2.0,
            straggler_rate: 0.001,
            straggler_mult: 4.0,
            straggler_window_s: 45.0,
            stall_rate: 0.1,
            reactions: true,
            max_retries: 2,
            retry_backoff_s: 0.1,
            eject_after: 5,
            probe_after_s: 3.0,
            hedge: false,
        };
        c.fleet.services = vec![
            FleetServiceConfig {
                name: "search".into(),
                priority: 2.0,
                tier: 0,
                error_budget: 0.02,
                class_mix: vec![(0, 0.7), (1, 0.3)],
                floor_cores: 4,
                slo_latency_ms: 400.0,
                trace: "burst:100:200".into(),
                base_rps: 50.0,
            },
            FleetServiceConfig {
                name: "feed".into(),
                tier: 1,
                ..Default::default()
            },
        ];
        let text = c.to_json().to_string_pretty();
        let back = Config::from_json(&parse(&text).unwrap()).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn fleet_validation_catches_bad_fleets() {
        let svc = |name: &str, floor: usize| FleetServiceConfig {
            name: name.into(),
            floor_cores: floor,
            ..Default::default()
        };
        // duplicate names
        let mut c = Config::default();
        c.fleet.services = vec![svc("a", 0), svc("a", 0)];
        assert!(c.validate().is_err());
        // slash in a name (would break cluster namespacing)
        let mut c = Config::default();
        c.fleet.services = vec![svc("a/b", 0)];
        assert!(c.validate().is_err());
        // floors exceeding the (cluster-derived) global budget of 20
        let mut c = Config::default();
        c.fleet.services = vec![svc("a", 12), svc("b", 12)];
        assert!(c.validate().is_err());
        // non-positive priority
        let mut c = Config::default();
        c.fleet.services = vec![FleetServiceConfig {
            name: "a".into(),
            priority: 0.0,
            ..Default::default()
        }];
        assert!(c.validate().is_err());
        // zero error budget (burn rate would divide by zero)
        let mut c = Config::default();
        c.fleet.services = vec![FleetServiceConfig {
            name: "a".into(),
            error_budget: 0.0,
            ..Default::default()
        }];
        assert!(c.validate().is_err());
        // non-positive class-mix weight
        let mut c = Config::default();
        c.fleet.services = vec![FleetServiceConfig {
            name: "a".into(),
            class_mix: vec![(0, 0.0)],
            ..Default::default()
        }];
        assert!(c.validate().is_err());
        // negative burn boost — rejected even with no declared services
        // (the CLI sets it on synthetic fleets)
        let mut c = Config::default();
        c.fleet.services = vec![svc("a", 0)];
        c.fleet.burn_boost = -1.0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.fleet.burn_boost = -0.1;
        assert!(c.validate().is_err());
        // negative or non-finite shed penalty — rejected even with no
        // declared services (the CLI sets it on synthetic fleets)
        let mut c = Config::default();
        c.fleet.shed_penalty = -0.5;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.fleet.shed_penalty = f64::INFINITY;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.fleet.shed_penalty = 1.25;
        c.validate().unwrap();
        // a well-formed fleet passes, explicit global budget respected
        let mut c = Config::default();
        c.fleet.global_budget = 30;
        c.fleet.services = vec![svc("a", 10), svc("b", 10)];
        c.validate().unwrap();
        assert_eq!(c.fleet.resolved_budget(&c.cluster), 30);
    }

    #[test]
    fn telemetry_validation_catches_bad_values() {
        let mut c = Config::default();
        assert!(!c.telemetry.enabled, "telemetry must default off");
        c.telemetry.flight_ticks = 0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.telemetry.shed_trip_fraction = 0.0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.telemetry.shed_trip_fraction = 1.5;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.telemetry.enabled = true;
        c.validate().unwrap();
    }

    #[test]
    fn fault_validation_catches_bad_values() {
        let c = Config::default();
        assert!(!c.fault.enabled, "faults must default off");
        assert!(!c.fault.reactions, "reactions must default off");
        c.validate().unwrap();
        // probabilities outside [0, 1]
        for set in [
            (|c: &mut Config| c.fault.crash_rate = -0.1) as fn(&mut Config),
            |c| c.fault.crash_rate = 1.5,
            |c| c.fault.straggler_rate = -1.0,
            |c| c.fault.stall_rate = f64::NAN,
            // windows and factors
            |c| c.fault.crash_start_s = -5.0,
            |c| c.fault.crash_end_s = -1.0, // before crash_start_s = 0
            |c| {
                c.fault.crash_start_s = 100.0;
                c.fault.crash_end_s = 50.0;
            },
            |c| c.fault.slow_start_factor = 0.5,
            |c| c.fault.straggler_mult = 0.0,
            |c| c.fault.straggler_window_s = -1.0,
            // reaction knobs
            |c| c.fault.max_retries = 17,
            |c| c.fault.retry_backoff_s = -0.01,
            |c| c.fault.eject_after = 0,
            |c| c.fault.probe_after_s = 0.0,
        ] {
            let mut c = Config::default();
            set(&mut c);
            assert!(c.validate().is_err(), "bad fault value must be rejected");
        }
        // a fully-specified valid fault section passes
        let mut c = Config::default();
        c.fault.enabled = true;
        c.fault.crash_rate = 0.01;
        c.fault.reactions = true;
        c.validate().unwrap();
    }

    #[test]
    fn admission_validation_catches_bad_values() {
        let mut c = Config::default();
        assert!(!c.admission.enabled, "admission must default off");
        c.admission.burst_s = 0.0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.admission.slack = -0.5;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.admission.ctl_window_s = 0.0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.admission.enabled = true;
        c.validate().unwrap();
    }

    #[test]
    fn partial_json_uses_defaults() {
        let v = parse(r#"{"weights":{"alpha":2.0,"beta":0.2}}"#).unwrap();
        let c = Config::from_json(&v).unwrap();
        assert_eq!(c.weights.alpha, 2.0);
        assert_eq!(c.weights.beta, 0.2);
        assert_eq!(c.weights.gamma, 0.001); // default
        assert_eq!(c.slo.latency_ms, 750.0);
    }

    #[test]
    fn file_roundtrip() {
        let dir = crate::util::testutil::TempDir::new();
        let path = dir.path().join("config.json");
        let c = Config::default();
        c.save(&path).unwrap();
        assert_eq!(Config::load(&path).unwrap(), c);
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut c = Config::default();
        c.slo.percentile = 1.5;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.cluster.budget = 0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.cluster.budget = 1000;
        assert!(c.validate().is_err());
    }
}
