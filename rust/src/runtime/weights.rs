//! Weight loading: `<variant>.weights.npz` -> ordered `(name, data, dims)`.
//!
//! The AOT exporter writes one array per model parameter with zero-padded
//! index keys (`p0000`, `p0001`, ...) matching jax's pytree flatten order
//! for the parameter list, so sorting by name recovers the exact positional
//! argument order the lowered HLO expects after the image input.

use super::xla;
use super::xla::FromRawBytes;
use anyhow::{Context, Result};
use std::path::Path;

/// Load all f32 arrays from an npz, sorted by entry name.
pub fn load_weights_f32(path: &Path) -> Result<Vec<(String, Vec<f32>, Vec<usize>)>> {
    let mut entries = xla::Literal::read_npz(path, &())
        .with_context(|| format!("reading weights npz {path:?}"))?;
    entries.sort_by(|a, b| a.0.cmp(&b.0));
    let mut out = Vec::with_capacity(entries.len());
    for (name, lit) in entries {
        let shape = lit
            .array_shape()
            .with_context(|| format!("weight {name} has non-array shape"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit
            .to_vec::<f32>()
            .with_context(|| format!("weight {name} is not f32"))?;
        anyhow::ensure!(
            data.len() == dims.iter().product::<usize>(),
            "weight {name}: data len {} != shape {:?}",
            data.len(),
            dims
        );
        out.push((name, data, dims));
    }
    anyhow::ensure!(!out.is_empty(), "empty weights file {path:?}");
    Ok(out)
}
