//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! and the Rust coordinator.

use crate::util::json::{parse, Value};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Metadata for one exported model variant.
#[derive(Debug, Clone)]
pub struct VariantMeta {
    pub name: String,
    /// Published ImageNet top-1 of the corresponding torchvision variant;
    /// this is the `acc_m` constant in the paper's objective.
    pub accuracy: f64,
    pub block: String,
    pub depths: Vec<usize>,
    pub params: u64,
    pub flops: u64,
    pub weights: String,
    /// batch size -> HLO file name.
    pub hlo: BTreeMap<usize, String>,
    pub num_weight_arrays: usize,
}

impl VariantMeta {
    fn from_json(v: &Value) -> Result<Self> {
        let mut hlo = BTreeMap::new();
        for (k, file) in v.req("hlo")?.as_obj()? {
            hlo.insert(
                k.parse::<usize>()
                    .with_context(|| format!("bad batch key {k:?}"))?,
                file.as_str()?.to_string(),
            );
        }
        Ok(Self {
            name: v.req("name")?.as_str()?.to_string(),
            accuracy: v.req("accuracy")?.as_f64()?,
            block: v.req("block")?.as_str()?.to_string(),
            depths: v
                .req("depths")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<_>>()?,
            params: v.req("params")?.as_u64()?,
            flops: v.req("flops")?.as_u64()?,
            weights: v.req("weights")?.as_str()?.to_string(),
            hlo,
            num_weight_arrays: v.req("num_weight_arrays")?.as_usize()?,
        })
    }

    /// Path of the HLO artifact for a given batch size.
    pub fn hlo_path(&self, dir: &Path, batch: usize) -> Result<PathBuf> {
        let name = self
            .hlo
            .get(&batch)
            .with_context(|| format!("{}: no artifact for batch {batch}", self.name))?;
        Ok(dir.join(name))
    }

    pub fn weights_path(&self, dir: &Path) -> PathBuf {
        dir.join(&self.weights)
    }

    pub fn batch_sizes(&self) -> Vec<usize> {
        self.hlo.keys().copied().collect()
    }
}

/// Metadata for the exported LSTM forecaster.
#[derive(Debug, Clone)]
pub struct ForecasterMeta {
    pub hlo: String,
    pub window: usize,
    pub horizon: usize,
    pub units: usize,
    pub rps_scale: f64,
    pub final_train_loss: f64,
    pub loss_curve: Vec<f64>,
}

impl ForecasterMeta {
    fn from_json(v: &Value) -> Result<Self> {
        Ok(Self {
            hlo: v.req("hlo")?.as_str()?.to_string(),
            window: v.req("window")?.as_usize()?,
            horizon: v.req("horizon")?.as_usize()?,
            units: v.req("units")?.as_usize()?,
            rps_scale: v.req("rps_scale")?.as_f64()?,
            final_train_loss: v.req("final_train_loss")?.as_f64()?,
            loss_curve: match v.get("loss_curve") {
                Some(c) => c.as_arr()?.iter().map(|x| x.as_f64()).collect::<Result<_>>()?,
                None => Vec::new(),
            },
        })
    }
}

/// Top-level manifest written by `aot.py`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub input_hw: usize,
    pub num_classes: usize,
    pub rps_scale: f64,
    pub variants: Vec<VariantMeta>,
    pub forecaster: Option<ForecasterMeta>,
}

impl Manifest {
    pub fn from_json(v: &Value) -> Result<Self> {
        let variants = v
            .req("variants")?
            .as_arr()?
            .iter()
            .map(VariantMeta::from_json)
            .collect::<Result<Vec<_>>>()?;
        let forecaster = match v.get("forecaster") {
            None | Some(Value::Null) => None,
            Some(f) => Some(ForecasterMeta::from_json(f)?),
        };
        let m = Self {
            input_hw: v.req("input_hw")?.as_usize()?,
            num_classes: v.req("num_classes")?.as_usize()?,
            rps_scale: v.req("rps_scale")?.as_f64()?,
            variants,
            forecaster,
        };
        anyhow::ensure!(!m.variants.is_empty(), "manifest has no variants");
        Ok(m)
    }

    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts` first)"))?;
        Self::from_json(&parse(&text).context("parsing manifest.json")?)
    }

    pub fn variant(&self, name: &str) -> Result<&VariantMeta> {
        self.variants
            .iter()
            .find(|v| v.name == name)
            .with_context(|| format!("unknown variant {name}"))
    }

    /// Variants sorted by ascending accuracy (the solver's canonical order).
    pub fn variants_by_accuracy(&self) -> Vec<&VariantMeta> {
        let mut v: Vec<&VariantMeta> = self.variants.iter().collect();
        v.sort_by(|a, b| a.accuracy.total_cmp(&b.accuracy));
        v
    }

    /// NHWC input shape for a variant at a given batch size.
    pub fn input_shape(&self, batch: usize) -> [usize; 4] {
        [batch, self.input_hw, self.input_hw, 3]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "input_hw": 32,
      "num_classes": 10,
      "rps_scale": 200.0,
      "variants": [
        {"name": "resnet18", "accuracy": 69.76, "block": "basic",
         "depths": [2,2,2,2], "params": 700266, "flops": 70000000,
         "weights": "resnet18.weights.npz",
         "hlo": {"1": "resnet18.b1.hlo.txt", "8": "resnet18.b8.hlo.txt"},
         "num_weight_arrays": 42},
        {"name": "resnet152", "accuracy": 78.31, "block": "bottleneck",
         "depths": [3,8,36,3], "params": 3648426, "flops": 465000000,
         "weights": "resnet152.weights.npz",
         "hlo": {"1": "resnet152.b1.hlo.txt"},
         "num_weight_arrays": 314}
      ],
      "forecaster": {"hlo": "forecaster.hlo.txt", "window": 120,
                     "horizon": 30, "units": 25, "rps_scale": 200.0,
                     "final_train_loss": 0.001, "loss_curve": [0.1, 0.001]}
    }"#;

    #[test]
    fn parses_full_manifest() {
        let m = Manifest::from_json(&parse(SAMPLE).unwrap()).unwrap();
        assert_eq!(m.variants.len(), 2);
        assert_eq!(m.input_shape(1), [1, 32, 32, 3]);
        let v = m.variant("resnet18").unwrap();
        assert_eq!(v.batch_sizes(), vec![1, 8]);
        assert_eq!(
            v.hlo_path(Path::new("/a"), 8).unwrap(),
            PathBuf::from("/a/resnet18.b8.hlo.txt")
        );
        assert!(v.hlo_path(Path::new("/a"), 4).is_err());
        let f = m.forecaster.unwrap();
        assert_eq!(f.window, 120);
        assert_eq!(f.loss_curve.len(), 2);
    }

    #[test]
    fn sorts_by_accuracy() {
        let m = Manifest::from_json(&parse(SAMPLE).unwrap()).unwrap();
        let sorted = m.variants_by_accuracy();
        assert_eq!(sorted[0].name, "resnet18");
        assert_eq!(sorted[1].name, "resnet152");
    }

    #[test]
    fn null_forecaster_is_none() {
        let text = SAMPLE.replace(
            r#""forecaster": {"hlo": "forecaster.hlo.txt", "window": 120,
                     "horizon": 30, "units": 25, "rps_scale": 200.0,
                     "final_train_loss": 0.001, "loss_curve": [0.1, 0.001]}"#,
            r#""forecaster": null"#,
        );
        let m = Manifest::from_json(&parse(&text).unwrap()).unwrap();
        assert!(m.forecaster.is_none());
    }

    #[test]
    fn unknown_variant_errors() {
        let m = Manifest::from_json(&parse(SAMPLE).unwrap()).unwrap();
        assert!(m.variant("resnet999").is_err());
    }
}
