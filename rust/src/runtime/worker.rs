//! Worker threads: the execution substrate for the real serving engine.
//!
//! The PJRT client is not `Send`, so each worker is a dedicated OS thread
//! that builds its own client, compiles the variant's HLO, uploads weights,
//! and then serves inference jobs from a shared MPMC queue.  A backend pod
//! with `n` cores is a [`WorkerPool`] of `n` workers — mirroring the paper's
//! chosen TF-Serving configuration (intra-op = 1, inter-op = #cores: n
//! independent single-threaded executors per container).
//!
//! Worker startup time (compile + weight upload) is measured and surfaced as
//! the variant's readiness time `rt_m` — the quantity the paper's loading
//! cost `LC = max(tc_m * rt_m)` penalizes.

use super::xla;
use crate::util::mpmc;
use anyhow::{Context, Result};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::manifest::{Manifest, VariantMeta};
use super::LoadedModel;

/// Completion callback: receives the logits (or error) and the total time
/// the job spent in the pool (queueing + execution).
pub type InferCallback = Box<dyn FnOnce(Result<Vec<f32>>, Duration) + Send + 'static>;

/// One inference job.
pub struct InferRequest {
    pub image: Arc<Vec<f32>>,
    pub respond: InferCallback,
    pub enqueued: Instant,
}

/// A pool of identical workers serving one (variant, batch) executable.
pub struct WorkerPool {
    tx: mpmc::Sender<InferRequest>,
    handles: Vec<std::thread::JoinHandle<()>>,
    /// Measured worker startup (HLO compile + weight upload), i.e. `rt_m`.
    pub readiness: Duration,
    pub variant: String,
    pub size: usize,
    /// Batch size of the compiled executable this pool serves.
    pub batch: usize,
    inflight: Arc<AtomicUsize>,
}

impl WorkerPool {
    /// Spawn `size` workers for `meta` at batch size `batch`.
    ///
    /// Blocks until every worker has finished compiling (is "ready"), so the
    /// caller observes the true readiness time.
    pub fn spawn(
        dir: &std::path::Path,
        manifest: &Manifest,
        meta: &VariantMeta,
        batch: usize,
        size: usize,
    ) -> Result<Self> {
        anyhow::ensure!(size > 0, "worker pool must have at least one worker");
        let (tx, rx) = mpmc::channel::<InferRequest>();
        let hlo: PathBuf = meta.hlo_path(dir, batch)?;
        let npz = meta.weights_path(dir);
        let input_shape = manifest.input_shape(batch);
        let num_classes = manifest.num_classes;
        let start = Instant::now();
        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<Result<()>>();
        let inflight = Arc::new(AtomicUsize::new(0));

        let mut handles = Vec::with_capacity(size);
        for wid in 0..size {
            let rx = rx.clone();
            let ready_tx = ready_tx.clone();
            let hlo = hlo.clone();
            let npz = npz.clone();
            let name = format!("{}#{}", meta.name, wid);
            let inflight = inflight.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("pjrt-{name}"))
                    .spawn(move || {
                        worker_main(rx, ready_tx, name, hlo, npz, input_shape, num_classes, inflight)
                    })
                    .context("spawning worker thread")?,
            );
        }
        drop(ready_tx);
        for _ in 0..size {
            ready_rx
                .recv()
                .context("worker died before signalling readiness")??;
        }
        Ok(Self {
            tx,
            handles,
            readiness: start.elapsed(),
            variant: meta.name.clone(),
            size,
            batch,
            inflight,
        })
    }

    /// Submit a job; `respond` runs on the worker thread when it completes.
    pub fn submit(
        &self,
        image: Arc<Vec<f32>>,
        respond: impl FnOnce(Result<Vec<f32>>, Duration) + Send + 'static,
    ) -> Result<()> {
        self.tx
            .send(InferRequest {
                image,
                respond: Box::new(respond),
                enqueued: Instant::now(),
            })
            .map_err(|_| anyhow::anyhow!("worker pool {} is shut down", self.variant))
    }

    /// Synchronous inference (profiling, examples).
    pub fn infer_blocking(&self, image: Arc<Vec<f32>>) -> Result<Vec<f32>> {
        let (tx, rx) = std::sync::mpsc::channel();
        self.submit(image, move |result, _elapsed| {
            let _ = tx.send(result);
        })?;
        rx.recv().context("worker dropped the response channel")?
    }

    /// Jobs queued but not yet picked up.
    pub fn queue_len(&self) -> usize {
        self.tx.len()
    }

    /// Jobs currently executing across the pool.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }

    /// Queued + executing jobs are drained, then the workers exit.
    pub fn shutdown(mut self) {
        let (dummy, _) = mpmc::channel();
        let _ = std::mem::replace(&mut self.tx, dummy);
        for h in std::mem::take(&mut self.handles) {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Closing the channel lets workers drain and exit; detach threads.
        let (dummy, _) = mpmc::channel();
        let _ = std::mem::replace(&mut self.tx, dummy);
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_main(
    rx: mpmc::Receiver<InferRequest>,
    ready_tx: std::sync::mpsc::Sender<Result<()>>,
    name: String,
    hlo: PathBuf,
    npz: PathBuf,
    input_shape: [usize; 4],
    num_classes: usize,
    inflight: Arc<AtomicUsize>,
) {
    let built = (|| -> Result<(xla::PjRtClient, LoadedModel)> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let model = LoadedModel::load(&client, &name, &hlo, &npz, input_shape, num_classes)?;
        Ok((client, model))
    })();
    let (client, model) = match built {
        Ok(cm) => {
            let _ = ready_tx.send(Ok(()));
            cm
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };
    drop(ready_tx);
    while let Some(req) = rx.recv() {
        inflight.fetch_add(1, Ordering::Relaxed);
        let result = model.infer(&client, &req.image);
        inflight.fetch_sub(1, Ordering::Relaxed);
        (req.respond)(result, req.enqueued.elapsed());
    }
}

/// A dedicated thread hosting the AOT LSTM forecaster.
pub struct RuntimeHandle {
    tx: mpmc::Sender<(Vec<f32>, std::sync::mpsc::Sender<Result<f32>>)>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl RuntimeHandle {
    /// Load `forecaster.hlo.txt` on its own thread.
    pub fn spawn_forecaster(dir: &std::path::Path, window: usize) -> Result<Self> {
        let hlo = dir.join("forecaster.hlo.txt");
        anyhow::ensure!(hlo.exists(), "missing forecaster artifact {hlo:?}");
        let (tx, rx) = mpmc::channel::<(Vec<f32>, std::sync::mpsc::Sender<Result<f32>>)>();
        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name("pjrt-forecaster".into())
            .spawn(move || {
                let built = (|| -> Result<(xla::PjRtClient, super::LoadedForecaster)> {
                    let client = xla::PjRtClient::cpu()?;
                    let f = super::LoadedForecaster::load(&client, &hlo, window)?;
                    Ok((client, f))
                })();
                let (client, forecaster) = match built {
                    Ok(cf) => {
                        let _ = ready_tx.send(Ok(()));
                        cf
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Some((win, resp)) = rx.recv() {
                    let _ = resp.send(forecaster.predict(&client, &win));
                }
            })?;
        ready_rx.recv().context("forecaster thread died")??;
        Ok(Self {
            tx,
            handle: Some(handle),
        })
    }

    /// Predict the next-horizon max rate (normalized units) from a window.
    pub fn predict(&self, window: Vec<f32>) -> Result<f32> {
        let (tx, rx) = std::sync::mpsc::channel();
        self.tx
            .send((window, tx))
            .map_err(|_| anyhow::anyhow!("forecaster thread is gone"))?;
        rx.recv().context("forecaster thread dropped response")?
    }
}

impl Drop for RuntimeHandle {
    fn drop(&mut self) {
        let (dummy_tx, _) = mpmc::channel();
        let _ = std::mem::replace(&mut self.tx, dummy_tx);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Alias kept for external readability.
pub type RuntimeWorker = WorkerPool;
