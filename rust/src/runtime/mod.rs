//! PJRT runtime: load AOT artifacts (`artifacts/*.hlo.txt`) and execute them.
//!
//! This is the only module that touches the `xla` crate.  The PJRT client is
//! `Rc`-based (not `Send`), so all execution is structured around
//! [`RuntimeWorker`] threads: each worker owns a client, compiles the
//! requested executables on its own thread, and serves inference requests
//! from an MPMC channel.  A "pod with n cores" in the serving layer is n
//! workers sharing one queue — exactly the paper's TF-Serving configuration
//! (intra-op = 1, inter-op = #cores, i.e. n single-threaded executors).
//!
//! Python never runs here: artifacts are produced once by `make artifacts`.

mod manifest;
mod weights;
mod worker;

/// PJRT bindings.  The offline build has no crate registry, so the real
/// `xla` crate is replaced by an API-compatible stub whose entry points
/// all fail with a clear error (see `xla_stub.rs`); swap this declaration
/// for `pub use ::xla;` to restore live execution.
#[path = "xla_stub.rs"]
pub mod xla;

pub use manifest::{ForecasterMeta, Manifest, VariantMeta};
pub use weights::load_weights_f32;
pub use worker::{InferRequest, RuntimeHandle, RuntimeWorker, WorkerPool};

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// A loaded, compiled model executable bound to one thread's PJRT client.
///
/// Weights are uploaded to device buffers once at load time; per-inference
/// work is a single input-buffer upload + `execute_b` + readback.
pub struct LoadedModel {
    exe: xla::PjRtLoadedExecutable,
    weight_bufs: Vec<xla::PjRtBuffer>,
    /// (batch, h, w, c) image input shape.
    pub input_shape: [usize; 4],
    pub num_classes: usize,
    pub name: String,
}

impl LoadedModel {
    /// Compile `hlo_path` on `client` and upload the weights from `npz_path`.
    pub fn load(
        client: &xla::PjRtClient,
        name: &str,
        hlo_path: &Path,
        npz_path: &Path,
        input_shape: [usize; 4],
        num_classes: usize,
    ) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(hlo_path)
            .with_context(|| format!("parsing HLO text {hlo_path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        let weights = load_weights_f32(npz_path)?;
        let mut weight_bufs = Vec::with_capacity(weights.len());
        for (wname, data, dims) in &weights {
            let buf = client
                .buffer_from_host_buffer::<f32>(data, dims, None)
                .with_context(|| format!("uploading weight {wname}"))?;
            weight_bufs.push(buf);
        }
        Ok(Self {
            exe,
            weight_bufs,
            input_shape,
            num_classes,
            name: name.to_string(),
        })
    }

    /// Number of image elements expected per call (batch * h * w * c).
    pub fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Run one inference; `image` is the flattened NHWC batch.
    /// Returns the flattened (batch, num_classes) logits.
    pub fn infer(&self, client: &xla::PjRtClient, image: &[f32]) -> Result<Vec<f32>> {
        anyhow::ensure!(
            image.len() == self.input_len(),
            "input length {} != expected {} for {}",
            image.len(),
            self.input_len(),
            self.name
        );
        let input = client.buffer_from_host_buffer::<f32>(image, &self.input_shape, None)?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + self.weight_bufs.len());
        args.push(&input);
        args.extend(self.weight_bufs.iter());
        let result = self.exe.execute_b(&args)?[0][0].to_literal_sync()?;
        // Lowered with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// A compiled forecaster executable (weights baked as constants).
pub struct LoadedForecaster {
    exe: xla::PjRtLoadedExecutable,
    pub window: usize,
}

impl LoadedForecaster {
    pub fn load(client: &xla::PjRtClient, hlo_path: &Path, window: usize) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(hlo_path)
            .with_context(|| format!("parsing forecaster HLO {hlo_path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("compiling forecaster")?;
        Ok(Self { exe, window })
    }

    /// Predict the next-horizon max rate from a normalized window.
    pub fn predict(&self, client: &xla::PjRtClient, window: &[f32]) -> Result<f32> {
        anyhow::ensure!(
            window.len() == self.window,
            "window length {} != expected {}",
            window.len(),
            self.window
        );
        let input = client.buffer_from_host_buffer::<f32>(window, &[self.window, 1], None)?;
        let result = self.exe.execute_b(&[&input])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        let v = out.to_vec::<f32>()?;
        anyhow::ensure!(v.len() == 1, "forecaster returned {} values", v.len());
        Ok(v[0])
    }
}

/// Resolve the artifacts directory: `$INFADAPTER_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("INFADAPTER_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}
