//! Offline stand-in for the `xla` PJRT bindings.
//!
//! The build environment has no crate registry, so the real `xla` crate
//! (PJRT FFI) cannot be fetched.  This module mirrors the exact API surface
//! the runtime uses; every entry point that would touch PJRT returns
//! [`Unavailable`], so the serving stack degrades exactly like a checkout
//! without AOT artifacts: `Manifest::load` / `WorkerPool::spawn` report an
//! error, callers fall back to the calibrated simulator, and every
//! PJRT-dependent test self-skips.  Restoring live execution means swapping
//! this module for the real crate in `runtime/mod.rs` — no call site
//! changes.

use std::fmt;
use std::path::Path;

/// The single error every stubbed entry point returns.
#[derive(Debug, Clone, Copy)]
pub struct Unavailable;

impl fmt::Display for Unavailable {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "PJRT runtime unavailable: offline build without the `xla` crate"
        )
    }
}

impl std::error::Error for Unavailable {}

type XResult<T> = Result<T, Unavailable>;

/// Stub of `xla::PjRtClient`.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> XResult<Self> {
        Err(Unavailable)
    }

    pub fn compile(&self, _computation: &XlaComputation) -> XResult<PjRtLoadedExecutable> {
        Err(Unavailable)
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> XResult<PjRtBuffer> {
        Err(Unavailable)
    }
}

/// Stub of `xla::PjRtBuffer`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> XResult<Literal> {
        Err(Unavailable)
    }
}

/// Stub of `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> XResult<Vec<Vec<PjRtBuffer>>> {
        Err(Unavailable)
    }
}

/// Stub of `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> XResult<Self> {
        Err(Unavailable)
    }
}

/// Stub of `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Stub of `xla::ArrayShape`.
pub struct ArrayShape;

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &[]
    }
}

/// Stub of `xla::FromRawBytes` (provides `Literal::read_npz`).
pub trait FromRawBytes: Sized {
    fn read_npz(path: &Path, options: &()) -> XResult<Vec<(String, Self)>>;
}

/// Stub of `xla::Literal`.
pub struct Literal;

impl FromRawBytes for Literal {
    fn read_npz(_path: &Path, _options: &()) -> XResult<Vec<(String, Self)>> {
        Err(Unavailable)
    }
}

impl Literal {
    pub fn to_tuple1(&self) -> XResult<Literal> {
        Err(Unavailable)
    }

    pub fn to_vec<T>(&self) -> XResult<Vec<T>> {
        Err(Unavailable)
    }

    pub fn array_shape(&self) -> XResult<ArrayShape> {
        Err(Unavailable)
    }
}
