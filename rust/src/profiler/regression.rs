//! Ordinary least-squares linear regression (the paper's throughput model).

/// `y = a·x + b` with fit diagnostics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinearRegression {
    pub slope: f64,
    pub intercept: f64,
    /// Coefficient of determination of the fit (paper reports 0.996/0.994).
    pub r_squared: f64,
}

impl LinearRegression {
    /// Least-squares fit over `(x, y)` points. Panics on < 2 points.
    pub fn fit(points: &[(f64, f64)]) -> Self {
        assert!(points.len() >= 2, "need at least two points to fit");
        let n = points.len() as f64;
        let sx: f64 = points.iter().map(|p| p.0).sum();
        let sy: f64 = points.iter().map(|p| p.1).sum();
        let sxx: f64 = points.iter().map(|p| p.0 * p.0).sum();
        let sxy: f64 = points.iter().map(|p| p.0 * p.1).sum();
        let denom = n * sxx - sx * sx;
        let (slope, intercept) = if denom.abs() < 1e-12 {
            (0.0, sy / n)
        } else {
            let a = (n * sxy - sx * sy) / denom;
            (a, (sy - a * sx) / n)
        };
        let mean_y = sy / n;
        let ss_tot: f64 = points.iter().map(|p| (p.1 - mean_y).powi(2)).sum();
        let ss_res: f64 = points
            .iter()
            .map(|p| (p.1 - (slope * p.0 + intercept)).powi(2))
            .sum();
        let r_squared = if ss_tot < 1e-12 {
            1.0
        } else {
            1.0 - ss_res / ss_tot
        };
        Self {
            slope,
            intercept,
            r_squared,
        }
    }

    pub fn predict(&self, x: f64) -> f64 {
        self.slope * x + self.intercept
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_line_recovers_parameters() {
        let pts: Vec<(f64, f64)> = (1..=16).map(|n| (n as f64, 3.5 * n as f64 + 1.0)).collect();
        let r = LinearRegression::fit(&pts);
        assert!((r.slope - 3.5).abs() < 1e-9);
        assert!((r.intercept - 1.0).abs() < 1e-9);
        assert!((r.r_squared - 1.0).abs() < 1e-12);
    }

    #[test]
    fn noisy_line_fits_with_high_r2() {
        let mut rng = crate::util::rng::Rng::seed_from_u64(4);
        let pts: Vec<(f64, f64)> = [1.0f64, 2.0, 4.0, 8.0, 16.0]
            .iter()
            .map(|&n| (n, 20.0 * n + rng.f64() * 2.0 - 1.0))
            .collect();
        let r = LinearRegression::fit(&pts);
        assert!((r.slope - 20.0).abs() < 0.5);
        assert!(r.r_squared > 0.99, "r2 {}", r.r_squared);
    }

    #[test]
    fn degenerate_x_falls_back_to_mean() {
        let r = LinearRegression::fit(&[(2.0, 5.0), (2.0, 7.0)]);
        assert_eq!(r.slope, 0.0);
        assert_eq!(r.intercept, 6.0);
    }
}
