//! Profiling substrate: per-variant performance models (paper §5,
//! "Profiling methodology").
//!
//! The paper profiles each variant under CPU allocations {1, 2, 4, 8, 16},
//! fits a **linear regression** `th_m(n) = a·n + b` to the sustained
//! throughput, and uses it to predict throughput at any allocation
//! (Figure 6 reports R² of 0.996 / 0.994 for ResNet18/50).
//!
//! [`VariantProfile`] carries the measured single-worker service time and
//! the fitted regression; [`ProfileSet`] is the collection the solver and
//! the simulation engine consume.  Profiles are measured against the real
//! PJRT engine ([`measure_real`]) or derived from the queueing model
//! ([`ProfileSet::from_service_times`]) and serialize to `profiles.json`.
//!
//! ## Batch model
//!
//! Server-side batching amortizes per-dispatch fixed costs over the batch:
//! a batch of `b` items takes `s(b) = s(1)·(f + (1−f)·b)` seconds, where
//! `f ∈ [0, 1)` is the calibrated fixed-cost fraction
//! ([`VariantProfile::batch_fixed_frac`]).  The implied throughput gain is
//! `s(1)·b / s(b) = b / (f + (1−f)·b)`, which is 1 at `b = 1` and saturates
//! at `1/(1−f)` — so `f = 0` reproduces the paper's CPU finding (batching
//! buys nothing) while `f > 0` models accelerator-style amortization.  The
//! batched curves `th_m(n, b)` ([`VariantProfile::throughput_batched`]) and
//! `p_m(n, b)` ([`VariantProfile::latency_batched`]) extend the per-core
//! regression along the batch axis; with `b = 1` both collapse to the
//! original tables, keeping non-batched paths bit-compatible.

mod regression;

pub use regression::LinearRegression;

use crate::util::json::{parse, Value};
use anyhow::{Context, Result};
use std::path::Path;

/// The CPU allocations the paper profiles at.
pub const PROFILE_POINTS: [usize; 5] = [1, 2, 4, 8, 16];

/// Default fixed-cost fraction of the batch amortization model: half of a
/// single-item service time is dispatch overhead amortized by batching
/// (asymptotic throughput gain 2x).  Replaced by measurement when batched
/// artifacts are profiled; irrelevant while batching is disabled (b = 1).
pub const DEFAULT_BATCH_FIXED_FRAC: f64 = 0.5;

/// Performance model of one model variant.
#[derive(Debug, Clone)]
pub struct VariantProfile {
    pub name: String,
    /// `acc_m`: accuracy metadata (percentage points).
    pub accuracy: f64,
    /// Mean single-worker service time, seconds per request.
    pub service_time_s: f64,
    /// Lognormal sigma of service-time noise (measured dispersion).
    pub service_sigma: f64,
    /// Measured readiness time `rt_m`, seconds (compile + weight upload).
    pub readiness_s: f64,
    /// Fitted `th_m(n) = a·n + b` (requests/second).
    pub throughput_model: LinearRegression,
    /// Raw (cores, throughput) points the regression was fitted on.
    pub profile_points: Vec<(usize, f64)>,
    /// Fixed-cost fraction `f` of the batch amortization model
    /// `s(b) = s(1)·(f + (1−f)·b)` (see the module docs).
    pub batch_fixed_frac: f64,
}

impl VariantProfile {
    /// Predicted sustainable throughput at `n` cores (never negative).
    pub fn throughput(&self, n: usize) -> f64 {
        if n == 0 {
            return 0.0;
        }
        self.throughput_model.predict(n as f64).max(0.0)
    }

    /// Predicted processing latency `p_m(n)` (seconds) at `n` cores under
    /// its sustainable load: with n parallel single-threaded workers the
    /// per-request service time stays ~constant; queueing headroom is what
    /// the SLO constraint checks.
    pub fn latency(&self, n: usize) -> f64 {
        if n == 0 {
            return f64::INFINITY;
        }
        self.service_time_s
    }

    /// Smallest allocation whose predicted latency meets `slo_s`, if any.
    pub fn min_cores_for_slo(&self, slo_s: f64, max_cores: usize) -> Option<usize> {
        (1..=max_cores).find(|&n| self.latency(n) <= slo_s)
    }

    /// Mean service time of a batch of `b` items on one worker:
    /// `s(b) = s(1)·(f + (1−f)·b)`.  `s(1) = service_time_s` exactly.
    pub fn service_time_batch(&self, b: usize) -> f64 {
        if b <= 1 {
            // exact, so b = 1 stays bit-compatible with the unbatched model
            return self.service_time_s;
        }
        let b = b as f64;
        let f = self.batch_fixed_frac.clamp(0.0, 1.0);
        self.service_time_s * (f + (1.0 - f) * b)
    }

    /// Throughput multiplier of batch size `b` over `b = 1`:
    /// `s(1)·b / s(b) = b / (f + (1−f)·b)`; 1 at `b = 1`, monotone
    /// increasing, saturating at `1/(1−f)`.
    pub fn batch_gain(&self, b: usize) -> f64 {
        if b <= 1 {
            return 1.0;
        }
        let b = b as f64;
        let f = self.batch_fixed_frac.clamp(0.0, 1.0);
        b / (f + (1.0 - f) * b)
    }

    /// Predicted sustainable throughput `th_m(n, b)` at `n` cores with
    /// server-side batches of `b` items: the per-core regression scaled by
    /// the batch amortization gain.  `b = 1` equals [`Self::throughput`].
    pub fn throughput_batched(&self, n: usize, b: usize) -> f64 {
        self.throughput(n) * self.batch_gain(b)
    }

    /// Predicted processing latency `p_m(n, b)` (seconds): a request rides
    /// a full batch, so it pays the whole batched service draw.  Batch
    /// *formation* wait is accounted separately by the solver (worst-case
    /// `max_wait_s`) and the simulator (actual accumulation time).
    pub fn latency_batched(&self, n: usize, b: usize) -> f64 {
        if n == 0 {
            return f64::INFINITY;
        }
        self.service_time_batch(b)
    }
}

/// The full profile collection (solver + sim input).
#[derive(Debug, Clone)]
pub struct ProfileSet {
    pub profiles: Vec<VariantProfile>,
}

impl ProfileSet {
    pub fn get(&self, name: &str) -> Result<&VariantProfile> {
        self.profiles
            .iter()
            .find(|p| p.name == name)
            .with_context(|| format!("no profile for variant {name}"))
    }

    /// Ascending-accuracy order (the solver's canonical enumeration order).
    pub fn by_accuracy(&self) -> Vec<&VariantProfile> {
        let mut v: Vec<&VariantProfile> = self.profiles.iter().collect();
        v.sort_by(|a, b| a.accuracy.total_cmp(&b.accuracy));
        v
    }

    /// Aggregate sustainable throughput Σ `th_m(n, b)` of an allocation —
    /// the admission gate's supply signal.  `alloc` maps variant → cores,
    /// `batches` the in-force batch sizes (absent = 1); unknown variants
    /// contribute nothing.
    pub fn supply_rps(
        &self,
        alloc: &std::collections::BTreeMap<String, usize>,
        batches: &std::collections::BTreeMap<String, usize>,
    ) -> f64 {
        alloc
            .iter()
            .filter(|&(_, &n)| n > 0)
            .filter_map(|(v, &n)| {
                self.get(v)
                    .ok()
                    .map(|p| p.throughput_batched(n, batches.get(v).copied().unwrap_or(1)))
            })
            .sum()
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![(
            "profiles",
            Value::Arr(
                self.profiles
                    .iter()
                    .map(|p| {
                        Value::obj(vec![
                            ("name", Value::Str(p.name.clone())),
                            ("accuracy", Value::Num(p.accuracy)),
                            ("service_time_s", Value::Num(p.service_time_s)),
                            ("service_sigma", Value::Num(p.service_sigma)),
                            ("readiness_s", Value::Num(p.readiness_s)),
                            ("batch_fixed_frac", Value::Num(p.batch_fixed_frac)),
                            (
                                "throughput_model",
                                Value::obj(vec![
                                    ("slope", Value::Num(p.throughput_model.slope)),
                                    ("intercept", Value::Num(p.throughput_model.intercept)),
                                    ("r_squared", Value::Num(p.throughput_model.r_squared)),
                                ]),
                            ),
                            (
                                "profile_points",
                                Value::Arr(
                                    p.profile_points
                                        .iter()
                                        .map(|&(n, th)| {
                                            Value::Arr(vec![
                                                Value::Num(n as f64),
                                                Value::Num(th),
                                            ])
                                        })
                                        .collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        )])
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let profiles = v
            .req("profiles")?
            .as_arr()?
            .iter()
            .map(|p| -> Result<VariantProfile> {
                let tm = p.req("throughput_model")?;
                Ok(VariantProfile {
                    name: p.req("name")?.as_str()?.to_string(),
                    accuracy: p.req("accuracy")?.as_f64()?,
                    service_time_s: p.req("service_time_s")?.as_f64()?,
                    service_sigma: p.req("service_sigma")?.as_f64()?,
                    readiness_s: p.req("readiness_s")?.as_f64()?,
                    // Absent in pre-batching profile files: use the default.
                    batch_fixed_frac: match p.get("batch_fixed_frac") {
                        Some(v) => v.as_f64()?,
                        None => DEFAULT_BATCH_FIXED_FRAC,
                    },
                    throughput_model: LinearRegression {
                        slope: tm.req("slope")?.as_f64()?,
                        intercept: tm.req("intercept")?.as_f64()?,
                        r_squared: tm.req("r_squared")?.as_f64()?,
                    },
                    profile_points: p
                        .req("profile_points")?
                        .as_arr()?
                        .iter()
                        .map(|pt| -> Result<(usize, f64)> {
                            let a = pt.as_arr()?;
                            anyhow::ensure!(a.len() == 2, "bad profile point");
                            Ok((a[0].as_usize()?, a[1].as_f64()?))
                        })
                        .collect::<Result<_>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Self { profiles })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())
            .with_context(|| format!("writing profiles {path:?}"))
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading profiles {path:?}"))?;
        Self::from_json(&parse(&text).context("parsing profiles.json")?)
    }

    /// Build profiles from known service times via the M/D/n-style capacity
    /// model the paper's TF-Serving configuration implies: a pod with `n`
    /// cores is `n` independent single-threaded workers, so the sustained
    /// throughput at the SLO is slightly below `n / service_time` (we apply
    /// a utilization margin `rho` and fit the regression through the same
    /// {1,2,4,8,16} points the paper measures).
    pub fn from_service_times(
        entries: &[(String, f64, f64, f64)], // (name, acc, service_s, readiness_s)
        rho: f64,
    ) -> Self {
        let profiles = entries
            .iter()
            .map(|(name, acc, st, rt)| {
                let pts: Vec<(usize, f64)> = PROFILE_POINTS
                    .iter()
                    .map(|&n| (n, rho * n as f64 / st))
                    .collect();
                let reg = LinearRegression::fit(
                    &pts.iter()
                        .map(|&(n, th)| (n as f64, th))
                        .collect::<Vec<_>>(),
                );
                VariantProfile {
                    name: name.clone(),
                    accuracy: *acc,
                    service_time_s: *st,
                    service_sigma: 0.12,
                    readiness_s: *rt,
                    throughput_model: reg,
                    profile_points: pts,
                    batch_fixed_frac: DEFAULT_BATCH_FIXED_FRAC,
                }
            })
            .collect();
        Self { profiles }
    }

    /// A synthetic five-variant family calibrated to the paper's throughput
    /// ladder (used by the figure benches and tests; real measurements from
    /// `measure_real` replace it when artifacts exist).
    ///
    /// Per-core sustained throughputs ~{23, 13, 10, 7, 5} rps reproduce the
    /// paper's motivating equivalences: ResNet50 @ 8 cores ≈ ResNet152 @ 20
    /// (80 vs 100 rps loosely), ResNet152 alone cannot cover 75 rps inside
    /// a 14-core budget (70 rps) — the Figure 2 regime where mixed variant
    /// sets beat single-variant selection.
    pub fn paper_like() -> Self {
        Self::from_service_times(
            &[
                ("resnet18".into(), 69.76, 0.040, 4.0),
                ("resnet34".into(), 73.31, 0.070, 6.0),
                ("resnet50".into(), 76.13, 0.092, 8.0),
                ("resnet101".into(), 77.37, 0.131, 12.0),
                ("resnet152".into(), 78.31, 0.184, 16.0),
            ],
            0.92,
        )
    }
}

/// Measure service time + readiness of every manifest variant on the real
/// PJRT engine: spawn a single worker, time `iters` sequential inferences.
pub fn measure_real(
    dir: &Path,
    manifest: &crate::runtime::Manifest,
    iters: usize,
    variants: Option<&[String]>,
) -> Result<ProfileSet> {
    use std::sync::Arc;
    let mut entries = Vec::new();
    for meta in &manifest.variants {
        if let Some(filter) = variants {
            if !filter.contains(&meta.name) {
                continue;
            }
        }
        let pool = crate::runtime::WorkerPool::spawn(dir, manifest, meta, 1, 1)?;
        let readiness = pool.readiness.as_secs_f64();
        let image = Arc::new(vec![0.5f32; manifest.input_shape(1).iter().product()]);
        // Warmup.
        pool.infer_blocking(image.clone())?;
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = std::time::Instant::now();
            pool.infer_blocking(image.clone())?;
            samples.push(t0.elapsed().as_secs_f64());
        }
        pool.shutdown();
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>()
            / samples.len() as f64;
        let sigma = (var.sqrt() / mean).min(0.5);
        entries.push((meta.name.clone(), meta.accuracy, mean, readiness, sigma));
    }
    anyhow::ensure!(!entries.is_empty(), "no variants measured");
    let mut set = ProfileSet::from_service_times(
        &entries
            .iter()
            .map(|(n, a, s, r, _)| (n.clone(), *a, *s, *r))
            .collect::<Vec<_>>(),
        0.92,
    );
    for (p, (_, _, _, _, sigma)) in set.profiles.iter_mut().zip(entries.iter()) {
        p.service_sigma = *sigma;
    }
    Ok(set)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_like_ladder_is_ordered() {
        let set = ProfileSet::paper_like();
        let by_acc = set.by_accuracy();
        assert_eq!(by_acc.first().unwrap().name, "resnet18");
        assert_eq!(by_acc.last().unwrap().name, "resnet152");
        // more accurate variants are slower (resnet34/50 invert in our
        // family just like the real bottleneck-vs-basic transition)
        assert!(
            by_acc.last().unwrap().service_time_s > by_acc.first().unwrap().service_time_s
        );
    }

    #[test]
    fn throughput_model_is_monotone_in_cores() {
        let set = ProfileSet::paper_like();
        for p in &set.profiles {
            for n in 1..32 {
                assert!(p.throughput(n + 1) > p.throughput(n), "{}", p.name);
            }
            assert_eq!(p.throughput(0), 0.0);
        }
    }

    #[test]
    fn regression_matches_generating_model() {
        let set = ProfileSet::paper_like();
        let p = set.get("resnet18").unwrap();
        // generated from th = rho*n/st, so the fit must be near-exact
        let expect = 0.92 * 10.0 / 0.040;
        assert!((p.throughput(10) - expect).abs() / expect < 0.01);
        assert!(p.throughput_model.r_squared > 0.999);
    }

    #[test]
    fn min_cores_for_slo() {
        let set = ProfileSet::paper_like();
        let p = set.get("resnet152").unwrap();
        assert_eq!(p.min_cores_for_slo(0.75, 32), Some(1));
        assert_eq!(p.min_cores_for_slo(0.01, 32), None);
    }

    #[test]
    fn supply_rps_sums_batched_throughputs() {
        use std::collections::BTreeMap;
        let set = ProfileSet::paper_like();
        let alloc = BTreeMap::from([
            ("resnet18".to_string(), 4usize),
            ("resnet50".to_string(), 2),
            ("unknown".to_string(), 8), // ignored
            ("resnet101".to_string(), 0), // zero cores contribute nothing
        ]);
        let batches = BTreeMap::from([("resnet50".to_string(), 4usize)]);
        let expect = set.get("resnet18").unwrap().throughput_batched(4, 1)
            + set.get("resnet50").unwrap().throughput_batched(2, 4);
        assert!((set.supply_rps(&alloc, &batches) - expect).abs() < 1e-9);
        assert_eq!(set.supply_rps(&BTreeMap::new(), &BTreeMap::new()), 0.0);
    }

    #[test]
    fn batch_model_is_anchored_and_monotone() {
        let set = ProfileSet::paper_like();
        let p = set.get("resnet50").unwrap();
        // b = 1 is exactly the unbatched model (bit-compat anchor).
        assert_eq!(p.service_time_batch(1), p.service_time_s);
        assert_eq!(p.batch_gain(1), 1.0);
        assert_eq!(p.throughput_batched(4, 1), p.throughput(4));
        assert_eq!(p.latency_batched(4, 1), p.latency(4));
        let cap = 1.0 / (1.0 - p.batch_fixed_frac);
        for b in 1..16 {
            assert!(p.service_time_batch(b + 1) > p.service_time_batch(b));
            assert!(p.batch_gain(b + 1) > p.batch_gain(b));
            assert!(p.batch_gain(b + 1) < cap);
        }
        assert_eq!(p.latency_batched(0, 4), f64::INFINITY);
    }

    #[test]
    fn batch_frac_roundtrips_and_defaults() {
        let dir = crate::util::testutil::TempDir::new();
        let path = dir.path().join("profiles.json");
        let mut set = ProfileSet::paper_like();
        set.profiles[0].batch_fixed_frac = 0.25;
        set.save(&path).unwrap();
        let back = ProfileSet::load(&path).unwrap();
        assert_eq!(back.profiles[0].batch_fixed_frac, 0.25);
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = crate::util::testutil::TempDir::new();
        let path = dir.path().join("profiles.json");
        let set = ProfileSet::paper_like();
        set.save(&path).unwrap();
        let back = ProfileSet::load(&path).unwrap();
        assert_eq!(back.profiles.len(), set.profiles.len());
        assert_eq!(back.get("resnet50").unwrap().accuracy, 76.13);
    }
}
