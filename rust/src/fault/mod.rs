//! Deterministic fault-injection plane.
//!
//! [`FaultPlane`] owns one SplitMix64-strided RNG stream per service —
//! the same striding discipline the engine uses for arrivals and
//! service-time noise (`fleet::sim::service_seed`), on its own offset —
//! so the fault draws never touch any other stream: a faults-off run is
//! bit-identical to a fault-free build, and any fault run replays
//! exactly from its seed at every `solver_threads` count (every draw
//! happens at a serial boundary of the tick protocol, in service-index
//! order, so thread count cannot reorder them).
//!
//! Four fault kinds, all configured via the `fault` config section or
//! the `fleet --faults SPEC` grammar ([`FaultConfig::apply_spec`]):
//!
//! - **Pod crashes** — a Ready pod dies at a cluster boundary; its
//!   in-flight requests fail (bounded retries with deterministic backoff
//!   when reactions are on) and the cluster respawns it as Pending with
//!   the variant's loading cost, the VPA-restart dynamic the paper
//!   measures against.
//! - **Slow starts** — crash respawns take `readiness ×
//!   slow_start_factor` to become Ready.
//! - **Stragglers** — a pod serves every batch `straggler_mult×` slower
//!   for a window; with reactions on, queued work hedges away from it.
//! - **Solver stalls** — a service's curve solve misses the tick
//!   deadline; with reactions on the adapter falls back to the last-good
//!   decision ([`SolveOutcome::Fallback`]) instead of blocking the tick.

use crate::config::FaultConfig;
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};

/// How an adapter tick obtained its decision for one service.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SolveOutcome {
    /// The curve solve ran and produced a fresh decision.
    Fresh,
    /// The solve stalled past the tick deadline; the last-good decision
    /// was reused.
    Fallback,
}

/// Faults drawn for one service at one cluster boundary.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PodFaults {
    /// Ready pods that crash at this boundary.
    pub crashed: Vec<u64>,
    /// Ready pods that begin a straggle episode at this boundary.
    pub straggling: Vec<u64>,
}

/// The seeded fault source: one RNG stream per service, advanced only at
/// serial boundaries and only when the corresponding rate is non-zero.
#[derive(Debug)]
pub struct FaultPlane {
    cfg: FaultConfig,
    streams: Vec<Rng>,
}

impl FaultPlane {
    /// Build the plane from per-service stream seeds (the engine derives
    /// them as `service_seed(base, i) + FAULT_STREAM_OFFSET` so the
    /// stride constant lives in one place).
    pub fn new(cfg: FaultConfig, seeds: Vec<u64>) -> Self {
        let streams = seeds.into_iter().map(Rng::seed_from_u64).collect();
        Self { cfg, streams }
    }

    pub fn cfg(&self) -> &FaultConfig {
        &self.cfg
    }

    /// Whether the failure-aware reactions are armed.
    pub fn reactions(&self) -> bool {
        self.cfg.enabled && self.cfg.reactions
    }

    /// Whether any pod-level fault can ever fire (crashes or stragglers).
    pub fn injecting(&self) -> bool {
        self.cfg.enabled && (self.cfg.crash_rate > 0.0 || self.cfg.straggler_rate > 0.0)
    }

    /// Draw this boundary's pod faults for service `i`.  `ready_pods`
    /// must be sorted (the caller passes ascending pod ids) so the draw
    /// sequence is a pure function of serial simulation state.
    pub fn draw_pod_faults(&mut self, i: usize, now: f64, ready_pods: &[u64]) -> PodFaults {
        let mut out = PodFaults::default();
        if !self.injecting() {
            return out;
        }
        debug_assert!(ready_pods.windows(2).all(|w| w[0] < w[1]));
        let crash_armed = self.cfg.crash_rate > 0.0
            && now >= self.cfg.crash_start_s
            && now < self.cfg.crash_end_s;
        let rng = &mut self.streams[i];
        for &pod in ready_pods {
            if crash_armed && rng.f64() < self.cfg.crash_rate {
                out.crashed.push(pod);
                continue; // a dead pod cannot also straggle
            }
            if self.cfg.straggler_rate > 0.0 && rng.f64() < self.cfg.straggler_rate {
                out.straggling.push(pod);
            }
        }
        out
    }

    /// Draw whether service `i`'s solve stalls this adapter tick.
    pub fn roll_stall(&mut self, i: usize) -> bool {
        if !self.cfg.enabled || self.cfg.stall_rate <= 0.0 {
            return false;
        }
        self.streams[i].f64() < self.cfg.stall_rate
    }
}

fn parse_bool(kind: &str, s: &str) -> Result<bool> {
    match s {
        "on" | "true" | "1" => Ok(true),
        "off" | "false" | "0" => Ok(false),
        _ => bail!("fault spec `{kind}` takes on|off, got `{s}`"),
    }
}

impl FaultConfig {
    /// Apply a `--faults` CLI spec on top of the config: comma-separated
    /// clauses, each `kind[:arg[:arg...]]`.  Any clause arms the plane
    /// (`enabled = true`); validation still runs afterwards, so out-of-
    /// range values are rejected with the config error messages.
    ///
    /// Grammar:
    /// `crash:RATE[:START_S[:END_S]]` · `slowstart:FACTOR` ·
    /// `straggler:RATE[:WINDOW_S[:MULT]]` · `stall:RATE` ·
    /// `reactions:on|off` · `retries:N` · `backoff:S` · `eject:N` ·
    /// `probe:S` · `hedge:on|off`
    pub fn apply_spec(&mut self, spec: &str) -> Result<()> {
        for clause in spec.split(',').filter(|c| !c.is_empty()) {
            let parts: Vec<&str> = clause.split(':').collect();
            let kind = parts[0];
            let args = &parts[1..];
            let f = |j: usize| -> Result<f64> {
                args[j]
                    .parse::<f64>()
                    .with_context(|| format!("fault spec `{kind}`: bad number `{}`", args[j]))
            };
            let need = |lo: usize, hi: usize| -> Result<()> {
                if args.len() < lo || args.len() > hi {
                    bail!(
                        "fault spec `{kind}` takes {lo}..={hi} args, got {} (in `{clause}`)",
                        args.len()
                    );
                }
                Ok(())
            };
            match kind {
                "crash" => {
                    need(1, 3)?;
                    self.crash_rate = f(0)?;
                    if args.len() >= 2 {
                        self.crash_start_s = f(1)?;
                    }
                    if args.len() >= 3 {
                        self.crash_end_s = f(2)?;
                    }
                }
                "slowstart" => {
                    need(1, 1)?;
                    self.slow_start_factor = f(0)?;
                }
                "straggler" => {
                    need(1, 3)?;
                    self.straggler_rate = f(0)?;
                    if args.len() >= 2 {
                        self.straggler_window_s = f(1)?;
                    }
                    if args.len() >= 3 {
                        self.straggler_mult = f(2)?;
                    }
                }
                "stall" => {
                    need(1, 1)?;
                    self.stall_rate = f(0)?;
                }
                "reactions" => {
                    need(1, 1)?;
                    self.reactions = parse_bool(kind, args[0])?;
                }
                "retries" => {
                    need(1, 1)?;
                    self.max_retries = args[0]
                        .parse()
                        .with_context(|| format!("fault spec `retries`: bad count `{}`", args[0]))?;
                }
                "backoff" => {
                    need(1, 1)?;
                    self.retry_backoff_s = f(0)?;
                }
                "eject" => {
                    need(1, 1)?;
                    self.eject_after = args[0]
                        .parse()
                        .with_context(|| format!("fault spec `eject`: bad count `{}`", args[0]))?;
                }
                "probe" => {
                    need(1, 1)?;
                    self.probe_after_s = f(0)?;
                }
                "hedge" => {
                    need(1, 1)?;
                    self.hedge = parse_bool(kind, args[0])?;
                }
                other => bail!(
                    "unknown fault kind `{other}` (valid: crash, slowstart, straggler, \
                     stall, reactions, retries, backoff, eject, probe, hedge)"
                ),
            }
            self.enabled = true;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_grammar_parses_every_kind() {
        let mut c = FaultConfig::default();
        c.apply_spec(
            "crash:0.004:60:180,slowstart:2,straggler:0.001:45:4,stall:0.1,\
             reactions:on,retries:2,backoff:0.1,eject:5,probe:3,hedge:off",
        )
        .unwrap();
        assert!(c.enabled);
        assert_eq!(c.crash_rate, 0.004);
        assert_eq!(c.crash_start_s, 60.0);
        assert_eq!(c.crash_end_s, 180.0);
        assert_eq!(c.slow_start_factor, 2.0);
        assert_eq!(c.straggler_rate, 0.001);
        assert_eq!(c.straggler_window_s, 45.0);
        assert_eq!(c.straggler_mult, 4.0);
        assert_eq!(c.stall_rate, 0.1);
        assert!(c.reactions);
        assert_eq!(c.max_retries, 2);
        assert_eq!(c.retry_backoff_s, 0.1);
        assert_eq!(c.eject_after, 5);
        assert_eq!(c.probe_after_s, 3.0);
        assert!(!c.hedge);
    }

    #[test]
    fn spec_rejects_unknown_kinds_and_bad_arity() {
        let mut c = FaultConfig::default();
        let err = c.apply_spec("crush:0.1").unwrap_err().to_string();
        assert!(err.contains("unknown fault kind"), "{err}");
        assert!(err.contains("crash"), "must list the valid kinds: {err}");
        assert!(c.apply_spec("crash").is_err(), "crash needs a rate");
        assert!(c.apply_spec("stall:0.1:2").is_err(), "stall takes one arg");
        assert!(c.apply_spec("reactions:maybe").is_err());
        assert!(c.apply_spec("crash:lots").is_err(), "rates are numbers");
        // an unparsed spec leaves defaults untouched except what it set
        let mut c = FaultConfig::default();
        c.apply_spec("").unwrap();
        assert!(!c.enabled, "empty spec must not arm the plane");
    }

    #[test]
    fn draws_are_deterministic_and_off_plane_draws_nothing() {
        let cfg = {
            let mut c = FaultConfig::default();
            c.apply_spec("crash:0.5,straggler:0.5").unwrap();
            c
        };
        let pods: Vec<u64> = (1..=64).collect();
        let mut a = FaultPlane::new(cfg, vec![7, 8]);
        let mut b = FaultPlane::new(cfg, vec![7, 8]);
        for boundary in 0..32 {
            let now = boundary as f64;
            for svc in 0..2 {
                assert_eq!(
                    a.draw_pod_faults(svc, now, &pods),
                    b.draw_pod_faults(svc, now, &pods),
                    "same seed must replay the same faults (t={now}, svc {svc})"
                );
            }
        }
        // at rate 0.5 over 64 pods × 32 boundaries something must fire
        let mut c = FaultPlane::new(cfg, vec![7]);
        let drawn: usize = (0..32)
            .map(|t| {
                let f = c.draw_pod_faults(0, t as f64, &pods);
                f.crashed.len() + f.straggling.len()
            })
            .sum();
        assert!(drawn > 0, "an armed plane must actually inject");
        // a disabled plane never draws, whatever the rates say
        let mut off = cfg;
        off.enabled = false;
        let mut p = FaultPlane::new(off, vec![7]);
        assert_eq!(p.draw_pod_faults(0, 0.0, &pods), PodFaults::default());
        assert!(!p.roll_stall(0));
        assert!(!p.injecting());
    }

    #[test]
    fn crash_window_gates_crashes_but_not_stragglers() {
        let mut cfg = FaultConfig::default();
        cfg.apply_spec("crash:1.0:10:20,straggler:1.0").unwrap();
        let mut p = FaultPlane::new(cfg, vec![3]);
        let before = p.draw_pod_faults(0, 5.0, &[1, 2]);
        assert!(before.crashed.is_empty(), "before the window: no crashes");
        assert_eq!(before.straggling, vec![1, 2], "rate-1 stragglers always fire");
        let inside = p.draw_pod_faults(0, 10.0, &[1, 2]);
        assert_eq!(inside.crashed, vec![1, 2], "rate-1 crashes fire in-window");
        let after = p.draw_pod_faults(0, 20.0, &[1, 2]);
        assert!(after.crashed.is_empty(), "the window end is exclusive");
    }

    #[test]
    fn stall_rolls_only_when_armed() {
        let mut cfg = FaultConfig::default();
        cfg.apply_spec("stall:1.0").unwrap();
        let mut p = FaultPlane::new(cfg, vec![11]);
        assert!(p.roll_stall(0), "rate-1 stall must fire");
        cfg.stall_rate = 0.0;
        let mut p = FaultPlane::new(cfg, vec![11]);
        assert!(!p.roll_stall(0));
    }
}
