//! One service's slice of the fleet data plane: the [`ServiceShard`].
//!
//! The fleet engine ([`super::sim::FleetSimEngine`]) used to be a single
//! serial loop over one global event heap.  This module carves out
//! everything that is *per-service* — trace stream, RNG, admission gate,
//! dispatcher, pods view, metrics, rate accounting, and the discrete-event
//! schedule itself (a [`TimerWheel`] calendar queue with heap-exact pop
//! order) — so the engine shrinks to an orchestrator running the
//! five-stage tick protocol (observe → solve → arbitrate → apply →
//! advance) over a `Vec<ServiceShard>`.
//!
//! **Why sharding preserves bit-identity.**  Between two consecutive
//! boundaries (cluster ticks and adapter ticks), the global engine's event
//! handlers for different services touch disjoint state: a service's
//! arrivals, completions, and batch timeouts read and write only its own
//! pods, its own RNG stream, its own metrics, and its own per-second rate
//! counters; the shared [`Cluster`] is only *read* (pod readiness for
//! routing) and only *mutated* at boundaries.  The global heap's
//! `(t, seq)` order therefore only matters *within* a service — and a
//! per-shard heap with a per-shard `seq` counter reproduces exactly that
//! within-service order, because each shard pushes its events in the same
//! relative order the global engine did.  Cross-service interleaving at
//! equal `t` is unobservable.  The one place global `seq` order is
//! visible is at a boundary itself, where the global engine's init-time
//! push order (arrivals < cluster ticks < adapter ticks < runtime events)
//! breaks `t` ties; [`ServiceShard::advance`] encodes that rule directly:
//! an *arrival* at exactly the boundary time runs before the boundary,
//! while runtime events (completions, batch timeouts) at that time run
//! after it.
//!
//! **Arena request state.**  The global engine grew a `Vec<RequestSim>`
//! and a `Vec<Vec<usize>>` batch table for the whole run — every arrival
//! and every formed batch was a fresh heap cell that lived forever.  The
//! shard instead owns a [`RequestArena`] (slab + free list: a request's
//! slot is recycled the moment its terminal record is written) and a
//! [`BatchArena`] (batch member vectors circulate between the pods'
//! forming buffers and the batch table by `mem::swap`, so steady state
//! allocates nothing).  Request/batch ids are internal indices the
//! simulation never compares across lifetimes, so id reuse is
//! behavior-neutral; `benches/micro_hotpaths.rs` measures the
//! alloc-reuse win (`arena.alloc_reuse`).

use super::curve_cache::CurveCache;
use crate::cluster::Cluster;
use crate::config::FaultConfig;
use crate::dispatcher::{AdmissionGate, HealthPolicy, RequestPath, RouteOutcome, Tier};
use crate::metrics::{MetricsCollector, RequestRecord};
use crate::monitoring::SloBurnMeter;
use crate::profiler::ProfileSet;
use crate::serving::sim::SimConfig;
use crate::serving::Decision;
use crate::telemetry::ShardTelemetry;
use crate::util::pool::WorkerPool;
use crate::util::rng::Rng;
use crate::util::sched::TimerWheel;
use crate::workload::ClassMixer;
use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};

use super::sim::{service_seed, FleetService};

/// Adaptation intervals the SLO-burn meter's rolling window covers.
pub(super) const BURN_WINDOW_INTERVALS: usize = 4;

/// Shortest window a rate sample may be normalized over.  Caps the
/// extrapolation factor at 4x: an adapter tick at t = 30.001 must not turn
/// one arrival in a 1 ms sliver into a 1000 rps sample (a max-picking
/// forecaster would seize on it).  Windows shorter than this merge into
/// the neighbouring sample instead.
const MIN_RATE_SAMPLE_SPAN_S: f64 = 0.25;

#[derive(Debug, Clone, Copy, PartialEq)]
enum EventKind {
    Arrival,
    /// One batched service draw finishing; `batch` indexes the batch arena.
    Completion { pod_id: u64, batch: u32 },
    /// Formation wait expired for the batch a pod opened at `forming_seq`.
    BatchTimeout { pod_id: u64, forming_seq: u64 },
    /// A crash-stranded request's scheduled retry attempt firing.
    Retry { req: u32 },
}

/// Stamp and schedule one event.  The wheel pops in ascending `(t, seq)` —
/// exactly the order the old `BinaryHeap<Reverse<Event>>` produced (see
/// [`TimerWheel`]'s exactness argument), so the monotone per-shard `seq`
/// keeps resolving equal-time ties the way the global engine's push order
/// did.
fn push_event(events: &mut TimerWheel<EventKind>, seq: &mut u64, t: f64, kind: EventKind) {
    *seq += 1;
    events.push(t, *seq, kind);
}

/// One simulated pod (M/G/n station) owned by the shard's service.
struct PodSim {
    /// Raw (un-namespaced) variant name within the owning service.
    variant: String,
    cores: usize,
    busy: usize,
    /// Formed batches (ids into the batch arena) awaiting a free core.
    queue: VecDeque<u32>,
    /// Requests accumulating toward the next batch (arena ids).
    forming: Vec<u32>,
    /// Bumped on every dispatch; stale `BatchTimeout` events don't match.
    forming_seq: u64,
    /// Current batch-size target for this pod's variant (1 = no batching).
    max_batch: usize,
    /// Requests waiting at this pod (forming + members of queued batches);
    /// kept as a counter so routing comparisons stay O(1).
    waiting: usize,
    /// End of the current straggle episode (0.0 = never straggled).
    slow_until: f64,
    /// Service-time multiplier while the straggle episode is open.
    slow_mult: f64,
    /// Batch ids currently holding a core (needed at crash time to find
    /// the in-flight work that dies with the pod).
    in_service: Vec<u32>,
}

impl PodSim {
    /// Waiting + in-service requests normalized by cores — the
    /// least-loaded routing metric.
    fn load(&self) -> f64 {
        (self.busy + self.waiting) as f64 / self.cores.max(1) as f64
    }
}

/// In-flight request state (the arena element).  The owning service is
/// implicit — each shard's arena holds only its own requests.
#[derive(Debug, Clone, Copy)]
pub struct RequestSim {
    pub arrival: f64,
    pub accuracy: f64,
    /// Priority tier the request arrived with (per-tier accounting).
    pub tier: Tier,
    /// Retry attempts already burned after pod-failure strandings.
    pub retries: u8,
}

/// Slab of request state with a free list: a slot is recycled the moment
/// its request's terminal record (completion, timeout, or drop) is
/// written, so steady-state arrivals allocate nothing.  Ids are plain
/// indices the simulation never compares across request lifetimes, which
/// is what makes reuse behavior-neutral.
#[derive(Debug, Default)]
pub struct RequestArena {
    slots: Vec<RequestSim>,
    free: Vec<u32>,
    allocs: u64,
    reuses: u64,
}

impl RequestArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pre-size the slab (e.g. for a known arrival count's high-water mark).
    pub fn reserve(&mut self, additional: usize) {
        self.slots.reserve(additional);
    }

    #[inline]
    pub fn alloc(&mut self, r: RequestSim) -> u32 {
        self.allocs += 1;
        if let Some(id) = self.free.pop() {
            self.reuses += 1;
            self.slots[id as usize] = r;
            id
        } else {
            let id = self.slots.len() as u32;
            self.slots.push(r);
            id
        }
    }

    #[inline]
    pub fn free(&mut self, id: u32) {
        debug_assert!((id as usize) < self.slots.len(), "freeing unallocated id");
        self.free.push(id);
    }

    #[inline]
    pub fn get(&self, id: u32) -> &RequestSim {
        &self.slots[id as usize]
    }

    #[inline]
    pub fn get_mut(&mut self, id: u32) -> &mut RequestSim {
        &mut self.slots[id as usize]
    }

    /// (total allocations, allocations served from the free list).
    pub fn stats(&self) -> (u64, u64) {
        (self.allocs, self.reuses)
    }

    /// Slots currently holding a live request.
    pub fn live(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    /// High-water mark: slots ever materialized.
    pub fn high_water(&self) -> usize {
        self.slots.len()
    }
}

/// Batch member table with a free list.  Member vectors circulate between
/// pods' forming buffers and the table by `mem::swap`, so a formed batch
/// in steady state reuses a previously-freed vector's capacity instead of
/// allocating.
#[derive(Debug, Default)]
pub struct BatchArena {
    slots: Vec<Vec<u32>>,
    free: Vec<u32>,
}

impl BatchArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pre-size the slot table (the member vectors themselves still grow
    /// on first use and then circulate).
    pub fn reserve(&mut self, additional: usize) {
        self.slots.reserve(additional);
    }

    /// Move `items`'s contents into a (possibly recycled) slot; `items`
    /// gets the slot's old empty-but-allocated vector back in exchange.
    #[inline]
    pub fn alloc_swap(&mut self, items: &mut Vec<u32>) -> u32 {
        if let Some(id) = self.free.pop() {
            debug_assert!(self.slots[id as usize].is_empty());
            std::mem::swap(&mut self.slots[id as usize], items);
            id
        } else {
            let id = self.slots.len() as u32;
            self.slots.push(std::mem::take(items));
            id
        }
    }

    #[inline]
    pub fn free(&mut self, id: u32) {
        self.slots[id as usize].clear();
        self.free.push(id);
    }

    #[inline]
    pub fn get(&self, id: u32) -> &[u32] {
        &self.slots[id as usize]
    }

    #[inline]
    pub fn get_mut(&mut self, id: u32) -> &mut Vec<u32> {
        &mut self.slots[id as usize]
    }
}

/// Everything one service of a fleet run owns at runtime: its event heap,
/// pods view, RNG stream, admission path, metrics, rate accounting, and
/// the arbitration scratch the five-stage tick protocol writes into
/// (`pending_*`).  Carved out of the old monolithic engine state; the
/// orchestrator holds a `Vec<ServiceShard>` indexed like its `services`.
pub struct ServiceShard {
    /// `"<name>/"`, or empty for the unprefixed single-service path.
    pub(crate) prefix: String,
    pub(crate) duration: f64,
    /// The admission-controlled request path: gate → tiers → smooth-WRR.
    pub(crate) path: RequestPath,
    /// Deterministic per-request tier assignment (no RNG).
    tier_mixer: ClassMixer,
    /// Rolling SLO-burn meter feeding the arbiter.
    pub(crate) burn: SloBurnMeter,
    /// Collector counts already folded into the burn meter.
    seen_violations: u64,
    seen_admitted: u64,
    pub(crate) metrics: MetricsCollector,
    rng: Rng,
    pub(crate) rate_history: Vec<f64>,
    arrivals_this_second: u64,
    last_whole_second: u64,
    /// Start of the window `arrivals_this_second` covers; advances with
    /// the per-second roll and with partial flushes at adapter ticks so
    /// every sample is normalized by the span it actually observed.
    counter_since: f64,
    /// Raw variant -> batch-size target in force (new pods inherit it).
    pub(crate) current_batches: BTreeMap<String, usize>,
    pub(crate) decisions: Vec<(f64, Decision)>,
    /// λ̂ carried from the solve phase into the decide phase.
    pub(crate) pending_lambda: f64,
    /// Value curve carried from the solve phase into the arbitrate phase.
    pub(crate) pending_curve: Option<Vec<f64>>,
    /// Decision carried from the decide phase into the apply phase.
    pub(crate) pending_decision: Option<Decision>,
    /// Cross-tick value-curve memory (arbitrated services only): exact
    /// hits skip the solve outright, near-hits warm-start it.
    pub(crate) curve_cache: CurveCache,
    /// Request-path and stage-timing counters — a pure observer of the
    /// decision path (no-ops when telemetry is disabled; see
    /// [`crate::telemetry`] for the bit-identity argument).
    pub(crate) telem: ShardTelemetry,
    /// This service's slice of the discrete-event schedule: a calendar
    /// queue with heap-exact `(t, seq)` pop order, pre-sized from the
    /// trace's peak rate.
    events: TimerWheel<EventKind>,
    seq: u64,
    /// This service's pods (the cluster's authoritative set, projected).
    pods: HashMap<u64, PodSim>,
    arena: RequestArena,
    batches: BatchArena,
    queue_timeout_s: f64,
    batch_max_wait_s: f64,
    /// This service's latency SLO — the retry budget's denominator.
    slo_s: f64,
    /// Fault-plane knobs (a copy; `reactions` below gates every hook).
    fault: FaultConfig,
    /// `fault.enabled && fault.reactions` — the single gate every
    /// failure-aware hook checks, so the defaults-off path never pays.
    reactions: bool,
    /// Batch ids whose pod crashed mid-service: the stale `Completion`
    /// event frees the member vector and clears the tombstone (freeing at
    /// crash time would let the arena alias a recycled id against it).
    dead_batches: HashSet<u32>,
    /// Last-good value curve for the solver-stall fallback (only
    /// maintained when the stall fault is armed).
    pub(crate) last_curve: Option<Vec<f64>>,
    /// Last-good decision for the solver-stall fallback (only maintained
    /// when the stall fault is armed).
    pub(crate) last_decision: Option<Decision>,
    /// This adapter tick's solve was rolled as stalled — set serially at
    /// the boundary by the engine, read by the parallel stages.
    pub(crate) stalled_tick: bool,
}

impl ServiceShard {
    pub(super) fn new(i: usize, s: &FleetService, cfg: &SimConfig) -> Self {
        let top_acc = s
            .profiles
            .profiles
            .iter()
            .map(|p| p.accuracy)
            .fold(0.0, f64::max);
        // Cutoff ladder of this service's gate: the range of tiers its
        // trace can actually emit — the class mix when one is set, the
        // service tier otherwise.  The floor matters: a tier-1-only
        // service must never cut off tier 1 (its whole stream).
        let mix: Vec<Tier> = s
            .trace
            .class_mix
            .iter()
            .filter(|&&(_, w)| w > 0.0)
            .map(|&(t, _)| t)
            .collect();
        let (min_tier, max_tier) = if mix.is_empty() {
            (s.tier, s.tier)
        } else {
            (
                mix.iter().copied().min().expect("non-empty"),
                mix.iter().copied().max().expect("non-empty"),
            )
        };
        // Pre-size per-shard state from the trace's peak rate so steady
        // state never reallocates mid-run: the wheel's coarse window covers
        // the whole horizon (overflow stays empty), and the request slab
        // covers the worst-case live set — arrivals stay resident at most
        // the queue timeout plus a couple of seconds of service.
        let duration = s.trace.duration_s() as f64;
        let peak_rate = s.trace.rates.iter().copied().fold(0.0, f64::max);
        let mut arena = RequestArena::new();
        let est_live = (peak_rate * (cfg.queue_timeout_s + 2.0)).ceil() as usize;
        arena.reserve(est_live.clamp(64, 1 << 20));
        let mut batches = BatchArena::new();
        batches.reserve(64);
        let shard = Self {
            prefix: if s.name.is_empty() {
                String::new()
            } else {
                format!("{}/", s.name)
            },
            duration,
            path: RequestPath::new(AdmissionGate::new(&cfg.admission, min_tier, max_tier)),
            tier_mixer: ClassMixer::new(&s.trace.class_mix, s.tier),
            burn: SloBurnMeter::new(s.error_budget, BURN_WINDOW_INTERVALS),
            seen_violations: 0,
            seen_admitted: 0,
            metrics: MetricsCollector::new(cfg.bucket_s, s.slo_s, top_acc),
            rng: Rng::seed_from_u64(service_seed(cfg.seed, i)),
            rate_history: Vec::new(),
            arrivals_this_second: 0,
            last_whole_second: 0,
            counter_since: 0.0,
            current_batches: BTreeMap::new(),
            decisions: Vec::new(),
            pending_lambda: 0.0,
            pending_curve: None,
            pending_decision: None,
            curve_cache: CurveCache::new(),
            telem: ShardTelemetry::new(cfg.telemetry.enabled),
            events: TimerWheel::sized_for(peak_rate, duration),
            seq: 0,
            pods: HashMap::new(),
            arena,
            batches,
            queue_timeout_s: cfg.queue_timeout_s,
            batch_max_wait_s: cfg.batch_max_wait_s,
            slo_s: s.slo_s,
            fault: cfg.fault,
            reactions: cfg.fault.enabled && cfg.fault.reactions,
            dead_batches: HashSet::new(),
            last_curve: None,
            last_decision: None,
            stalled_tick: false,
        };
        if shard.reactions {
            shard.path.dispatcher().set_health(Some(HealthPolicy {
                eject_after: cfg.fault.eject_after,
                probe_after_s: cfg.fault.probe_after_s,
            }));
        }
        shard
    }

    /// Whether the solver-stall fallback is armed for this shard: the
    /// engine only pays for last-good snapshots when a stall can draw.
    pub(crate) fn stall_armed(&self) -> bool {
        self.fault.enabled && self.fault.stall_rate > 0.0 && self.fault.reactions
    }

    /// Load this service's arrival stream into the shard schedule (the
    /// same push order the global engine used, so `(t, seq)` ties resolve
    /// identically within the service).  The arena was already pre-sized
    /// from the peak rate in [`ServiceShard::new`].
    pub(super) fn seed_arrivals(&mut self, times: &[f64]) {
        for &t in times {
            push_event(&mut self.events, &mut self.seq, t, EventKind::Arrival);
        }
    }

    /// Project a cluster pod into this shard (warm start and `PodReady`).
    pub(super) fn insert_pod(&mut self, pod_id: u64, namespaced_variant: &str, cores: usize) {
        let raw = namespaced_variant[self.prefix.len()..].to_string();
        let max_batch = self.current_batches.get(&raw).copied().unwrap_or(1);
        self.pods.insert(
            pod_id,
            PodSim {
                variant: raw,
                cores,
                busy: 0,
                queue: VecDeque::with_capacity(4),
                forming: Vec::with_capacity(max_batch.max(1)),
                forming_seq: 0,
                max_batch,
                waiting: 0,
                slow_until: 0.0,
                slow_mult: 1.0,
                in_service: Vec::with_capacity(cores.max(1)),
            },
        );
    }

    /// Per-second arrival-counter roll, exactly the global engine's loop
    /// (the division is by exactly 1.0 — a bit-exact no-op — unless an
    /// adapter tick partially flushed this second; a sliver left by a
    /// flush just before the boundary merges into the next second).  The
    /// roll is a pure catch-up on shard-local state, so rolling lazily at
    /// this shard's own events plus at every boundary produces the same
    /// sample stream as the global engine's roll-on-every-event.
    pub(super) fn roll_to(&mut self, sec: u64) {
        while self.last_whole_second < sec {
            let boundary = (self.last_whole_second + 1) as f64;
            let span = boundary - self.counter_since;
            if span >= MIN_RATE_SAMPLE_SPAN_S {
                self.rate_history
                    .push(self.arrivals_this_second as f64 / span);
                self.arrivals_this_second = 0;
                self.counter_since = boundary;
            }
            self.last_whole_second += 1;
        }
    }

    /// Observe stage at an adapter boundary: flush the in-progress partial
    /// second so the just-observed load is visible to the policy
    /// (normalized by the span it actually covers; slivers below the
    /// minimum span stay in the counter), then fold the interval's
    /// (violations, admitted) delta into the SLO-burn meter.
    pub(super) fn flush_rate_window(&mut self, now: f64) {
        let span = now - self.counter_since;
        if span >= MIN_RATE_SAMPLE_SPAN_S {
            self.rate_history
                .push(self.arrivals_this_second as f64 / span);
            self.arrivals_this_second = 0;
            self.counter_since = now;
        }
        let (v, a) = self.metrics.live_counts();
        self.burn.observe(v - self.seen_violations, a - self.seen_admitted);
        self.seen_violations = v;
        self.seen_admitted = a;
    }

    /// Advance stage: process this shard's events up to the next
    /// arbitration boundary.  The admission rule encodes the global
    /// heap's `(t, seq)` tie order at a boundary: arrivals (seeded at
    /// init, before any tick event) run *before* the boundary they
    /// coincide with; runtime events (completions, batch timeouts, whose
    /// `seq` always exceeds every init-time push) run *after* it.  Pass
    /// `f64::INFINITY` to drain (completions may land past the trace end
    /// and every request must be accounted for — conservation).
    pub(super) fn advance(&mut self, cluster: &Cluster, profiles: &ProfileSet, until: f64) {
        while let Some((t, _, &kind)) = self.events.peek() {
            let due = t < until || (t == until && kind == EventKind::Arrival);
            if !due {
                break;
            }
            self.events.pop();
            let now = t;
            self.roll_to(now as u64);
            match kind {
                EventKind::Arrival => self.handle_arrival(cluster, profiles, now),
                EventKind::Completion { pod_id, batch } => {
                    self.handle_completion(profiles, now, pod_id, batch)
                }
                EventKind::BatchTimeout { pod_id, forming_seq } => {
                    self.handle_batch_timeout(profiles, now, pod_id, forming_seq)
                }
                EventKind::Retry { req } => self.handle_retry(cluster, profiles, now, req),
            }
        }
    }

    fn handle_arrival(&mut self, cluster: &Cluster, profiles: &ProfileSet, now: f64) {
        self.arrivals_this_second += 1;
        let tier = self.tier_mixer.next();
        // The unified request path: admission gate (sheds excess offered
        // load at the door — recorded, never enqueued; a disabled gate
        // admits unconditionally, the pre-admission behaviour) →
        // smooth-WRR variant routing.  The least-loaded ready pod of the
        // routed variant then takes the request.
        let variant = match self.path.handle(now, tier) {
            RouteOutcome::Shed(t) => {
                self.telem.record_shed(t);
                self.metrics.record_request(RequestRecord::shed(now, t));
                return;
            }
            RouteOutcome::Routed(v) => {
                self.telem.record_admit(tier);
                Some(v)
            }
            // unconfigured / zero-capacity: fall through to the any-pod
            // fallback, then drop
            RouteOutcome::Denied(r) => {
                self.telem.record_admit(tier);
                self.telem.record_noroute(r);
                None
            }
        };
        let pod_id = match variant.as_deref() {
            Some(v) => match self.pick_pod(cluster, &namespaced(&self.prefix, v)) {
                Some(pid) => Some(pid),
                None => {
                    // the routed backend had no ready pod — a routing
                    // failure the health tracker holds against it
                    if self.reactions && self.path.dispatcher().record_failure(v, now) {
                        self.telem.record_ejection();
                    }
                    self.any_pod(cluster)
                }
            },
            None => None,
        };
        let Some(pid) = pod_id else {
            let rid = self.arena.alloc(RequestSim {
                arrival: now,
                accuracy: 0.0,
                tier,
                retries: 0,
            });
            self.arena.free(rid);
            self.metrics
                .record_request(RequestRecord::new(now, f64::INFINITY, 0.0, tier));
            return;
        };
        let accuracy = acc_of(profiles, &self.pods[&pid].variant);
        let rid = self.arena.alloc(RequestSim {
            arrival: now,
            accuracy,
            tier,
            retries: 0,
        });
        self.enqueue_request(profiles, pid, rid, now);
    }

    fn handle_completion(&mut self, profiles: &ProfileSet, now: f64, pod_id: u64, batch: u32) {
        if self.dead_batches.remove(&batch) {
            // the pod crashed mid-service: the members were failed or
            // rescheduled at the crash boundary; the stale completion only
            // returns the member vector to the arena
            self.batches.free(batch);
            return;
        }
        // Terminal records for every member, then recycle their slots and
        // the batch's member vector.
        let members = self.batches.get(batch).len();
        for idx in 0..members {
            let rid = self.batches.get(batch)[idx];
            let r = *self.arena.get(rid);
            self.metrics.record_request(RequestRecord::new(
                r.arrival,
                now - r.arrival,
                r.accuracy,
                r.tier,
            ));
            self.arena.free(rid);
        }
        self.batches.free(batch);
        if self.reactions {
            // a served batch is the health signal that readmits an
            // ejected backend (and clears its failure streak)
            if let Some(p) = self.pods.get(&pod_id) {
                let variant = p.variant.clone();
                self.path.dispatcher().record_success(&variant);
            }
        }
        let Some(pod) = self.pods.get_mut(&pod_id) else {
            return;
        };
        pod.busy = pod.busy.saturating_sub(1);
        if let Some(pos) = pod.in_service.iter().position(|&b| b == batch) {
            pod.in_service.swap_remove(pos);
        }
        // Start the next formed batch, dropping members that queued past
        // the client timeout (in-place compaction: no fresh member vec).
        while let Some(bid) = pod.queue.pop_front() {
            let count = self.batches.get(bid).len();
            pod.waiting = pod.waiting.saturating_sub(count);
            let mut kept = 0usize;
            for idx in 0..count {
                let rid = self.batches.get(bid)[idx];
                let r = *self.arena.get(rid);
                if now - r.arrival > self.queue_timeout_s {
                    self.metrics.record_request(RequestRecord::new(
                        r.arrival,
                        f64::INFINITY,
                        r.accuracy,
                        r.tier,
                    ));
                    self.arena.free(rid);
                } else {
                    self.batches.get_mut(bid)[kept] = rid;
                    kept += 1;
                }
            }
            self.batches.get_mut(bid).truncate(kept);
            if kept == 0 {
                self.batches.free(bid);
                continue;
            }
            pod.busy += 1;
            pod.in_service.push(bid);
            let mut stime = sample_service_batch(profiles, &pod.variant, kept, &mut self.rng);
            if now < pod.slow_until {
                stime *= pod.slow_mult;
            }
            push_event(
                &mut self.events,
                &mut self.seq,
                now + stime,
                EventKind::Completion { pod_id, batch: bid },
            );
            break;
        }
    }

    fn handle_batch_timeout(
        &mut self,
        profiles: &ProfileSet,
        now: f64,
        pod_id: u64,
        forming_seq: u64,
    ) {
        let Some(pod) = self.pods.get_mut(&pod_id) else {
            return;
        };
        if pod.forming_seq == forming_seq && !pod.forming.is_empty() {
            let mut items = std::mem::take(&mut pod.forming);
            pod.forming_seq += 1;
            dispatch_batch(
                profiles,
                pod,
                pod_id,
                &mut items,
                now,
                &mut self.batches,
                &mut self.events,
                &mut self.seq,
                &mut self.rng,
                &mut self.telem,
            );
            pod.forming = items;
        }
    }

    /// Add one routed request to a pod: it joins the forming batch, which
    /// dispatches when full (immediately at `max_batch = 1`); opening a
    /// fresh batch arms the formation timeout.
    fn enqueue_request(&mut self, profiles: &ProfileSet, pod_id: u64, rid: u32, now: f64) {
        let pod = self.pods.get_mut(&pod_id).expect("routed to unknown pod");
        pod.forming.push(rid);
        pod.waiting += 1;
        if pod.forming.len() >= pod.max_batch {
            let mut items = std::mem::take(&mut pod.forming);
            pod.forming_seq += 1;
            dispatch_batch(
                profiles,
                pod,
                pod_id,
                &mut items,
                now,
                &mut self.batches,
                &mut self.events,
                &mut self.seq,
                &mut self.rng,
                &mut self.telem,
            );
            pod.forming = items;
        } else if pod.forming.len() == 1 {
            push_event(
                &mut self.events,
                &mut self.seq,
                now + self.batch_max_wait_s,
                EventKind::BatchTimeout {
                    pod_id,
                    forming_seq: pod.forming_seq,
                },
            );
        }
    }

    /// `PodRemoved` at a cluster boundary: re-route still-waiting requests
    /// (queued batches and the forming buffer) within this service.
    pub(super) fn handle_pod_removed(
        &mut self,
        cluster: &Cluster,
        profiles: &ProfileSet,
        pod_id: u64,
        now: f64,
    ) {
        let Some(mut dead) = self.pods.remove(&pod_id) else {
            return;
        };
        let mut orphans: Vec<u32> = Vec::new();
        for bid in dead.queue.drain(..) {
            orphans.extend_from_slice(self.batches.get(bid));
            self.batches.free(bid);
        }
        orphans.append(&mut dead.forming);
        for rid in orphans {
            // already-admitted requests are re-routed, never re-gated
            if let Some(target) = self
                .path
                .dispatcher()
                .route()
                .and_then(|v| self.pick_pod(cluster, &namespaced(&self.prefix, &v)))
                .or_else(|| self.any_pod(cluster))
            {
                let acc = acc_of(profiles, &self.pods[&target].variant);
                self.arena.get_mut(rid).accuracy = acc;
                self.enqueue_request(profiles, target, rid, now);
            } else {
                let r = *self.arena.get(rid);
                self.metrics.record_request(RequestRecord::new(
                    r.arrival,
                    f64::INFINITY,
                    r.accuracy,
                    r.tier,
                ));
                self.arena.free(rid);
            }
        }
    }

    /// A pod crash at a cluster boundary (injected by the fault plane):
    /// in-flight batches die with the pod — their members fail or, with
    /// reactions on, schedule bounded retries charged against the SLO
    /// budget — while waiting work re-routes to surviving pods (reactions
    /// on) or dies at the door (reactions off, the Part D baseline).
    pub(super) fn handle_pod_crashed(
        &mut self,
        cluster: &Cluster,
        profiles: &ProfileSet,
        pod_id: u64,
        now: f64,
    ) {
        let Some(mut dead) = self.pods.remove(&pod_id) else {
            return;
        };
        if self.reactions && self.path.dispatcher().record_failure(&dead.variant, now) {
            self.telem.record_ejection();
        }
        // In-service batches: tombstone the ids so the now-stale
        // Completion events return the member vectors without recording —
        // freeing here would let the arena alias a recycled batch id
        // against the stale event.
        let mut casualties: Vec<u32> = Vec::new();
        for &bid in &dead.in_service {
            casualties.extend_from_slice(self.batches.get(bid));
            self.dead_batches.insert(bid);
        }
        for rid in casualties {
            self.retry_or_fail(now, rid);
        }
        // Waiting work (queued batches + the forming buffer) never
        // started service, so it re-routes immediately when reactions are
        // on; with none ready it takes the retry path too.
        let mut stranded: Vec<u32> = Vec::new();
        for bid in dead.queue.drain(..) {
            stranded.extend_from_slice(self.batches.get(bid));
            self.batches.free(bid);
        }
        stranded.append(&mut dead.forming);
        for rid in stranded {
            if self.reactions {
                if !self.reroute(cluster, profiles, rid, now) {
                    self.retry_or_fail(now, rid);
                }
            } else {
                self.fail_request(rid);
            }
        }
    }

    /// A straggle episode opening on one of this service's pods: every
    /// batch it serves while the window is open takes `straggler_mult ×`
    /// the sampled service time.  With reactions + hedging on, queued
    /// batches and the forming buffer flee to other ready pods
    /// (in-service batches finish where they are).
    pub(super) fn handle_straggler(
        &mut self,
        cluster: &Cluster,
        profiles: &ProfileSet,
        pod_id: u64,
        now: f64,
    ) {
        if !self.pods.contains_key(&pod_id) {
            return;
        }
        let hedge = self.reactions
            && self.fault.hedge
            && self.any_pod_except(cluster, pod_id).is_some();
        let mult = self.fault.straggler_mult;
        let window = self.fault.straggler_window_s;
        let pod = self.pods.get_mut(&pod_id).expect("checked above");
        // overlapping episodes keep the worst multiplier
        pod.slow_mult = if now < pod.slow_until {
            pod.slow_mult.max(mult)
        } else {
            mult
        };
        pod.slow_until = now + window;
        if !hedge {
            return;
        }
        let mut movers: Vec<u32> = Vec::new();
        let mut hedged = 0u64;
        for bid in pod.queue.drain(..) {
            movers.extend_from_slice(self.batches.get(bid));
            self.batches.free(bid);
            hedged += 1;
        }
        if !pod.forming.is_empty() {
            hedged += 1;
        }
        movers.append(&mut pod.forming);
        pod.waiting = 0;
        for _ in 0..hedged {
            self.telem.record_hedge();
        }
        for rid in movers {
            let target = self.any_pod_except(cluster, pod_id).unwrap_or(pod_id);
            let acc = acc_of(profiles, &self.pods[&target].variant);
            self.arena.get_mut(rid).accuracy = acc;
            self.enqueue_request(profiles, target, rid, now);
        }
    }

    /// A scheduled retry firing: the stranded request re-enters routing;
    /// with still nowhere to land it burns another attempt (or the last
    /// of its budget).
    fn handle_retry(&mut self, cluster: &Cluster, profiles: &ProfileSet, now: f64, rid: u32) {
        if !self.reroute(cluster, profiles, rid, now) {
            self.retry_or_fail(now, rid);
        }
    }

    /// Land `rid` on any ready pod (dispatcher's health-aware choice
    /// first, least-loaded fallback).  False when none is ready.
    fn reroute(&mut self, cluster: &Cluster, profiles: &ProfileSet, rid: u32, now: f64) -> bool {
        let target = self
            .path
            .dispatcher()
            .try_route_at(now)
            .ok()
            .and_then(|v| self.pick_pod(cluster, &namespaced(&self.prefix, &v)))
            .or_else(|| self.any_pod(cluster));
        match target {
            Some(pid) => {
                self.arena.get_mut(rid).accuracy = acc_of(profiles, &self.pods[&pid].variant);
                self.enqueue_request(profiles, pid, rid, now);
                true
            }
            None => false,
        }
    }

    /// Bounded, SLO-budget-charged retry: attempt `k` waits
    /// `backoff × 2^k`; an attempt that cannot fire before the deadline —
    /// or past the retry budget, or with reactions off — fails instead.
    fn retry_or_fail(&mut self, now: f64, rid: u32) {
        let r = *self.arena.get(rid);
        let attempt = r.retries;
        let retry_t = now + self.fault.retry_backoff_s * f64::powi(2.0, attempt as i32);
        if self.reactions
            && (attempt as u32) < self.fault.max_retries
            && retry_t < r.arrival + self.slo_s
        {
            self.arena.get_mut(rid).retries = attempt + 1;
            self.telem.record_retry();
            push_event(
                &mut self.events,
                &mut self.seq,
                retry_t,
                EventKind::Retry { req: rid },
            );
        } else {
            self.fail_request(rid);
        }
    }

    /// Terminal failure: the `Failed` outcome counts inside the SLO
    /// violation rate (like a drop) but is tallied separately.
    fn fail_request(&mut self, rid: u32) {
        let r = *self.arena.get(rid);
        self.telem.record_failed();
        self.metrics
            .record_request(RequestRecord::failed(r.arrival, r.tier));
        self.arena.free(rid);
    }

    /// Apply stage: install one decision — dispatcher weights, batch-size
    /// targets (a shrunk target can complete a forming batch), and the
    /// prediction/batch metrics records.  Pods are visited in id order —
    /// HashMap iteration order would make the RNG draw sequence
    /// nondeterministic across runs.
    pub(super) fn apply_decision(&mut self, profiles: &ProfileSet, now: f64, d: &Decision) {
        self.path.set_weights(&d.quotas);
        self.current_batches = d
            .target
            .keys()
            .map(|v| (v.clone(), d.batch_of(v)))
            .collect();
        let mut pod_ids: Vec<u64> = self.pods.keys().copied().collect();
        pod_ids.sort_unstable();
        for pid in pod_ids {
            let pod = self.pods.get_mut(&pid).expect("listed pod");
            let mb = self.current_batches.get(&pod.variant).copied().unwrap_or(1);
            if mb != pod.max_batch {
                pod.max_batch = mb;
                if pod.forming.len() >= mb {
                    let mut items = std::mem::take(&mut pod.forming);
                    pod.forming_seq += 1;
                    dispatch_batch(
                        profiles,
                        pod,
                        pid,
                        &mut items,
                        now,
                        &mut self.batches,
                        &mut self.events,
                        &mut self.seq,
                        &mut self.rng,
                        &mut self.telem,
                    );
                    pod.forming = items;
                }
            }
        }
        for (v, &b) in self.current_batches.iter().filter(|&(_, &b)| b > 1) {
            self.metrics.record_batch_decision(now, v, b);
        }
        self.metrics.record_prediction(now, d.predicted_lambda);
    }

    /// Least-loaded ready pod of a namespaced variant key.
    fn pick_pod(&self, cluster: &Cluster, key: &str) -> Option<u64> {
        cluster
            .ready_pods_of(key)
            .iter()
            .filter_map(|p| self.pods.get(&p.id).map(|ps| (p.id, ps)))
            .min_by(|a, b| a.1.load().total_cmp(&b.1.load()))
            .map(|(id, _)| id)
    }

    /// Any ready pod of this service (fallback when the chosen variant has
    /// none yet).  The shard's pods map holds only its own pods, so the
    /// cluster-wide scan self-filters.
    fn any_pod(&self, cluster: &Cluster) -> Option<u64> {
        cluster
            .pods()
            .iter()
            .filter(|p| p.is_ready())
            .filter_map(|p| self.pods.get(&p.id).map(|ps| (p.id, ps)))
            .min_by(|a, b| a.1.load().total_cmp(&b.1.load()))
            .map(|(id, _)| id)
    }

    /// Least-loaded ready pod of this service other than `except` — the
    /// hedging target picker.
    fn any_pod_except(&self, cluster: &Cluster, except: u64) -> Option<u64> {
        cluster
            .pods()
            .iter()
            .filter(|p| p.is_ready() && p.id != except)
            .filter_map(|p| self.pods.get(&p.id).map(|ps| (p.id, ps)))
            .min_by(|a, b| a.1.load().total_cmp(&b.1.load()))
            .map(|(id, _)| id)
    }

    /// Arena counters for diagnostics: (allocs, reuses, live, high-water).
    pub fn arena_stats(&self) -> (u64, u64, usize, usize) {
        let (a, r) = self.arena.stats();
        (a, r, self.arena.live(), self.arena.high_water())
    }

    /// Event-wheel counters for diagnostics: (peak scheduled events,
    /// coarse-bucket cascades over the run).
    pub fn wheel_stats(&self) -> (usize, u64) {
        (self.events.high_water(), self.events.cascades())
    }
}

/// Cluster-facing variant key of a service's variant.
pub(super) fn namespaced(prefix: &str, variant: &str) -> String {
    if prefix.is_empty() {
        variant.to_string()
    } else {
        format!("{prefix}{variant}")
    }
}

fn acc_of(profiles: &ProfileSet, variant: &str) -> f64 {
    profiles.get(variant).map(|p| p.accuracy).unwrap_or(0.0)
}

/// Draw one service time for a batch of `batch` requests on a variant
/// (lognormal around the amortized mean; `batch = 1` is the plain
/// measured service time).
fn sample_service_batch(profiles: &ProfileSet, variant: &str, batch: usize, rng: &mut Rng) -> f64 {
    let p = profiles.get(variant).expect("unknown variant");
    rng.lognormal_mean(p.service_time_batch(batch), p.service_sigma.max(1e-6))
}

/// Hand a formed batch to the pod: one service draw on a free core, or
/// the formed-batch queue when all cores are busy.  `items` comes back
/// holding the recycled slot's empty vector (capacity circulation).
#[allow(clippy::too_many_arguments)]
fn dispatch_batch(
    profiles: &ProfileSet,
    pod: &mut PodSim,
    pod_id: u64,
    items: &mut Vec<u32>,
    now: f64,
    batches: &mut BatchArena,
    events: &mut TimerWheel<EventKind>,
    seq: &mut u64,
    rng: &mut Rng,
    telem: &mut ShardTelemetry,
) {
    let bid = batches.alloc_swap(items);
    let len = batches.get(bid).len();
    telem.record_batch(pod.max_batch, len);
    if pod.busy < pod.cores {
        pod.busy += 1;
        pod.in_service.push(bid);
        pod.waiting = pod.waiting.saturating_sub(len);
        let mut stime = sample_service_batch(profiles, &pod.variant, len, rng);
        if now < pod.slow_until {
            stime *= pod.slow_mult;
        }
        push_event(events, seq, now + stime, EventKind::Completion { pod_id, batch: bid });
    } else {
        pod.queue.push_back(bid);
    }
}

/// Run `f(i, &mut a[i], &mut b[i])` for every index — serially in index
/// order when no pool is supplied (the thread-free N = 1 wrapper path and
/// `solver_threads = 1`), otherwise fanned out over the engine's
/// persistent [`WorkerPool`].  Each task owns a disjoint pair of `&mut`
/// slots, every result lands in the task's own slot, and callers read the
/// slots back in index order — so thread scheduling cannot influence any
/// outcome and the parallel path is bit-identical to the serial one by
/// construction (pinned by `parallel_fleet_is_bit_identical_to_serial`).
///
/// **Safety of the fan-out.**  The pool's closure receives a plain index
/// and re-derives `(&mut a[i], &mut b[i])` from raw base pointers.  This is
/// sound because [`WorkerPool::dispatch`] claims every index exactly once
/// (so no two tasks alias a slot), `A: Send`/`B: Send` make moving the
/// borrows across threads legal, and `dispatch` blocks until all claimed
/// tasks completed — the borrows never outlive the call.
///
/// **Panic discipline.**  A panicking task flips the pool's abort flag
/// (siblings stop claiming), the unclaimed remainder is drained, and the
/// panic re-raises at the caller — the same observable behavior as the old
/// scoped-spawn path (`worker_panic_aborts_cleanly_without_hanging`).
pub(crate) fn parallel_zip<A, B, F>(pool: Option<&WorkerPool>, a: &mut [A], b: &mut [B], f: F)
where
    A: Send,
    B: Send,
    F: Fn(usize, &mut A, &mut B) + Sync,
{
    debug_assert_eq!(a.len(), b.len());
    let n = a.len().min(b.len());
    let pool = match pool {
        Some(p) if n > 1 => p,
        _ => {
            for (i, (x, y)) in a.iter_mut().zip(b.iter_mut()).enumerate() {
                f(i, x, y);
            }
            return;
        }
    };
    let pa = a.as_mut_ptr() as usize;
    let pb = b.as_mut_ptr() as usize;
    pool.dispatch(n, &|i| {
        // SAFETY: i < n <= len of both slices, and the pool hands out each
        // index exactly once, so these are disjoint in-bounds `&mut`s that
        // live only for this call (dispatch blocks until every task ends).
        let (x, y) = unsafe { (&mut *(pa as *mut A).add(i), &mut *(pb as *mut B).add(i)) };
        f(i, x, y);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_arena_reuses_freed_slots() {
        let mut arena = RequestArena::new();
        let a = arena.alloc(RequestSim { arrival: 1.0, accuracy: 0.5, tier: 0, retries: 0 });
        let b = arena.alloc(RequestSim { arrival: 2.0, accuracy: 0.6, tier: 1, retries: 0 });
        assert_eq!((a, b), (0, 1));
        assert_eq!(arena.live(), 2);
        arena.free(a);
        assert_eq!(arena.live(), 1);
        // the freed slot is recycled before the slab grows
        let c = arena.alloc(RequestSim { arrival: 3.0, accuracy: 0.7, tier: 0, retries: 0 });
        assert_eq!(c, a);
        assert_eq!(arena.get(c).arrival, 3.0);
        assert_eq!(arena.high_water(), 2);
        let (allocs, reuses) = arena.stats();
        assert_eq!((allocs, reuses), (3, 1));
    }

    #[test]
    fn batch_arena_circulates_capacity_through_swap() {
        let mut arena = BatchArena::new();
        let mut forming: Vec<u32> = (0..64).collect();
        let cap = forming.capacity();
        let bid = arena.alloc_swap(&mut forming);
        assert!(forming.is_empty());
        assert_eq!(arena.get(bid).len(), 64);
        arena.free(bid);
        // next alloc reuses the freed slot; the caller's vector gets the
        // 64-element capacity back in exchange
        forming.push(7);
        let bid2 = arena.alloc_swap(&mut forming);
        assert_eq!(bid2, bid);
        assert_eq!(arena.get(bid2), &[7]);
        assert!(forming.capacity() >= cap);
    }

    #[test]
    fn parallel_zip_is_bit_identical_to_serial_at_any_thread_count() {
        let f = |i: usize, x: &mut u64, y: &mut u64| {
            *x = (i as u64 + 1) * 3;
            *y = *x ^ 0xDEAD;
        };
        let n = 257;
        let mut a1 = vec![0u64; n];
        let mut b1 = vec![0u64; n];
        parallel_zip(None, &mut a1, &mut b1, f);
        for threads in [2, 4, 8, 64] {
            let pool = WorkerPool::new(threads, false);
            let mut a = vec![0u64; n];
            let mut b = vec![0u64; n];
            parallel_zip(Some(&pool), &mut a, &mut b, f);
            assert_eq!(a, a1, "threads={threads}");
            assert_eq!(b, b1, "threads={threads}");
        }
    }

    #[test]
    fn parallel_zip_handles_more_threads_than_items() {
        let pool = WorkerPool::new(16, false);
        let mut a = vec![1u32; 3];
        let mut b = vec![2u32; 3];
        parallel_zip(Some(&pool), &mut a, &mut b, |_, x, y| {
            *x += 1;
            *y += 1;
        });
        assert_eq!(a, vec![2, 2, 2]);
        assert_eq!(b, vec![3, 3, 3]);
    }

    /// Satellite (a): a panic in one of eight workers must propagate to
    /// the caller as a panic (clean abort), not hang the dispatcher — and
    /// the same pool must keep serving fresh dispatches afterwards.
    #[test]
    fn worker_panic_aborts_cleanly_without_hanging() {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        let pool = WorkerPool::new(8, false);
        let n = 64;
        let mut a: Vec<u64> = (0..n as u64).collect();
        let mut b = vec![0u64; n];
        let result = catch_unwind(AssertUnwindSafe(|| {
            parallel_zip(Some(&pool), &mut a, &mut b, |i, _x, y| {
                if i == 13 {
                    panic!("worker down");
                }
                *y = 1;
            });
        }));
        assert!(result.is_err(), "the worker panic must reach the caller");
        // the pool survives the aborted generation: a fresh parallel_zip
        // on the same pool works
        let mut c = vec![0u64; 8];
        let mut d = vec![0u64; 8];
        parallel_zip(Some(&pool), &mut c, &mut d, |i, x, _y| *x = i as u64);
        assert_eq!(c, (0..8).collect::<Vec<u64>>());
    }
}
