//! Fleet scheduling: many independent services on one shared cluster.
//!
//! The paper's adapter manages a single model family that owns the entire
//! core budget.  A production cluster instead runs *many* such services —
//! each with its own variant family, latency SLO, and traffic — competing
//! for the same cores (the model-less/multi-pipeline serving setting of
//! INFaaS and Loki).  This module adds that layer:
//!
//! * [`arbiter::CoreArbiter`] — re-partitions the global core budget
//!   across services every adaptation interval by water-filling on
//!   priority-weighted marginal utility, with guaranteed-minimum floors:
//!   a binary heap of per-service claims, `O(B log N)` per tick.  Utility
//!   comes from each service's own ILP, whose whole per-grant value curve
//!   is the output of *one* single-pass solve
//!   ([`crate::solver::Solver::solve_curve`]).
//! * [`curve_cache::CurveCache`] — cross-tick curve memory keyed by
//!   (quantized λ̂, current-cores signature, weights): identical inputs
//!   skip the solve entirely, near-identical inputs warm-start the
//!   incumbent curve so steady-state ticks prune almost everything.
//!   Always exact — partitions are bit-identical to uncached runs.
//! * **Admission-aware value curves** (PR 5) — with
//!   `FleetConfig::shed_penalty` set, each service's ILP prices the
//!   offered load its capacity cannot cover (tier-weighted by
//!   [`shed_value_weight`]), so the value curves the arbiter water-fills
//!   already carry the cost of shedding: a service facing overload sees
//!   its marginal utility rise in the same tick the forecast does —
//!   before the lagging `SloBurnMeter` signal trips — and contended
//!   cores flow toward the highest-value shed first.
//! * [`shard::ServiceShard`] — one service's slice of the data plane
//!   (trace stream, RNG, gate, dispatcher, pods view, metrics, event
//!   heap, and arena-backed request state), the unit the engine
//!   parallelizes over.
//! * [`sim::FleetSimEngine`] — the orchestrator: drives N shards against
//!   one shared [`crate::cluster::Cluster`] in virtual time through the
//!   five-stage tick protocol (observe → solve ∥ → arbitrate → apply →
//!   advance ∥), with per-service RNG streams (deterministic under a
//!   fixed seed; parallel stages are bit-identical to serial at every
//!   `solver_threads` count); the single-service engine is its N = 1
//!   special case.
//! * [`FleetScenario`] — the experiment-facing bundle (services + budget +
//!   modes): utility arbitration vs a static even split vs independent
//!   VPA+ instances, used by the `fleet` CLI subcommand and
//!   `benches/fig_fleet.rs`.

pub mod arbiter;
pub mod curve_cache;
pub mod shard;
pub mod sim;

pub use arbiter::{ArbiterEntry, CoreArbiter};
pub use curve_cache::{CurveCache, CurveCacheStats};
pub use shard::{BatchArena, RequestArena, RequestSim, ServiceShard};
pub use sim::{FleetPolicyRef, FleetService, FleetSimEngine};

use crate::adapter::InfAdapterPolicy;
use crate::baselines::VpaPolicy;
use crate::config::{
    AdmissionConfig, BatchingConfig, Config, FaultConfig, ObjectiveWeights, TelemetryConfig,
};
use crate::dispatcher::Tier;
use crate::forecaster;
use crate::metrics::{FleetSummary, RunSummary};
use crate::profiler::ProfileSet;
use crate::replay::{Recorder, RunTrace};
use crate::serving::sim::{SimConfig, SimResult};
use crate::solver::BranchBoundSolver;
use crate::telemetry::FleetTelemetry;
use crate::workload::{RateSeries, Trace};
use anyhow::Result;
use std::path::Path;

/// Seed for service `i`'s *trace* generator: the engine's per-service
/// streams own `sim::service_seed(base, i)` and `+ 1` (service-time noise
/// and arrivals), so trace noise hops past that pair — without the offset
/// a service's trace noise would replay another stream's draws exactly.
fn trace_seed(base: u64, i: usize) -> u64 {
    sim::service_seed(base, i).wrapping_add(2)
}

/// Value weight of one shed request under a service's traffic mix: tier
/// `t` traffic is worth `2^-t` (tier 0 full price, each lower priority
/// tier half the one above), and a class mix prices the *expected* tier
/// of a shed request — `Σ share_t · 2^-t`.  An empty (or fully
/// non-positive) mix prices everything at the service's own tier, exactly
/// like the request-path [`crate::workload::ClassMixer`] fallback.  The
/// fleet multiplies `FleetConfig::shed_penalty` by this weight per
/// service, so the arbiter sees high-value shed as costlier than
/// best-effort shed.
pub fn shed_value_weight(class_mix: &[(Tier, f64)], default_tier: Tier) -> f64 {
    fn tier_value(t: Tier) -> f64 {
        0.5f64.powi(t as i32)
    }
    let total: f64 = class_mix.iter().filter(|&&(_, w)| w > 0.0).map(|&(_, w)| w).sum();
    if total <= 0.0 {
        tier_value(default_tier)
    } else {
        class_mix
            .iter()
            .filter(|&&(_, w)| w > 0.0)
            .map(|&(t, w)| w * tier_value(t))
            .sum::<f64>()
            / total
    }
}

/// Everything one service of a fleet scenario needs (owned; the sim-facing
/// borrowed form is [`sim::FleetService`]).
#[derive(Clone)]
pub struct ServiceSpec {
    pub name: String,
    pub trace: RateSeries,
    pub profiles: ProfileSet,
    pub slo_s: f64,
    pub weights: ObjectiveWeights,
    pub priority: f64,
    /// Strict priority tier (0 = most important); also the default
    /// per-request tier when the trace carries no class mix.
    pub tier: Tier,
    /// Allowed SLO-violation fraction (burn-rate denominator).
    pub error_budget: f64,
    pub floor_cores: usize,
    pub forecaster: String,
    pub headroom: f64,
    pub batching: BatchingConfig,
}

/// How the fleet shares the cluster.
#[derive(Debug, Clone, PartialEq)]
pub enum FleetMode {
    /// Utility-based core arbitration (the tentpole).
    Arbiter,
    /// Static even split of the global budget, one InfAdapter per share.
    EvenSplit,
    /// N independent VPA+ instances pinned to the named variant, one even
    /// share each (no accuracy scaling, no arbitration).
    IndependentVpa(String),
}

impl FleetMode {
    pub fn label(&self) -> String {
        match self {
            FleetMode::Arbiter => "fleet-arbiter".into(),
            FleetMode::EvenSplit => "even-split".into(),
            FleetMode::IndependentVpa(v) => {
                format!("vpa-{}", v.trim_start_matches("resnet"))
            }
        }
    }

    /// Round-trippable spec string (`arbiter | even | vpa:<variant>`) —
    /// the CLI's `--mode` grammar, and what a recorded run trace stores.
    pub fn spec(&self) -> String {
        match self {
            FleetMode::Arbiter => "arbiter".into(),
            FleetMode::EvenSplit => "even".into(),
            FleetMode::IndependentVpa(v) => format!("vpa:{v}"),
        }
    }

    /// Parse [`Self::spec`]'s grammar.
    pub fn from_spec(spec: &str) -> Result<Self> {
        match spec {
            "arbiter" => Ok(FleetMode::Arbiter),
            "even" => Ok(FleetMode::EvenSplit),
            s => match s.strip_prefix("vpa:") {
                Some(v) if !v.is_empty() => Ok(FleetMode::IndependentVpa(v.to_string())),
                _ => anyhow::bail!("unknown fleet mode {spec:?} (arbiter | even | vpa:<variant>)"),
            },
        }
    }
}

/// One fleet run's output: per-service streams plus the aggregate.
pub struct FleetRunOutput {
    pub mode: String,
    pub per_service: Vec<SimResult>,
    pub summary: FleetSummary,
    /// Engine-level telemetry (stage profiler, flight recorder, merged
    /// counters); `None` when the plane is disabled.
    pub telemetry: Option<FleetTelemetry>,
}

/// A fully-specified multi-service experiment.
#[derive(Clone)]
pub struct FleetScenario {
    pub services: Vec<ServiceSpec>,
    /// Shared core budget the arbiter (or the even split) partitions.
    pub global_budget: usize,
    pub node_cores: Vec<usize>,
    pub adapter_interval_s: f64,
    pub seed: u64,
    /// Request-path admission control (off by default).
    pub admission: AdmissionConfig,
    /// Arbiter SLO-burn boost strength (0 = off).
    pub burn_boost: f64,
    /// Per-request lost-goodput price for admission-aware value curves
    /// (0 = off); weighted per service by [`shed_value_weight`].
    pub shed_penalty: f64,
    /// Worker threads for the engine's parallel stages (0 = auto,
    /// 1 = serial reference path).  Wall-clock only — never results.
    pub solver_threads: usize,
    /// Telemetry plane (off by default; bit-identical on vs off).
    pub telemetry: TelemetryConfig,
    /// Fault plane (off by default; bit-identical on vs off).
    pub fault: FaultConfig,
}

impl FleetScenario {
    /// Build a scenario from a [`Config`] with a populated `fleet` section
    /// (`config.validate()` first — floors, names, budget).
    pub fn from_config(config: &Config, profiles: &ProfileSet, seconds: usize) -> Result<Self> {
        anyhow::ensure!(
            !config.fleet.services.is_empty(),
            "config.fleet has no services; use `--services N` for a synthetic fleet"
        );
        let services = config
            .fleet
            .services
            .iter()
            .enumerate()
            .map(|(i, s)| -> Result<ServiceSpec> {
                Ok(ServiceSpec {
                    name: s.name.clone(),
                    trace: {
                        let trace = Trace::from_spec(
                            &s.trace,
                            s.base_rps,
                            seconds,
                            trace_seed(config.seed, i),
                        )?;
                        // A config-level mix overrides; otherwise keep
                        // whatever the trace itself carries (a CSV tier
                        // directive, a synthetic generator's mix) instead
                        // of clobbering it with an empty Vec.
                        if s.class_mix.is_empty() {
                            trace
                        } else {
                            trace.with_class_mix(s.class_mix.clone())
                        }
                    },
                    profiles: profiles.clone(),
                    slo_s: s.slo_latency_ms / 1000.0,
                    weights: config.weights,
                    priority: s.priority,
                    tier: s.tier,
                    error_budget: s.error_budget,
                    floor_cores: s.floor_cores,
                    forecaster: config.adapter.forecaster.clone(),
                    headroom: config.adapter.headroom,
                    batching: config.batching,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            services,
            global_budget: config.fleet.resolved_budget(&config.cluster),
            node_cores: config.cluster.node_cores.clone(),
            adapter_interval_s: config.adapter.interval_s,
            seed: config.seed,
            admission: config.admission,
            burn_boost: config.fleet.burn_boost,
            shed_penalty: config.fleet.shed_penalty,
            solver_threads: config.fleet.solver_threads,
            telemetry: config.telemetry,
            fault: config.fault,
        })
    }

    /// A synthetic N-service fleet with interleaved bursts: service `i`
    /// bursts to `5 × base` inside its own window (windows evenly staggered
    /// so at most one service bursts at a time) and SLOs alternate between
    /// the paper's 750 ms and a tighter 400 ms.  This is the `fig_fleet`
    /// experiment's workload and the `fleet --services N` default.
    pub fn synthetic(
        n: usize,
        base: f64,
        seconds: usize,
        global_budget: usize,
        config: &Config,
        profiles: &ProfileSet,
    ) -> Self {
        assert!(n >= 1, "a fleet needs at least one service");
        let floor = (global_budget / (2 * n).max(1)).min(2);
        let services = (0..n)
            .map(|i| {
                let start = seconds * (2 * i + 1) / (2 * n + 1);
                let len = seconds / (2 * n + 1);
                ServiceSpec {
                    name: format!("svc{i}"),
                    trace: Trace::burst_window(
                        base,
                        base * 5.0,
                        seconds,
                        start,
                        len,
                        trace_seed(config.seed, i),
                    ),
                    profiles: profiles.clone(),
                    slo_s: if i % 2 == 0 { 0.75 } else { 0.4 },
                    weights: config.weights,
                    priority: 1.0,
                    tier: 0,
                    error_budget: 0.01,
                    floor_cores: floor,
                    forecaster: config.adapter.forecaster.clone(),
                    headroom: config.adapter.headroom,
                    batching: config.batching,
                }
            })
            .collect();
        Self {
            services,
            global_budget,
            node_cores: config.cluster.node_cores.clone(),
            adapter_interval_s: config.adapter.interval_s,
            seed: config.seed,
            admission: config.admission,
            burn_boost: config.fleet.burn_boost,
            shed_penalty: config.fleet.shed_penalty,
            solver_threads: config.fleet.solver_threads,
            telemetry: config.telemetry,
            fault: config.fault,
        }
    }

    /// A synthetic N-service *overload* fleet: every service bursts to
    /// `5 × base` in the **same** window, so for that stretch the summed
    /// demand exceeds anything the arbiter can grant — the admission /
    /// priority-tier experiment's workload (`fig_fleet` §Shedding).  With
    /// `tiered`, service `i` rides tier `i % 2` (alternating
    /// high-priority / best-effort); otherwise everyone shares tier 0.
    pub fn synthetic_overload(
        n: usize,
        base: f64,
        seconds: usize,
        global_budget: usize,
        tiered: bool,
        config: &Config,
        profiles: &ProfileSet,
    ) -> Self {
        assert!(n >= 1, "a fleet needs at least one service");
        let floor = (global_budget / (2 * n).max(1)).min(2);
        let start = seconds / 4;
        let len = seconds / 2;
        let services = (0..n)
            .map(|i| ServiceSpec {
                name: format!("svc{i}"),
                trace: Trace::burst_window(
                    base,
                    base * 5.0,
                    seconds,
                    start,
                    len,
                    trace_seed(config.seed, i),
                ),
                profiles: profiles.clone(),
                slo_s: 0.75,
                weights: config.weights,
                priority: 1.0,
                tier: if tiered { (i % 2) as Tier } else { 0 },
                error_budget: 0.01,
                floor_cores: floor,
                forecaster: config.adapter.forecaster.clone(),
                headroom: config.adapter.headroom,
                batching: config.batching,
            })
            .collect();
        Self {
            services,
            global_budget,
            node_cores: config.cluster.node_cores.clone(),
            adapter_interval_s: config.adapter.interval_s,
            seed: config.seed,
            admission: config.admission,
            burn_boost: config.fleet.burn_boost,
            shed_penalty: config.fleet.shed_penalty,
            solver_threads: config.fleet.solver_threads,
            telemetry: config.telemetry,
            fault: config.fault,
        }
    }

    fn sim_engine(&self, mode: &FleetMode) -> FleetSimEngine {
        FleetSimEngine::new(
            SimConfig {
                // Informational only: the fleet engine judges every request
                // against its own service's SLO (FleetService::slo_s) and
                // never consults this field.  Filled with the tightest SLO
                // so a dumped SimConfig still reads sensibly.
                slo_s: self
                    .services
                    .iter()
                    .map(|s| s.slo_s)
                    .fold(f64::INFINITY, f64::min),
                adapter_interval_s: self.adapter_interval_s,
                node_cores: self.node_cores.clone(),
                seed: self.seed,
                bucket_s: 10.0,
                queue_timeout_s: 10.0,
                // the wait cap the services' solvers charged against their
                // SLOs — pods must not hold forming batches any longer
                batch_max_wait_s: self
                    .services
                    .first()
                    .map(|s| s.batching.max_wait_s)
                    .unwrap_or(0.05),
                admission: self.admission,
                solver_threads: self.solver_threads,
                telemetry: self.telemetry,
                fault: self.fault,
            },
            match mode {
                FleetMode::Arbiter => {
                    Some(CoreArbiter::new(self.global_budget).with_burn_boost(self.burn_boost))
                }
                _ => None,
            },
        )
    }

    /// Per-service budget under a static split.
    fn even_share(&self) -> usize {
        (self.global_budget / self.services.len().max(1)).max(1)
    }

    /// Run the fleet in one mode; `artifacts` feeds the forecaster builder
    /// (LSTM weights when present, classical fallback otherwise).
    pub fn run(&self, mode: &FleetMode, artifacts: &Path) -> FleetRunOutput {
        self.run_inner(mode, artifacts, None)
    }

    /// [`Self::run`] with deterministic recording: every per-tick decision
    /// record, arrival-stream fingerprint, and fault draw is captured into
    /// a [`RunTrace`] alongside the normal output.  The recorder is a pure
    /// observer — the returned output is bit-identical to [`Self::run`]
    /// (pinned by `recording_is_a_pure_observer`).
    pub fn run_recorded(&self, mode: &FleetMode, artifacts: &Path) -> (FleetRunOutput, RunTrace) {
        let mut recorder = Recorder::new(self.services.len());
        let out = self.run_inner(mode, artifacts, Some(&mut recorder));
        let trace = RunTrace::capture(self, mode, recorder, &out);
        (out, trace)
    }

    fn run_inner(
        &self,
        mode: &FleetMode,
        artifacts: &Path,
        recorder: Option<&mut Recorder>,
    ) -> FleetRunOutput {
        let share = self.even_share();
        let engine = self.sim_engine(mode);
        let (results, telemetry) = match mode {
            FleetMode::Arbiter | FleetMode::EvenSplit => {
                let mut policies: Vec<InfAdapterPolicy> = self
                    .services
                    .iter()
                    .map(|s| {
                        InfAdapterPolicy::new(
                            s.profiles.clone(),
                            forecaster::build(&s.forecaster, artifacts, self.adapter_interval_s),
                            // exact and ~700x faster than brute force
                            Box::new(BranchBoundSolver),
                            s.weights,
                            s.slo_s,
                            share, // overwritten every tick under arbitration
                            s.headroom,
                        )
                        .with_batching(s.batching)
                        // tier-weighted lost-goodput price (0 = off): the
                        // ILP sees shed traffic, so the arbiter trades
                        // cores against shedding within the same tick
                        .with_shed_pricing(
                            self.shed_penalty * shed_value_weight(&s.trace.class_mix, s.tier),
                        )
                    })
                    .collect();
                let mut services: Vec<FleetService> = policies
                    .iter_mut()
                    .zip(&self.services)
                    .map(|(p, s)| FleetService {
                        name: s.name.clone(),
                        trace: &s.trace,
                        profiles: s.profiles.clone(),
                        slo_s: s.slo_s,
                        priority: s.priority,
                        tier: s.tier,
                        error_budget: s.error_budget,
                        floor_cores: s.floor_cores,
                        policy: FleetPolicyRef::Arbitrated(p),
                    })
                    .collect();
                engine.run_traced(&mut services, recorder)
            }
            FleetMode::IndependentVpa(variant) => {
                let mut policies: Vec<VpaPolicy> = self
                    .services
                    .iter()
                    .map(|s| VpaPolicy::new(variant, s.profiles.clone(), share))
                    .collect();
                let mut services: Vec<FleetService> = policies
                    .iter_mut()
                    .zip(&self.services)
                    .map(|(p, s)| FleetService {
                        name: s.name.clone(),
                        trace: &s.trace,
                        profiles: s.profiles.clone(),
                        slo_s: s.slo_s,
                        priority: s.priority,
                        tier: s.tier,
                        error_budget: s.error_budget,
                        floor_cores: share,
                        policy: FleetPolicyRef::Plain(p),
                    })
                    .collect();
                engine.run_traced(&mut services, recorder)
            }
        };
        let summaries: Vec<RunSummary> = results
            .iter()
            .zip(&self.services)
            .map(|(r, s)| {
                let mut summary = r.metrics.summary(&s.name, r.duration_s);
                // attach the run's telemetry scalars (None when disabled,
                // so the summary stays bit-identical to a pre-telemetry one)
                summary.telemetry = r.telemetry;
                summary
            })
            .collect();
        let horizon_s = results.iter().map(|r| r.duration_s).fold(0.0, f64::max);
        FleetRunOutput {
            mode: mode.label(),
            per_service: results,
            summary: FleetSummary::from_services(summaries, horizon_s),
            telemetry,
        }
    }
}

/// Pretty-print one fleet run: per-service rows plus the aggregate line
/// (the fleet CLI's and `fig_fleet`'s terminal output).
pub fn print_fleet(title: &str, out: &FleetRunOutput) {
    println!("\n== {title} [{}] ==", out.mode);
    println!(
        "{:<10} {:>9} {:>8} {:>10} {:>10} {:>10} {:>9} {:>8} {:>9}",
        "service", "requests", "SLOviol%", "acc.loss", "cost(avg)", "P99(ms)", "dropped", "failed", "shed"
    );
    for s in &out.summary.services {
        println!(
            "{:<10} {:>9} {:>8.2} {:>10.3} {:>10.2} {:>10.0} {:>9} {:>8} {:>9}",
            s.policy,
            s.total_requests,
            s.slo_violation_rate * 100.0,
            s.avg_accuracy_loss,
            s.avg_cost_cores,
            s.p99_latency_s * 1000.0,
            s.dropped,
            s.failed,
            s.shed
        );
    }
    let a = &out.summary;
    println!(
        "{:<10} {:>9} {:>8.2} {:>10.3} {:>10.2} {:>10.0} {:>9} {:>8} {:>9}",
        "TOTAL",
        a.total_requests,
        a.slo_violation_rate * 100.0,
        a.avg_accuracy_loss,
        a.avg_cost_cores,
        a.worst_p99_latency_s * 1000.0,
        a.dropped,
        a.failed,
        a.shed
    );
    // Per-tier breakdown whenever the run was actually tiered or shed.
    if a.shed > 0 || a.tiers.len() > 1 {
        for t in &a.tiers {
            println!(
                "  tier {}: {} requests, {:.2}% SLO-violation (admitted), {} shed",
                t.tier,
                t.total,
                t.slo_violation_rate * 100.0,
                t.shed
            );
        }
    }
    let cc = out
        .per_service
        .iter()
        .fold(CurveCacheStats::default(), |acc, r| CurveCacheStats {
            hits: acc.hits + r.curve_cache.hits,
            warm: acc.warm + r.curve_cache.warm,
            cold: acc.cold + r.curve_cache.cold,
        });
    if cc.total() > 0 {
        println!(
            "curve cache: {} hits / {} warm / {} cold over {} arbitration solves",
            cc.hits,
            cc.warm,
            cc.cold,
            cc.total()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scenario(seconds: usize, budget: usize) -> FleetScenario {
        let mut config = Config::default();
        config.adapter.forecaster = "last_max".into();
        config.seed = 17;
        FleetScenario::synthetic(2, 30.0, seconds, budget, &config, &ProfileSet::paper_like())
    }

    #[test]
    fn synthetic_fleet_staggers_bursts_and_alternates_slos() {
        let s = scenario(600, 12);
        assert_eq!(s.services.len(), 2);
        assert_eq!(s.services[0].slo_s, 0.75);
        assert_eq!(s.services[1].slo_s, 0.4);
        // windows [120, 240) and [360, 480): never both bursting
        let peak_at = |svc: &ServiceSpec| {
            svc.trace
                .rates
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(t, _)| t)
                .unwrap()
        };
        let p0 = peak_at(&s.services[0]);
        let p1 = peak_at(&s.services[1]);
        assert!((120..240).contains(&p0), "p0 {p0}");
        assert!((360..480).contains(&p1), "p1 {p1}");
        let floors: usize = s.services.iter().map(|x| x.floor_cores).sum();
        assert!(floors <= s.global_budget);
    }

    /// The acceptance criterion: under interleaved bursts the arbiter
    /// beats the static even split on aggregate SLO violations at equal or
    /// lower total cores — re-partitioning moves cores to whichever
    /// service is bursting instead of stranding half the budget on the
    /// quiet one.
    #[test]
    fn arbiter_beats_even_split_under_interleaved_bursts() {
        let s = scenario(600, 12);
        let dir = Path::new("/nonexistent");
        let arb = s.run(&FleetMode::Arbiter, dir);
        let even = s.run(&FleetMode::EvenSplit, dir);
        assert!(
            arb.summary.slo_violation_rate < even.summary.slo_violation_rate,
            "arbiter {} !< even {}",
            arb.summary.slo_violation_rate,
            even.summary.slo_violation_rate
        );
        // both modes keep the same global budget; allow a small slack for
        // reallocation double-occupancy windows (create-before-remove)
        assert!(
            arb.summary.avg_cost_cores <= even.summary.avg_cost_cores + 2.0,
            "arbiter cost {} vs even {}",
            arb.summary.avg_cost_cores,
            even.summary.avg_cost_cores
        );
    }

    #[test]
    fn independent_vpa_drowns_where_the_fleet_adapts() {
        // VPA pinned to resnet50 on a half-budget share cannot cover a
        // 5x burst; the arbitrated fleet can.
        let s = scenario(600, 12);
        let dir = Path::new("/nonexistent");
        let arb = s.run(&FleetMode::Arbiter, dir);
        let vpa = s.run(&FleetMode::IndependentVpa("resnet50".into()), dir);
        assert!(
            arb.summary.slo_violation_rate < vpa.summary.slo_violation_rate,
            "arbiter {} !< vpa {}",
            arb.summary.slo_violation_rate,
            vpa.summary.slo_violation_rate
        );
    }

    /// The ISSUE's overload acceptance criterion: with the cluster
    /// genuinely oversubscribed (both services burst at once), admission +
    /// strict tiers cut the high-tier service's SLO violations at equal or
    /// lower cost than the PR 3 baseline, with the shedding pushed onto
    /// the low tier.
    #[test]
    fn admission_and_tiers_protect_the_high_tier_under_overload() {
        let mut config = Config::default();
        config.adapter.forecaster = "last_max".into();
        config.seed = 17;
        let dir = Path::new("/nonexistent");
        // Baseline: PR 3 semantics — no admission, single tier.
        let base = FleetScenario::synthetic_overload(
            2,
            30.0,
            600,
            8,
            false,
            &config,
            &ProfileSet::paper_like(),
        );
        let base_out = base.run(&FleetMode::Arbiter, dir);
        // Treatment: admission on, tiers on (svc0 = tier 0), burn boost on.
        config.admission.enabled = true;
        config.fleet.burn_boost = 1.0;
        let treated = FleetScenario::synthetic_overload(
            2,
            30.0,
            600,
            8,
            true,
            &config,
            &ProfileSet::paper_like(),
        );
        assert_eq!(treated.services[0].tier, 0);
        assert_eq!(treated.services[1].tier, 1);
        let treated_out = treated.run(&FleetMode::Arbiter, dir);

        // the baseline's high-priority service drowns in the shared burst
        let base_high = &base_out.summary.services[0];
        assert!(
            base_high.slo_violation_rate > 0.10,
            "overload baseline must violate: {base_high:?}"
        );
        // admission + tiers: the tier-0 service is protected...
        let treated_high = &treated_out.summary.services[0];
        assert!(
            treated_high.slo_violation_rate < base_high.slo_violation_rate * 0.5,
            "high tier must improve: {} vs baseline {}",
            treated_high.slo_violation_rate,
            base_high.slo_violation_rate
        );
        // ...by shedding, mostly at the low tier
        assert!(treated_out.summary.shed > 0);
        let tiers = &treated_out.summary.tiers;
        assert_eq!(tiers.len(), 2, "{tiers:?}");
        assert!(
            tiers[1].shed > tiers[0].shed,
            "shedding must land lowest-tier-first: {tiers:?}"
        );
        // at equal or lower cost (same budget, admission adds no cores)
        assert!(
            treated_out.summary.avg_cost_cores <= base_out.summary.avg_cost_cores + 1.0,
            "cost {} vs baseline {}",
            treated_out.summary.avg_cost_cores,
            base_out.summary.avg_cost_cores
        );
    }

    #[test]
    fn shed_value_weight_prices_tiers_and_mixes() {
        // pure tiers halve per level
        assert!((shed_value_weight(&[], 0) - 1.0).abs() < 1e-12);
        assert!((shed_value_weight(&[], 1) - 0.5).abs() < 1e-12);
        assert!((shed_value_weight(&[], 3) - 0.125).abs() < 1e-12);
        // a mix prices the expected tier of a shed request
        let w = shed_value_weight(&[(0, 7.0), (1, 3.0)], 0);
        assert!((w - (0.7 + 0.3 * 0.5)).abs() < 1e-12, "{w}");
        // non-positive weights are dropped, like the ClassMixer
        let w = shed_value_weight(&[(0, 0.0), (2, 1.0)], 0);
        assert!((w - 0.25).abs() < 1e-12, "{w}");
        // a fully-dropped mix falls back to the service tier
        assert!((shed_value_weight(&[(0, 0.0)], 2) - 0.25).abs() < 1e-12);
    }

    /// The ISSUE's acceptance criterion: with both services drowning in
    /// the same burst and NO strict tiers and NO burn boost in the
    /// arbiter, pricing shed traffic into the value curves shifts
    /// contended cores toward the service whose shed is most valuable
    /// (tier-0 traffic) — its shed drops vs the unpriced run, at the same
    /// global budget.
    #[test]
    fn shed_pricing_shifts_cores_toward_the_high_value_shedder() {
        let mk = |shed_penalty: f64| {
            let mut config = Config::default();
            config.adapter.forecaster = "last_max".into();
            config.seed = 23;
            config.admission.enabled = true;
            config.fleet.shed_penalty = shed_penalty;
            // tiered=false: both services share arbiter tier 0 — any core
            // movement is the pricing, not the lexicographic pre-pass
            let mut s = FleetScenario::synthetic_overload(
                2,
                30.0,
                420,
                8,
                false,
                &config,
                &ProfileSet::paper_like(),
            );
            // value-asymmetric traffic: svc0 carries tier-0 requests
            // (weight 1.0), svc1 tier-1 (weight 0.5)
            s.services[0].trace = s.services[0].trace.clone().with_class_mix(vec![(0, 1.0)]);
            s.services[1].trace = s.services[1].trace.clone().with_class_mix(vec![(1, 1.0)]);
            s
        };
        let dir = Path::new("/nonexistent");
        let off = mk(0.0).run(&FleetMode::Arbiter, dir);
        let on = mk(1.0).run(&FleetMode::Arbiter, dir);
        // same offered traffic either way
        assert_eq!(
            off.summary.total_requests, on.summary.total_requests,
            "pricing must not touch the arrival streams"
        );
        let t0_shed = |out: &FleetRunOutput| {
            out.summary
                .tiers
                .iter()
                .find(|t| t.tier == 0)
                .map(|t| t.shed)
                .unwrap_or(0)
        };
        assert!(t0_shed(&off) > 0, "the overload must shed: {:?}", off.summary.tiers);
        assert!(
            t0_shed(&on) < t0_shed(&off),
            "pricing must cut high-value shed: on {} !< off {}",
            t0_shed(&on),
            t0_shed(&off)
        );
        // at (essentially) equal cost: same budget, pricing adds no cores
        assert!(
            on.summary.avg_cost_cores <= off.summary.avg_cost_cores + 1.0,
            "cost {} vs {}",
            on.summary.avg_cost_cores,
            off.summary.avg_cost_cores
        );
    }

    #[test]
    fn from_config_builds_every_declared_service() {
        use crate::config::FleetServiceConfig;
        let mut config = Config::default();
        config.fleet.global_budget = 16;
        config.fleet.services = vec![
            FleetServiceConfig {
                name: "search".into(),
                slo_latency_ms: 400.0,
                trace: "steady:25".into(),
                base_rps: 25.0,
                ..Default::default()
            },
            FleetServiceConfig {
                name: "feed".into(),
                trace: "burst:100:80".into(),
                base_rps: 20.0,
                ..Default::default()
            },
        ];
        config.validate().unwrap();
        let s =
            FleetScenario::from_config(&config, &ProfileSet::paper_like(), 300).unwrap();
        assert_eq!(s.global_budget, 16);
        assert_eq!(s.services.len(), 2);
        assert_eq!(s.services[0].name, "search");
        assert!((s.services[0].slo_s - 0.4).abs() < 1e-12);
        assert_eq!(s.services[0].trace.duration_s(), 300);
        // bad trace spec surfaces as an error, not a panic
        config.fleet.services[0].trace = "wat".into();
        assert!(FleetScenario::from_config(&config, &ProfileSet::paper_like(), 300).is_err());
    }
}
