//! The fleet's core arbiter: top-level partitioning of the shared core
//! budget across services.
//!
//! In the sharded tick protocol (PR 6, see [`super::sim`]) the arbiter is
//! the *serial* stage between the two parallel ones: value curves are
//! solved per shard in the fan-out solve stage, the arbiter partitions
//! the budget over all of them in one deterministic index-ordered pass,
//! and the resulting grants fan back out to the parallel decide stage.
//! Keeping the partition serial is what makes the whole tick a pure
//! function of its inputs regardless of thread count.
//!
//! Every adaptation interval each arbitrated service reports a *value
//! curve* `v_i(g)` — the best objective `α·AA − (β·RC + γ·LC)` its own
//! solver can achieve inside a grant of `g` cores.  The whole curve is the
//! output of **one** single-pass solve ([`crate::solver::Solver::solve_curve`]):
//! the objective depends on the budget only through the feasibility bound,
//! so the solver bins the best objective by resource cost while it
//! enumerates and prefix-maxes the bins — with *curve-aware pruning* in
//! branch-and-bound (a partial assignment survives only if its optimistic
//! completion bound improves the incumbent curve at some reachable cost),
//! and cross-tick warm starts from the fleet's `CurveCache` (a previous
//! curve's winners re-scored under the new problem seed the incumbent, so
//! steady-state ticks prune almost everything).  This replaces the
//! original `N × (B+1)`-solves-per-tick decision path with `N` single
//! passes, exactly — partitions are bit-identical.
//!
//! The arbiter then **water-fills**: starting every service at its
//! guaranteed-minimum floor, it repeatedly grants one core to the service
//! with the highest *effective marginal utility*
//! `w_i · burn_i · (v_i(g_i + 1) − v_i(g_i))` until the global budget is
//! exhausted or every curve is at its cap.  Because a service's next
//! marginal changes only when *its own* grant changes, every service keeps
//! exactly one live claim in a binary max-heap and each grant is one pop +
//! one push — `O(B log N)` per tick instead of the old `O(B · N)` linear
//! rescan ([`CoreArbiter::partition_scan`], kept as the property-test
//! reference and perf baseline).  Ties break toward the lowest service
//! index, so the partition is a pure function of its inputs —
//! deterministic across runs with the same seed.
//!
//! Two priority signals modulate the fill:
//!
//! * **Strict tiers** ([`ArbiterEntry::tier`], 0 = most important) are a
//!   *lexicographic pre-pass*: while any tier-0 service still has positive
//!   marginal utility, no tier-1 weight — however large — can claim a
//!   core.  Within a tier the weighted fill is unchanged; leftover budget
//!   (all positive marginals exhausted) falls through to a final
//!   tier-blind fill so grants-as-caps keep widening feasible sets.  A
//!   single-tier fleet takes the heap fill directly and is bit-identical
//!   to the pre-tier arbiter.
//! * **SLO burn rate** ([`ArbiterEntry::burn`], from
//!   [`crate::monitoring::SloBurnMeter`]) boosts the marginals of services
//!   actively burning their error budget: the multiplier is
//!   `1 + burn_boost · min(burn − 1, 3)` for `burn > 1`, neutral (exactly
//!   1.0) otherwise, so a `burn_boost` of 0 — the default — leaves every
//!   marginal bit-identical to the burn-unaware arbiter.
//!
//! With **admission-aware value curves** (PR 5, `fleet.shed_penalty`)
//! the curves themselves already price shed traffic: an overloaded
//! service's `v_i` carries `−shed_penalty_i · max(0, λ̂_offered −
//! capacity)`, so its marginals steepen by the tier-weighted value of
//! the traffic it would otherwise shed — the arbiter trades cores
//! against shedding *within the tick that forecasts it*, instead of
//! waiting for the rolling burn signal above to cross its budget.
//!
//! Grants are **caps**, not reservations: each service's solver still
//! decides how many of its granted cores to actually allocate (the β·RC
//! term makes unused grant free), so handing out the whole budget never
//! hurts — it only widens the feasible set of the per-service solve.
//! Exact solvers make `v_i` monotone nondecreasing (anything feasible at
//! `g` is feasible at `g + 1`), so the marginals are nonnegative and the
//! fill order follows genuine utility.

use crate::dispatcher::Tier;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Cap on how far past its budget a burning service's overshoot can
/// scale the boost (keeps one melting service from flattening the
/// weighted order entirely).
const BURN_OVERSHOOT_CLAMP: f64 = 3.0;

/// One service's input to [`CoreArbiter::partition`].
#[derive(Debug, Clone)]
pub struct ArbiterEntry {
    /// Arbitration weight `w_i` (> 0); scales this service's marginals.
    pub priority: f64,
    /// Strict priority tier (0 = most important): outranks any weight of
    /// a numerically higher tier while this service has positive marginal
    /// utility.
    pub tier: Tier,
    /// SLO-burn-rate signal: rolling violation rate over the error budget
    /// (≤ 1 = inside budget, neutral; > 1 = burning, boosts marginals
    /// when the arbiter's `burn_boost` is enabled).
    pub burn: f64,
    /// Guaranteed-minimum core grant, handed out before water-filling.
    pub floor: usize,
    /// `v(g)` for `g in 0..=cap` (length `cap + 1`).  `None` marks a
    /// fixed-budget service outside arbitration (e.g. an independent VPA
    /// instance): it is locked at exactly its floor.
    pub curve: Option<Vec<f64>>,
}

/// One service's standing claim on the next marginal core.  Max-heap
/// order: highest marginal first, ties to the lowest service index (the
/// same tie-break as the reference linear scan).
struct Claim {
    marginal: f64,
    idx: usize,
}

impl PartialEq for Claim {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Claim {}
impl PartialOrd for Claim {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Claim {
    fn cmp(&self, other: &Self) -> Ordering {
        self.marginal
            .total_cmp(&other.marginal)
            .then_with(|| other.idx.cmp(&self.idx))
    }
}

/// Water-filling partitioner of the global core budget.
#[derive(Debug, Clone, Copy)]
pub struct CoreArbiter {
    /// Total cores the fleet may grant across all services.
    pub global_budget: usize,
    /// Strength of the SLO-burn marginal boost; 0 (default) disables it
    /// and keeps partitions bit-identical to the burn-unaware arbiter.
    pub burn_boost: f64,
}

impl CoreArbiter {
    pub fn new(global_budget: usize) -> Self {
        Self {
            global_budget,
            burn_boost: 0.0,
        }
    }

    /// Enable the SLO-burn marginal boost (builder style).
    pub fn with_burn_boost(mut self, burn_boost: f64) -> Self {
        self.burn_boost = burn_boost;
        self
    }

    /// Effective arbitration weight of one entry: priority, scaled by the
    /// burn boost when the service is past its error budget.  With
    /// `burn_boost == 0` this returns `priority` itself (no float op), so
    /// the default arbiter multiplies nothing.
    fn weight(&self, e: &ArbiterEntry) -> f64 {
        if self.burn_boost == 0.0 {
            e.priority
        } else {
            let overshoot = (e.burn - 1.0).clamp(0.0, BURN_OVERSHOOT_CLAMP);
            e.priority * (1.0 + self.burn_boost * overshoot)
        }
    }

    /// Partition the global budget into per-service core grants.
    ///
    /// Invariants (see `prop_arbiter_*` in `tests/properties.rs`):
    /// * `Σ grants ≤ global_budget`;
    /// * `grants[i] ≥ entries[i].floor` for every service;
    /// * curve-less entries receive exactly their floor;
    /// * no grant exceeds its curve's cap (`curve.len() − 1`);
    /// * the result is a pure function of `entries` (deterministic) and
    ///   equals [`Self::partition_scan`] grant for grant.
    ///
    /// Floors are trusted to fit inside the budget — `FleetConfig`
    /// validation enforces it before a run ever starts.
    pub fn partition(&self, entries: &[ArbiterEntry]) -> Vec<usize> {
        let mut grants: Vec<usize> = entries.iter().map(|e| e.floor).collect();
        let floors: usize = grants.iter().sum();
        debug_assert!(
            floors <= self.global_budget,
            "floors {floors} exceed the global budget {}",
            self.global_budget
        );
        let mut remaining = self.global_budget.saturating_sub(floors);
        let mut tiers: Vec<Tier> = entries.iter().map(|e| e.tier).collect();
        tiers.sort_unstable();
        tiers.dedup();
        if tiers.len() > 1 {
            // Lexicographic pre-pass: each tier drains its positive
            // marginals before any lower tier sees a core.
            for &t in &tiers {
                remaining = self.heap_fill(entries, &mut grants, remaining, Some(t), true);
            }
        }
        // Tier-blind fill of whatever is left (the single-tier fast path
        // runs only this, bit-identical to the pre-tier arbiter).
        self.heap_fill(entries, &mut grants, remaining, None, false);
        grants
    }

    /// One water-fill round over `entries`, restricted to `tier` when
    /// given.  With `positive_only` the fill stops as soon as the best
    /// live claim has no positive marginal (so a saturated high tier
    /// cannot absorb budget a lower tier still has real utility for);
    /// otherwise it runs to budget/cap exhaustion.  Returns the budget
    /// left over.
    fn heap_fill(
        &self,
        entries: &[ArbiterEntry],
        grants: &mut [usize],
        mut remaining: usize,
        tier: Option<Tier>,
        positive_only: bool,
    ) -> usize {
        let claim_at = |i: usize, g: usize| -> Option<Claim> {
            if tier.is_some_and(|t| entries[i].tier != t) {
                return None;
            }
            let curve = entries[i].curve.as_ref()?;
            if g + 1 >= curve.len() {
                return None; // at this curve's cap
            }
            let marginal = self.weight(&entries[i]) * (curve[g + 1] - curve[g]);
            if marginal.is_nan() {
                return None; // unsolvable curve (-inf flats): never claims
            }
            Some(Claim { marginal, idx: i })
        };
        let mut heap: BinaryHeap<Claim> = BinaryHeap::with_capacity(entries.len());
        for (i, &g) in grants.iter().enumerate() {
            if let Some(c) = claim_at(i, g) {
                heap.push(c);
            }
        }
        // Each service holds exactly one claim (its marginal at its
        // current grant), so a pop is always fresh — no lazy invalidation.
        while remaining > 0 {
            let Some(claim) = heap.pop() else {
                break;
            };
            if positive_only && claim.marginal <= 0.0 {
                break;
            }
            let i = claim.idx;
            grants[i] += 1;
            remaining -= 1;
            if let Some(c) = claim_at(i, grants[i]) {
                heap.push(c);
            }
        }
        remaining
    }

    /// Reference implementation: the original `O(budget × N)` linear
    /// marginal rescan (with the same tier pre-pass structure).  Kept as
    /// the ground truth the heap-based [`Self::partition`] is
    /// property-tested against, and as the "old" side of the
    /// `micro_hotpaths` arbiter comparison.
    pub fn partition_scan(&self, entries: &[ArbiterEntry]) -> Vec<usize> {
        let mut grants: Vec<usize> = entries.iter().map(|e| e.floor).collect();
        let floors: usize = grants.iter().sum();
        debug_assert!(
            floors <= self.global_budget,
            "floors {floors} exceed the global budget {}",
            self.global_budget
        );
        let mut remaining = self.global_budget.saturating_sub(floors);
        let mut tiers: Vec<Tier> = entries.iter().map(|e| e.tier).collect();
        tiers.sort_unstable();
        tiers.dedup();
        if tiers.len() > 1 {
            for &t in &tiers {
                remaining = self.scan_fill(entries, &mut grants, remaining, Some(t), true);
            }
        }
        self.scan_fill(entries, &mut grants, remaining, None, false);
        grants
    }

    fn scan_fill(
        &self,
        entries: &[ArbiterEntry],
        grants: &mut [usize],
        mut remaining: usize,
        tier: Option<Tier>,
        positive_only: bool,
    ) -> usize {
        while remaining > 0 {
            // Highest effective marginal utility wins the next core;
            // strict `>` keeps ties at the lowest index.
            let mut pick: Option<(usize, f64)> = None;
            for (i, e) in entries.iter().enumerate() {
                if tier.is_some_and(|t| e.tier != t) {
                    continue;
                }
                let Some(curve) = &e.curve else { continue };
                if grants[i] + 1 >= curve.len() {
                    continue; // at this curve's cap
                }
                let marginal = self.weight(e) * (curve[grants[i] + 1] - curve[grants[i]]);
                if marginal.is_nan() {
                    continue; // unsolvable curve (-inf flats): never claims
                }
                if pick.map_or(true, |(_, m)| marginal > m) {
                    pick = Some((i, marginal));
                }
            }
            let Some((i, m)) = pick else { break };
            if positive_only && m <= 0.0 {
                break;
            }
            grants[i] += 1;
            remaining -= 1;
        }
        remaining
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(priority: f64, floor: usize, curve: Option<Vec<f64>>) -> ArbiterEntry {
        ArbiterEntry {
            priority,
            tier: 0,
            burn: 1.0,
            floor,
            curve,
        }
    }

    fn tiered(tier: Tier, priority: f64, curve: Vec<f64>) -> ArbiterEntry {
        ArbiterEntry {
            priority,
            tier,
            burn: 1.0,
            floor: 0,
            curve: Some(curve),
        }
    }

    /// `v(g) = slope·min(g, knee)`: steep utility up to a knee, flat after.
    fn kneed(cap: usize, knee: usize, slope: f64) -> Vec<f64> {
        (0..=cap)
            .map(|g| slope * g.min(knee) as f64)
            .collect()
    }

    #[test]
    fn single_service_gets_the_whole_budget() {
        let arb = CoreArbiter::new(20);
        let grants = arb.partition(&[entry(1.0, 0, Some(kneed(20, 8, 1.0)))]);
        assert_eq!(grants, vec![20]);
    }

    #[test]
    fn longer_rising_curve_absorbs_the_marginal_cores() {
        // A's utility saturates at 4 cores, B's at 10: the budget covers
        // both knees exactly, so the fill stops at (4, 10) — nothing is
        // wasted past a knee while the other service still has utility.
        let arb = CoreArbiter::new(14);
        let grants = arb.partition(&[
            entry(1.0, 0, Some(kneed(14, 4, 1.0))),
            entry(1.0, 0, Some(kneed(14, 10, 1.0))),
        ]);
        assert_eq!(grants, vec![4, 10]);
    }

    #[test]
    fn priority_weights_break_contention() {
        // Identical curves, one service twice as important: it must fill
        // its knee first when the budget cannot cover both.
        let arb = CoreArbiter::new(8);
        let grants = arb.partition(&[
            entry(1.0, 0, Some(kneed(8, 8, 1.0))),
            entry(2.0, 0, Some(kneed(8, 8, 1.0))),
        ]);
        assert_eq!(grants, vec![0, 8]);
    }

    #[test]
    fn floors_are_guaranteed_even_with_flat_curves() {
        let arb = CoreArbiter::new(10);
        let grants = arb.partition(&[
            entry(1.0, 3, Some(vec![0.0; 11])), // flat: no marginal utility
            entry(1.0, 0, Some(kneed(7, 7, 1.0))),
        ]);
        assert!(grants[0] >= 3, "{grants:?}");
        assert!(grants.iter().sum::<usize>() <= 10);
    }

    #[test]
    fn fixed_budget_entries_hold_exactly_their_floor() {
        let arb = CoreArbiter::new(10);
        let grants = arb.partition(&[
            entry(1.0, 4, None), // e.g. an independent VPA instance
            entry(1.0, 0, Some(kneed(6, 6, 1.0))),
        ]);
        assert_eq!(grants[0], 4);
        assert_eq!(grants[1], 6);
    }

    #[test]
    fn leftover_stays_unallocated_when_every_curve_is_capped() {
        let arb = CoreArbiter::new(20);
        let grants = arb.partition(&[
            entry(1.0, 0, Some(kneed(5, 5, 1.0))),
            entry(1.0, 0, Some(kneed(5, 5, 1.0))),
        ]);
        assert_eq!(grants, vec![5, 5]); // 10 cores idle, grants are caps
    }

    #[test]
    fn unsolvable_curves_never_claim_marginal_cores() {
        // An all -inf curve (empty per-service problem) has NaN marginals;
        // it must hold its floor and starve nothing — identically in the
        // heap fill and the reference scan.
        let arb = CoreArbiter::new(12);
        let entries = [
            entry(1.0, 0, Some(kneed(8, 8, 1.0))),
            entry(5.0, 2, Some(vec![f64::NEG_INFINITY; 13])),
        ];
        let grants = arb.partition(&entries);
        assert_eq!(grants, arb.partition_scan(&entries));
        assert_eq!(grants, vec![8, 2]);
    }

    #[test]
    fn heap_fill_ties_break_to_the_lowest_index_like_the_scan() {
        // Identical linear curves at equal priority: every marginal ties,
        // so the lowest index must win every single round — in the heap
        // fill exactly as in the reference scan.
        let arb = CoreArbiter::new(9);
        let entries = [
            entry(1.0, 0, Some(kneed(9, 9, 1.0))),
            entry(1.0, 0, Some(kneed(9, 9, 1.0))),
            entry(1.0, 0, Some(kneed(9, 9, 1.0))),
        ];
        let heap = arb.partition(&entries);
        let scan = arb.partition_scan(&entries);
        assert_eq!(heap, scan);
        assert_eq!(heap, vec![9, 0, 0]);
    }

    #[test]
    fn strict_tier_outranks_any_weight() {
        // The ISSUE's semantics: tier 0 at weight 1 beats tier 1 at
        // weight 100 for every core tier 0 has positive marginal for.
        let arb = CoreArbiter::new(8);
        let grants = arb.partition(&[
            tiered(1, 100.0, kneed(8, 8, 1.0)),
            tiered(0, 1.0, kneed(8, 8, 1.0)),
        ]);
        assert_eq!(grants, vec![0, 8]);
    }

    #[test]
    fn saturated_high_tier_passes_the_leftover_down() {
        // Tier 0 saturates at 3 cores; the rest must flow to tier 1, not
        // pile up as zero-marginal grants on tier 0.
        let arb = CoreArbiter::new(10);
        let entries = [
            tiered(0, 1.0, kneed(10, 3, 1.0)),
            tiered(1, 1.0, kneed(10, 7, 1.0)),
        ];
        let grants = arb.partition(&entries);
        assert_eq!(grants, arb.partition_scan(&entries));
        assert_eq!(grants[0], 3);
        assert_eq!(grants[1], 7);
    }

    #[test]
    fn tiers_fill_lexicographically_across_three_levels() {
        let arb = CoreArbiter::new(9);
        let entries = [
            tiered(2, 10.0, kneed(9, 4, 1.0)),
            tiered(0, 1.0, kneed(9, 4, 1.0)),
            tiered(1, 5.0, kneed(9, 4, 1.0)),
        ];
        let grants = arb.partition(&entries);
        assert_eq!(grants, arb.partition_scan(&entries));
        // 9 cores: tier 0 fills its knee (4), tier 1 next (4), tier 2
        // gets the single leftover
        assert_eq!(grants, vec![1, 4, 4]);
    }

    #[test]
    fn burn_boost_shifts_contended_cores_to_the_burning_service() {
        // Identical curves and weights; service 1 is burning at 3x its
        // error budget.  With the boost off the tie goes to index 0
        // everywhere; with it on, service 1's marginals win.
        let mk = || {
            [
                ArbiterEntry {
                    priority: 1.0,
                    tier: 0,
                    burn: 0.5,
                    floor: 0,
                    curve: Some(kneed(8, 8, 1.0)),
                },
                ArbiterEntry {
                    priority: 1.0,
                    tier: 0,
                    burn: 3.0,
                    floor: 0,
                    curve: Some(kneed(8, 8, 1.0)),
                },
            ]
        };
        let neutral = CoreArbiter::new(8).partition(&mk());
        assert_eq!(neutral, vec![8, 0]);
        let boosted_arb = CoreArbiter::new(8).with_burn_boost(1.0);
        let boosted = boosted_arb.partition(&mk());
        assert_eq!(boosted, boosted_arb.partition_scan(&mk()));
        assert_eq!(boosted, vec![0, 8]);
    }

    #[test]
    fn burn_under_budget_is_neutral() {
        // burn ≤ 1 must not perturb the fill even with the boost enabled.
        let arb = CoreArbiter::new(8).with_burn_boost(2.0);
        let entries = [
            ArbiterEntry {
                priority: 1.0,
                tier: 0,
                burn: 0.0,
                floor: 0,
                curve: Some(kneed(8, 8, 1.0)),
            },
            ArbiterEntry {
                priority: 1.0,
                tier: 0,
                burn: 1.0,
                floor: 0,
                curve: Some(kneed(8, 8, 1.0)),
            },
        ];
        // every marginal still ties -> lowest index wins every round
        assert_eq!(arb.partition(&entries), vec![8, 0]);
    }
}
