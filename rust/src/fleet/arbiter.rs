//! The fleet's core arbiter: top-level partitioning of the shared core
//! budget across services.
//!
//! Every adaptation interval each arbitrated service reports a *value
//! curve* `v_i(g)` — the best objective `α·AA − (β·RC + γ·LC)` its own
//! solver can achieve inside a grant of `g` cores, computed by re-solving
//! the per-service ILP at every candidate budget
//! ([`crate::solver::value_curve`]).  The arbiter then **water-fills**:
//! starting every service at its guaranteed-minimum floor, it repeatedly
//! grants one core to the service with the highest *priority-weighted
//! marginal utility* `w_i · (v_i(g_i + 1) − v_i(g_i))` until the global
//! budget is exhausted or every curve is at its cap.  Ties break toward
//! the lowest service index, so the partition is a pure function of its
//! inputs — deterministic across runs with the same seed.
//!
//! Grants are **caps**, not reservations: each service's solver still
//! decides how many of its granted cores to actually allocate (the β·RC
//! term makes unused grant free), so handing out the whole budget never
//! hurts — it only widens the feasible set of the per-service solve.
//! Exact solvers make `v_i` monotone nondecreasing (anything feasible at
//! `g` is feasible at `g + 1`), so the marginals are nonnegative and the
//! fill order follows genuine utility.

/// One service's input to [`CoreArbiter::partition`].
#[derive(Debug, Clone)]
pub struct ArbiterEntry {
    /// Arbitration weight `w_i` (> 0); scales this service's marginals.
    pub priority: f64,
    /// Guaranteed-minimum core grant, handed out before water-filling.
    pub floor: usize,
    /// `v(g)` for `g in 0..=cap` (length `cap + 1`).  `None` marks a
    /// fixed-budget service outside arbitration (e.g. an independent VPA
    /// instance): it is locked at exactly its floor.
    pub curve: Option<Vec<f64>>,
}

/// Water-filling partitioner of the global core budget.
#[derive(Debug, Clone, Copy)]
pub struct CoreArbiter {
    /// Total cores the fleet may grant across all services.
    pub global_budget: usize,
}

impl CoreArbiter {
    pub fn new(global_budget: usize) -> Self {
        Self { global_budget }
    }

    /// Partition the global budget into per-service core grants.
    ///
    /// Invariants (see `prop_arbiter_*` in `tests/properties.rs`):
    /// * `Σ grants ≤ global_budget`;
    /// * `grants[i] ≥ entries[i].floor` for every service;
    /// * curve-less entries receive exactly their floor;
    /// * no grant exceeds its curve's cap (`curve.len() − 1`);
    /// * the result is a pure function of `entries` (deterministic).
    ///
    /// Floors are trusted to fit inside the budget — `FleetConfig`
    /// validation enforces it before a run ever starts.
    pub fn partition(&self, entries: &[ArbiterEntry]) -> Vec<usize> {
        let mut grants: Vec<usize> = entries.iter().map(|e| e.floor).collect();
        let floors: usize = grants.iter().sum();
        debug_assert!(
            floors <= self.global_budget,
            "floors {floors} exceed the global budget {}",
            self.global_budget
        );
        let mut remaining = self.global_budget.saturating_sub(floors);
        while remaining > 0 {
            // Highest priority-weighted marginal utility wins the next
            // core; strict `>` keeps ties at the lowest index.
            let mut pick: Option<(usize, f64)> = None;
            for (i, e) in entries.iter().enumerate() {
                let Some(curve) = &e.curve else { continue };
                if grants[i] + 1 >= curve.len() {
                    continue; // at this curve's cap
                }
                let marginal = e.priority * (curve[grants[i] + 1] - curve[grants[i]]);
                if pick.map_or(true, |(_, m)| marginal > m) {
                    pick = Some((i, marginal));
                }
            }
            let Some((i, _)) = pick else { break };
            grants[i] += 1;
            remaining -= 1;
        }
        grants
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(priority: f64, floor: usize, curve: Option<Vec<f64>>) -> ArbiterEntry {
        ArbiterEntry {
            priority,
            floor,
            curve,
        }
    }

    /// `v(g) = slope·min(g, knee)`: steep utility up to a knee, flat after.
    fn kneed(cap: usize, knee: usize, slope: f64) -> Vec<f64> {
        (0..=cap)
            .map(|g| slope * g.min(knee) as f64)
            .collect()
    }

    #[test]
    fn single_service_gets_the_whole_budget() {
        let arb = CoreArbiter::new(20);
        let grants = arb.partition(&[entry(1.0, 0, Some(kneed(20, 8, 1.0)))]);
        assert_eq!(grants, vec![20]);
    }

    #[test]
    fn longer_rising_curve_absorbs_the_marginal_cores() {
        // A's utility saturates at 4 cores, B's at 10: the budget covers
        // both knees exactly, so the fill stops at (4, 10) — nothing is
        // wasted past a knee while the other service still has utility.
        let arb = CoreArbiter::new(14);
        let grants = arb.partition(&[
            entry(1.0, 0, Some(kneed(14, 4, 1.0))),
            entry(1.0, 0, Some(kneed(14, 10, 1.0))),
        ]);
        assert_eq!(grants, vec![4, 10]);
    }

    #[test]
    fn priority_weights_break_contention() {
        // Identical curves, one service twice as important: it must fill
        // its knee first when the budget cannot cover both.
        let arb = CoreArbiter::new(8);
        let grants = arb.partition(&[
            entry(1.0, 0, Some(kneed(8, 8, 1.0))),
            entry(2.0, 0, Some(kneed(8, 8, 1.0))),
        ]);
        assert_eq!(grants, vec![0, 8]);
    }

    #[test]
    fn floors_are_guaranteed_even_with_flat_curves() {
        let arb = CoreArbiter::new(10);
        let grants = arb.partition(&[
            entry(1.0, 3, Some(vec![0.0; 11])), // flat: no marginal utility
            entry(1.0, 0, Some(kneed(7, 7, 1.0))),
        ]);
        assert!(grants[0] >= 3, "{grants:?}");
        assert!(grants.iter().sum::<usize>() <= 10);
    }

    #[test]
    fn fixed_budget_entries_hold_exactly_their_floor() {
        let arb = CoreArbiter::new(10);
        let grants = arb.partition(&[
            entry(1.0, 4, None), // e.g. an independent VPA instance
            entry(1.0, 0, Some(kneed(6, 6, 1.0))),
        ]);
        assert_eq!(grants[0], 4);
        assert_eq!(grants[1], 6);
    }

    #[test]
    fn leftover_stays_unallocated_when_every_curve_is_capped() {
        let arb = CoreArbiter::new(20);
        let grants = arb.partition(&[
            entry(1.0, 0, Some(kneed(5, 5, 1.0))),
            entry(1.0, 0, Some(kneed(5, 5, 1.0))),
        ]);
        assert_eq!(grants, vec![5, 5]); // 10 cores idle, grants are caps
    }
}
