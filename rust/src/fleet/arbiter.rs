//! The fleet's core arbiter: top-level partitioning of the shared core
//! budget across services.
//!
//! Every adaptation interval each arbitrated service reports a *value
//! curve* `v_i(g)` — the best objective `α·AA − (β·RC + γ·LC)` its own
//! solver can achieve inside a grant of `g` cores.  The whole curve is the
//! output of **one** single-pass solve ([`crate::solver::Solver::solve_curve`]):
//! the objective depends on the budget only through the feasibility bound,
//! so the solver bins the best objective by resource cost while it
//! enumerates and prefix-maxes the bins — with *curve-aware pruning* in
//! branch-and-bound (a partial assignment survives only if its optimistic
//! completion bound improves the incumbent curve at some reachable cost),
//! and cross-tick warm starts from the fleet's `CurveCache` (a previous
//! curve's winners re-scored under the new problem seed the incumbent, so
//! steady-state ticks prune almost everything).  This replaces the
//! original `N × (B+1)`-solves-per-tick decision path with `N` single
//! passes, exactly — partitions are bit-identical.
//!
//! The arbiter then **water-fills**: starting every service at its
//! guaranteed-minimum floor, it repeatedly grants one core to the service
//! with the highest *priority-weighted marginal utility*
//! `w_i · (v_i(g_i + 1) − v_i(g_i))` until the global budget is exhausted
//! or every curve is at its cap.  Because a service's next marginal
//! changes only when *its own* grant changes, every service keeps exactly
//! one live claim in a binary max-heap and each grant is one pop + one
//! push — `O(B log N)` per tick instead of the old `O(B · N)` linear
//! rescan ([`CoreArbiter::partition_scan`], kept as the property-test
//! reference and perf baseline).  Ties break toward the lowest service
//! index, so the partition is a pure function of its inputs —
//! deterministic across runs with the same seed.
//!
//! Grants are **caps**, not reservations: each service's solver still
//! decides how many of its granted cores to actually allocate (the β·RC
//! term makes unused grant free), so handing out the whole budget never
//! hurts — it only widens the feasible set of the per-service solve.
//! Exact solvers make `v_i` monotone nondecreasing (anything feasible at
//! `g` is feasible at `g + 1`), so the marginals are nonnegative and the
//! fill order follows genuine utility.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// One service's input to [`CoreArbiter::partition`].
#[derive(Debug, Clone)]
pub struct ArbiterEntry {
    /// Arbitration weight `w_i` (> 0); scales this service's marginals.
    pub priority: f64,
    /// Guaranteed-minimum core grant, handed out before water-filling.
    pub floor: usize,
    /// `v(g)` for `g in 0..=cap` (length `cap + 1`).  `None` marks a
    /// fixed-budget service outside arbitration (e.g. an independent VPA
    /// instance): it is locked at exactly its floor.
    pub curve: Option<Vec<f64>>,
}

/// One service's standing claim on the next marginal core.  Max-heap
/// order: highest marginal first, ties to the lowest service index (the
/// same tie-break as the reference linear scan).
struct Claim {
    marginal: f64,
    idx: usize,
}

impl PartialEq for Claim {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Claim {}
impl PartialOrd for Claim {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Claim {
    fn cmp(&self, other: &Self) -> Ordering {
        self.marginal
            .total_cmp(&other.marginal)
            .then_with(|| other.idx.cmp(&self.idx))
    }
}

/// Water-filling partitioner of the global core budget.
#[derive(Debug, Clone, Copy)]
pub struct CoreArbiter {
    /// Total cores the fleet may grant across all services.
    pub global_budget: usize,
}

impl CoreArbiter {
    pub fn new(global_budget: usize) -> Self {
        Self { global_budget }
    }

    /// Partition the global budget into per-service core grants.
    ///
    /// Invariants (see `prop_arbiter_*` in `tests/properties.rs`):
    /// * `Σ grants ≤ global_budget`;
    /// * `grants[i] ≥ entries[i].floor` for every service;
    /// * curve-less entries receive exactly their floor;
    /// * no grant exceeds its curve's cap (`curve.len() − 1`);
    /// * the result is a pure function of `entries` (deterministic) and
    ///   equals [`Self::partition_scan`] grant for grant.
    ///
    /// Floors are trusted to fit inside the budget — `FleetConfig`
    /// validation enforces it before a run ever starts.
    pub fn partition(&self, entries: &[ArbiterEntry]) -> Vec<usize> {
        let mut grants: Vec<usize> = entries.iter().map(|e| e.floor).collect();
        let floors: usize = grants.iter().sum();
        debug_assert!(
            floors <= self.global_budget,
            "floors {floors} exceed the global budget {}",
            self.global_budget
        );
        let mut remaining = self.global_budget.saturating_sub(floors);
        let claim_at = |i: usize, g: usize| -> Option<Claim> {
            let curve = entries[i].curve.as_ref()?;
            if g + 1 >= curve.len() {
                return None; // at this curve's cap
            }
            let marginal = entries[i].priority * (curve[g + 1] - curve[g]);
            if marginal.is_nan() {
                return None; // unsolvable curve (-inf flats): never claims
            }
            Some(Claim { marginal, idx: i })
        };
        let mut heap: BinaryHeap<Claim> = BinaryHeap::with_capacity(entries.len());
        for (i, &g) in grants.iter().enumerate() {
            if let Some(c) = claim_at(i, g) {
                heap.push(c);
            }
        }
        // Each service holds exactly one claim (its marginal at its
        // current grant), so a pop is always fresh — no lazy invalidation.
        while remaining > 0 {
            let Some(Claim { idx: i, .. }) = heap.pop() else {
                break;
            };
            grants[i] += 1;
            remaining -= 1;
            if let Some(c) = claim_at(i, grants[i]) {
                heap.push(c);
            }
        }
        grants
    }

    /// Reference implementation: the original `O(budget × N)` linear
    /// marginal rescan.  Kept as the ground truth the heap-based
    /// [`Self::partition`] is property-tested against, and as the "old"
    /// side of the `micro_hotpaths` arbiter comparison.
    pub fn partition_scan(&self, entries: &[ArbiterEntry]) -> Vec<usize> {
        let mut grants: Vec<usize> = entries.iter().map(|e| e.floor).collect();
        let floors: usize = grants.iter().sum();
        debug_assert!(
            floors <= self.global_budget,
            "floors {floors} exceed the global budget {}",
            self.global_budget
        );
        let mut remaining = self.global_budget.saturating_sub(floors);
        while remaining > 0 {
            // Highest priority-weighted marginal utility wins the next
            // core; strict `>` keeps ties at the lowest index.
            let mut pick: Option<(usize, f64)> = None;
            for (i, e) in entries.iter().enumerate() {
                let Some(curve) = &e.curve else { continue };
                if grants[i] + 1 >= curve.len() {
                    continue; // at this curve's cap
                }
                let marginal = e.priority * (curve[grants[i] + 1] - curve[grants[i]]);
                if marginal.is_nan() {
                    continue; // unsolvable curve (-inf flats): never claims
                }
                if pick.map_or(true, |(_, m)| marginal > m) {
                    pick = Some((i, marginal));
                }
            }
            let Some((i, _)) = pick else { break };
            grants[i] += 1;
            remaining -= 1;
        }
        grants
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(priority: f64, floor: usize, curve: Option<Vec<f64>>) -> ArbiterEntry {
        ArbiterEntry {
            priority,
            floor,
            curve,
        }
    }

    /// `v(g) = slope·min(g, knee)`: steep utility up to a knee, flat after.
    fn kneed(cap: usize, knee: usize, slope: f64) -> Vec<f64> {
        (0..=cap)
            .map(|g| slope * g.min(knee) as f64)
            .collect()
    }

    #[test]
    fn single_service_gets_the_whole_budget() {
        let arb = CoreArbiter::new(20);
        let grants = arb.partition(&[entry(1.0, 0, Some(kneed(20, 8, 1.0)))]);
        assert_eq!(grants, vec![20]);
    }

    #[test]
    fn longer_rising_curve_absorbs_the_marginal_cores() {
        // A's utility saturates at 4 cores, B's at 10: the budget covers
        // both knees exactly, so the fill stops at (4, 10) — nothing is
        // wasted past a knee while the other service still has utility.
        let arb = CoreArbiter::new(14);
        let grants = arb.partition(&[
            entry(1.0, 0, Some(kneed(14, 4, 1.0))),
            entry(1.0, 0, Some(kneed(14, 10, 1.0))),
        ]);
        assert_eq!(grants, vec![4, 10]);
    }

    #[test]
    fn priority_weights_break_contention() {
        // Identical curves, one service twice as important: it must fill
        // its knee first when the budget cannot cover both.
        let arb = CoreArbiter::new(8);
        let grants = arb.partition(&[
            entry(1.0, 0, Some(kneed(8, 8, 1.0))),
            entry(2.0, 0, Some(kneed(8, 8, 1.0))),
        ]);
        assert_eq!(grants, vec![0, 8]);
    }

    #[test]
    fn floors_are_guaranteed_even_with_flat_curves() {
        let arb = CoreArbiter::new(10);
        let grants = arb.partition(&[
            entry(1.0, 3, Some(vec![0.0; 11])), // flat: no marginal utility
            entry(1.0, 0, Some(kneed(7, 7, 1.0))),
        ]);
        assert!(grants[0] >= 3, "{grants:?}");
        assert!(grants.iter().sum::<usize>() <= 10);
    }

    #[test]
    fn fixed_budget_entries_hold_exactly_their_floor() {
        let arb = CoreArbiter::new(10);
        let grants = arb.partition(&[
            entry(1.0, 4, None), // e.g. an independent VPA instance
            entry(1.0, 0, Some(kneed(6, 6, 1.0))),
        ]);
        assert_eq!(grants[0], 4);
        assert_eq!(grants[1], 6);
    }

    #[test]
    fn leftover_stays_unallocated_when_every_curve_is_capped() {
        let arb = CoreArbiter::new(20);
        let grants = arb.partition(&[
            entry(1.0, 0, Some(kneed(5, 5, 1.0))),
            entry(1.0, 0, Some(kneed(5, 5, 1.0))),
        ]);
        assert_eq!(grants, vec![5, 5]); // 10 cores idle, grants are caps
    }

    #[test]
    fn unsolvable_curves_never_claim_marginal_cores() {
        // An all -inf curve (empty per-service problem) has NaN marginals;
        // it must hold its floor and starve nothing — identically in the
        // heap fill and the reference scan.
        let arb = CoreArbiter::new(12);
        let entries = [
            entry(1.0, 0, Some(kneed(8, 8, 1.0))),
            entry(5.0, 2, Some(vec![f64::NEG_INFINITY; 13])),
        ];
        let grants = arb.partition(&entries);
        assert_eq!(grants, arb.partition_scan(&entries));
        assert_eq!(grants, vec![8, 2]);
    }

    #[test]
    fn heap_fill_ties_break_to_the_lowest_index_like_the_scan() {
        // Identical linear curves at equal priority: every marginal ties,
        // so the lowest index must win every single round — in the heap
        // fill exactly as in the reference scan.
        let arb = CoreArbiter::new(9);
        let entries = [
            entry(1.0, 0, Some(kneed(9, 9, 1.0))),
            entry(1.0, 0, Some(kneed(9, 9, 1.0))),
            entry(1.0, 0, Some(kneed(9, 9, 1.0))),
        ];
        let heap = arb.partition(&entries);
        let scan = arb.partition_scan(&entries);
        assert_eq!(heap, scan);
        assert_eq!(heap, vec![9, 0, 0]);
    }
}
