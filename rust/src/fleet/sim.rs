//! Multi-service virtual-time serving simulator — the fleet engine.
//!
//! Generalizes the single-adapter discrete-event loop (see
//! [`crate::serving::sim`], which is now a thin single-service wrapper
//! around this engine) to N independent services sharing one [`Cluster`]:
//!
//! * **Shared substrate** — one node pool, one event heap, one virtual
//!   clock.  Pods are namespaced on the cluster as `"<service>/<variant>"`
//!   so services never collide; placement, readiness, and
//!   create-before-remove work exactly as before.
//! * **Per-service everything else** — each service brings its own trace,
//!   profile set, SLO, dispatcher, metrics collector, rate accounting,
//!   and policy.  Arrival timestamps and service-time noise come from
//!   per-service RNG streams: service `i` draws from
//!   `seed + i·SPLITMIX_GAMMA` (arrivals from that value + 1), so a fixed
//!   seed is deterministic regardless of how the services' events
//!   interleave, and service 0's streams equal the single-engine streams.
//! * **Arbitration** — when the engine holds a [`CoreArbiter`], every
//!   adaptation interval runs a three-phase protocol: (1) each arbitrated
//!   service observes its rate history and predicts λ̂, (2) it reports a
//!   value curve over candidate core grants — one single-pass solve
//!   ([`InfAdapterPolicy::value_curve_seeded`]) behind a per-service
//!   cross-tick [`CurveCache`] (exact hits skip the solve, same-bin λ̂
//!   wobble warm-starts it; values are bit-identical either way), and
//!   (3) the arbiter water-fills the global budget, each service then
//!   solving its own variant/batch selection inside its grant.  Without
//!   an arbiter every service keeps its configured budget (the "static
//!   split" baseline).
//!
//! **Bit-identity invariant:** a single-service fleet performs the same
//! cluster operations, heap pushes, and RNG draws in the same order as the
//! pre-fleet single-adapter engine — arbitration only inserts pure solver
//! work between the forecast and the decision (`decide` ≡
//! `observe_and_predict` + `decide_with_lambda`, and a lone service is
//! always granted the whole budget).  `single_service_fleet_matches_single_adapter_path`
//! below pins this.

use super::arbiter::{ArbiterEntry, CoreArbiter};
use super::curve_cache::CurveCache;
use crate::adapter::InfAdapterPolicy;
use crate::cluster::{Cluster, ClusterEvent};
use crate::dispatcher::{AdmissionGate, RequestPath, RouteOutcome, Tier};
use crate::metrics::{MetricsCollector, RequestRecord};
use crate::monitoring::SloBurnMeter;
use crate::profiler::ProfileSet;
use crate::serving::sim::{SimConfig, SimResult};
use crate::serving::{Decision, Policy};
use crate::util::rng::Rng;
use crate::workload::{ArrivalProcess, ClassMixer, RateSeries};
use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, HashMap, VecDeque};

/// Adaptation intervals the SLO-burn meter's rolling window covers.
const BURN_WINDOW_INTERVALS: usize = 4;

/// Seed of service `i`'s RNG stream.  Service 0 uses the base seed
/// unchanged — a single-service fleet reproduces the single-adapter engine
/// draw for draw — and later services hop by the SplitMix64 constant so
/// streams never collide.  Service `i` owns the pair
/// (`service_seed(base, i)` for service-time noise,
/// `service_seed(base, i) + 1` for arrivals); anything else deriving
/// per-service seeds from the same base (e.g. trace generators, see
/// [`super::FleetScenario`]) must offset past that pair.  Public so
/// conservation tests can regenerate a service's exact arrival stream
/// (`prop_shed_conservation` counts ground-truth arrivals from it).
pub fn service_seed(base: u64, i: usize) -> u64 {
    base.wrapping_add((i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// Shortest window a rate sample may be normalized over.  Caps the
/// extrapolation factor at 4x: an adapter tick at t = 30.001 must not turn
/// one arrival in a 1 ms sliver into a 1000 rps sample (a max-picking
/// forecaster would seize on it).  Windows shorter than this merge into
/// the neighbouring sample instead.
const MIN_RATE_SAMPLE_SPAN_S: f64 = 0.25;

#[derive(Debug, Clone, Copy, PartialEq)]
enum EventKind {
    Arrival { svc: usize },
    /// One batched service draw finishing; `batch` indexes the batch table.
    Completion { pod_id: u64, batch: usize },
    /// Formation wait expired for the batch a pod opened at `forming_seq`.
    BatchTimeout { pod_id: u64, forming_seq: u64 },
    ClusterTick,
    AdapterTick,
}

#[derive(Debug, Clone, Copy)]
struct Event {
    t: f64,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t.total_cmp(&other.t).then(self.seq.cmp(&other.seq))
    }
}

fn push_event(heap: &mut BinaryHeap<Reverse<Event>>, seq: &mut u64, t: f64, kind: EventKind) {
    *seq += 1;
    heap.push(Reverse(Event { t, seq: *seq, kind }));
}

/// One simulated pod (M/G/n station) owned by a service.
struct PodSim {
    /// Index of the owning service (RNG stream, metrics, profiles).
    svc: usize,
    /// Raw (un-namespaced) variant name within the owning service.
    variant: String,
    cores: usize,
    busy: usize,
    /// Formed batches (ids into the batch table) awaiting a free core.
    queue: VecDeque<usize>,
    /// Requests accumulating toward the next batch (ids).
    forming: Vec<usize>,
    /// Bumped on every dispatch; stale `BatchTimeout` events don't match.
    forming_seq: u64,
    /// Current batch-size target for this pod's variant (1 = no batching).
    max_batch: usize,
    /// Requests waiting at this pod (forming + members of queued batches);
    /// kept as a counter so routing comparisons stay O(1).
    waiting: usize,
}

impl PodSim {
    /// Waiting + in-service requests normalized by cores — the
    /// least-loaded routing metric.
    fn load(&self) -> f64 {
        (self.busy + self.waiting) as f64 / self.cores.max(1) as f64
    }
}

struct RequestSim {
    arrival: f64,
    accuracy: f64,
    svc: usize,
    /// Priority tier the request arrived with (per-tier accounting).
    tier: Tier,
}

/// One service of a fleet run: the adaptation policy plus everything it
/// serves (trace, profiles, SLO) and its arbitration terms.
pub struct FleetService<'a> {
    /// Service name; namespaces this service's pods on the shared cluster
    /// as `"<name>/<variant>"`.  May be empty only in a single-service
    /// fleet (the unprefixed single-adapter compatibility path); must not
    /// contain `/`.
    pub name: String,
    pub trace: &'a RateSeries,
    pub profiles: ProfileSet,
    /// Latency SLO for this service's metrics accounting, seconds.
    pub slo_s: f64,
    /// Arbitration weight (higher claims marginal cores first).
    pub priority: f64,
    /// Strict priority tier (0 = most important): a lexicographic
    /// pre-pass in the arbiter, and the default per-request tier when the
    /// trace carries no class mix.
    pub tier: Tier,
    /// Allowed SLO-violation fraction — the burn-rate signal denominator.
    pub error_budget: f64,
    /// Guaranteed-minimum core grant under arbitration; also the fixed
    /// reservation of a [`FleetPolicyRef::Plain`] service.
    pub floor_cores: usize,
    pub policy: FleetPolicyRef<'a>,
}

/// How a service's policy participates in the fleet.
pub enum FleetPolicyRef<'a> {
    /// Fixed-budget policy (VPA+, MS+, static, or an InfAdapter holding a
    /// static share): its decisions are used as-is and it stays outside
    /// arbitration.  Under an arbiter it is expected to stay within its
    /// `floor_cores` reservation (checked in debug builds — the arbiter
    /// partitions the rest of the budget assuming it).
    Plain(&'a mut dyn Policy),
    /// An InfAdapter whose core budget is re-set to the arbiter's grant
    /// every adaptation interval.  With no arbiter on the engine it keeps
    /// its configured budget (the "static split" baseline) and behaves
    /// exactly like `Plain`.
    Arbitrated(&'a mut InfAdapterPolicy),
}

/// Per-service runtime state.
struct SvcState {
    /// `"<name>/"`, or empty for the unprefixed single-service path.
    prefix: String,
    duration: f64,
    /// The admission-controlled request path: gate → tiers → smooth-WRR.
    path: RequestPath,
    /// Deterministic per-request tier assignment (no RNG).
    tier_mixer: ClassMixer,
    /// Rolling SLO-burn meter feeding the arbiter.
    burn: SloBurnMeter,
    /// Collector counts already folded into the burn meter.
    seen_violations: u64,
    seen_admitted: u64,
    metrics: MetricsCollector,
    rng: Rng,
    rate_history: Vec<f64>,
    arrivals_this_second: u64,
    last_whole_second: u64,
    /// Start of the window `arrivals_this_second` covers; advances with
    /// the per-second roll and with partial flushes at adapter ticks so
    /// every sample is normalized by the span it actually observed.
    counter_since: f64,
    /// Raw variant -> batch-size target in force (new pods inherit it).
    current_batches: BTreeMap<String, usize>,
    decisions: Vec<(f64, Decision)>,
    /// λ̂ carried from the arbitration phase into the decision phase.
    pending_lambda: f64,
    /// Cross-tick value-curve memory (arbitrated services only): exact
    /// hits skip the solve outright, near-hits warm-start it.
    curve_cache: CurveCache,
}

/// The multi-service engine.
pub struct FleetSimEngine {
    pub config: SimConfig,
    /// `None`: every service keeps its own fixed budget (static split).
    pub arbiter: Option<CoreArbiter>,
}

impl FleetSimEngine {
    pub fn new(config: SimConfig, arbiter: Option<CoreArbiter>) -> Self {
        Self { config, arbiter }
    }

    /// Run every service's event stream against the shared cluster;
    /// returns one [`SimResult`] per service, in input order.
    pub fn run(&self, services: &mut [FleetService]) -> Vec<SimResult> {
        let cfg = &self.config;
        let n = services.len();
        assert!(n > 0, "a fleet needs at least one service");
        if n > 1 {
            let mut names: Vec<&str> = services.iter().map(|s| s.name.as_str()).collect();
            assert!(
                names.iter().all(|nm| !nm.is_empty() && !nm.contains('/')),
                "multi-service fleets need non-empty, slash-free service names"
            );
            names.sort_unstable();
            names.dedup();
            assert_eq!(names.len(), n, "service names must be unique");
        }
        let max_duration = services
            .iter()
            .map(|s| s.trace.duration_s())
            .max()
            .unwrap_or(0) as f64;

        let mut st: Vec<SvcState> = services
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let top_acc = s
                    .profiles
                    .profiles
                    .iter()
                    .map(|p| p.accuracy)
                    .fold(0.0, f64::max);
                // Cutoff ladder of this service's gate: the range of
                // tiers its trace can actually emit — the class mix when
                // one is set, the service tier otherwise.  The floor
                // matters: a tier-1-only service must never cut off
                // tier 1 (its whole stream).
                let mix: Vec<Tier> = s
                    .trace
                    .class_mix
                    .iter()
                    .filter(|&&(_, w)| w > 0.0)
                    .map(|&(t, _)| t)
                    .collect();
                let (min_tier, max_tier) = if mix.is_empty() {
                    (s.tier, s.tier)
                } else {
                    (
                        mix.iter().copied().min().expect("non-empty"),
                        mix.iter().copied().max().expect("non-empty"),
                    )
                };
                SvcState {
                    prefix: if s.name.is_empty() {
                        String::new()
                    } else {
                        format!("{}/", s.name)
                    },
                    duration: s.trace.duration_s() as f64,
                    path: RequestPath::new(AdmissionGate::new(
                        &cfg.admission,
                        min_tier,
                        max_tier,
                    )),
                    tier_mixer: ClassMixer::new(&s.trace.class_mix, s.tier),
                    burn: SloBurnMeter::new(s.error_budget, BURN_WINDOW_INTERVALS),
                    seen_violations: 0,
                    seen_admitted: 0,
                    metrics: MetricsCollector::new(cfg.bucket_s, s.slo_s, top_acc),
                    rng: Rng::seed_from_u64(service_seed(cfg.seed, i)),
                    rate_history: Vec::new(),
                    arrivals_this_second: 0,
                    last_whole_second: 0,
                    counter_since: 0.0,
                    current_batches: BTreeMap::new(),
                    decisions: Vec::new(),
                    pending_lambda: 0.0,
                    curve_cache: CurveCache::new(),
                }
            })
            .collect();

        let mut cluster = Cluster::new(&cfg.node_cores);

        // --- Warm start: every service decides at t = 0 and its pods
        // become ready instantly (as in the paper's experiments).
        let first_rates: Vec<Vec<f64>> = services
            .iter()
            .map(|s| vec![s.trace.rates.first().copied().unwrap_or(0.0)])
            .collect();
        let empty_committed: Vec<BTreeMap<String, usize>> = vec![BTreeMap::new(); n];
        let grants = self.arbitrate(services, &mut st, &first_rates, &empty_committed);
        let decisions0 = decide_all(0.0, services, &st, &first_rates, &empty_committed, &grants);
        let merged = merged_target(&st, &decisions0);
        cluster.apply(&merged, 0.0, |_| 0.0);
        cluster.tick(0.0);
        for (i, d) in decisions0.iter().enumerate() {
            let s = &mut st[i];
            s.path.set_weights(&d.quotas);
            s.metrics.record_prediction(0.0, d.predicted_lambda);
            s.current_batches = d
                .target
                .keys()
                .map(|v| (v.clone(), d.batch_of(v)))
                .collect();
            for (v, &b) in s.current_batches.iter().filter(|&(_, &b)| b > 1) {
                s.metrics.record_batch_decision(0.0, v, b);
            }
        }
        refresh_gates(&cluster, services, &mut st, 0.0);
        record_costs(&cluster, &mut st, 0.0);

        // --- Event queue.
        let mut heap: BinaryHeap<Reverse<Event>> = BinaryHeap::new();
        let mut seq = 0u64;
        let arrival_lists: Vec<Vec<f64>> = services
            .iter()
            .enumerate()
            .map(|(i, s)| {
                ArrivalProcess::poisson(s.trace, service_seed(cfg.seed, i).wrapping_add(1))
            })
            .collect();
        for (i, list) in arrival_lists.iter().enumerate() {
            for &t in list {
                push_event(&mut heap, &mut seq, t, EventKind::Arrival { svc: i });
            }
        }
        let total_arrivals: usize = arrival_lists.iter().map(|l| l.len()).sum();
        let mut t_next = 1.0;
        while t_next < max_duration {
            push_event(&mut heap, &mut seq, t_next, EventKind::ClusterTick);
            t_next += 1.0;
        }
        let mut t_adapt = cfg.adapter_interval_s;
        while t_adapt < max_duration {
            push_event(&mut heap, &mut seq, t_adapt, EventKind::AdapterTick);
            t_adapt += cfg.adapter_interval_s;
        }

        // --- State.
        let mut pods: HashMap<u64, PodSim> = HashMap::new();
        for p in cluster.pods() {
            let svc = owner_of(&st, &p.variant);
            let raw = p.variant[st[svc].prefix.len()..].to_string();
            let max_batch = st[svc].current_batches.get(&raw).copied().unwrap_or(1);
            pods.insert(
                p.id,
                PodSim {
                    svc,
                    variant: raw,
                    cores: p.cores,
                    busy: 0,
                    queue: VecDeque::new(),
                    forming: Vec::new(),
                    forming_seq: 0,
                    max_batch,
                    waiting: 0,
                },
            );
        }
        let mut requests: Vec<RequestSim> = Vec::with_capacity(total_arrivals);
        // batch id -> member request ids (set at dispatch, pruned of
        // timed-out members at service start)
        let mut batches: Vec<Vec<usize>> = Vec::new();
        for (i, d) in decisions0.into_iter().enumerate() {
            st[i].decisions.push((0.0, d));
        }

        // --- Main loop.  Arrivals and ticks all fall inside
        // [0, max_duration); completions may land past the end and are
        // drained so every request is accounted for (conservation).
        while let Some(Reverse(ev)) = heap.pop() {
            let now = ev.t;
            // roll every service's per-second arrival counter (the division
            // is by exactly 1.0 — a bit-exact no-op — unless an adapter
            // tick partially flushed this second; a sliver left by a flush
            // just before the boundary merges into the next second)
            let sec = now as u64;
            for s in st.iter_mut() {
                while s.last_whole_second < sec {
                    let boundary = (s.last_whole_second + 1) as f64;
                    let span = boundary - s.counter_since;
                    if span >= MIN_RATE_SAMPLE_SPAN_S {
                        s.rate_history.push(s.arrivals_this_second as f64 / span);
                        s.arrivals_this_second = 0;
                        s.counter_since = boundary;
                    }
                    s.last_whole_second += 1;
                }
            }

            match ev.kind {
                EventKind::Arrival { svc } => {
                    st[svc].arrivals_this_second += 1;
                    let rid = requests.len();
                    let tier = st[svc].tier_mixer.next();
                    // The unified request path: admission gate (sheds
                    // excess offered load at the door — recorded, never
                    // enqueued; a disabled gate admits unconditionally,
                    // the pre-admission behaviour) → smooth-WRR variant
                    // routing.  The least-loaded ready pod of the routed
                    // variant then takes the request.
                    let variant = match st[svc].path.handle(now, tier) {
                        RouteOutcome::Shed(t) => {
                            st[svc]
                                .metrics
                                .record_request(RequestRecord::shed(now, t));
                            continue;
                        }
                        RouteOutcome::Routed(v) => Some(v),
                        // unconfigured / zero-capacity: fall through to
                        // the any-pod fallback, then drop
                        RouteOutcome::Denied(_) => None,
                    };
                    let pod_id = variant.as_deref().and_then(|v| {
                        pick_pod(&cluster, &pods, &namespaced(&st[svc].prefix, v))
                            .or_else(|| any_pod(&cluster, &pods, svc))
                    });
                    let Some(pid) = pod_id else {
                        requests.push(RequestSim {
                            arrival: now,
                            accuracy: 0.0,
                            svc,
                            tier,
                        });
                        st[svc].metrics.record_request(RequestRecord::new(
                            now,
                            f64::INFINITY,
                            0.0,
                            tier,
                        ));
                        continue;
                    };
                    let accuracy = acc_of(&services[svc].profiles, &pods[&pid].variant);
                    requests.push(RequestSim {
                        arrival: now,
                        accuracy,
                        svc,
                        tier,
                    });
                    enqueue_request(
                        &services[svc].profiles,
                        cfg.batch_max_wait_s,
                        pid,
                        rid,
                        now,
                        &mut pods,
                        &mut batches,
                        &mut heap,
                        &mut seq,
                        &mut st[svc].rng,
                    );
                }
                EventKind::Completion { pod_id, batch } => {
                    for &rid in &batches[batch] {
                        let r = &requests[rid];
                        st[r.svc].metrics.record_request(RequestRecord::new(
                            r.arrival,
                            now - r.arrival,
                            r.accuracy,
                            r.tier,
                        ));
                    }
                    if let Some(pod) = pods.get_mut(&pod_id) {
                        pod.busy = pod.busy.saturating_sub(1);
                        // Start the next formed batch, dropping members
                        // that queued past the client timeout.
                        while let Some(bid) = pod.queue.pop_front() {
                            pod.waiting = pod.waiting.saturating_sub(batches[bid].len());
                            let mut live = Vec::with_capacity(batches[bid].len());
                            for &rid in &batches[bid] {
                                let waited = now - requests[rid].arrival;
                                if waited > self.config.queue_timeout_s {
                                    st[requests[rid].svc].metrics.record_request(
                                        RequestRecord::new(
                                            requests[rid].arrival,
                                            f64::INFINITY,
                                            requests[rid].accuracy,
                                            requests[rid].tier,
                                        ),
                                    );
                                } else {
                                    live.push(rid);
                                }
                            }
                            if live.is_empty() {
                                continue;
                            }
                            pod.busy += 1;
                            let svc = pod.svc;
                            let stime = sample_service_batch(
                                &services[svc].profiles,
                                &pod.variant,
                                live.len(),
                                &mut st[svc].rng,
                            );
                            batches[bid] = live;
                            push_event(
                                &mut heap,
                                &mut seq,
                                now + stime,
                                EventKind::Completion { pod_id, batch: bid },
                            );
                            break;
                        }
                    }
                }
                EventKind::BatchTimeout { pod_id, forming_seq } => {
                    if let Some(pod) = pods.get_mut(&pod_id) {
                        if pod.forming_seq == forming_seq && !pod.forming.is_empty() {
                            let items = std::mem::take(&mut pod.forming);
                            pod.forming_seq += 1;
                            let svc = pod.svc;
                            dispatch_batch(
                                &services[svc].profiles,
                                pod,
                                pod_id,
                                items,
                                now,
                                &mut batches,
                                &mut heap,
                                &mut seq,
                                &mut st[svc].rng,
                            );
                        }
                    }
                }
                EventKind::ClusterTick => {
                    for event in cluster.tick(now) {
                        match event {
                            ClusterEvent::PodReady { pod_id, variant } => {
                                let cores = cluster
                                    .pods()
                                    .iter()
                                    .find(|p| p.id == pod_id)
                                    .map(|p| p.cores)
                                    .unwrap_or(0);
                                let svc = owner_of(&st, &variant);
                                let raw = variant[st[svc].prefix.len()..].to_string();
                                let max_batch =
                                    st[svc].current_batches.get(&raw).copied().unwrap_or(1);
                                pods.insert(
                                    pod_id,
                                    PodSim {
                                        svc,
                                        variant: raw,
                                        cores,
                                        busy: 0,
                                        queue: VecDeque::new(),
                                        forming: Vec::new(),
                                        forming_seq: 0,
                                        max_batch,
                                        waiting: 0,
                                    },
                                );
                            }
                            ClusterEvent::PodRemoved { pod_id, .. } => {
                                // Re-route still-waiting requests (queued
                                // batches and the forming buffer) within
                                // the owning service.
                                if let Some(mut dead) = pods.remove(&pod_id) {
                                    let svc = dead.svc;
                                    let mut orphans: Vec<usize> = Vec::new();
                                    for bid in dead.queue.drain(..) {
                                        orphans.append(&mut batches[bid]);
                                    }
                                    orphans.append(&mut dead.forming);
                                    for rid in orphans {
                                        // already-admitted requests are
                                        // re-routed, never re-gated
                                        if let Some(target) = st[svc]
                                            .path
                                            .dispatcher()
                                            .route()
                                            .and_then(|v| {
                                                pick_pod(
                                                    &cluster,
                                                    &pods,
                                                    &namespaced(&st[svc].prefix, &v),
                                                )
                                            })
                                            .or_else(|| any_pod(&cluster, &pods, svc))
                                        {
                                            requests[rid].accuracy = acc_of(
                                                &services[svc].profiles,
                                                &pods[&target].variant,
                                            );
                                            enqueue_request(
                                                &services[svc].profiles,
                                                cfg.batch_max_wait_s,
                                                target,
                                                rid,
                                                now,
                                                &mut pods,
                                                &mut batches,
                                                &mut heap,
                                                &mut seq,
                                                &mut st[svc].rng,
                                            );
                                        } else {
                                            st[svc].metrics.record_request(RequestRecord::new(
                                                requests[rid].arrival,
                                                f64::INFINITY,
                                                requests[rid].accuracy,
                                                requests[rid].tier,
                                            ));
                                        }
                                    }
                                }
                            }
                        }
                    }
                    record_costs(&cluster, &mut st, now);
                }
                EventKind::AdapterTick => {
                    // Flush every service's in-progress partial second so
                    // the just-observed load is visible to its policy
                    // (normalized by the span it actually covers; slivers
                    // below the minimum span stay in the counter).
                    for s in st.iter_mut() {
                        let span = now - s.counter_since;
                        if span >= MIN_RATE_SAMPLE_SPAN_S {
                            s.rate_history.push(s.arrivals_this_second as f64 / span);
                            s.arrivals_this_second = 0;
                            s.counter_since = now;
                        }
                        // Fold the interval's (violations, admitted) delta
                        // into the SLO-burn meter the arbiter reads.
                        let (v, a) = s.metrics.live_counts();
                        s.burn.observe(v - s.seen_violations, a - s.seen_admitted);
                        s.seen_violations = v;
                        s.seen_admitted = a;
                    }
                    let committed_full = cluster.committed_allocation();
                    let committed: Vec<BTreeMap<String, usize>> = (0..n)
                        .map(|i| {
                            committed_full
                                .iter()
                                .filter(|(k, _)| owner_of(&st, k) == i)
                                .map(|(k, &c)| (k[st[i].prefix.len()..].to_string(), c))
                                .collect()
                        })
                        .collect();
                    let histories: Vec<Vec<f64>> = st
                        .iter_mut()
                        .map(|s| std::mem::take(&mut s.rate_history))
                        .collect();
                    let grants = self.arbitrate(services, &mut st, &histories, &committed);
                    let decisions = decide_all(now, services, &st, &histories, &committed, &grants);
                    let merged = merged_target(&st, &decisions);
                    {
                        let svc_view: &[FleetService] = services;
                        cluster.apply(&merged, now, |v| readiness_of(svc_view, &st, v));
                    }
                    for (i, d) in decisions.iter().enumerate() {
                        let s = &mut st[i];
                        s.path.set_weights(&d.quotas);
                        // Propagate batch-size targets to this service's
                        // live and future pods; a shrunk target can
                        // complete a forming batch.  Visit pods in id
                        // order — HashMap iteration order would make the
                        // RNG draw sequence nondeterministic across runs.
                        s.current_batches = d
                            .target
                            .keys()
                            .map(|v| (v.clone(), d.batch_of(v)))
                            .collect();
                        let mut pod_ids: Vec<u64> = pods
                            .iter()
                            .filter(|(_, p)| p.svc == i)
                            .map(|(&id, _)| id)
                            .collect();
                        pod_ids.sort_unstable();
                        for pid in pod_ids {
                            let pod = pods.get_mut(&pid).expect("listed pod");
                            let mb = s.current_batches.get(&pod.variant).copied().unwrap_or(1);
                            if mb != pod.max_batch {
                                pod.max_batch = mb;
                                if pod.forming.len() >= mb {
                                    let items = std::mem::take(&mut pod.forming);
                                    pod.forming_seq += 1;
                                    dispatch_batch(
                                        &services[i].profiles,
                                        pod,
                                        pid,
                                        items,
                                        now,
                                        &mut batches,
                                        &mut heap,
                                        &mut seq,
                                        &mut s.rng,
                                    );
                                }
                            }
                        }
                        for (v, &b) in s.current_batches.iter().filter(|&(_, &b)| b > 1) {
                            s.metrics.record_batch_decision(now, v, b);
                        }
                        s.metrics.record_prediction(now, d.predicted_lambda);
                    }
                    refresh_gates(&cluster, services, &mut st, now);
                    record_costs(&cluster, &mut st, now);
                    for (i, d) in decisions.into_iter().enumerate() {
                        st[i].decisions.push((now, d));
                    }
                }
            }
        }

        st.into_iter()
            .map(|s| SimResult {
                metrics: s.metrics,
                duration_s: s.duration,
                decisions: s.decisions,
                curve_cache: s.curve_cache.stats,
            })
            .collect()
    }

    /// Arbitration phase: arbitrated services observe their rate history,
    /// predict λ̂, and report value curves; the arbiter water-fills the
    /// global budget.  Returns `None` per service when the engine has no
    /// arbiter (every policy keeps its own budget).
    fn arbitrate(
        &self,
        services: &mut [FleetService],
        st: &mut [SvcState],
        histories: &[Vec<f64>],
        committed: &[BTreeMap<String, usize>],
    ) -> Vec<Option<usize>> {
        let Some(arb) = &self.arbiter else {
            return vec![None; services.len()];
        };
        let floors_sum: usize = services.iter().map(|s| s.floor_cores).sum();
        let mut entries = Vec::with_capacity(services.len());
        for (i, s) in services.iter_mut().enumerate() {
            let floor = s.floor_cores;
            let priority = s.priority;
            let tier = s.tier;
            // Rolling SLO-burn signal: the arbiter boosts burning
            // services' marginals (inert at the default burn_boost = 0).
            let burn = st[i].burn.burn_rate();
            let entry = match &mut s.policy {
                FleetPolicyRef::Plain(_) => ArbiterEntry {
                    priority,
                    tier,
                    burn,
                    floor,
                    curve: None,
                },
                FleetPolicyRef::Arbitrated(p) => {
                    let lambda = p.observe_and_predict(&histories[i]);
                    st[i].pending_lambda = lambda;
                    // The most this service could ever be granted: the
                    // whole budget minus everyone else's floors.
                    let cap = arb.global_budget.saturating_sub(floors_sum - floor);
                    // Cross-tick cache: exact hit skips the solve, a
                    // same-bin λ̂ wobble warm-starts it; the curve values
                    // are bit-identical to an uncached solve either way.
                    let curve = st[i].curve_cache.curve(&**p, lambda, &committed[i], cap);
                    ArbiterEntry {
                        priority,
                        tier,
                        burn,
                        floor,
                        curve: Some(curve),
                    }
                }
            };
            entries.push(entry);
        }
        arb.partition(&entries).into_iter().map(Some).collect()
    }
}

/// Re-size every service's admission gate from its *committed* allocation:
/// supply = Σ per-variant `th_m(n, b)` over the pods the cluster is
/// actually holding for the service (Pending + Ready), at the batch sizes
/// in force — the "granted capacity" the token bucket refills at.  Called
/// at the warm start and every adaptation tick; a no-op fast path when no
/// gate is enabled keeps the default run untouched.
fn refresh_gates(cluster: &Cluster, services: &[FleetService], st: &mut [SvcState], now: f64) {
    if !st.iter().any(|s| s.path.gate().enabled()) {
        return;
    }
    let committed = cluster.committed_allocation();
    for i in 0..st.len() {
        let alloc: BTreeMap<String, usize> = committed
            .iter()
            .filter(|(k, _)| owner_of(st, k) == i)
            .map(|(k, &c)| (k[st[i].prefix.len()..].to_string(), c))
            .collect();
        let supply = services[i]
            .profiles
            .supply_rps(&alloc, &st[i].current_batches);
        st[i].path.set_supply(now, supply);
    }
}

/// Decision phase: every service solves inside its grant (arbitrated) or
/// decides with its own fixed budget (plain / no arbiter).
fn decide_all(
    now: f64,
    services: &mut [FleetService],
    st: &[SvcState],
    histories: &[Vec<f64>],
    committed: &[BTreeMap<String, usize>],
    grants: &[Option<usize>],
) -> Vec<Decision> {
    services
        .iter_mut()
        .enumerate()
        .map(|(i, s)| match &mut s.policy {
            FleetPolicyRef::Plain(p) => {
                let d = p.decide(now, &histories[i], &committed[i]);
                // Under arbitration a plain service's floor is its whole
                // reservation — the arbiter partitioned the rest of the
                // budget assuming it.  A policy that targets more quietly
                // oversubscribes the cluster; catch the misconfiguration
                // in debug builds.
                if let Some(g) = grants[i] {
                    debug_assert!(
                        d.target.values().sum::<usize>() <= g,
                        "plain service {} targets {} cores but is reserved only {} \
                         of the arbitrated budget",
                        s.name,
                        d.target.values().sum::<usize>(),
                        g
                    );
                }
                d
            }
            FleetPolicyRef::Arbitrated(p) => match grants[i] {
                Some(g) => {
                    p.budget = g;
                    p.decide_with_lambda(st[i].pending_lambda, &committed[i])
                }
                None => p.decide(now, &histories[i], &committed[i]),
            },
        })
        .collect()
}

/// Cluster-facing variant key of a service's variant.
fn namespaced(prefix: &str, variant: &str) -> String {
    if prefix.is_empty() {
        variant.to_string()
    } else {
        format!("{prefix}{variant}")
    }
}

/// Which service owns a cluster variant key.  Prefixes end in `/` and
/// names are slash-free, so matches are unambiguous; the empty prefix
/// (single-service compatibility path) owns everything.
fn owner_of(st: &[SvcState], key: &str) -> usize {
    st.iter()
        .position(|s| !s.prefix.is_empty() && key.starts_with(&s.prefix))
        .unwrap_or(0)
}

/// Union of every service's namespaced target (the shared cluster's
/// reconciliation goal; keys absent from the union are drained).
fn merged_target(st: &[SvcState], decisions: &[Decision]) -> BTreeMap<String, usize> {
    let mut merged = BTreeMap::new();
    for (s, d) in st.iter().zip(decisions) {
        for (v, &c) in &d.target {
            merged.insert(namespaced(&s.prefix, v), c);
        }
    }
    merged
}

/// Readiness time of a namespaced variant key (owner's profile).
fn readiness_of(services: &[FleetService], st: &[SvcState], key: &str) -> f64 {
    let i = owner_of(st, key);
    let raw = &key[st[i].prefix.len()..];
    services[i]
        .profiles
        .get(raw)
        .map(|p| p.readiness_s)
        .unwrap_or(10.0)
}

/// Cores billed to one service right now (its share of the shared bill).
fn billed_of(cluster: &Cluster, st: &[SvcState], i: usize) -> usize {
    cluster
        .pods()
        .iter()
        .filter(|p| p.is_billed() && owner_of(st, &p.variant) == i)
        .map(|p| p.cores)
        .sum()
}

/// Sample every service's billed cores at `now` — but only inside that
/// service's own metric window `[0, duration]`.  Traces of different
/// lengths share one clock: cluster ticks run to the fleet-wide maximum,
/// and a sample past a short service's end would otherwise be integrated
/// by `MetricsCollector::summary` (which normalizes by the service's own
/// duration), inflating its average cost.
fn record_costs(cluster: &Cluster, st: &mut [SvcState], now: f64) {
    for i in 0..st.len() {
        if now > st[i].duration {
            continue;
        }
        let billed = billed_of(cluster, st, i);
        st[i].metrics.record_cost(now, billed);
    }
}

fn acc_of(profiles: &ProfileSet, variant: &str) -> f64 {
    profiles.get(variant).map(|p| p.accuracy).unwrap_or(0.0)
}

/// Draw one service time for a batch of `batch` requests on a variant
/// (lognormal around the amortized mean; `batch = 1` is the plain
/// measured service time).
fn sample_service_batch(
    profiles: &ProfileSet,
    variant: &str,
    batch: usize,
    rng: &mut Rng,
) -> f64 {
    let p = profiles.get(variant).expect("unknown variant");
    rng.lognormal_mean(p.service_time_batch(batch), p.service_sigma.max(1e-6))
}

/// Add one routed request to a pod: it joins the forming batch, which
/// dispatches when full (immediately at `max_batch = 1`); opening a fresh
/// batch arms the formation timeout.
#[allow(clippy::too_many_arguments)]
fn enqueue_request(
    profiles: &ProfileSet,
    batch_max_wait_s: f64,
    pod_id: u64,
    rid: usize,
    now: f64,
    pods: &mut HashMap<u64, PodSim>,
    batches: &mut Vec<Vec<usize>>,
    heap: &mut BinaryHeap<Reverse<Event>>,
    seq: &mut u64,
    rng: &mut Rng,
) {
    let pod = pods.get_mut(&pod_id).expect("routed to unknown pod");
    pod.forming.push(rid);
    pod.waiting += 1;
    if pod.forming.len() >= pod.max_batch {
        let items = std::mem::take(&mut pod.forming);
        pod.forming_seq += 1;
        dispatch_batch(profiles, pod, pod_id, items, now, batches, heap, seq, rng);
    } else if pod.forming.len() == 1 {
        push_event(
            heap,
            seq,
            now + batch_max_wait_s,
            EventKind::BatchTimeout {
                pod_id,
                forming_seq: pod.forming_seq,
            },
        );
    }
}

/// Hand a formed batch to the pod: one service draw on a free core, or
/// the formed-batch queue when all cores are busy.
#[allow(clippy::too_many_arguments)]
fn dispatch_batch(
    profiles: &ProfileSet,
    pod: &mut PodSim,
    pod_id: u64,
    items: Vec<usize>,
    now: f64,
    batches: &mut Vec<Vec<usize>>,
    heap: &mut BinaryHeap<Reverse<Event>>,
    seq: &mut u64,
    rng: &mut Rng,
) {
    let bid = batches.len();
    batches.push(items);
    if pod.busy < pod.cores {
        pod.busy += 1;
        pod.waiting = pod.waiting.saturating_sub(batches[bid].len());
        let stime = sample_service_batch(profiles, &pod.variant, batches[bid].len(), rng);
        push_event(
            heap,
            seq,
            now + stime,
            EventKind::Completion { pod_id, batch: bid },
        );
    } else {
        pod.queue.push_back(bid);
    }
}

/// Least-loaded ready pod of a namespaced variant key.
fn pick_pod(cluster: &Cluster, pods: &HashMap<u64, PodSim>, key: &str) -> Option<u64> {
    cluster
        .ready_pods_of(key)
        .iter()
        .filter_map(|p| pods.get(&p.id).map(|ps| (p.id, ps)))
        .min_by(|a, b| a.1.load().total_cmp(&b.1.load()))
        .map(|(id, _)| id)
}

/// Any ready pod of the service (fallback when the chosen variant has
/// none yet).
fn any_pod(cluster: &Cluster, pods: &HashMap<u64, PodSim>, svc: usize) -> Option<u64> {
    cluster
        .pods()
        .iter()
        .filter(|p| p.is_ready())
        .filter_map(|p| pods.get(&p.id).map(|ps| (p.id, ps)))
        .filter(|(_, ps)| ps.svc == svc)
        .min_by(|a, b| a.1.load().total_cmp(&b.1.load()))
        .map(|(id, _)| id)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::InfAdapterPolicy;
    use crate::baselines::StaticPolicy;
    use crate::config::ObjectiveWeights;
    use crate::forecaster::LastMaxForecaster;
    use crate::serving::sim::SimEngine;
    use crate::solver::BranchBoundSolver;
    use crate::workload::Trace;

    fn inf_policy(budget: usize) -> InfAdapterPolicy {
        InfAdapterPolicy::new(
            ProfileSet::paper_like(),
            Box::new(LastMaxForecaster::new(120, 1.0)),
            Box::new(BranchBoundSolver),
            ObjectiveWeights::default(),
            0.75,
            budget,
            1.1,
        )
    }

    /// The ISSUE acceptance criterion: a single service run through the
    /// fleet path (arbiter on, namespaced pods, per-service RNG streams)
    /// is bit-identical to the pre-fleet single-adapter path.
    #[test]
    fn single_service_fleet_matches_single_adapter_path() {
        let profiles = ProfileSet::paper_like();
        let trace = Trace::bursty(40.0, 100.0, 420, 9);
        let config = SimConfig {
            seed: 9,
            ..Default::default()
        };
        let mut p1 = inf_policy(20);
        let base = SimEngine::new(profiles.clone(), config.clone()).run(&mut p1, &trace);
        let mut p2 = inf_policy(20);
        let mut services = [FleetService {
            name: "svc0".into(),
            trace: &trace,
            profiles: profiles.clone(),
            slo_s: 0.75,
            priority: 1.0,
            tier: 0,
            error_budget: 0.01,
            floor_cores: 0,
            policy: FleetPolicyRef::Arbitrated(&mut p2),
        }];
        let fleet = FleetSimEngine::new(config, Some(CoreArbiter::new(20))).run(&mut services);
        let a = base.metrics.summary("single", base.duration_s);
        let b = fleet[0].metrics.summary("fleet", fleet[0].duration_s);
        assert_eq!(a.total_requests, b.total_requests);
        assert_eq!(a.dropped, b.dropped);
        assert_eq!(a.p99_latency_s, b.p99_latency_s);
        assert_eq!(a.p50_latency_s, b.p50_latency_s);
        assert_eq!(a.mean_latency_s, b.mean_latency_s);
        assert_eq!(a.slo_violation_rate, b.slo_violation_rate);
        assert_eq!(a.avg_accuracy, b.avg_accuracy);
        assert_eq!(a.core_seconds, b.core_seconds);
        assert_eq!(base.decisions.len(), fleet[0].decisions.len());
        for ((t1, d1), (t2, d2)) in base.decisions.iter().zip(&fleet[0].decisions) {
            assert_eq!(t1, t2);
            assert_eq!(d1.target, d2.target);
            assert_eq!(d1.quotas, d2.quotas);
            assert_eq!(d1.predicted_lambda, d2.predicted_lambda);
        }
    }

    #[test]
    fn multi_service_fleet_is_deterministic_per_seed() {
        let profiles = ProfileSet::paper_like();
        let ta = Trace::burst_window(30.0, 120.0, 300, 60, 80, 4);
        let tb = Trace::burst_window(30.0, 120.0, 300, 180, 80, 5);
        let run = || {
            let mut pa = inf_policy(6);
            let mut pb = inf_policy(6);
            let mut services = [
                FleetService {
                    name: "a".into(),
                    trace: &ta,
                    profiles: profiles.clone(),
                    slo_s: 0.75,
                    priority: 1.0,
                    tier: 0,
                    error_budget: 0.01,
                    floor_cores: 1,
                    policy: FleetPolicyRef::Arbitrated(&mut pa),
                },
                FleetService {
                    name: "b".into(),
                    trace: &tb,
                    profiles: profiles.clone(),
                    slo_s: 0.4,
                    priority: 1.0,
                    tier: 0,
                    error_budget: 0.01,
                    floor_cores: 1,
                    policy: FleetPolicyRef::Arbitrated(&mut pb),
                },
            ];
            let cfg = SimConfig {
                seed: 21,
                ..Default::default()
            };
            FleetSimEngine::new(cfg, Some(CoreArbiter::new(12))).run(&mut services)
        };
        let r1 = run();
        let r2 = run();
        for (x, y) in r1.iter().zip(&r2) {
            let sx = x.metrics.summary("x", x.duration_s);
            let sy = y.metrics.summary("y", y.duration_s);
            assert_eq!(sx.total_requests, sy.total_requests);
            assert_eq!(sx.p99_latency_s, sy.p99_latency_s);
            assert_eq!(sx.core_seconds, sy.core_seconds);
            assert!(sx.total_requests > 0);
        }
    }

    #[test]
    fn plain_services_share_the_cluster_without_interfering() {
        // Two static services on one cluster: each keeps its own pods and
        // serves only its own trace.
        let profiles = ProfileSet::paper_like();
        let ta = Trace::steady(30.0, 120);
        let tb = Trace::steady(10.0, 120);
        let mut pa = StaticPolicy::new("resnet18", 4);
        let mut pb = StaticPolicy::new("resnet50", 4);
        let mut services = [
            FleetService {
                name: "a".into(),
                trace: &ta,
                profiles: profiles.clone(),
                slo_s: 0.75,
                priority: 1.0,
                tier: 0,
                error_budget: 0.01,
                floor_cores: 4,
                policy: FleetPolicyRef::Plain(&mut pa),
            },
            FleetService {
                name: "b".into(),
                trace: &tb,
                profiles: profiles.clone(),
                slo_s: 0.75,
                priority: 1.0,
                tier: 0,
                error_budget: 0.01,
                floor_cores: 4,
                policy: FleetPolicyRef::Plain(&mut pb),
            },
        ];
        let cfg = SimConfig {
            seed: 3,
            ..Default::default()
        };
        let results = FleetSimEngine::new(cfg, None).run(&mut services);
        let sa = results[0].metrics.summary("a", 120.0);
        let sb = results[1].metrics.summary("b", 120.0);
        assert!(sa.total_requests > 3000, "{sa:?}");
        assert!(sb.total_requests > 900, "{sb:?}");
        assert_eq!(sa.dropped, 0);
        assert_eq!(sb.dropped, 0);
        // service a runs resnet18 (69.76), service b resnet50 (76.13):
        // routing never leaks across the namespace boundary
        assert!((sa.avg_accuracy - 69.76).abs() < 1e-6, "{sa:?}");
        assert!((sb.avg_accuracy - 76.13).abs() < 1e-6, "{sb:?}");
        // both bills stay near the static allocations
        assert!((sa.avg_cost_cores - 4.0).abs() < 0.5, "{sa:?}");
        assert!((sb.avg_cost_cores - 4.0).abs() < 0.5, "{sb:?}");
    }

    #[test]
    fn short_trace_service_cost_normalizes_over_its_own_window() {
        // Services with different trace lengths share one clock: the
        // cluster keeps ticking (and billing) until the fleet-wide
        // maximum, but a service's summary normalizes by its *own*
        // duration — cost samples past its end must not be integrated
        // (they would report 4 cores over 100 s as ~16 avg cores here).
        let profiles = ProfileSet::paper_like();
        let ta = Trace::steady(20.0, 100);
        let tb = Trace::steady(20.0, 400);
        let mut pa = StaticPolicy::new("resnet18", 4);
        let mut pb = StaticPolicy::new("resnet18", 4);
        let mut services = [
            FleetService {
                name: "short".into(),
                trace: &ta,
                profiles: profiles.clone(),
                slo_s: 0.75,
                priority: 1.0,
                tier: 0,
                error_budget: 0.01,
                floor_cores: 4,
                policy: FleetPolicyRef::Plain(&mut pa),
            },
            FleetService {
                name: "long".into(),
                trace: &tb,
                profiles: profiles.clone(),
                slo_s: 0.75,
                priority: 1.0,
                tier: 0,
                error_budget: 0.01,
                floor_cores: 4,
                policy: FleetPolicyRef::Plain(&mut pb),
            },
        ];
        let cfg = SimConfig {
            seed: 8,
            ..Default::default()
        };
        let results = FleetSimEngine::new(cfg, None).run(&mut services);
        let short = results[0].metrics.summary("short", results[0].duration_s);
        let long = results[1].metrics.summary("long", results[1].duration_s);
        assert!((short.avg_cost_cores - 4.0).abs() < 0.5, "{short:?}");
        assert!((long.avg_cost_cores - 4.0).abs() < 0.5, "{long:?}");
    }

    #[test]
    fn steady_state_ticks_reuse_cached_curves() {
        // Two steady services under arbitration: one curve solve per
        // service per arbitration tick (t=0 plus every interval), and the
        // stable λ̂ / committed cores must produce exact hits or warm
        // starts — the steady-state tick must not re-solve cold forever.
        let profiles = ProfileSet::paper_like();
        let ta = Trace::steady(30.0, 600);
        let tb = Trace::steady(20.0, 600);
        let mut pa = inf_policy(6);
        let mut pb = inf_policy(6);
        let mut services = [
            FleetService {
                name: "a".into(),
                trace: &ta,
                profiles: profiles.clone(),
                slo_s: 0.75,
                priority: 1.0,
                tier: 0,
                error_budget: 0.01,
                floor_cores: 1,
                policy: FleetPolicyRef::Arbitrated(&mut pa),
            },
            FleetService {
                name: "b".into(),
                trace: &tb,
                profiles: profiles.clone(),
                slo_s: 0.75,
                priority: 1.0,
                tier: 0,
                error_budget: 0.01,
                floor_cores: 1,
                policy: FleetPolicyRef::Arbitrated(&mut pb),
            },
        ];
        let cfg = SimConfig {
            seed: 5,
            ..Default::default()
        };
        let results = FleetSimEngine::new(cfg, Some(CoreArbiter::new(12))).run(&mut services);
        // arbitration runs at t=0 and every 30 s tick inside (0, 600)
        let ticks = 1 + (600.0f64 / 30.0).ceil() as u64 - 1;
        for r in &results {
            let cc = &r.curve_cache;
            assert_eq!(cc.total(), ticks, "{cc:?}");
            assert!(cc.cold >= 1, "first tick is always cold: {cc:?}");
            assert!(
                cc.hits + cc.warm > 0,
                "steady state must reuse curves: {cc:?}"
            );
        }
        // the plain single-service path never touches the cache
        let mut p = inf_policy(20);
        let single = SimEngine::new(ProfileSet::paper_like(), SimConfig::default())
            .run(&mut p, &Trace::steady(30.0, 120));
        assert_eq!(single.curve_cache.total(), 0);
    }

    #[test]
    fn admission_off_is_the_default_and_admitting_gates_are_bit_identical() {
        // Two pins in one: (1) a run with the gate *enabled but never
        // binding* (offered load far under the granted supply) performs
        // the same event sequence and RNG draws as the default
        // admission-off run — summaries equal field for field; (2) it
        // sheds nothing.
        use crate::config::AdmissionConfig;
        let profiles = ProfileSet::paper_like();
        let trace = Trace::steady(40.0, 180);
        let run = |admission: AdmissionConfig| {
            let mut policy = StaticPolicy::new("resnet18", 4);
            let mut services = [FleetService {
                name: "svc".into(),
                trace: &trace,
                profiles: profiles.clone(),
                slo_s: 0.75,
                priority: 1.0,
                tier: 0,
                error_budget: 0.01,
                floor_cores: 4,
                policy: FleetPolicyRef::Plain(&mut policy),
            }];
            let cfg = SimConfig {
                seed: 33,
                admission,
                ..Default::default()
            };
            FleetSimEngine::new(cfg, None)
                .run(&mut services)
                .pop()
                .unwrap()
        };
        let off = run(AdmissionConfig::default());
        let on = run(AdmissionConfig {
            enabled: true,
            ..Default::default()
        });
        let a = off.metrics.summary("off", off.duration_s);
        let b = on.metrics.summary("on", on.duration_s);
        assert_eq!(b.shed, 0, "under-capacity gate must not shed: {b:?}");
        assert_eq!(a.total_requests, b.total_requests);
        assert_eq!(a.dropped, b.dropped);
        assert_eq!(a.p99_latency_s, b.p99_latency_s);
        assert_eq!(a.mean_latency_s, b.mean_latency_s);
        assert_eq!(a.slo_violation_rate, b.slo_violation_rate);
        assert_eq!(a.avg_accuracy, b.avg_accuracy);
        assert_eq!(a.core_seconds, b.core_seconds);
    }

    #[test]
    fn admission_sheds_overload_instead_of_violating_every_slo() {
        // A static pod holding ~92 rps of supply is offered 250 rps.
        // Without admission the queue blows through (nearly) every
        // request's SLO; with the gate the excess is shed at the door and
        // the admitted stream keeps meeting its SLO.
        use crate::config::AdmissionConfig;
        let profiles = ProfileSet::paper_like();
        let trace = Trace::steady(250.0, 240);
        let run = |enabled: bool| {
            let mut policy = StaticPolicy::new("resnet18", 4);
            let mut services = [FleetService {
                name: "svc".into(),
                trace: &trace,
                profiles: profiles.clone(),
                slo_s: 0.75,
                priority: 1.0,
                tier: 0,
                error_budget: 0.01,
                floor_cores: 4,
                policy: FleetPolicyRef::Plain(&mut policy),
            }];
            let cfg = SimConfig {
                seed: 44,
                admission: AdmissionConfig {
                    enabled,
                    ..Default::default()
                },
                ..Default::default()
            };
            let r = FleetSimEngine::new(cfg, None)
                .run(&mut services)
                .pop()
                .unwrap();
            r.metrics.summary(if enabled { "on" } else { "off" }, r.duration_s)
        };
        let off = run(false);
        let on = run(true);
        assert_eq!(off.shed, 0);
        assert!(
            off.slo_violation_rate > 0.5,
            "unshed overload must drown: {off:?}"
        );
        assert!(on.shed > 0, "{on:?}");
        // the gate admits ~supply/offered ≈ 37%: the rest is refused
        let shed_frac = on.shed as f64 / on.total_requests as f64;
        assert!((0.4..0.9).contains(&shed_frac), "shed {shed_frac}");
        assert!(
            on.slo_violation_rate < 0.2,
            "admitted stream must be protected: {on:?}"
        );
        assert!(
            on.goodput_admitted_rps > off.goodput_rps,
            "shedding must raise useful throughput: {} vs {}",
            on.goodput_admitted_rps,
            off.goodput_rps
        );
    }

    #[test]
    fn class_mix_sheds_lower_tier_requests_first() {
        // One service, 70% tier-0 / 30% tier-1 requests, 2.5x overload:
        // the gate's cutoff must push the shedding onto tier 1.
        use crate::config::AdmissionConfig;
        let profiles = ProfileSet::paper_like();
        let trace =
            Trace::steady(250.0, 240).with_class_mix(vec![(0, 7.0), (1, 3.0)]);
        let mut policy = StaticPolicy::new("resnet18", 4);
        let mut services = [FleetService {
            name: "svc".into(),
            trace: &trace,
            profiles: profiles.clone(),
            slo_s: 0.75,
            priority: 1.0,
            tier: 0,
            error_budget: 0.01,
            floor_cores: 4,
            policy: FleetPolicyRef::Plain(&mut policy),
        }];
        let cfg = SimConfig {
            seed: 45,
            admission: AdmissionConfig {
                enabled: true,
                ..Default::default()
            },
            ..Default::default()
        };
        let r = FleetSimEngine::new(cfg, None)
            .run(&mut services)
            .pop()
            .unwrap();
        let s = r.metrics.summary("mix", r.duration_s);
        assert_eq!(s.tiers.len(), 2, "{:?}", s.tiers);
        let t0 = &s.tiers[0];
        let t1 = &s.tiers[1];
        // tier 1 (30% of 250 = 75 rps offered) is shed almost entirely;
        // tier 0 (175 rps offered vs ~92 rps supply) sheds only its own
        // excess — a strictly smaller *fraction* than tier 1
        let f0 = t0.shed as f64 / t0.total.max(1) as f64;
        let f1 = t1.shed as f64 / t1.total.max(1) as f64;
        assert!(f1 > 0.9, "tier 1 shed fraction {f1}: {t1:?}");
        assert!(f0 < f1, "lowest tier must shed first: {f0} vs {f1}");
        assert!(t0.served > 0);
    }

    #[test]
    fn arbiter_shifts_cores_toward_the_bursting_service() {
        // Service a bursts in [60, 180); b stays quiet.  Under arbitration
        // a's grant during its burst must exceed the even share, and its
        // solved allocation must actually use more than the share.
        let profiles = ProfileSet::paper_like();
        let ta = Trace::burst_window(30.0, 150.0, 360, 60, 120, 11);
        let tb = Trace::steady(30.0, 360);
        let mut pa = inf_policy(6);
        let mut pb = inf_policy(6);
        let mut services = [
            FleetService {
                name: "a".into(),
                trace: &ta,
                profiles: profiles.clone(),
                slo_s: 0.75,
                priority: 1.0,
                tier: 0,
                error_budget: 0.01,
                floor_cores: 2,
                policy: FleetPolicyRef::Arbitrated(&mut pa),
            },
            FleetService {
                name: "b".into(),
                trace: &tb,
                profiles: profiles.clone(),
                slo_s: 0.75,
                priority: 1.0,
                tier: 0,
                error_budget: 0.01,
                floor_cores: 2,
                policy: FleetPolicyRef::Arbitrated(&mut pb),
            },
        ];
        let cfg = SimConfig {
            seed: 13,
            ..Default::default()
        };
        let results = FleetSimEngine::new(cfg, Some(CoreArbiter::new(12))).run(&mut services);
        // find service a's decision right inside the burst window
        let in_burst = results[0]
            .decisions
            .iter()
            .find(|(t, _)| (90.0..180.0).contains(t))
            .map(|(_, d)| d.target.values().sum::<usize>())
            .expect("a decision inside the burst");
        assert!(in_burst > 6, "burst grant should exceed the even share, got {in_burst}");
    }
}
