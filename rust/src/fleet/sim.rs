//! Multi-service virtual-time serving simulator — the fleet orchestrator.
//!
//! Generalizes the single-adapter discrete-event loop (see
//! [`crate::serving::sim`], which is a thin single-service wrapper around
//! this engine) to N independent services sharing one [`Cluster`].  The
//! data plane is *sharded*: each service's trace stream, RNG, admission
//! gate, dispatcher, pods view, metrics, and event wheel live in its own
//! [`ServiceShard`] (see [`super::shard`]), and this module is only the
//! orchestrator driving the five-stage tick protocol at every adaptation
//! boundary:
//!
//! ```text
//!             │ shards advance own event wheels to the boundary│
//!   advance ──┤  (parallel; disjoint per-service state)        │
//!             ▼
//!   observe ── flush rate windows + SLO-burn meters  (serial, index order)
//!             ▼
//!   solve ──── forecast λ̂ + value-curve solves       (parallel, worker pool)
//!             ▼
//!   arbitrate─ water-fill the global core budget      (serial, index order)
//!             ▼
//!   apply ──── decide inside grants, reconcile pods   (decide parallel,
//!             ▼                                        cluster apply serial)
//!   advance ── … next interval
//! ```
//!
//! * **Shared substrate** — one node pool and one virtual clock.  Pods are
//!   namespaced on the cluster as `"<service>/<variant>"` so services
//!   never collide; placement, readiness, and create-before-remove work
//!   exactly as before.  Between boundaries the cluster is only *read*
//!   (routing looks at pod readiness), so shards advance concurrently.
//! * **Per-service everything else** — arrival timestamps and
//!   service-time noise come from per-service RNG streams: service `i`
//!   draws from `seed + i·SPLITMIX_GAMMA` (arrivals from that value + 1),
//!   so a fixed seed is deterministic regardless of how the services'
//!   events interleave, and service 0's streams equal the single-engine
//!   streams.
//! * **Arbitration** — when the engine holds a [`CoreArbiter`], every
//!   adaptation interval runs solve → arbitrate → apply: each arbitrated
//!   service observes its rate history and predicts λ̂, reports a value
//!   curve over candidate core grants — one single-pass solve
//!   ([`InfAdapterPolicy::value_curve_seeded`]) behind a per-shard
//!   cross-tick [`super::CurveCache`] — and the arbiter water-fills the
//!   global budget, each service then solving its own variant/batch
//!   selection inside its grant.  Without an arbiter every service keeps
//!   its configured budget (the "static split" baseline).
//!
//! **Bit-identity invariants.**  (1) A single-service fleet performs the
//! same cluster operations, heap pushes, and RNG draws in the same order
//! as the pre-fleet single-adapter engine
//! (`single_service_fleet_matches_single_adapter_path` pins it).  (2) A
//! parallel run is bit-identical to the serial run at *every* thread
//! count: the parallel stages (advance, solve, decide) only touch
//! disjoint per-shard state, and every fan-in reads results back in
//! service-index order, never in thread-completion order — so worker
//! scheduling cannot reach any output
//! (`parallel_fleet_is_bit_identical_to_serial` in
//! `tests/regression_pins.rs` pins it).  The old single-heap engine's
//! global `(t, seq)` event order is reproduced exactly: within a shard by
//! the shard's own timer wheel (whose pop order is provably the heap's —
//! see [`crate::util::sched`]), across shards by the boundary admission rule in
//! [`ServiceShard::advance`] (arrivals at a boundary run before it,
//! runtime events after it — matching the global engine's init-time vs
//! runtime sequence numbers), and boundary times themselves by the same
//! float accumulation (`+= 1.0` / `+= interval`) the old engine used when
//! seeding its tick events.

use super::arbiter::{ArbiterEntry, CoreArbiter};
use super::shard::{namespaced, parallel_zip, ServiceShard};
use crate::adapter::InfAdapterPolicy;
use crate::cluster::{Cluster, ClusterEvent};
use crate::dispatcher::Tier;
use crate::fault::{FaultPlane, SolveOutcome};
use crate::profiler::ProfileSet;
use crate::replay::{Recorder, ServiceRecord};
use crate::serving::sim::{SimConfig, SimResult};
use crate::serving::{Decision, Policy};
use crate::telemetry::{
    curve_knee, FleetTelemetry, ServiceTick, TelemetrySummary, TickTrace, STAGE_ADVANCE,
    STAGE_APPLY, STAGE_ARBITRATE, STAGE_DISPATCH, STAGE_OBSERVE, STAGE_SOLVE,
};
use crate::util::pool::WorkerPool;
use crate::workload::{ArrivalProcess, RateSeries};
use std::collections::BTreeMap;
use std::time::Instant;

/// Seed of service `i`'s RNG stream.  Service 0 uses the base seed
/// unchanged — a single-service fleet reproduces the single-adapter engine
/// draw for draw — and later services hop by the SplitMix64 constant so
/// streams never collide.  Service `i` owns the pair
/// (`service_seed(base, i)` for service-time noise,
/// `service_seed(base, i) + 1` for arrivals); anything else deriving
/// per-service seeds from the same base (e.g. trace generators, see
/// [`super::FleetScenario`]) must offset past that pair.  Public so
/// conservation tests can regenerate a service's exact arrival stream
/// (`prop_shed_conservation` counts ground-truth arrivals from it).
pub fn service_seed(base: u64, i: usize) -> u64 {
    base.wrapping_add((i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// Offset of service `i`'s fault stream above `service_seed(base, i)`.
/// The pair +0/+1 belongs to noise/arrivals (see [`service_seed`]) and +2
/// to the scenario trace generators; the fault plane takes +3 so arming
/// it never perturbs any other stream — the root of the faults-off
/// bit-identity pin.
pub const FAULT_STREAM_OFFSET: u64 = 3;

/// One service of a fleet run: the adaptation policy plus everything it
/// serves (trace, profiles, SLO) and its arbitration terms.
pub struct FleetService<'a> {
    /// Service name; namespaces this service's pods on the shared cluster
    /// as `"<name>/<variant>"`.  May be empty only in a single-service
    /// fleet (the unprefixed single-adapter compatibility path); must not
    /// contain `/`.
    pub name: String,
    pub trace: &'a RateSeries,
    pub profiles: ProfileSet,
    /// Latency SLO for this service's metrics accounting, seconds.
    pub slo_s: f64,
    /// Arbitration weight (higher claims marginal cores first).
    pub priority: f64,
    /// Strict priority tier (0 = most important): a lexicographic
    /// pre-pass in the arbiter, and the default per-request tier when the
    /// trace carries no class mix.
    pub tier: Tier,
    /// Allowed SLO-violation fraction — the burn-rate signal denominator.
    pub error_budget: f64,
    /// Guaranteed-minimum core grant under arbitration; also the fixed
    /// reservation of a [`FleetPolicyRef::Plain`] service.
    pub floor_cores: usize,
    pub policy: FleetPolicyRef<'a>,
}

/// How a service's policy participates in the fleet.
pub enum FleetPolicyRef<'a> {
    /// Fixed-budget policy (VPA+, MS+, static, or an InfAdapter holding a
    /// static share): its decisions are used as-is and it stays outside
    /// arbitration.  Under an arbiter it is expected to stay within its
    /// `floor_cores` reservation (checked in debug builds — the arbiter
    /// partitions the rest of the budget assuming it).
    Plain(&'a mut dyn Policy),
    /// An InfAdapter whose core budget is re-set to the arbiter's grant
    /// every adaptation interval.  With no arbiter on the engine it keeps
    /// its configured budget (the "static split" baseline) and behaves
    /// exactly like `Plain`.
    Arbitrated(&'a mut InfAdapterPolicy),
}

/// The multi-service engine.
pub struct FleetSimEngine {
    pub config: SimConfig,
    /// `None`: every service keeps its own fixed budget (static split).
    pub arbiter: Option<CoreArbiter>,
}

/// Worker count for the parallel stages: the configured value, with `0`
/// meaning "auto" (the machine's available parallelism), clamped to the
/// service count.  `1` is the serial reference path — no threads are ever
/// spawned (so the N=1 single-adapter wrapper stays thread-free).  The
/// resolved value never influences results, only wall-clock.
fn effective_threads(configured: usize, n: usize) -> usize {
    let t = if configured == 0 {
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
    } else {
        configured
    };
    t.min(n)
}

/// Serial stage stopwatch for the tick profiler: `lap(stage)` charges the
/// span since the previous lap to that stage.  Inert (never reads the
/// clock) when telemetry is off, so the disabled path does no timing work
/// at all — and since timing is only ever *recorded*, never consulted,
/// the enabled path cannot diverge either.
struct StageClock {
    enabled: bool,
    t: Option<Instant>,
    ns: [u64; 6],
}

impl StageClock {
    fn start(enabled: bool) -> Self {
        Self {
            enabled,
            t: enabled.then(Instant::now),
            ns: [0; 6],
        }
    }

    fn lap(&mut self, stage: usize) {
        if !self.enabled {
            return;
        }
        let now = Instant::now();
        if let Some(prev) = self.t {
            self.ns[stage] += now.duration_since(prev).as_nanos() as u64;
        }
        self.t = Some(now);
    }

    /// Like [`Self::lap`], but carves `dispatch_ns` — worker-pool fan-out
    /// overhead the pool measured inside this span — out of `stage` and
    /// charges it to the dispatch lap, so the parallel stages' histograms
    /// measure solver/simulation work rather than thread machinery.
    fn lap_split(&mut self, stage: usize, dispatch_ns: u64) {
        if !self.enabled {
            return;
        }
        let now = Instant::now();
        if let Some(prev) = self.t {
            let span = now.duration_since(prev).as_nanos() as u64;
            let d = dispatch_ns.min(span);
            self.ns[stage] += span - d;
            self.ns[STAGE_DISPATCH] += d;
        }
        self.t = Some(now);
    }
}

impl FleetSimEngine {
    pub fn new(config: SimConfig, arbiter: Option<CoreArbiter>) -> Self {
        Self { config, arbiter }
    }

    /// Run every service's event stream against the shared cluster;
    /// returns one [`SimResult`] per service, in input order.
    pub fn run(&self, services: &mut [FleetService]) -> Vec<SimResult> {
        self.run_with_telemetry(services).0
    }

    /// [`Self::run`], additionally returning the engine-level telemetry
    /// (stage profiler, flight recorder, merged counters) when
    /// `SimConfig::telemetry` enables it.  The returned results are
    /// bit-identical to a telemetry-off run — the plane is a pure
    /// observer (pinned by `telemetry_on_is_bit_identical_to_off`).
    pub fn run_with_telemetry(
        &self,
        services: &mut [FleetService],
    ) -> (Vec<SimResult>, Option<FleetTelemetry>) {
        self.run_traced(services, None)
    }

    /// [`Self::run_with_telemetry`] with an optional [`Recorder`] sink for
    /// deterministic record/replay.  The record hooks live only at the
    /// serial tick boundaries (warm start, cluster boundary's fault draws,
    /// adapter boundary) and read state the stages already computed — no
    /// RNG draws, no mutation — so `None` here is bit-identical to the
    /// pre-replay engine and `Some` is bit-identical to `None` (pinned by
    /// `recording_is_a_pure_observer`), and a recorded trace is
    /// independent of `solver_threads`.
    pub fn run_traced(
        &self,
        services: &mut [FleetService],
        mut recorder: Option<&mut Recorder>,
    ) -> (Vec<SimResult>, Option<FleetTelemetry>) {
        let cfg = &self.config;
        let n = services.len();
        assert!(n > 0, "a fleet needs at least one service");
        if n > 1 {
            let mut names: Vec<&str> = services.iter().map(|s| s.name.as_str()).collect();
            assert!(
                names.iter().all(|nm| !nm.is_empty() && !nm.contains('/')),
                "multi-service fleets need non-empty, slash-free service names"
            );
            names.sort_unstable();
            names.dedup();
            assert_eq!(names.len(), n, "service names must be unique");
        }
        let max_duration = services
            .iter()
            .map(|s| s.trace.duration_s())
            .max()
            .unwrap_or(0) as f64;
        let threads = effective_threads(cfg.solver_threads, n);
        // The persistent worker pool for the parallel stages: spawned once
        // here, parked between dispatches, dropped (joined) when the run
        // returns.  `threads == 1` is the serial reference path — no pool,
        // no threads, ever (the N=1 single-adapter wrapper rides it).
        // Timed only when telemetry is on, so the disabled path never
        // reads the clock.
        let pool = (threads > 1).then(|| WorkerPool::new(threads, cfg.telemetry.enabled));
        let pool = pool.as_ref();
        let mut telem = cfg
            .telemetry
            .enabled
            .then(|| FleetTelemetry::new(&cfg.telemetry));

        let mut shards: Vec<ServiceShard> = services
            .iter()
            .enumerate()
            .map(|(i, s)| ServiceShard::new(i, s, cfg))
            .collect();

        let mut cluster = Cluster::new(&cfg.node_cores);

        // The fault plane's per-service RNG streams ride the same
        // SplitMix64 stride as every other stream, on their own offset;
        // a defaults-off config never draws from them (pinned by
        // `faults_off_is_bit_identical`).
        let mut faults = FaultPlane::new(
            cfg.fault,
            (0..n)
                .map(|i| service_seed(cfg.seed, i).wrapping_add(FAULT_STREAM_OFFSET))
                .collect(),
        );

        // --- Warm start: every service decides at t = 0 and its pods
        // become ready instantly (as in the paper's experiments).  Same
        // solve → arbitrate → apply stages as a live boundary, minus the
        // flush (nothing observed yet) — readiness is forced to zero.
        let first_rates: Vec<Vec<f64>> = services
            .iter()
            .map(|s| vec![s.trace.rates.first().copied().unwrap_or(0.0)])
            .collect();
        let empty_committed: Vec<BTreeMap<String, usize>> = vec![BTreeMap::new(); n];
        let mut warm_clock = StageClock::start(false);
        let grants = self.arbitrate(
            pool,
            services,
            &mut shards,
            &first_rates,
            &empty_committed,
            &mut warm_clock,
        );
        let decisions0 = decide_all(
            pool,
            0.0,
            services,
            &mut shards,
            &first_rates,
            &empty_committed,
            &grants,
        );
        let merged = merged_target(&shards, &decisions0);
        cluster.apply(&merged, 0.0, |_| 0.0);
        cluster.tick(0.0);
        for (i, d) in decisions0.iter().enumerate() {
            shards[i].apply_decision(&services[i].profiles, 0.0, d);
        }
        refresh_gates(&cluster, services, &mut shards, 0.0);
        record_costs(&cluster, &mut shards, 0.0);
        if let Some(rec) = recorder.as_deref_mut() {
            rec.record_tick(0, 0.0, decision_records(services, &shards, &grants, &decisions0));
        }

        // --- Seed every shard: its arrival stream and its view of the
        // warm-started pods.
        for (i, s) in services.iter().enumerate() {
            let list = ArrivalProcess::poisson(s.trace, service_seed(cfg.seed, i).wrapping_add(1));
            if let Some(rec) = recorder.as_deref_mut() {
                rec.record_arrivals(i, &list);
            }
            shards[i].seed_arrivals(&list);
        }
        for p in cluster.pods() {
            let svc = owner_of(&shards, &p.variant);
            shards[svc].insert_pod(p.id, &p.variant, p.cores);
        }
        for (i, d) in decisions0.into_iter().enumerate() {
            shards[i].decisions.push((0.0, d));
        }

        // --- Main loop: boundary-driven.  Cluster ticks land at every
        // whole second, adapter ticks at every interval, both strictly
        // inside [0, max_duration) — the same accumulated times the old
        // single-heap engine seeded as tick events.  Between boundaries
        // the shards advance independently (parallel); at a shared time
        // the cluster boundary runs before the adapter boundary, matching
        // the old engine's init-push sequence order.
        let mut next_cluster = 1.0f64;
        let mut next_adapter = cfg.adapter_interval_s;
        // Wall-clock the advance stage spends between adapter boundaries
        // (folded into the next tick's `advance` slot, minus the pool
        // dispatch overhead which lands in the `dispatch` slot), and the
        // 1-based adapter-tick ordinal (the warm start is not traced).
        let mut pending_advance_ns = 0u64;
        let mut pending_dispatch_ns = 0u64;
        // Cores lost to crashes since the last adapter tick (telemetry's
        // capacity-loss signal; drained into `on_tick`).
        let mut pending_lost_cores = 0u64;
        let mut tick_no = 0u64;
        loop {
            let cluster_due = next_cluster < max_duration;
            let adapter_due = next_adapter < max_duration;
            let t = match (cluster_due, adapter_due) {
                (true, true) => next_cluster.min(next_adapter),
                (true, false) => next_cluster,
                (false, true) => next_adapter,
                (false, false) => break,
            };
            let adv_start = telem.is_some().then(Instant::now);
            let adv_ov0 = pool.map_or(0, |p| p.overhead_ns());
            advance_all(pool, services, &mut shards, &cluster, t);
            if let Some(s) = adv_start {
                let span = s.elapsed().as_nanos() as u64;
                let d = (pool.map_or(0, |p| p.overhead_ns()) - adv_ov0).min(span);
                pending_advance_ns += span - d;
                pending_dispatch_ns += d;
            }
            // catch every shard's per-second rate accounting up to the
            // boundary (idle shards included — the old engine rolled all
            // services at every event pop; the roll is a pure catch-up,
            // so rolling lazily-then-here yields the same samples)
            for sh in shards.iter_mut() {
                sh.roll_to(t as u64);
            }
            if cluster_due && next_cluster == t {
                pending_lost_cores += cluster_boundary(
                    &mut cluster,
                    services,
                    &mut shards,
                    &mut faults,
                    t,
                    recorder.as_deref_mut(),
                );
                next_cluster += 1.0;
            }
            if adapter_due && next_adapter == t {
                tick_no += 1;
                self.adapter_boundary(
                    pool,
                    &mut cluster,
                    services,
                    &mut shards,
                    &mut faults,
                    t,
                    &mut telem,
                    tick_no,
                    std::mem::take(&mut pending_advance_ns),
                    std::mem::take(&mut pending_dispatch_ns),
                    std::mem::take(&mut pending_lost_cores),
                    recorder.as_deref_mut(),
                );
                next_adapter += cfg.adapter_interval_s;
            }
        }
        // --- Drain: completions may land past the trace end and every
        // request must be accounted for (conservation).
        advance_all(pool, services, &mut shards, &cluster, f64::INFINITY);

        // Telemetry fan-in, strictly in service-index order (the counters
        // are plain sums, so this is belt and braces on top of the merge
        // itself being order-deterministic).
        if let Some(ft) = telem.as_mut() {
            for sh in &shards {
                ft.shard.merge(&sh.telem);
                ft.cache.hits += sh.curve_cache.stats.hits;
                ft.cache.warm += sh.curve_cache.stats.warm;
                ft.cache.cold += sh.curve_cache.stats.cold;
                ft.solve.add(sh.curve_cache.solve_stats);
                let (allocs, reuses, _, arena_high) = sh.arena_stats();
                ft.arena_allocs += allocs;
                ft.arena_reuses += reuses;
                ft.arena_high_water = ft.arena_high_water.max(arena_high as u64);
                let (wheel_high, cascades) = sh.wheel_stats();
                ft.wheel_high_water = ft.wheel_high_water.max(wheel_high as u64);
                ft.wheel_cascades += cascades;
            }
            if let Some(p) = pool {
                ft.pool_dispatches = p.dispatches();
                ft.pool_dispatch_ns = p.overhead_ns();
            }
        }
        let summarize = cfg.telemetry.enabled;
        let results = shards
            .into_iter()
            .map(|sh| {
                let telemetry = summarize.then(|| {
                    let (allocs, reuses, _, arena_high) = sh.arena_stats();
                    let (wheel_high, cascades) = sh.wheel_stats();
                    TelemetrySummary::from_shard(
                        &sh.telem,
                        sh.curve_cache.stats,
                        sh.curve_cache.solve_stats,
                        allocs,
                        reuses,
                        arena_high as u64,
                        wheel_high as u64,
                        cascades,
                    )
                });
                SimResult {
                    metrics: sh.metrics,
                    duration_s: sh.duration,
                    decisions: sh.decisions,
                    curve_cache: sh.curve_cache.stats,
                    telemetry,
                }
            })
            .collect();
        (results, telem)
    }

    /// Solve + arbitrate stages.  The solve fans out over the persistent
    /// worker pool — each arbitrated service forecasts λ̂ and solves its
    /// value curve into its own shard's `pending_*` slots — then the
    /// arbiter water-fills the global budget serially over the entries
    /// collected in service-index order.  Returns `None` per service when
    /// the engine has no arbiter (every policy keeps its own budget; the
    /// solve stage is skipped entirely).
    fn arbitrate(
        &self,
        pool: Option<&WorkerPool>,
        services: &mut [FleetService],
        shards: &mut [ServiceShard],
        histories: &[Vec<f64>],
        committed: &[BTreeMap<String, usize>],
        clock: &mut StageClock,
    ) -> Vec<Option<usize>> {
        let Some(arb) = &self.arbiter else {
            return vec![None; services.len()];
        };
        let floors_sum: usize = services.iter().map(|s| s.floor_cores).sum();
        let global_budget = arb.global_budget;
        // Solve stage (parallel): per-service forecast + curve solve.
        // Everything written lands in the task's own (service, shard)
        // pair, so thread scheduling cannot affect any value — the
        // telemetry records included (each shard's recorder is its own
        // disjoint state, and timing is observed, never consulted).
        let ov0 = pool.map_or(0, |p| p.overhead_ns());
        parallel_zip(pool, services, shards, |i, s, sh| {
            if let FleetPolicyRef::Arbitrated(p) = &mut s.policy {
                let lambda = p.observe_and_predict(&histories[i]);
                sh.pending_lambda = lambda;
                if sh.stalled_tick {
                    // Solver stall (fault plane): the solve missed the
                    // tick deadline, so the arbiter sees the last-good
                    // curve instead of blocking the whole boundary.  The
                    // forecast above still ran — only the solve is lost.
                    sh.pending_curve = sh.last_curve.clone();
                    return;
                }
                // The most this service could ever be granted: the
                // whole budget minus everyone else's floors.
                let cap = global_budget.saturating_sub(floors_sum - s.floor_cores);
                // Cross-tick cache: exact hit skips the solve, a
                // same-bin λ̂ wobble warm-starts it; the curve values
                // are bit-identical to an uncached solve either way.
                let t0 = sh.telem.enabled.then(Instant::now);
                let curve = sh.curve_cache.curve(&**p, lambda, &committed[i], cap);
                if let Some(t0) = t0 {
                    sh.telem.record_solve_ns(t0.elapsed().as_nanos() as u64);
                    sh.telem.last_curve_knee = curve_knee(&curve);
                }
                if sh.stall_armed() {
                    sh.last_curve = Some(curve.clone());
                }
                sh.pending_curve = Some(curve);
            }
        });
        clock.lap_split(STAGE_SOLVE, pool.map_or(0, |p| p.overhead_ns()) - ov0);
        // Arbitrate stage (serial): fan in strictly by service index.
        let entries: Vec<ArbiterEntry> = services
            .iter()
            .enumerate()
            .map(|(i, s)| ArbiterEntry {
                priority: s.priority,
                tier: s.tier,
                // Rolling SLO-burn signal: the arbiter boosts burning
                // services' marginals (inert at the default burn_boost = 0).
                burn: shards[i].burn.burn_rate(),
                floor: s.floor_cores,
                curve: shards[i].pending_curve.take(),
            })
            .collect();
        let grants: Vec<Option<usize>> = arb.partition(&entries).into_iter().map(Some).collect();
        clock.lap(STAGE_ARBITRATE);
        grants
    }

    /// One adapter boundary: observe → solve → arbitrate → apply.  When
    /// telemetry is on, the stage clock laps each phase and the boundary
    /// ends with a [`TickTrace`] folded into the flight recorder —
    /// assembled strictly in service-index order, from values the stages
    /// already computed.
    #[allow(clippy::too_many_arguments)]
    fn adapter_boundary(
        &self,
        pool: Option<&WorkerPool>,
        cluster: &mut Cluster,
        services: &mut [FleetService],
        shards: &mut [ServiceShard],
        faults: &mut FaultPlane,
        now: f64,
        telem: &mut Option<FleetTelemetry>,
        tick: u64,
        advance_ns: u64,
        dispatch_ns: u64,
        lost_cores: u64,
        recorder: Option<&mut Recorder>,
    ) {
        let n = services.len();
        let mut clock = StageClock::start(telem.is_some());
        // Stall pre-pass (serial, index order): roll every service's
        // stall draw *unconditionally first*, so the stream position
        // never depends on reactions or decision history — a reactions-on
        // and a reactions-off run of the same fault seed see identical
        // crash/straggler draws.
        for (i, sh) in shards.iter_mut().enumerate() {
            let stalled = faults.roll_stall(i);
            sh.stalled_tick = stalled && faults.reactions() && sh.last_decision.is_some();
        }
        // Observe stage (serial): flush every shard's in-progress partial
        // second and fold the interval's SLO-burn delta.
        for sh in shards.iter_mut() {
            sh.flush_rate_window(now);
        }
        let committed_full = cluster.committed_allocation();
        let committed: Vec<BTreeMap<String, usize>> = (0..n)
            .map(|i| {
                committed_full
                    .iter()
                    .filter(|(k, _)| owner_of(shards, k) == i)
                    .map(|(k, &c)| (k[shards[i].prefix.len()..].to_string(), c))
                    .collect()
            })
            .collect();
        let histories: Vec<Vec<f64>> = shards
            .iter_mut()
            .map(|s| std::mem::take(&mut s.rate_history))
            .collect();
        clock.lap(STAGE_OBSERVE);
        let grants = self.arbitrate(pool, services, shards, &histories, &committed, &mut clock);
        let ov0 = pool.map_or(0, |p| p.overhead_ns());
        let decisions = decide_all(pool, now, services, shards, &histories, &committed, &grants);
        let decide_dispatch_ns = pool.map_or(0, |p| p.overhead_ns()) - ov0;
        // Apply stage (serial): reconcile the shared cluster against the
        // union target, then install each decision shard by shard.
        let merged = merged_target(shards, &decisions);
        {
            let svc_view: &[FleetService] = services;
            cluster.apply(&merged, now, |v| readiness_of(svc_view, shards, v));
        }
        for (i, d) in decisions.iter().enumerate() {
            shards[i].apply_decision(&services[i].profiles, now, d);
        }
        refresh_gates(cluster, services, shards, now);
        record_costs(cluster, shards, now);
        clock.lap_split(STAGE_APPLY, decide_dispatch_ns);
        if let Some(ft) = telem.as_mut() {
            let mut stage_ns = clock.ns;
            stage_ns[STAGE_ADVANCE] = advance_ns;
            stage_ns[STAGE_DISPATCH] += dispatch_ns;
            for (stage, &ns) in stage_ns.iter().enumerate() {
                ft.stages.record(stage, ns);
            }
            let rows: Vec<ServiceTick> = services
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    let sh = &shards[i];
                    let offered = match &s.policy {
                        FleetPolicyRef::Arbitrated(p) => p.last_offered(),
                        FleetPolicyRef::Plain(_) => 0.0,
                    };
                    ServiceTick {
                        name: s.name.clone(),
                        lambda_hat: sh.pending_lambda,
                        offered,
                        grant: grants[i],
                        curve_knee: sh.telem.last_curve_knee,
                        target_cores: decisions[i].target.values().sum(),
                        supply_rps: sh.path.gate().supply_rps(),
                        gate_cutoff: sh.path.gate().tier_cutoff(),
                        burn: sh.burn.burn_rate(),
                        cache: sh.curve_cache.stats,
                        solve: sh.curve_cache.solve_stats,
                    }
                })
                .collect();
            let admitted: u64 = shards.iter().map(|sh| sh.path.gate().admitted).sum();
            let shed: u64 = shards.iter().map(|sh| sh.path.gate().shed).sum();
            let max_burn = shards
                .iter()
                .map(|sh| sh.burn.burn_rate())
                .fold(0.0, f64::max);
            let ready_cores: u64 = cluster
                .pods()
                .iter()
                .filter(|p| p.is_ready())
                .map(|p| p.cores as u64)
                .sum();
            ft.on_tick(
                TickTrace {
                    tick,
                    t_s: now,
                    stage_ns,
                    services: rows,
                },
                admitted,
                shed,
                max_burn,
                lost_cores,
                ready_cores,
            );
        }
        if let Some(rec) = recorder {
            rec.record_tick(tick, now, decision_records(services, shards, &grants, &decisions));
        }
        for (i, d) in decisions.into_iter().enumerate() {
            shards[i].decisions.push((now, d));
        }
    }
}

/// Assemble one [`crate::replay::ServiceRecord`] per service from values
/// the boundary's stages already computed — strictly in service-index
/// order, pure reads (the replay twin of the telemetry `ServiceTick`
/// assembly above).
fn decision_records(
    services: &[FleetService],
    shards: &[ServiceShard],
    grants: &[Option<usize>],
    decisions: &[Decision],
) -> Vec<ServiceRecord> {
    services
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let sh = &shards[i];
            let d = &decisions[i];
            let offered = match &s.policy {
                FleetPolicyRef::Arbitrated(p) => p.last_offered(),
                FleetPolicyRef::Plain(_) => 0.0,
            };
            ServiceRecord {
                lambda_hat: sh.pending_lambda,
                offered,
                grant: grants[i],
                target: d.target.clone(),
                batches: d.batches.clone(),
                quotas: d.quotas.clone(),
                predicted_lambda: d.predicted_lambda,
                decision_supply_rps: d.supply_rps,
                gate_supply_rps: sh.path.gate().supply_rps(),
                gate_cutoff: sh.path.gate().tier_cutoff(),
                stalled: sh.stalled_tick,
            }
        })
        .collect()
}

/// Advance stage: every shard processes its own events up to `until`
/// (exclusive, plus arrivals at exactly `until` — see
/// [`ServiceShard::advance`] for the boundary tie rule).  Parallel over
/// the worker pool; shards share no mutable state and the cluster is
/// read-only here, so the fan-out is bit-neutral.
fn advance_all(
    pool: Option<&WorkerPool>,
    services: &mut [FleetService],
    shards: &mut [ServiceShard],
    cluster: &Cluster,
    until: f64,
) {
    parallel_zip(pool, services, shards, |_, s, sh| {
        sh.advance(cluster, &s.profiles, until);
    });
}

/// One cluster boundary (every whole second): pods come ready or drain
/// away, orphaned requests re-route within their shard, and every service
/// samples its billed cores.  Serial — this is the one place shards touch
/// the shared cluster's mutations — which is exactly why the fault plane
/// draws here: the draw sequence is a pure function of serial state
/// (service-index order, ascending pod ids), so thread count cannot
/// reorder it.  Returns the cores lost to crashes at this boundary.
fn cluster_boundary(
    cluster: &mut Cluster,
    services: &[FleetService],
    shards: &mut [ServiceShard],
    faults: &mut FaultPlane,
    now: f64,
    mut recorder: Option<&mut Recorder>,
) -> u64 {
    for event in cluster.tick(now) {
        match event {
            ClusterEvent::PodReady { pod_id, variant } => {
                let cores = cluster
                    .pods()
                    .iter()
                    .find(|p| p.id == pod_id)
                    .map(|p| p.cores)
                    .unwrap_or(0);
                let svc = owner_of(shards, &variant);
                shards[svc].insert_pod(pod_id, &variant, cores);
            }
            ClusterEvent::PodRemoved { pod_id, variant } => {
                let svc = owner_of(shards, &variant);
                shards[svc].handle_pod_removed(cluster, &services[svc].profiles, pod_id, now);
            }
        }
    }
    let mut lost_cores = 0u64;
    if faults.injecting() {
        for i in 0..shards.len() {
            let mut ready: Vec<(u64, String, usize)> = cluster
                .pods()
                .iter()
                .filter(|p| p.is_ready() && owner_of(shards, &p.variant) == i)
                .map(|p| (p.id, p.variant.clone(), p.cores))
                .collect();
            ready.sort_unstable_by_key(|&(id, _, _)| id);
            let ids: Vec<u64> = ready.iter().map(|&(id, _, _)| id).collect();
            let drawn = faults.draw_pod_faults(i, now, &ids);
            if let Some(rec) = recorder.as_deref_mut() {
                rec.record_fault_draw(now, i, &drawn.crashed, &drawn.straggling);
            }
            for &pod in &drawn.crashed {
                let (_, variant, cores) = &ready[ids.binary_search(&pod).expect("drawn from ids")];
                // The replacement pays the variant's loading cost,
                // inflated by the slow-start factor (the VPA-restart
                // penalty the paper measures against).
                let respawn =
                    readiness_of(services, shards, variant) * faults.cfg().slow_start_factor;
                if cluster.fail_pod(pod, now, respawn) {
                    shards[i].handle_pod_crashed(cluster, &services[i].profiles, pod, now);
                    shards[i].telem.record_crash(*cores);
                    lost_cores += *cores as u64;
                }
            }
            for &pod in &drawn.straggling {
                shards[i].handle_straggler(cluster, &services[i].profiles, pod, now);
            }
        }
        // Crashed capacity is gone *now*, not at the next adapter tick:
        // resize the admission gates from what is actually Ready, so the
        // gate sheds into the hole instead of admitting into it.  (The
        // Pending replacements keep the committed view unchanged, so the
        // regular tick-time refresh would see no loss at all.)
        if lost_cores > 0 && faults.reactions() {
            refresh_gates_ready(cluster, services, shards, now);
        }
    }
    record_costs(cluster, shards, now);
    lost_cores
}

/// Re-size every service's admission gate from its *committed* allocation:
/// supply = Σ per-variant `th_m(n, b)` over the pods the cluster is
/// actually holding for the service (Pending + Ready), at the batch sizes
/// in force — the "granted capacity" the token bucket refills at.  Called
/// at the warm start and every adaptation tick; a no-op fast path when no
/// gate is enabled keeps the default run untouched.
fn refresh_gates(
    cluster: &Cluster,
    services: &[FleetService],
    shards: &mut [ServiceShard],
    now: f64,
) {
    if !shards.iter().any(|s| s.path.gate().enabled()) {
        return;
    }
    let committed = cluster.committed_allocation();
    for i in 0..shards.len() {
        let alloc: BTreeMap<String, usize> = committed
            .iter()
            .filter(|(k, _)| owner_of(shards, k) == i)
            .map(|(k, &c)| (k[shards[i].prefix.len()..].to_string(), c))
            .collect();
        let supply = services[i]
            .profiles
            .supply_rps(&alloc, &shards[i].current_batches);
        shards[i].path.set_supply(now, supply);
    }
}

/// Emergency variant of [`refresh_gates`] for crash boundaries: supply is
/// computed from the *Ready* allocation only.  A crash leaves the
/// committed view unchanged (the Failed pod's replacement is already
/// Pending), so the regular refresh would keep admitting at full rate
/// into capacity that no longer exists; this one shrinks the token
/// bucket to the surviving pods until the replacement comes up.
fn refresh_gates_ready(
    cluster: &Cluster,
    services: &[FleetService],
    shards: &mut [ServiceShard],
    now: f64,
) {
    if !shards.iter().any(|s| s.path.gate().enabled()) {
        return;
    }
    let ready = cluster.ready_allocation();
    for i in 0..shards.len() {
        let alloc: BTreeMap<String, usize> = ready
            .iter()
            .filter(|(k, _)| owner_of(shards, k) == i)
            .map(|(k, &c)| (k[shards[i].prefix.len()..].to_string(), c))
            .collect();
        let supply = services[i]
            .profiles
            .supply_rps(&alloc, &shards[i].current_batches);
        shards[i].path.set_supply(now, supply);
    }
}

/// Decide stage: every service solves inside its grant (arbitrated) or
/// decides with its own fixed budget (plain / no arbiter).  Parallel —
/// each decision is a pure function of its own policy and shard state and
/// lands in its own shard's `pending_decision` slot; the fan-in collects
/// strictly by service index.
fn decide_all(
    pool: Option<&WorkerPool>,
    now: f64,
    services: &mut [FleetService],
    shards: &mut [ServiceShard],
    histories: &[Vec<f64>],
    committed: &[BTreeMap<String, usize>],
    grants: &[Option<usize>],
) -> Vec<Decision> {
    parallel_zip(pool, services, shards, |i, s, sh| {
        let t0 = sh.telem.enabled.then(Instant::now);
        // Solver-stall fallback: a stalled tick reuses the last-good
        // decision instead of blocking the boundary on the late solve.
        // `stalled_tick` is only ever set when reactions are armed and a
        // last-good decision exists (see the boundary pre-pass).
        let outcome = if sh.stalled_tick {
            SolveOutcome::Fallback
        } else {
            SolveOutcome::Fresh
        };
        if outcome == SolveOutcome::Fallback {
            sh.telem.record_fallback();
            let d = sh
                .last_decision
                .clone()
                .expect("stalled_tick implies a last-good decision");
            if let Some(t0) = t0 {
                sh.telem.record_decide_ns(t0.elapsed().as_nanos() as u64);
            }
            sh.pending_decision = Some(d);
            return;
        }
        let d = match &mut s.policy {
            FleetPolicyRef::Plain(p) => {
                let d = p.decide(now, &histories[i], &committed[i]);
                // Under arbitration a plain service's floor is its whole
                // reservation — the arbiter partitioned the rest of the
                // budget assuming it.  A policy that targets more quietly
                // oversubscribes the cluster; catch the misconfiguration
                // in debug builds.
                if let Some(g) = grants[i] {
                    debug_assert!(
                        d.target.values().sum::<usize>() <= g,
                        "plain service {} targets {} cores but is reserved only {} \
                         of the arbitrated budget",
                        s.name,
                        d.target.values().sum::<usize>(),
                        g
                    );
                }
                d
            }
            FleetPolicyRef::Arbitrated(p) => match grants[i] {
                Some(g) => {
                    p.budget = g;
                    p.decide_with_lambda(sh.pending_lambda, &committed[i])
                }
                None => p.decide(now, &histories[i], &committed[i]),
            },
        };
        if let Some(t0) = t0 {
            sh.telem.record_decide_ns(t0.elapsed().as_nanos() as u64);
        }
        if sh.stall_armed() {
            sh.last_decision = Some(d.clone());
        }
        sh.pending_decision = Some(d);
    });
    shards
        .iter_mut()
        .map(|sh| sh.pending_decision.take().expect("every service decided"))
        .collect()
}

/// Which service owns a cluster variant key.  Prefixes end in `/` and
/// names are slash-free, so matches are unambiguous; the empty prefix
/// (single-service compatibility path) owns everything.
fn owner_of(shards: &[ServiceShard], key: &str) -> usize {
    shards
        .iter()
        .position(|s| !s.prefix.is_empty() && key.starts_with(&s.prefix))
        .unwrap_or(0)
}

/// Union of every service's namespaced target (the shared cluster's
/// reconciliation goal; keys absent from the union are drained).
fn merged_target(shards: &[ServiceShard], decisions: &[Decision]) -> BTreeMap<String, usize> {
    let mut merged = BTreeMap::new();
    for (s, d) in shards.iter().zip(decisions) {
        for (v, &c) in &d.target {
            merged.insert(namespaced(&s.prefix, v), c);
        }
    }
    merged
}

/// Readiness time of a namespaced variant key (owner's profile).
fn readiness_of(services: &[FleetService], shards: &[ServiceShard], key: &str) -> f64 {
    let i = owner_of(shards, key);
    let raw = &key[shards[i].prefix.len()..];
    services[i]
        .profiles
        .get(raw)
        .map(|p| p.readiness_s)
        .unwrap_or(10.0)
}

/// Cores billed to one service right now (its share of the shared bill).
fn billed_of(cluster: &Cluster, shards: &[ServiceShard], i: usize) -> usize {
    cluster
        .pods()
        .iter()
        .filter(|p| p.is_billed() && owner_of(shards, &p.variant) == i)
        .map(|p| p.cores)
        .sum()
}

/// Sample every service's billed cores at `now` — but only inside that
/// service's own metric window `[0, duration]`.  Traces of different
/// lengths share one clock: cluster ticks run to the fleet-wide maximum,
/// and a sample past a short service's end would otherwise be integrated
/// by `MetricsCollector::summary` (which normalizes by the service's own
/// duration), inflating its average cost.
fn record_costs(cluster: &Cluster, shards: &mut [ServiceShard], now: f64) {
    for i in 0..shards.len() {
        if now > shards[i].duration {
            continue;
        }
        let billed = billed_of(cluster, shards, i);
        shards[i].metrics.record_cost(now, billed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::InfAdapterPolicy;
    use crate::baselines::StaticPolicy;
    use crate::config::ObjectiveWeights;
    use crate::forecaster::LastMaxForecaster;
    use crate::serving::sim::SimEngine;
    use crate::solver::BranchBoundSolver;
    use crate::workload::Trace;

    fn inf_policy(budget: usize) -> InfAdapterPolicy {
        InfAdapterPolicy::new(
            ProfileSet::paper_like(),
            Box::new(LastMaxForecaster::new(120, 1.0)),
            Box::new(BranchBoundSolver),
            ObjectiveWeights::default(),
            0.75,
            budget,
            1.1,
        )
    }

    /// The ISSUE acceptance criterion: a single service run through the
    /// fleet path (arbiter on, namespaced pods, per-service RNG streams)
    /// is bit-identical to the pre-fleet single-adapter path.
    #[test]
    fn single_service_fleet_matches_single_adapter_path() {
        let profiles = ProfileSet::paper_like();
        let trace = Trace::bursty(40.0, 100.0, 420, 9);
        let config = SimConfig {
            seed: 9,
            ..Default::default()
        };
        let mut p1 = inf_policy(20);
        let base = SimEngine::new(profiles.clone(), config.clone()).run(&mut p1, &trace);
        let mut p2 = inf_policy(20);
        let mut services = [FleetService {
            name: "svc0".into(),
            trace: &trace,
            profiles: profiles.clone(),
            slo_s: 0.75,
            priority: 1.0,
            tier: 0,
            error_budget: 0.01,
            floor_cores: 0,
            policy: FleetPolicyRef::Arbitrated(&mut p2),
        }];
        let fleet = FleetSimEngine::new(config, Some(CoreArbiter::new(20))).run(&mut services);
        let a = base.metrics.summary("single", base.duration_s);
        let b = fleet[0].metrics.summary("fleet", fleet[0].duration_s);
        assert_eq!(a.total_requests, b.total_requests);
        assert_eq!(a.dropped, b.dropped);
        assert_eq!(a.p99_latency_s, b.p99_latency_s);
        assert_eq!(a.p50_latency_s, b.p50_latency_s);
        assert_eq!(a.mean_latency_s, b.mean_latency_s);
        assert_eq!(a.slo_violation_rate, b.slo_violation_rate);
        assert_eq!(a.avg_accuracy, b.avg_accuracy);
        assert_eq!(a.core_seconds, b.core_seconds);
        assert_eq!(base.decisions.len(), fleet[0].decisions.len());
        for ((t1, d1), (t2, d2)) in base.decisions.iter().zip(&fleet[0].decisions) {
            assert_eq!(t1, t2);
            assert_eq!(d1.target, d2.target);
            assert_eq!(d1.quotas, d2.quotas);
            assert_eq!(d1.predicted_lambda, d2.predicted_lambda);
        }
    }

    #[test]
    fn multi_service_fleet_is_deterministic_per_seed() {
        let profiles = ProfileSet::paper_like();
        let ta = Trace::burst_window(30.0, 120.0, 300, 60, 80, 4);
        let tb = Trace::burst_window(30.0, 120.0, 300, 180, 80, 5);
        let run = || {
            let mut pa = inf_policy(6);
            let mut pb = inf_policy(6);
            let mut services = [
                FleetService {
                    name: "a".into(),
                    trace: &ta,
                    profiles: profiles.clone(),
                    slo_s: 0.75,
                    priority: 1.0,
                    tier: 0,
                    error_budget: 0.01,
                    floor_cores: 1,
                    policy: FleetPolicyRef::Arbitrated(&mut pa),
                },
                FleetService {
                    name: "b".into(),
                    trace: &tb,
                    profiles: profiles.clone(),
                    slo_s: 0.4,
                    priority: 1.0,
                    tier: 0,
                    error_budget: 0.01,
                    floor_cores: 1,
                    policy: FleetPolicyRef::Arbitrated(&mut pb),
                },
            ];
            let cfg = SimConfig {
                seed: 21,
                ..Default::default()
            };
            FleetSimEngine::new(cfg, Some(CoreArbiter::new(12))).run(&mut services)
        };
        let r1 = run();
        let r2 = run();
        for (x, y) in r1.iter().zip(&r2) {
            let sx = x.metrics.summary("x", x.duration_s);
            let sy = y.metrics.summary("y", y.duration_s);
            assert_eq!(sx.total_requests, sy.total_requests);
            assert_eq!(sx.p99_latency_s, sy.p99_latency_s);
            assert_eq!(sx.core_seconds, sy.core_seconds);
            assert!(sx.total_requests > 0);
        }
    }

    #[test]
    fn plain_services_share_the_cluster_without_interfering() {
        // Two static services on one cluster: each keeps its own pods and
        // serves only its own trace.
        let profiles = ProfileSet::paper_like();
        let ta = Trace::steady(30.0, 120);
        let tb = Trace::steady(10.0, 120);
        let mut pa = StaticPolicy::new("resnet18", 4);
        let mut pb = StaticPolicy::new("resnet50", 4);
        let mut services = [
            FleetService {
                name: "a".into(),
                trace: &ta,
                profiles: profiles.clone(),
                slo_s: 0.75,
                priority: 1.0,
                tier: 0,
                error_budget: 0.01,
                floor_cores: 4,
                policy: FleetPolicyRef::Plain(&mut pa),
            },
            FleetService {
                name: "b".into(),
                trace: &tb,
                profiles: profiles.clone(),
                slo_s: 0.75,
                priority: 1.0,
                tier: 0,
                error_budget: 0.01,
                floor_cores: 4,
                policy: FleetPolicyRef::Plain(&mut pb),
            },
        ];
        let cfg = SimConfig {
            seed: 3,
            ..Default::default()
        };
        let results = FleetSimEngine::new(cfg, None).run(&mut services);
        let sa = results[0].metrics.summary("a", 120.0);
        let sb = results[1].metrics.summary("b", 120.0);
        assert!(sa.total_requests > 3000, "{sa:?}");
        assert!(sb.total_requests > 900, "{sb:?}");
        assert_eq!(sa.dropped, 0);
        assert_eq!(sb.dropped, 0);
        // service a runs resnet18 (69.76), service b resnet50 (76.13):
        // routing never leaks across the namespace boundary
        assert!((sa.avg_accuracy - 69.76).abs() < 1e-6, "{sa:?}");
        assert!((sb.avg_accuracy - 76.13).abs() < 1e-6, "{sb:?}");
        // both bills stay near the static allocations
        assert!((sa.avg_cost_cores - 4.0).abs() < 0.5, "{sa:?}");
        assert!((sb.avg_cost_cores - 4.0).abs() < 0.5, "{sb:?}");
    }

    #[test]
    fn short_trace_service_cost_normalizes_over_its_own_window() {
        // Services with different trace lengths share one clock: the
        // cluster keeps ticking (and billing) until the fleet-wide
        // maximum, but a service's summary normalizes by its *own*
        // duration — cost samples past its end must not be integrated
        // (they would report 4 cores over 100 s as ~16 avg cores here).
        let profiles = ProfileSet::paper_like();
        let ta = Trace::steady(20.0, 100);
        let tb = Trace::steady(20.0, 400);
        let mut pa = StaticPolicy::new("resnet18", 4);
        let mut pb = StaticPolicy::new("resnet18", 4);
        let mut services = [
            FleetService {
                name: "short".into(),
                trace: &ta,
                profiles: profiles.clone(),
                slo_s: 0.75,
                priority: 1.0,
                tier: 0,
                error_budget: 0.01,
                floor_cores: 4,
                policy: FleetPolicyRef::Plain(&mut pa),
            },
            FleetService {
                name: "long".into(),
                trace: &tb,
                profiles: profiles.clone(),
                slo_s: 0.75,
                priority: 1.0,
                tier: 0,
                error_budget: 0.01,
                floor_cores: 4,
                policy: FleetPolicyRef::Plain(&mut pb),
            },
        ];
        let cfg = SimConfig {
            seed: 8,
            ..Default::default()
        };
        let results = FleetSimEngine::new(cfg, None).run(&mut services);
        let short = results[0].metrics.summary("short", results[0].duration_s);
        let long = results[1].metrics.summary("long", results[1].duration_s);
        assert!((short.avg_cost_cores - 4.0).abs() < 0.5, "{short:?}");
        assert!((long.avg_cost_cores - 4.0).abs() < 0.5, "{long:?}");
    }

    #[test]
    fn steady_state_ticks_reuse_cached_curves() {
        // Two steady services under arbitration: one curve solve per
        // service per arbitration tick (t=0 plus every interval), and the
        // stable λ̂ / committed cores must produce exact hits or warm
        // starts — the steady-state tick must not re-solve cold forever.
        let profiles = ProfileSet::paper_like();
        let ta = Trace::steady(30.0, 600);
        let tb = Trace::steady(20.0, 600);
        let mut pa = inf_policy(6);
        let mut pb = inf_policy(6);
        let mut services = [
            FleetService {
                name: "a".into(),
                trace: &ta,
                profiles: profiles.clone(),
                slo_s: 0.75,
                priority: 1.0,
                tier: 0,
                error_budget: 0.01,
                floor_cores: 1,
                policy: FleetPolicyRef::Arbitrated(&mut pa),
            },
            FleetService {
                name: "b".into(),
                trace: &tb,
                profiles: profiles.clone(),
                slo_s: 0.75,
                priority: 1.0,
                tier: 0,
                error_budget: 0.01,
                floor_cores: 1,
                policy: FleetPolicyRef::Arbitrated(&mut pb),
            },
        ];
        let cfg = SimConfig {
            seed: 5,
            ..Default::default()
        };
        let results = FleetSimEngine::new(cfg, Some(CoreArbiter::new(12))).run(&mut services);
        // arbitration runs at t=0 and every 30 s tick inside (0, 600)
        let ticks = 1 + (600.0f64 / 30.0).ceil() as u64 - 1;
        for r in &results {
            let cc = &r.curve_cache;
            assert_eq!(cc.total(), ticks, "{cc:?}");
            assert!(cc.cold >= 1, "first tick is always cold: {cc:?}");
            assert!(
                cc.hits + cc.warm > 0,
                "steady state must reuse curves: {cc:?}"
            );
        }
        // the plain single-service path never touches the cache
        let mut p = inf_policy(20);
        let single = SimEngine::new(ProfileSet::paper_like(), SimConfig::default())
            .run(&mut p, &Trace::steady(30.0, 120));
        assert_eq!(single.curve_cache.total(), 0);
    }

    #[test]
    fn admission_off_is_the_default_and_admitting_gates_are_bit_identical() {
        // Two pins in one: (1) a run with the gate *enabled but never
        // binding* (offered load far under the granted supply) performs
        // the same event sequence and RNG draws as the default
        // admission-off run — summaries equal field for field; (2) it
        // sheds nothing.
        use crate::config::AdmissionConfig;
        let profiles = ProfileSet::paper_like();
        let trace = Trace::steady(40.0, 180);
        let run = |admission: AdmissionConfig| {
            let mut policy = StaticPolicy::new("resnet18", 4);
            let mut services = [FleetService {
                name: "svc".into(),
                trace: &trace,
                profiles: profiles.clone(),
                slo_s: 0.75,
                priority: 1.0,
                tier: 0,
                error_budget: 0.01,
                floor_cores: 4,
                policy: FleetPolicyRef::Plain(&mut policy),
            }];
            let cfg = SimConfig {
                seed: 33,
                admission,
                ..Default::default()
            };
            FleetSimEngine::new(cfg, None)
                .run(&mut services)
                .pop()
                .unwrap()
        };
        let off = run(AdmissionConfig::default());
        let on = run(AdmissionConfig {
            enabled: true,
            ..Default::default()
        });
        let a = off.metrics.summary("off", off.duration_s);
        let b = on.metrics.summary("on", on.duration_s);
        assert_eq!(b.shed, 0, "under-capacity gate must not shed: {b:?}");
        assert_eq!(a.total_requests, b.total_requests);
        assert_eq!(a.dropped, b.dropped);
        assert_eq!(a.p99_latency_s, b.p99_latency_s);
        assert_eq!(a.mean_latency_s, b.mean_latency_s);
        assert_eq!(a.slo_violation_rate, b.slo_violation_rate);
        assert_eq!(a.avg_accuracy, b.avg_accuracy);
        assert_eq!(a.core_seconds, b.core_seconds);
    }

    #[test]
    fn admission_sheds_overload_instead_of_violating_every_slo() {
        // A static pod holding ~92 rps of supply is offered 250 rps.
        // Without admission the queue blows through (nearly) every
        // request's SLO; with the gate the excess is shed at the door and
        // the admitted stream keeps meeting its SLO.
        use crate::config::AdmissionConfig;
        let profiles = ProfileSet::paper_like();
        let trace = Trace::steady(250.0, 240);
        let run = |enabled: bool| {
            let mut policy = StaticPolicy::new("resnet18", 4);
            let mut services = [FleetService {
                name: "svc".into(),
                trace: &trace,
                profiles: profiles.clone(),
                slo_s: 0.75,
                priority: 1.0,
                tier: 0,
                error_budget: 0.01,
                floor_cores: 4,
                policy: FleetPolicyRef::Plain(&mut policy),
            }];
            let cfg = SimConfig {
                seed: 44,
                admission: AdmissionConfig {
                    enabled,
                    ..Default::default()
                },
                ..Default::default()
            };
            let r = FleetSimEngine::new(cfg, None)
                .run(&mut services)
                .pop()
                .unwrap();
            r.metrics.summary(if enabled { "on" } else { "off" }, r.duration_s)
        };
        let off = run(false);
        let on = run(true);
        assert_eq!(off.shed, 0);
        assert!(
            off.slo_violation_rate > 0.5,
            "unshed overload must drown: {off:?}"
        );
        assert!(on.shed > 0, "{on:?}");
        // the gate admits ~supply/offered ≈ 37%: the rest is refused
        let shed_frac = on.shed as f64 / on.total_requests as f64;
        assert!((0.4..0.9).contains(&shed_frac), "shed {shed_frac}");
        assert!(
            on.slo_violation_rate < 0.2,
            "admitted stream must be protected: {on:?}"
        );
        assert!(
            on.goodput_admitted_rps > off.goodput_rps,
            "shedding must raise useful throughput: {} vs {}",
            on.goodput_admitted_rps,
            off.goodput_rps
        );
    }

    #[test]
    fn class_mix_sheds_lower_tier_requests_first() {
        // One service, 70% tier-0 / 30% tier-1 requests, 2.5x overload:
        // the gate's cutoff must push the shedding onto tier 1.
        use crate::config::AdmissionConfig;
        let profiles = ProfileSet::paper_like();
        let trace =
            Trace::steady(250.0, 240).with_class_mix(vec![(0, 7.0), (1, 3.0)]);
        let mut policy = StaticPolicy::new("resnet18", 4);
        let mut services = [FleetService {
            name: "svc".into(),
            trace: &trace,
            profiles: profiles.clone(),
            slo_s: 0.75,
            priority: 1.0,
            tier: 0,
            error_budget: 0.01,
            floor_cores: 4,
            policy: FleetPolicyRef::Plain(&mut policy),
        }];
        let cfg = SimConfig {
            seed: 45,
            admission: AdmissionConfig {
                enabled: true,
                ..Default::default()
            },
            ..Default::default()
        };
        let r = FleetSimEngine::new(cfg, None)
            .run(&mut services)
            .pop()
            .unwrap();
        let s = r.metrics.summary("mix", r.duration_s);
        assert_eq!(s.tiers.len(), 2, "{:?}", s.tiers);
        let t0 = &s.tiers[0];
        let t1 = &s.tiers[1];
        // tier 1 (30% of 250 = 75 rps offered) is shed almost entirely;
        // tier 0 (175 rps offered vs ~92 rps supply) sheds only its own
        // excess — a strictly smaller *fraction* than tier 1
        let f0 = t0.shed as f64 / t0.total.max(1) as f64;
        let f1 = t1.shed as f64 / t1.total.max(1) as f64;
        assert!(f1 > 0.9, "tier 1 shed fraction {f1}: {t1:?}");
        assert!(f0 < f1, "lowest tier must shed first: {f0} vs {f1}");
        assert!(t0.served > 0);
    }

    #[test]
    fn arbiter_shifts_cores_toward_the_bursting_service() {
        // Service a bursts in [60, 180); b stays quiet.  Under arbitration
        // a's grant during its burst must exceed the even share, and its
        // solved allocation must actually use more than the share.
        let profiles = ProfileSet::paper_like();
        let ta = Trace::burst_window(30.0, 150.0, 360, 60, 120, 11);
        let tb = Trace::steady(30.0, 360);
        let mut pa = inf_policy(6);
        let mut pb = inf_policy(6);
        let mut services = [
            FleetService {
                name: "a".into(),
                trace: &ta,
                profiles: profiles.clone(),
                slo_s: 0.75,
                priority: 1.0,
                tier: 0,
                error_budget: 0.01,
                floor_cores: 2,
                policy: FleetPolicyRef::Arbitrated(&mut pa),
            },
            FleetService {
                name: "b".into(),
                trace: &tb,
                profiles: profiles.clone(),
                slo_s: 0.75,
                priority: 1.0,
                tier: 0,
                error_budget: 0.01,
                floor_cores: 2,
                policy: FleetPolicyRef::Arbitrated(&mut pb),
            },
        ];
        let cfg = SimConfig {
            seed: 13,
            ..Default::default()
        };
        let results = FleetSimEngine::new(cfg, Some(CoreArbiter::new(12))).run(&mut services);
        // find service a's decision right inside the burst window
        let in_burst = results[0]
            .decisions
            .iter()
            .find(|(t, _)| (90.0..180.0).contains(t))
            .map(|(_, d)| d.target.values().sum::<usize>())
            .expect("a decision inside the burst");
        assert!(in_burst > 6, "burst grant should exceed the even share, got {in_burst}");
    }

    /// The five-stage protocol's thread knob must be inert: the same fleet
    /// at solver_threads 1 (serial reference) and 4 produces identical
    /// decision streams.  (The full summary-level pin lives in
    /// `tests/regression_pins.rs`; this is the fast in-module guard.)
    #[test]
    fn solver_threads_do_not_change_decisions() {
        let profiles = ProfileSet::paper_like();
        let ta = Trace::burst_window(30.0, 120.0, 240, 60, 80, 4);
        let tb = Trace::steady(25.0, 240);
        let run = |threads: usize| {
            let mut pa = inf_policy(6);
            let mut pb = inf_policy(6);
            let mut services = [
                FleetService {
                    name: "a".into(),
                    trace: &ta,
                    profiles: profiles.clone(),
                    slo_s: 0.75,
                    priority: 1.0,
                    tier: 0,
                    error_budget: 0.01,
                    floor_cores: 1,
                    policy: FleetPolicyRef::Arbitrated(&mut pa),
                },
                FleetService {
                    name: "b".into(),
                    trace: &tb,
                    profiles: profiles.clone(),
                    slo_s: 0.75,
                    priority: 1.0,
                    tier: 0,
                    error_budget: 0.01,
                    floor_cores: 1,
                    policy: FleetPolicyRef::Arbitrated(&mut pb),
                },
            ];
            let cfg = SimConfig {
                seed: 21,
                solver_threads: threads,
                ..Default::default()
            };
            FleetSimEngine::new(cfg, Some(CoreArbiter::new(12))).run(&mut services)
        };
        let serial = run(1);
        let parallel = run(4);
        for (x, y) in serial.iter().zip(&parallel) {
            assert_eq!(x.decisions.len(), y.decisions.len());
            for ((t1, d1), (t2, d2)) in x.decisions.iter().zip(&y.decisions) {
                assert_eq!(t1, t2);
                assert_eq!(d1.target, d2.target);
                assert_eq!(d1.quotas, d2.quotas);
                assert_eq!(d1.predicted_lambda, d2.predicted_lambda);
            }
            let sx = x.metrics.summary("s", x.duration_s);
            let sy = y.metrics.summary("p", y.duration_s);
            assert_eq!(sx.total_requests, sy.total_requests);
            assert_eq!(sx.p99_latency_s, sy.p99_latency_s);
            assert_eq!(sx.core_seconds, sy.core_seconds);
        }
    }
}
