//! Cross-tick value-curve cache: the arbiter's per-service curves barely
//! change between adaptation intervals in steady state, so re-deriving
//! them from scratch every tick wastes the bulk of the fleet's decision
//! budget.  Each arbitrated service owns one [`CurveCache`] keyed by
//! (λ̂, current-cores signature, objective weights, grant cap, and — when
//! shed pricing is on — the shed penalty and offered rate; see PR 5):
//!
//! * **Hit** — bit-identical λ̂ and an identical key: the cached curve *is*
//!   the exact answer (same problem, deterministic solver); zero solver
//!   work.
//! * **Warm** — λ̂ moved but stayed inside the same quantization bin (2%
//!   relative, [`lambda_bin`]) with the same cores signature and weights:
//!   a fresh exact solve runs, but the previous curve's winner vectors are
//!   re-scored under the *new* problem to pre-load the incumbent curve
//!   ([`crate::solver::Solver::solve_curve_seeded`]), so branch-and-bound
//!   prunes almost everything.  Because only currently-achievable
//!   objectives enter the incumbent, the values are exactly those of a
//!   cold solve — warm starts change cost, never results.
//! * **Cold** — anything else (λ̂ jumped bins, the committed allocation
//!   changed, first tick): a plain single-pass solve.
//!
//! Either way the arbiter sees bit-identical curves to an uncached run,
//! so fleet partitions — and every downstream headline number — are
//! unchanged; only the tick cost drops.  [`CurveCacheStats`] counts the
//! outcomes and surfaces through `SimResult` / the `fleet` CLI.

use crate::adapter::InfAdapterPolicy;
use crate::config::ObjectiveWeights;
use crate::solver::{SolveStats, ValueCurve};
use std::collections::BTreeMap;

/// Tick outcome counters (hits + warm + cold = arbitration ticks served).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CurveCacheStats {
    /// Exact-key hits: curve returned with no solver work.
    pub hits: u64,
    /// Warm-started fresh solves (same λ̂ bin / cores / weights).
    pub warm: u64,
    /// Cold solves (key changed or first tick).
    pub cold: u64,
}

impl CurveCacheStats {
    pub fn total(&self) -> u64 {
        self.hits + self.warm + self.cold
    }
}

struct CacheEntry {
    lambda: f64,
    lambda_bin: i64,
    committed: BTreeMap<String, usize>,
    weights: ObjectiveWeights,
    cap: usize,
    /// Shed-pricing key: the penalty the curve was solved under, and the
    /// offered rate it was priced against.  A hit must never cross
    /// penalties (or, when priced, offered rates) — the curves are
    /// genuinely different functions; warm starts may (re-scoring makes
    /// any seed sound), but the key keeps them to the same-penalty case
    /// where the incumbent actually prunes.
    shed_penalty: f64,
    offered: f64,
    curve: ValueCurve,
}

/// One service's cross-tick curve memory (single entry: consecutive ticks
/// are the only reuse pattern that occurs).
#[derive(Default)]
pub struct CurveCache {
    entry: Option<CacheEntry>,
    pub stats: CurveCacheStats,
    /// Accumulated solver introspection from warm/cold solves (hits add
    /// nothing — no solver ran).  Pure observation for the telemetry
    /// plane; never read on the decision path.
    pub solve_stats: SolveStats,
}

/// 2% relative quantization bin of λ̂ — wide enough that steady-state
/// forecast wobble stays in one bin (warm start applies), narrow enough
/// that a real load shift forces a cold solve where the old incumbent
/// would prune nothing anyway.
fn lambda_bin(lambda: f64) -> i64 {
    (lambda.max(1e-9).ln() / 0.02).round() as i64
}

impl CurveCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// The value curve for this tick's (λ̂, committed, cap), served from
    /// cache when the inputs match, warm-started when they nearly match,
    /// and solved cold otherwise.  Always exact (see module docs).
    pub fn curve(
        &mut self,
        policy: &InfAdapterPolicy,
        lambda: f64,
        committed: &BTreeMap<String, usize>,
        cap: usize,
    ) -> Vec<f64> {
        let weights = policy.weights;
        let shed_penalty = policy.shed_penalty;
        // The curve depends on the offered rate only through the penalty:
        // an unpriced solve ignores it entirely, so keying it there would
        // spuriously miss on every forecast wobble.
        let offered = if shed_penalty != 0.0 {
            policy.last_offered()
        } else {
            0.0
        };
        let price_matches = |e: &CacheEntry| {
            e.shed_penalty.to_bits() == shed_penalty.to_bits()
                && (shed_penalty == 0.0 || e.offered.to_bits() == offered.to_bits())
        };
        if let Some(e) = &self.entry {
            if e.lambda.to_bits() == lambda.to_bits()
                && e.cap == cap
                && e.weights == weights
                && e.committed == *committed
                && price_matches(e)
            {
                self.stats.hits += 1;
                return e.curve.values().to_vec();
            }
        }
        let warm = matches!(
            &self.entry,
            Some(e) if e.lambda_bin == lambda_bin(lambda)
                && e.weights == weights
                && e.committed == *committed
                && e.shed_penalty.to_bits() == shed_penalty.to_bits()
                && (shed_penalty == 0.0
                    || lambda_bin(e.offered) == lambda_bin(offered))
        );
        let seed = if warm {
            self.entry.as_ref().map(|e| &e.curve)
        } else {
            None
        };
        let (curve, solve_stats) = policy.value_curve_seeded_stats(lambda, committed, cap, seed);
        self.solve_stats.add(solve_stats);
        if warm {
            self.stats.warm += 1;
        } else {
            self.stats.cold += 1;
        }
        let values = curve.values().to_vec();
        self.entry = Some(CacheEntry {
            lambda,
            lambda_bin: lambda_bin(lambda),
            committed: committed.clone(),
            weights,
            cap,
            shed_penalty,
            offered,
            curve,
        });
        values
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ObjectiveWeights;
    use crate::forecaster::LastMaxForecaster;
    use crate::profiler::ProfileSet;
    use crate::solver::BranchBoundSolver;

    fn policy() -> InfAdapterPolicy {
        InfAdapterPolicy::new(
            ProfileSet::paper_like(),
            Box::new(LastMaxForecaster::new(120, 1.0)),
            Box::new(BranchBoundSolver),
            ObjectiveWeights::default(),
            0.75,
            20,
            1.1,
        )
    }

    #[test]
    fn identical_inputs_hit_without_resolving() {
        let p = policy();
        let mut cache = CurveCache::new();
        let committed = BTreeMap::from([("resnet18".to_string(), 2)]);
        let a = cache.curve(&p, 75.0, &committed, 20);
        let b = cache.curve(&p, 75.0, &committed, 20);
        assert_eq!(a, b);
        assert_eq!(
            cache.stats,
            CurveCacheStats {
                hits: 1,
                warm: 0,
                cold: 1
            }
        );
        // and the cached curve is the uncached answer
        assert_eq!(a, p.value_curve(75.0, &committed, 20));
    }

    #[test]
    fn lambda_wobble_warm_starts_and_stays_exact() {
        let p = policy();
        let mut cache = CurveCache::new();
        let committed = BTreeMap::new();
        cache.curve(&p, 75.0, &committed, 20);
        // 75.0 -> 75.5 stays inside the 2% bin: fresh solve, warm-seeded,
        // values identical to an uncached solve
        let warm = cache.curve(&p, 75.5, &committed, 20);
        assert_eq!(warm, p.value_curve(75.5, &committed, 20));
        // 75.5 -> 150.0 jumps bins: cold solve
        let cold = cache.curve(&p, 150.0, &committed, 20);
        assert_eq!(cold, p.value_curve(150.0, &committed, 20));
        assert_eq!(
            cache.stats,
            CurveCacheStats {
                hits: 0,
                warm: 1,
                cold: 2
            }
        );
    }

    #[test]
    fn penalty_change_invalidates_and_never_hits_across_prices() {
        let mut p = policy().with_shed_pricing(1.0);
        // overload λ̂ so the penalty genuinely changes the curve values
        p.observe_and_predict(&vec![300.0; 60]);
        let mut cache = CurveCache::new();
        let committed = BTreeMap::new();
        let a = cache.curve(&p, 330.0, &committed, 20);
        // same inputs, same penalty: exact hit
        let a2 = cache.curve(&p, 330.0, &committed, 20);
        assert_eq!(a, a2);
        assert_eq!(cache.stats.hits, 1);
        // the penalty changes: the cached curve is for a different
        // objective and must not be returned (not even as a warm start —
        // the incumbent would be for the wrong prices)
        p.shed_penalty = 0.25;
        let b = cache.curve(&p, 330.0, &committed, 20);
        assert_eq!(
            cache.stats,
            CurveCacheStats {
                hits: 1,
                warm: 0,
                cold: 2
            }
        );
        assert_eq!(b, p.value_curve(330.0, &committed, 20));
        assert_ne!(a, b, "different penalties must price the curve differently");
        // a changed offered rate under the same penalty also invalidates
        // (120 quiet samples push the 300s out of the forecast window)
        p.observe_and_predict(&vec![150.0; 120]);
        let c = cache.curve(&p, 330.0, &committed, 20);
        assert_eq!(cache.stats.cold, 3);
        assert_eq!(c, p.value_curve(330.0, &committed, 20));
    }

    #[test]
    fn committed_cores_change_invalidates() {
        let p = policy();
        let mut cache = CurveCache::new();
        let before = BTreeMap::from([("resnet18".to_string(), 2)]);
        let after = BTreeMap::from([("resnet50".to_string(), 4)]);
        cache.curve(&p, 75.0, &before, 20);
        // same λ̂, different current cores: loading costs shift, so the
        // curve must be re-solved (cold — the old incumbent is not even
        // warm-start keyed)
        let fresh = cache.curve(&p, 75.0, &after, 20);
        assert_eq!(fresh, p.value_curve(75.0, &after, 20));
        assert_eq!(
            cache.stats,
            CurveCacheStats {
                hits: 0,
                warm: 0,
                cold: 2
            }
        );
    }
}
