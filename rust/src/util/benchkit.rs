//! Tiny timing harness for the `harness = false` benches (no criterion in
//! the offline build).
//!
//! [`bench`] warms up, runs timed iterations until a wall budget or
//! iteration cap, and reports mean / p50 / p99 per-iteration time.
//! [`BenchReport`] wraps it for whole-bench runs: it honors `--short`
//! (small per-entry wall budget, for CI) and `--json <path>` (machine-
//! readable emission of every entry plus derived ratios — the perf
//! trajectory artifact CI uploads as `BENCH_solver.json`).

use super::json::Value;
use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
    pub min: Duration,
}

impl BenchStats {
    pub fn per_sec(&self) -> f64 {
        if self.mean.as_secs_f64() > 0.0 {
            1.0 / self.mean.as_secs_f64()
        } else {
            f64::INFINITY
        }
    }
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean {:>10.3?}  p50 {:>10.3?}  p99 {:>10.3?}  min {:>10.3?}  ({} iters, {:.1}/s)",
            self.mean,
            self.p50,
            self.p99,
            self.min,
            self.iters,
            self.per_sec()
        )
    }
}

/// Time `f` repeatedly: `warmup` untimed runs, then iterate until `budget`
/// wall time or `max_iters`, whichever first (at least one iteration).
pub fn bench<F: FnMut()>(warmup: usize, budget: Duration, max_iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let start = Instant::now();
    let mut samples: Vec<Duration> = Vec::new();
    while (samples.is_empty() || start.elapsed() < budget) && samples.len() < max_iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    let q = |p: f64| samples[((p * samples.len() as f64) as usize).min(samples.len() - 1)];
    BenchStats {
        iters: samples.len(),
        mean: total / samples.len() as u32,
        p50: q(0.50),
        p99: q(0.99),
        min: samples[0],
    }
}

/// Convenience: run + print one named benchmark.
pub fn run_named<F: FnMut()>(name: &str, f: F) -> BenchStats {
    let stats = bench(2, Duration::from_secs(2), 10_000, f);
    println!("{name:<40} {stats}");
    stats
}

/// Named-entry collector for a whole bench binary.
///
/// * `--short` — 200 ms wall budget per entry instead of 2 s (CI mode).
/// * `--json <path>` / `--json=<path>` — write every entry
///   (`{mean_ns, p50_ns, p99_ns, min_ns, iters, per_sec}`) plus the
///   [`Self::derive`]d scalars to `path` on [`Self::finish`].
///
/// Unknown flags are ignored (`cargo bench` injects `--bench` into the
/// harness args).
pub struct BenchReport {
    budget: Duration,
    json_path: Option<String>,
    entries: Vec<(String, BenchStats)>,
    derived: Vec<(String, f64)>,
}

impl BenchReport {
    /// Parse `--short` / `--json` from the process arguments.
    pub fn from_args() -> Self {
        let args: Vec<String> = std::env::args().skip(1).collect();
        let mut short = false;
        let mut json_path = None;
        let mut i = 0;
        while i < args.len() {
            match args[i].as_str() {
                "--short" => short = true,
                "--json" => {
                    json_path = args.get(i + 1).cloned();
                    i += 1;
                }
                other => {
                    if let Some(p) = other.strip_prefix("--json=") {
                        json_path = Some(p.to_string());
                    }
                }
            }
            i += 1;
        }
        Self {
            budget: if short {
                Duration::from_millis(200)
            } else {
                Duration::from_secs(2)
            },
            json_path,
            entries: Vec::new(),
            derived: Vec::new(),
        }
    }

    /// Time + print + record one named entry.
    pub fn run<F: FnMut()>(&mut self, name: &str, f: F) -> BenchStats {
        let stats = bench(2, self.budget, 10_000, f);
        println!("{name:<44} {stats}");
        self.entries.push((name.to_string(), stats));
        stats
    }

    /// Record a derived scalar (e.g. an old/new speedup ratio).
    pub fn derive(&mut self, name: &str, value: f64) {
        println!("{name:<44} {value:.2}");
        self.derived.push((name.to_string(), value));
    }

    /// Emit the JSON report if `--json` was requested.
    pub fn finish(&self) {
        let Some(path) = &self.json_path else { return };
        let entries: Vec<(&str, Value)> = self
            .entries
            .iter()
            .map(|(name, s)| {
                (
                    name.as_str(),
                    Value::obj(vec![
                        ("mean_ns", Value::Num(s.mean.as_nanos() as f64)),
                        ("p50_ns", Value::Num(s.p50.as_nanos() as f64)),
                        ("p99_ns", Value::Num(s.p99.as_nanos() as f64)),
                        ("min_ns", Value::Num(s.min.as_nanos() as f64)),
                        ("iters", Value::Num(s.iters as f64)),
                        ("per_sec", Value::Num(s.per_sec())),
                    ]),
                )
            })
            .collect();
        let derived: Vec<(&str, Value)> = self
            .derived
            .iter()
            .map(|(name, v)| (name.as_str(), Value::Num(*v)))
            .collect();
        let report = Value::obj(vec![
            ("schema", Value::Str("benchkit-v1".to_string())),
            ("entries", Value::obj(entries)),
            ("derived", Value::obj(derived)),
        ]);
        std::fs::write(path, report.to_string_pretty()).expect("write bench json");
        println!("bench report -> {path}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reports_sane_statistics() {
        let stats = bench(1, Duration::from_millis(50), 1000, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(stats.iters >= 1);
        assert!(stats.min <= stats.p50 && stats.p50 <= stats.p99);
        assert!(stats.mean.as_nanos() > 0);
    }

    #[test]
    fn respects_iteration_cap() {
        let stats = bench(0, Duration::from_secs(10), 5, || {});
        assert_eq!(stats.iters, 5);
    }
}
