//! Tiny timing harness for the `harness = false` benches (no criterion in
//! the offline build).
//!
//! [`bench`] warms up, runs timed iterations until a wall budget or
//! iteration cap, and reports mean / p50 / p99 per-iteration time.

use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
    pub min: Duration,
}

impl BenchStats {
    pub fn per_sec(&self) -> f64 {
        if self.mean.as_secs_f64() > 0.0 {
            1.0 / self.mean.as_secs_f64()
        } else {
            f64::INFINITY
        }
    }
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean {:>10.3?}  p50 {:>10.3?}  p99 {:>10.3?}  min {:>10.3?}  ({} iters, {:.1}/s)",
            self.mean,
            self.p50,
            self.p99,
            self.min,
            self.iters,
            self.per_sec()
        )
    }
}

/// Time `f` repeatedly: `warmup` untimed runs, then iterate until `budget`
/// wall time or `max_iters`, whichever first (at least one iteration).
pub fn bench<F: FnMut()>(warmup: usize, budget: Duration, max_iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let start = Instant::now();
    let mut samples: Vec<Duration> = Vec::new();
    while (samples.is_empty() || start.elapsed() < budget) && samples.len() < max_iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    let q = |p: f64| samples[((p * samples.len() as f64) as usize).min(samples.len() - 1)];
    BenchStats {
        iters: samples.len(),
        mean: total / samples.len() as u32,
        p50: q(0.50),
        p99: q(0.99),
        min: samples[0],
    }
}

/// Convenience: run + print one named benchmark.
pub fn run_named<F: FnMut()>(name: &str, f: F) -> BenchStats {
    let stats = bench(2, Duration::from_secs(2), 10_000, f);
    println!("{name:<40} {stats}");
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reports_sane_statistics() {
        let stats = bench(1, Duration::from_millis(50), 1000, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(stats.iters >= 1);
        assert!(stats.min <= stats.p50 && stats.p50 <= stats.p99);
        assert!(stats.mean.as_nanos() > 0);
    }

    #[test]
    fn respects_iteration_cap() {
        let stats = bench(0, Duration::from_secs(10), 5, || {});
        assert_eq!(stats.iters, 5);
    }
}
