//! Test helpers (no `tempfile` crate in the offline build).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A unique temp directory, removed on drop.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    pub fn new() -> Self {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "infadapter-test-{}-{}-{}",
            std::process::id(),
            n,
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&path).expect("create temp dir");
        Self { path }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Default for TempDir {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}
