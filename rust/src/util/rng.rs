//! Deterministic PRNG + distribution samplers (no external crates).
//!
//! xoshiro256++ seeded via SplitMix64; samplers for the distributions the
//! serving substrate needs: uniform, standard normal (Box–Muller),
//! exponential, Poisson (inversion / PTRS for large mean), lognormal.

/// xoshiro256++ — fast, high-quality, reproducible across platforms.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller variate.
    gauss_spare: Option<f64>,
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        // SplitMix64 expansion, per Vigna's reference implementation.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Self {
            s,
            gauss_spare: None,
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // multiply-shift; bias negligible for our n
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Exponential with rate 1.
    pub fn exp1(&mut self) -> f64 {
        -self.f64().max(1e-300).ln()
    }

    /// Lognormal with the given *mean* and shape sigma:
    /// `exp(N(ln mean − σ²/2, σ))` so `E[X] = mean`.
    pub fn lognormal_mean(&mut self, mean: f64, sigma: f64) -> f64 {
        let mu = mean.ln() - sigma * sigma / 2.0;
        (mu + sigma * self.normal()).exp()
    }

    /// Poisson(λ): inversion for small λ, normal approximation for large.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            // Knuth inversion.
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        }
        // Normal approximation with continuity correction (adequate for
        // workload generation at λ ≥ 30).
        let z = self.normal();
        (lambda + lambda.sqrt() * z + 0.5).max(0.0) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::seed_from_u64(1);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.005);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(2);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn exp1_mean() {
        let mut r = Rng::seed_from_u64(3);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| r.exp1()).sum();
        assert!((sum / n as f64 - 1.0).abs() < 0.02);
    }

    #[test]
    fn poisson_small_and_large_mean() {
        let mut r = Rng::seed_from_u64(4);
        for lambda in [0.5, 5.0, 50.0, 500.0] {
            let n = 20_000;
            let sum: u64 = (0..n).map(|_| r.poisson(lambda)).sum();
            let mean = sum as f64 / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.sqrt() * 0.1 + 0.05,
                "λ={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn lognormal_mean_parameterization() {
        let mut r = Rng::seed_from_u64(5);
        let n = 200_000;
        let sum: f64 = (0..n).map(|_| r.lognormal_mean(0.15, 0.3)).sum();
        assert!((sum / n as f64 - 0.15).abs() < 0.003);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::seed_from_u64(6);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
