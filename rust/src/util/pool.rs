//! Persistent worker pool for the fleet engine's parallel stages.
//!
//! [`WorkerPool`] replaces the per-stage `std::thread::scope` + fresh mpmc
//! channel machinery: workers are spawned once per run and park on a condvar
//! between stages, waking on a generation counter.  A dispatch publishes one
//! borrowed closure plus a task count; workers claim indices from a shared
//! cursor, so the fan-out allocates nothing and spawns nothing in steady
//! state.
//!
//! # Determinism
//!
//! The pool preserves the sharded data plane's bit-identity-by-construction
//! argument unchanged: each claimed index is executed exactly once, callers
//! hand workers disjoint `&mut` pairs per index, and any fan-in the caller
//! does afterwards is in index order.  Which worker runs which index — and
//! in what interleaving — can never influence results.
//!
//! # Panic discipline
//!
//! A panicking task sets an abort flag (so peers stop claiming), drains the
//! unclaimed indices (so the dispatcher cannot hang), and the first payload
//! is re-thrown from [`WorkerPool::dispatch`] on the caller's thread — the
//! same observable behavior as the old scoped path, which is kept here as
//! [`scoped_dispatch`] for benchmark comparison.

use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::util::mpmc;

/// Borrowed task closure, lifetime-erased for the worker threads.  Workers
/// only dereference it while executing an index claimed from the current
/// generation, and `dispatch` blocks until every claimed index has finished
/// — so the referent always outlives every use.
#[derive(Clone, Copy)]
struct Job(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (shared calls from many threads are fine)
// and `dispatch` guarantees it outlives the workers' use; the raw pointer
// itself is plain data.
unsafe impl Send for Job {}

#[derive(Default)]
struct State {
    /// Bumped once per dispatch; workers wake when it moves past the one
    /// they last served.
    generation: u64,
    job: Option<Job>,
    /// Task count of the current generation.
    n: usize,
    /// Next unclaimed index.
    next: usize,
    /// Claimed-but-unfinished plus unclaimed tasks; `dispatch` returns when
    /// this reaches zero.
    remaining: usize,
    /// Set by the first panicking task: peers stop claiming.
    abort: bool,
    panicked: Option<Box<dyn Any + Send>>,
    shutdown: bool,
    dispatches: u64,
    tasks: u64,
    /// Cumulative dispatch overhead: wall time of each dispatch minus the
    /// busiest worker's task time (only tracked when the pool is timed).
    overhead_ns: u64,
    /// Busiest worker's task nanoseconds within the current generation.
    busy_max_ns: u64,
}

struct Shared {
    state: Mutex<State>,
    /// Workers park here between generations.
    work: Condvar,
    /// The dispatcher parks here until `remaining == 0`.
    done: Condvar,
}

fn lock(m: &Mutex<State>) -> MutexGuard<'_, State> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

fn wait<'a>(cv: &Condvar, g: MutexGuard<'a, State>) -> MutexGuard<'a, State> {
    cv.wait(g).unwrap_or_else(PoisonError::into_inner)
}

/// Persistent fan-out pool; see the module docs.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    threads: usize,
    timed: bool,
}

impl WorkerPool {
    /// Spawn `threads` parked workers.  `timed` enables per-dispatch
    /// overhead accounting (one `Instant` read per task) for telemetry;
    /// leave it off on untimed runs so the hot path stays clock-free.
    pub fn new(threads: usize, timed: bool) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State::default()),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (0..threads)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("fleet-pool-{i}"))
                    .spawn(move || worker_loop(&shared, timed))
                    .expect("spawn pool worker")
            })
            .collect();
        Self {
            shared,
            handles,
            threads,
            timed,
        }
    }

    /// Run `f(0..n)` across the pool and block until every index finished.
    /// Each index is claimed and executed exactly once; a panicking task
    /// aborts the remainder and re-throws here.
    pub fn dispatch(&self, n: usize, f: &(dyn Fn(usize) + Sync)) {
        if n == 0 {
            return;
        }
        let t0 = self.timed.then(Instant::now);
        // SAFETY: erasing the lifetime is sound because this function blocks
        // below until `remaining == 0`, i.e. until no worker will touch the
        // pointer again.
        let job = Job(unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), *const (dyn Fn(usize) + Sync)>(f)
        });
        let mut st = lock(&self.shared.state);
        st.generation = st.generation.wrapping_add(1);
        st.job = Some(job);
        st.n = n;
        st.next = 0;
        st.remaining = n;
        st.abort = false;
        st.busy_max_ns = 0;
        st.dispatches += 1;
        st.tasks += n as u64;
        self.shared.work.notify_all();
        while st.remaining > 0 {
            st = wait(&self.shared.done, st);
        }
        st.job = None;
        let payload = st.panicked.take();
        if payload.is_none() {
            if let Some(t0) = t0 {
                let wall = t0.elapsed().as_nanos() as u64;
                st.overhead_ns += wall.saturating_sub(st.busy_max_ns);
            }
        }
        drop(st);
        if let Some(p) = payload {
            resume_unwind(p);
        }
    }

    /// Worker thread count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Dispatches served over the pool's lifetime.
    pub fn dispatches(&self) -> u64 {
        lock(&self.shared.state).dispatches
    }

    /// Tasks executed over the pool's lifetime.
    pub fn tasks(&self) -> u64 {
        lock(&self.shared.state).tasks
    }

    /// Cumulative fan-out overhead (dispatch wall time minus the busiest
    /// worker's task time).  Zero unless the pool was built with
    /// `timed = true`.
    pub fn overhead_ns(&self) -> u64 {
        lock(&self.shared.state).overhead_ns
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = lock(&self.shared.state);
            st.shutdown = true;
        }
        self.shared.work.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared, timed: bool) {
    let mut seen_gen = 0u64;
    loop {
        let mut st = lock(&shared.state);
        loop {
            if st.shutdown {
                return;
            }
            if st.generation != seen_gen && st.job.is_some() {
                break;
            }
            st = wait(&shared.work, st);
        }
        seen_gen = st.generation;
        let job = st.job.expect("job published with the generation bump");
        let n = st.n;
        let mut busy_ns = 0u64;
        loop {
            if st.abort || st.next >= n {
                break;
            }
            let i = st.next;
            st.next += 1;
            drop(st);
            let t0 = timed.then(Instant::now);
            // SAFETY: index `i` was claimed from the current generation, so
            // the dispatcher is still blocked on `remaining` and the closure
            // behind the pointer is alive.
            let res = catch_unwind(AssertUnwindSafe(|| unsafe { (*job.0)(i) }));
            if let Some(t0) = t0 {
                busy_ns += t0.elapsed().as_nanos() as u64;
            }
            st = lock(&shared.state);
            if let Err(payload) = res {
                st.abort = true;
                if st.panicked.is_none() {
                    st.panicked = Some(payload);
                }
                // Drain the indices nobody will claim so the dispatcher
                // cannot hang on `remaining`.
                st.remaining -= n - st.next;
                st.next = n;
            }
            st.remaining -= 1;
            if st.remaining == 0 {
                if busy_ns > st.busy_max_ns {
                    st.busy_max_ns = busy_ns;
                }
                shared.done.notify_all();
            }
        }
        // Both breaks above leave the lock held; fold this worker's busy
        // time in before the final release (max is idempotent, so the
        // finisher's early fold above double-counts nothing).
        if busy_ns > st.busy_max_ns {
            st.busy_max_ns = busy_ns;
        }
        drop(st);
    }
}

/// Sets the shared abort flag on drop — i.e. when a task panics past it.
struct PanicFlag<'a>(&'a AtomicBool);

impl Drop for PanicFlag<'_> {
    fn drop(&mut self) {
        self.0.store(true, Ordering::Relaxed);
    }
}

/// The pre-pool fan-out machinery, kept as the benchmark reference: a fresh
/// index channel plus scoped spawns per call, with the same abort-on-panic
/// discipline the pool inherits.  `micro_hotpaths` measures
/// `pool.scoped_spawn` against `pool.dispatch` to price the per-stage spawn
/// tax the persistent pool removes.
pub fn scoped_dispatch(threads: usize, n: usize, f: &(dyn Fn(usize) + Sync)) {
    let workers = threads.min(n);
    if workers <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let panicked = AtomicBool::new(false);
    let (tx, rx) = mpmc::channel();
    for i in 0..n {
        tx.send(i).unwrap_or_else(|_| unreachable!("receiver held open"));
    }
    drop(tx);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let rx = rx.clone();
            let panicked = &panicked;
            scope.spawn(move || {
                while let Some(i) = rx.recv() {
                    if panicked.load(Ordering::Relaxed) {
                        return;
                    }
                    let flag = PanicFlag(panicked);
                    f(i);
                    std::mem::forget(flag);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    fn counters(n: usize) -> Vec<AtomicU64> {
        (0..n).map(|_| AtomicU64::new(0)).collect()
    }

    #[test]
    fn dispatch_runs_every_index_exactly_once_across_generations() {
        let pool = WorkerPool::new(4, false);
        let hits = counters(64);
        for round in 1..=3u64 {
            pool.dispatch(64, &|i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), round, "index {i} round {round}");
            }
        }
        assert_eq!(pool.dispatches(), 3);
        assert_eq!(pool.tasks(), 192);
    }

    #[test]
    fn dispatch_handles_fewer_tasks_than_workers() {
        let pool = WorkerPool::new(16, false);
        let hits = counters(3);
        pool.dispatch(3, &|i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        pool.dispatch(0, &|_| unreachable!("n = 0 never runs"));
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn panicking_task_aborts_cleanly_and_pool_survives() {
        let pool = WorkerPool::new(8, false);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.dispatch(32, &|i| {
                if i == 5 {
                    panic!("boom at {i}");
                }
            });
        }));
        assert!(result.is_err(), "panic must propagate to the dispatcher");
        // the pool is reusable after an aborted generation
        let hits = counters(32);
        pool.dispatch(32, &|i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn timed_pool_accounts_overhead_monotonically() {
        let pool = WorkerPool::new(2, true);
        pool.dispatch(8, &|_| {});
        let first = pool.overhead_ns();
        pool.dispatch(8, &|_| {});
        assert!(pool.overhead_ns() >= first);
    }

    #[test]
    fn scoped_dispatch_matches_serial_and_propagates_panics() {
        let hits = counters(40);
        scoped_dispatch(8, 40, &|i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        let result = catch_unwind(AssertUnwindSafe(|| {
            scoped_dispatch(4, 16, &|i| {
                if i == 3 {
                    panic!("boom");
                }
            });
        }));
        assert!(result.is_err());
    }
}
