//! Minimal JSON codec (the Python↔Rust interchange: manifest.json,
//! profiles.json, config files, experiment outputs).
//!
//! Full JSON: objects, arrays, strings (with escapes, \uXXXX), numbers,
//! bools, null.  Parsing is recursive-descent; serialization pretty-prints
//! with two-space indents (matching `json.dumps(indent=2)` closely enough
//! for diffs).

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    // ---- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key)
            .with_context(|| format!("missing JSON key {key:?}"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected non-negative integer, got {f}");
        }
        Ok(f as usize)
    }

    pub fn as_u64(&self) -> Result<u64> {
        Ok(self.as_usize()? as u64)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    // ---- constructors ----------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_f64_slice(v: &[f64]) -> Value {
        Value::Arr(v.iter().map(|&x| Value::Num(x)).collect())
    }

    // ---- serialization ---------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push(']');
            }
            Value::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---- parsing ---------------------------------------------------------------

/// Parse a JSON document (must consume the whole input).
pub fn parse(text: &str) -> Result<Value> {
    let bytes = text.as_bytes();
    let mut pos = 0usize;
    let v = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        bail!("trailing characters at byte {pos}");
    }
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Value> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        bail!("unexpected end of input");
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Value::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Value::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Value::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Value::Null),
        _ => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Value) -> Result<Value> {
    if b.len() - *pos >= lit.len() && &b[*pos..*pos + lit.len()] == lit.as_bytes() {
        *pos += lit.len();
        Ok(v)
    } else {
        bail!("invalid literal at byte {pos}");
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Value> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos])?;
    let n: f64 = s
        .parse()
        .with_context(|| format!("invalid number {s:?} at byte {start}"))?;
    Ok(Value::Num(n))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    loop {
        if *pos >= b.len() {
            bail!("unterminated string");
        }
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    bail!("unterminated escape");
                }
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        if *pos + 4 >= b.len() {
                            bail!("truncated \\u escape");
                        }
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])?;
                        let code = u32::from_str_radix(hex, 16)
                            .with_context(|| format!("bad \\u escape {hex:?}"))?;
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    c => bail!("bad escape \\{}", c as char),
                }
                *pos += 1;
            }
            _ => {
                // copy one UTF-8 scalar
                let s = std::str::from_utf8(&b[*pos..])?;
                let c = s.chars().next().unwrap();
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Value> {
    *pos += 1; // '['
    let mut out = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Value::Arr(out));
    }
    loop {
        out.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        if *pos >= b.len() {
            bail!("unterminated array");
        }
        match b[*pos] {
            b',' => *pos += 1,
            b']' => {
                *pos += 1;
                return Ok(Value::Arr(out));
            }
            c => bail!("expected ',' or ']', got {:?}", c as char),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Value> {
    *pos += 1; // '{'
    let mut out = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Value::Obj(out));
    }
    loop {
        skip_ws(b, pos);
        if *pos >= b.len() || b[*pos] != b'"' {
            bail!("expected object key at byte {pos}");
        }
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if *pos >= b.len() || b[*pos] != b':' {
            bail!("expected ':' at byte {pos}");
        }
        *pos += 1;
        let val = parse_value(b, pos)?;
        out.insert(key, val);
        skip_ws(b, pos);
        if *pos >= b.len() {
            bail!("unterminated object");
        }
        match b[*pos] {
            b',' => *pos += 1,
            b'}' => {
                *pos += 1;
                return Ok(Value::Obj(out));
            }
            c => bail!("expected ',' or '}}', got {:?}", c as char),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-1.5e3").unwrap(), Value::Num(-1500.0));
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("\"hi\\n\"").unwrap(), Value::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": {"e": false}}"#).unwrap();
        assert_eq!(v.req("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.req("a").unwrap().as_arr().unwrap()[2]
                .req("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "c"
        );
        assert!(!v.req("d").unwrap().req("e").unwrap().as_bool().unwrap());
    }

    #[test]
    fn roundtrips_pretty_and_compact() {
        let src = r#"{"name":"x","vals":[1,2.5,null],"nested":{"ok":true}}"#;
        let v = parse(src).unwrap();
        for text in [v.to_string_pretty(), v.to_string_compact()] {
            assert_eq!(parse(&text).unwrap(), v);
        }
    }

    #[test]
    fn handles_escapes_and_unicode() {
        let v = parse(r#""tab\there é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "tab\there é");
        let back = parse(&Value::Str("a\"b\\c\n".into()).to_string_compact()).unwrap();
        assert_eq!(back.as_str().unwrap(), "a\"b\\c\n");
    }

    #[test]
    fn rejects_malformed_input() {
        for bad in ["{", "[1,", "\"unterminated", "{\"a\" 1}", "1 2", "tru"] {
            assert!(parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn parses_python_style_manifest_snippet() {
        let text = r#"{
  "input_hw": 32,
  "variants": [
    {"name": "resnet18", "accuracy": 69.76, "hlo": {"1": "resnet18.b1.hlo.txt"}}
  ],
  "forecaster": null
}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.req("input_hw").unwrap().as_usize().unwrap(), 32);
        assert!(matches!(v.req("forecaster").unwrap(), Value::Null));
    }
}
