//! Self-built substrates (the build is fully offline: `anyhow` is a
//! vendored shim under `vendor/anyhow` and the `xla` PJRT bindings are
//! replaced by `runtime::xla_stub` — see Cargo.toml).
//!
//! * [`rng`] — xoshiro256++ PRNG with normal / exponential / Poisson /
//!   lognormal samplers.
//! * [`json`] — minimal, correct JSON value codec (manifest/config/profiles
//!   interchange with the Python layer).
//! * [`mpmc`] — multi-producer multi-consumer FIFO channel (worker pools).
//! * [`pool`] — persistent generation-parked worker pool for the fleet
//!   engine's parallel stages (zero spawns/allocations per dispatch).
//! * [`sched`] — hierarchical calendar queue ([`sched::TimerWheel`]) with
//!   heap-exact `(t, seq)` pop order for shard event scheduling.
//! * [`benchkit`] — timing harness for the `harness = false` benches.

pub mod benchkit;
pub mod json;
pub mod mpmc;
pub mod pool;
pub mod rng;
pub mod sched;
pub mod testutil;
