//! Self-built substrates (the build is fully offline: `anyhow` is a
//! vendored shim under `vendor/anyhow` and the `xla` PJRT bindings are
//! replaced by `runtime::xla_stub` — see Cargo.toml).
//!
//! * [`rng`] — xoshiro256++ PRNG with normal / exponential / Poisson /
//!   lognormal samplers.
//! * [`json`] — minimal, correct JSON value codec (manifest/config/profiles
//!   interchange with the Python layer).
//! * [`mpmc`] — multi-producer multi-consumer FIFO channel (worker pools).
//! * [`benchkit`] — timing harness for the `harness = false` benches.

pub mod benchkit;
pub mod json;
pub mod mpmc;
pub mod rng;
pub mod testutil;
