//! Multi-producer multi-consumer FIFO channel (Mutex + Condvar).
//!
//! The worker pools need an MPMC queue (std::sync::mpsc receivers are not
//! cloneable).  Throughput requirements are modest — requests arrive at
//! trace rates, far below contention limits — so a mutexed VecDeque with a
//! condvar is the right complexity point.
//!
//! Close protocol, symmetric on both halves:
//! * last `Sender` dropped → channel closes; receivers drain the queue and
//!   then see `None`.
//! * last `Receiver` dropped → channel closes; sends fail with
//!   [`SendError`] instead of queueing items nobody can pop.
//!
//! Ordering guarantee: pushes and pops serialize under one mutex, so every
//! consumer observes a subsequence of a single total order — in
//! particular, items from any one producer arrive at any one consumer in
//! the order they were sent (asserted by the stress test below).
//!
//! Panic tolerance: every lock acquisition shrugs off mutex poisoning
//! (`PoisonError::into_inner`).  The queue's invariants hold at every
//! await point — state mutations are single assignments under the lock —
//! so a worker that panicked while holding it leaves valid state behind,
//! and the one real panic should propagate to the caller instead of
//! cascading into `PoisonError` unwinds on every other worker (see
//! `fleet::shard::parallel_zip`'s panic discipline).

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};

struct Shared<T> {
    queue: Mutex<ChannelState<T>>,
    available: Condvar,
}

impl<T> Shared<T> {
    /// Lock the channel state, treating a poisoned mutex as still valid.
    fn lock(&self) -> MutexGuard<'_, ChannelState<T>> {
        self.queue.lock().unwrap_or_else(PoisonError::into_inner)
    }
}

struct ChannelState<T> {
    items: VecDeque<T>,
    senders: usize,
    receivers: usize,
    closed: bool,
}

/// Sending half; clone freely.
pub struct Sender<T> {
    shared: Arc<Shared<T>>,
}

/// Receiving half; clone freely.
pub struct Receiver<T> {
    shared: Arc<Shared<T>>,
}

/// Create an unbounded MPMC channel.
pub fn channel<T>() -> (Sender<T>, Receiver<T>) {
    let shared = Arc::new(Shared {
        queue: Mutex::new(ChannelState {
            items: VecDeque::new(),
            senders: 1,
            receivers: 1,
            closed: false,
        }),
        available: Condvar::new(),
    });
    (
        Sender {
            shared: shared.clone(),
        },
        Receiver { shared },
    )
}

/// Error returned when sending into a closed channel.
#[derive(Debug, PartialEq, Eq)]
pub struct SendError<T>(pub T);

impl<T> Sender<T> {
    pub fn send(&self, item: T) -> Result<(), SendError<T>> {
        let mut st = self.shared.lock();
        if st.closed {
            return Err(SendError(item));
        }
        st.items.push_back(item);
        drop(st);
        self.shared.available.notify_one();
        Ok(())
    }

    /// Queued item count (backpressure signals).
    pub fn len(&self) -> usize {
        self.shared.lock().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.shared.lock().senders += 1;
        Self {
            shared: self.shared.clone(),
        }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut st = self.shared.lock();
        st.senders -= 1;
        if st.senders == 0 {
            st.closed = true;
            drop(st);
            self.shared.available.notify_all();
        }
    }
}

impl<T> Receiver<T> {
    /// Block until an item is available or all senders are gone.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.shared.lock();
        loop {
            if let Some(item) = st.items.pop_front() {
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self
                .shared
                .available
                .wait(st)
                .unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<T> {
        self.shared.lock().items.pop_front()
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        self.shared.lock().receivers += 1;
        Self {
            shared: self.shared.clone(),
        }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        let mut st = self.shared.lock();
        st.receivers -= 1;
        if st.receivers == 0 {
            // Nobody can ever pop again: close so senders fail fast
            // instead of growing the queue forever, and wake any racing
            // receivers mid-drop (they already hold clones, so this arm
            // only fires for the last one).
            st.closed = true;
            drop(st);
            self.shared.available.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn fifo_single_thread() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(rx.try_recv(), None);
    }

    #[test]
    fn recv_returns_none_after_all_senders_drop() {
        let (tx, rx) = channel::<u32>();
        tx.send(7).unwrap();
        drop(tx);
        assert_eq!(rx.recv(), Some(7));
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn send_fails_on_closed_channel() {
        let (tx, rx) = channel::<u32>();
        let tx2 = tx.clone();
        drop(tx);
        drop(tx2);
        // channel closed; a fresh handle can't exist, but cloning rx is fine
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn mpmc_all_items_delivered_exactly_once() {
        let (tx, rx) = channel::<u64>();
        let n_producers = 4;
        let n_consumers = 4;
        let per_producer = 1000u64;
        let mut producers = Vec::new();
        for p in 0..n_producers {
            let tx = tx.clone();
            producers.push(thread::spawn(move || {
                for i in 0..per_producer {
                    tx.send(p * per_producer + i).unwrap();
                }
            }));
        }
        drop(tx);
        let mut consumers = Vec::new();
        for _ in 0..n_consumers {
            let rx = rx.clone();
            consumers.push(thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(v) = rx.recv() {
                    got.push(v);
                }
                got
            }));
        }
        for p in producers {
            p.join().unwrap();
        }
        let mut all: Vec<u64> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        let expect: Vec<u64> = (0..n_producers * per_producer).collect();
        assert_eq!(all, expect);
    }

    #[test]
    fn send_fails_after_all_receivers_drop() {
        let (tx, rx) = channel::<u32>();
        let rx2 = rx.clone();
        tx.send(1).unwrap();
        drop(rx);
        tx.send(2).unwrap(); // one receiver still alive
        drop(rx2);
        assert_eq!(tx.send(3), Err(SendError(3)));
        assert_eq!(tx.len(), 2, "queued items are not discarded on close");
    }

    #[test]
    fn mpmc_stress_conserves_total_order() {
        // 8 producers x 8 consumers x 2000 items each.  Two assertions:
        // (a) exact-once delivery — the union of everything the consumers
        //     popped is exactly the multiset sent, nothing lost, nothing
        //     duplicated; (b) per-producer FIFO per consumer — because
        //     pops serialize under the mutex, each consumer's stream is a
        //     subsequence of one total order, so the sequence numbers it
        //     sees from any single producer must be strictly increasing.
        let (tx, rx) = channel::<(u64, u64)>();
        let n_producers = 8u64;
        let n_consumers = 8;
        let per_producer = 2000u64;
        let mut producers = Vec::new();
        for p in 0..n_producers {
            let tx = tx.clone();
            producers.push(thread::spawn(move || {
                for i in 0..per_producer {
                    tx.send((p, i)).unwrap();
                }
            }));
        }
        drop(tx);
        let mut consumers = Vec::new();
        for _ in 0..n_consumers {
            let rx = rx.clone();
            consumers.push(thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(v) = rx.recv() {
                    got.push(v);
                }
                got
            }));
        }
        drop(rx);
        for p in producers {
            p.join().unwrap();
        }
        let mut all: Vec<(u64, u64)> = Vec::new();
        for c in consumers {
            let got = c.join().unwrap();
            let mut last_seq = vec![None::<u64>; n_producers as usize];
            for &(p, i) in &got {
                if let Some(prev) = last_seq[p as usize] {
                    assert!(
                        i > prev,
                        "producer {p} reordered at this consumer: {prev} then {i}"
                    );
                }
                last_seq[p as usize] = Some(i);
            }
            all.extend(got);
        }
        all.sort_unstable();
        let expect: Vec<(u64, u64)> = (0..n_producers)
            .flat_map(|p| (0..per_producer).map(move |i| (p, i)))
            .collect();
        assert_eq!(all, expect);
    }
}
