//! Hierarchical calendar queue — the shard event scheduler.
//!
//! [`TimerWheel`] replaces the per-shard `BinaryHeap<Reverse<Event>>` with a
//! two-level calendar queue bucketed by virtual time: a *fine* ring of `n0`
//! buckets of width `w0` seconds covering the current coarse window, a
//! *coarse* ring of `n1` buckets of width `n0 * w0` seconds, and an unsorted
//! overflow list for events beyond the coarse horizon.  Push and pop are
//! O(1) amortized; each fine bucket drains as one small, cache-friendly
//! sorted batch instead of a pointer-chasing heap sift.
//!
//! # Exactness
//!
//! Pop order is element-for-element identical to the binary heap this
//! replaces: ascending `(t, seq)` with `f64::total_cmp` on the timestamp and
//! the monotone sequence stamp as the tiebreak.  The argument:
//!
//! * the fine-bucket index `b0(t) = floor(t / w0)` is monotone in `t`, so an
//!   event in an earlier bucket never sorts after one in a later bucket;
//! * the current bucket is kept fully sorted (descending, so the minimum sits
//!   at the tail and `pop` is a `Vec::pop`) and is completely drained before
//!   the wheel advances — late pushes that land at or before the current
//!   bucket are sorted-inserted in place, exactly where the heap would have
//!   surfaced them;
//! * within a bucket, ties on `t` (events stamped precisely at an adapter or
//!   cluster boundary) break on `seq`, exactly as the heap's `Ord` did.
//!
//! Geometry (`w0`, `n0`, `n1`) therefore affects performance only, never
//! order — the property suite in `tests/sched.rs` randomizes it while
//! asserting heap equivalence.

use std::cmp::Ordering;

/// Ascending `(t, seq)` — the exact `Ord` the shard event heap used.
#[inline]
fn cmp_key(ta: f64, sa: u64, tb: f64, sb: u64) -> Ordering {
    ta.total_cmp(&tb).then(sa.cmp(&sb))
}

#[derive(Debug)]
struct Entry<T> {
    t: f64,
    seq: u64,
    item: T,
}

/// Two-level calendar queue keyed by `(t, seq)`; see the module docs for the
/// heap-equivalence argument.
#[derive(Debug)]
pub struct TimerWheel<T> {
    /// Fine bucket width in virtual seconds.
    w0: f64,
    /// Fine slots per coarse bucket.
    n0: usize,
    /// Coarse slots (the look-ahead window is `n0 * n1 * w0` seconds).
    n1: usize,
    /// Current bucket, sorted **descending** by `(t, seq)`: `pop()` takes
    /// from the tail, late pushes sorted-insert.  Holds every live entry
    /// with `b0(t) <= cur_b0`.
    cur: Vec<Entry<T>>,
    /// Absolute fine-bucket index `cur` covers (starts at -1: nothing
    /// drained yet).
    cur_b0: i64,
    /// Absolute coarse-bucket index the fine ring covers.
    b1cur: i64,
    /// Fine ring: entries with `b1(t) == b1cur` and `b0(t) > cur_b0`,
    /// slot `b0 % n0`, unsorted until drained.
    ring0: Vec<Vec<Entry<T>>>,
    count0: usize,
    /// Coarse ring: entries with `b1(t) - b1cur ∈ (0, n1]`, slot `b1 % n1`
    /// (residues are unique within the window, so a slot never mixes
    /// coarse buckets).
    ring1: Vec<Vec<Entry<T>>>,
    count1: usize,
    /// Entries beyond the coarse window; re-routed whenever the window
    /// advances.
    overflow: Vec<Entry<T>>,
    len: usize,
    pushes: u64,
    high_water: usize,
    cascades: u64,
}

impl<T> Default for TimerWheel<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> TimerWheel<T> {
    /// Default geometry: 31.25 ms fine buckets, 2 s coarse buckets, 512 s
    /// look-ahead window.
    pub fn new() -> Self {
        Self::with_geometry(1.0 / 32.0, 64, 256)
    }

    /// Explicit geometry.  `w0` is the fine bucket width in seconds, `n0`
    /// the fine slots per coarse bucket, `n1` the coarse slots.
    pub fn with_geometry(w0: f64, n0: usize, n1: usize) -> Self {
        assert!(w0.is_finite() && w0 > 0.0, "fine bucket width must be > 0");
        assert!(n0 >= 1 && n1 >= 1, "ring sizes must be >= 1");
        Self {
            w0,
            n0,
            n1,
            cur: Vec::new(),
            cur_b0: -1,
            b1cur: 0,
            ring0: (0..n0).map(|_| Vec::new()).collect(),
            count0: 0,
            ring1: (0..n1).map(|_| Vec::new()).collect(),
            count1: 0,
            overflow: Vec::new(),
            len: 0,
            pushes: 0,
            high_water: 0,
            cascades: 0,
        }
    }

    /// Geometry sized from a trace: fine buckets hold ~a handful of events
    /// at the peak arrival rate, and the coarse window covers the whole
    /// horizon so steady-state traffic never touches the overflow list.
    pub fn sized_for(peak_rate: f64, horizon_s: f64) -> Self {
        let peak = if peak_rate.is_finite() {
            peak_rate.max(1.0)
        } else {
            1.0
        };
        let mut w0 = 0.25;
        while w0 * peak > 4.0 && w0 > 1.0 / 1024.0 {
            w0 *= 0.5;
        }
        let n0 = 64;
        let coarse = w0 * n0 as f64;
        let horizon = if horizon_s.is_finite() {
            horizon_s.max(1.0)
        } else {
            1.0
        };
        let n1 = ((horizon / coarse).ceil() as usize + 2).clamp(64, 4096);
        Self::with_geometry(w0, n0, n1)
    }

    #[inline]
    fn b0_of(&self, t: f64) -> i64 {
        // `as` saturates (and maps NaN to 0), so degenerate timestamps
        // still route somewhere; order within `cur` is by total_cmp anyway.
        (t / self.w0).floor() as i64
    }

    /// Route an entry to its level per the invariants above.  Shared by
    /// `push`, cascades, and overflow rescue.
    fn route(&mut self, e: Entry<T>) {
        let b0 = self.b0_of(e.t);
        if b0 <= self.cur_b0 {
            // At or before the drain point: sorted-insert into the current
            // batch so pop order still matches the heap exactly.
            let idx = self
                .cur
                .partition_point(|x| cmp_key(x.t, x.seq, e.t, e.seq) == Ordering::Greater);
            self.cur.insert(idx, e);
            return;
        }
        let b1 = b0.div_euclid(self.n0 as i64);
        if b1 == self.b1cur {
            self.ring0[b0.rem_euclid(self.n0 as i64) as usize].push(e);
            self.count0 += 1;
        } else if b1 - self.b1cur <= self.n1 as i64 {
            self.ring1[b1.rem_euclid(self.n1 as i64) as usize].push(e);
            self.count1 += 1;
        } else {
            self.overflow.push(e);
        }
    }

    /// Schedule `item` at virtual time `t` with tiebreak stamp `seq`.
    pub fn push(&mut self, t: f64, seq: u64, item: T) {
        self.pushes += 1;
        self.len += 1;
        if self.len > self.high_water {
            self.high_water = self.len;
        }
        self.route(Entry { t, seq, item });
    }

    /// Pull overflow entries that now fit the coarse window back in.
    fn rescue_overflow(&mut self) {
        let limit = self.b1cur + self.n1 as i64;
        let mut i = 0;
        while i < self.overflow.len() {
            let b1 = self.b0_of(self.overflow[i].t).div_euclid(self.n0 as i64);
            if b1 <= limit {
                let e = self.overflow.swap_remove(i);
                self.route(e);
            } else {
                i += 1;
            }
        }
    }

    /// Advance the wheel until `cur` holds the next batch (or the wheel is
    /// empty).  Amortized O(1): each entry is touched at most once per
    /// level on its way to `cur`.
    fn ensure_front(&mut self) {
        while self.cur.is_empty() && self.len > 0 {
            if self.count0 > 0 {
                // Drain the next non-empty fine slot of the current coarse
                // bucket as one sorted batch.
                let base = self.b1cur * self.n0 as i64;
                let start = (self.cur_b0 + 1 - base).max(0) as usize;
                let s = (start..self.n0)
                    .find(|&s| !self.ring0[s].is_empty())
                    .expect("count0 > 0 implies a non-empty fine slot ahead");
                std::mem::swap(&mut self.cur, &mut self.ring0[s]);
                self.count0 -= self.cur.len();
                self.cur.sort_unstable_by(|a, b| cmp_key(b.t, b.seq, a.t, a.seq));
                self.cur_b0 = base + s as i64;
                continue;
            }
            if self.count1 > 0 {
                // Cascade the next non-empty coarse bucket into the fine
                // ring; residue uniqueness means the slot holds exactly one
                // coarse bucket's entries.
                let d = (1..=self.n1 as i64)
                    .find(|&d| {
                        let s = (self.b1cur + d).rem_euclid(self.n1 as i64) as usize;
                        !self.ring1[s].is_empty()
                    })
                    .expect("count1 > 0 implies a non-empty coarse slot in the window");
                let b1 = self.b1cur + d;
                let s = b1.rem_euclid(self.n1 as i64) as usize;
                let entries = std::mem::take(&mut self.ring1[s]);
                self.count1 -= entries.len();
                self.b1cur = b1;
                self.cur_b0 = b1 * self.n0 as i64 - 1;
                self.cascades += 1;
                for e in entries {
                    self.route(e);
                }
                // The window advanced: overflow entries may fit now.
                self.rescue_overflow();
                continue;
            }
            // Only overflow left: restart the window just before the
            // earliest overflow bucket and re-route; the next iteration
            // cascades it.
            let min_b1 = self
                .overflow
                .iter()
                .map(|e| self.b0_of(e.t).div_euclid(self.n0 as i64))
                .min()
                .expect("len > 0 with empty rings implies overflow entries");
            self.b1cur = min_b1 - 1;
            self.cur_b0 = min_b1 * self.n0 as i64 - 1;
            self.rescue_overflow();
        }
    }

    /// The next `(t, seq, item)` in pop order, without removing it.
    pub fn peek(&mut self) -> Option<(f64, u64, &T)> {
        self.ensure_front();
        self.cur.last().map(|e| (e.t, e.seq, &e.item))
    }

    /// Remove and return the next entry in ascending `(t, seq)` order.
    pub fn pop(&mut self) -> Option<(f64, u64, T)> {
        self.ensure_front();
        let e = self.cur.pop()?;
        self.len -= 1;
        Some((e.t, e.seq, e.item))
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Total pushes over the wheel's lifetime.
    pub fn pushes(&self) -> u64 {
        self.pushes
    }

    /// Peak number of simultaneously scheduled events.
    pub fn high_water(&self) -> usize {
        self.high_water
    }

    /// Coarse-bucket cascades performed (each touches one bucket's entries).
    pub fn cascades(&self) -> u64 {
        self.cascades
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain<T>(w: &mut TimerWheel<T>) -> Vec<(f64, u64)> {
        let mut out = Vec::new();
        while let Some((t, s, _)) = w.pop() {
            out.push((t, s));
        }
        out
    }

    #[test]
    fn pops_in_heap_order_with_coincident_timestamps() {
        let mut w = TimerWheel::with_geometry(0.5, 4, 4);
        // deliberately shuffled, with exact ties on t broken by seq
        let evs = [
            (3.0, 7),
            (0.1, 2),
            (3.0, 4),
            (0.1, 1),
            (1.0, 3),
            (0.0, 0),
            (1.0, 9),
        ];
        for &(t, s) in &evs {
            w.push(t, s, ());
        }
        let mut want: Vec<(f64, u64)> = evs.to_vec();
        want.sort_by(|a, b| cmp_key(a.0, a.1, b.0, b.1));
        assert_eq!(drain(&mut w), want);
        assert!(w.is_empty());
    }

    #[test]
    fn late_push_into_drained_bucket_pops_in_place() {
        let mut w = TimerWheel::with_geometry(1.0, 4, 4);
        w.push(0.2, 0, ());
        w.push(5.0, 1, ());
        assert_eq!(w.pop().map(|(t, s, _)| (t, s)), Some((0.2, 0)));
        // the wheel has advanced past bucket 0; a push behind the drain
        // point must still surface before the 5.0 event
        w.push(0.7, 2, ());
        w.push(0.7, 1, ());
        assert_eq!(
            drain(&mut w),
            vec![(0.7, 1), (0.7, 2), (5.0, 1)],
            "late pushes sorted-insert into the current batch"
        );
    }

    #[test]
    fn far_future_events_survive_overflow_and_cascades() {
        let mut w = TimerWheel::with_geometry(0.5, 2, 2);
        // window is 2 * 2 * 0.5 = 2 s; these span far beyond it
        let evs = [(1000.0, 5), (0.3, 0), (999.75, 4), (3.0, 1), (40.0, 2)];
        for &(t, s) in &evs {
            w.push(t, s, ());
        }
        let mut want: Vec<(f64, u64)> = evs.to_vec();
        want.sort_by(|a, b| cmp_key(a.0, a.1, b.0, b.1));
        assert_eq!(drain(&mut w), want);
        assert!(w.cascades() > 0, "tiny geometry must have cascaded");
    }

    #[test]
    fn interleaved_push_pop_stays_exact() {
        let mut w = TimerWheel::with_geometry(0.25, 4, 8);
        let mut seq = 0u64;
        let mut rng = 0x2545F491u64;
        let mut next = |hi: f64| {
            rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (rng >> 33) as f64 / (1u64 << 31) as f64 * hi
        };
        for _ in 0..64 {
            w.push(next(10.0), seq, ());
            seq += 1;
        }
        let mut last = (f64::NEG_INFINITY, 0u64);
        for _ in 0..512 {
            let (t, s, _) = w.pop().expect("non-empty");
            assert!(
                cmp_key(last.0, last.1, t, s) != Ordering::Greater,
                "pop order regressed: {last:?} then {:?}",
                (t, s)
            );
            last = (t, s);
            // reschedule ahead of the popped time, like a completion event
            w.push(t + 0.1 + next(3.0), seq, ());
            seq += 1;
        }
        assert_eq!(w.len(), 64);
        assert_eq!(w.high_water(), 64);
    }

    #[test]
    fn sized_for_clamps_geometry_to_sane_bounds() {
        let w = TimerWheel::<()>::sized_for(100_000.0, 1e12);
        assert!(w.w0 >= 1.0 / 1024.0);
        assert!(w.n1 <= 4096);
        let w = TimerWheel::<()>::sized_for(0.0, 0.0);
        assert!(w.n1 >= 64);
        assert!((w.w0 - 0.25).abs() < 1e-12);
    }

    #[test]
    fn high_water_tracks_peak_occupancy() {
        let mut w = TimerWheel::with_geometry(1.0, 4, 4);
        for i in 0..10u64 {
            w.push(i as f64, i, ());
        }
        for _ in 0..6 {
            w.pop();
        }
        w.push(100.0, 11, ());
        assert_eq!(w.high_water(), 10);
        assert_eq!(w.len(), 5);
        assert_eq!(w.pushes(), 11);
    }
}
