//! Trace generators (see module docs in `mod.rs`).

use super::RateSeries;
use crate::dispatcher::Tier;
use crate::util::rng::Rng;
use anyhow::{Context, Result};
use std::path::Path;

/// Parse a `tier:weight[,tier:weight]*` class mix (the CSV `# tiers:`
/// directive).
fn parse_tier_mix(s: &str) -> Result<Vec<(Tier, f64)>> {
    s.split(',')
        .map(|pair| -> Result<(Tier, f64)> {
            let (t, w) = pair
                .trim()
                .split_once(':')
                .with_context(|| format!("expected tier:weight, got {pair:?}"))?;
            let tier: Tier = t.trim().parse().with_context(|| format!("bad tier {t:?}"))?;
            let weight: f64 = w.trim().parse().with_context(|| format!("bad weight {w:?}"))?;
            anyhow::ensure!(
                weight.is_finite() && weight >= 0.0,
                "weight {weight} must be finite and >= 0"
            );
            Ok((tier, weight))
        })
        .collect()
}

/// Namespace for the generators.
pub struct Trace;

impl Trace {
    /// Constant-rate trace (tests, profiling saturation points).
    pub fn steady(rps: f64, seconds: usize) -> RateSeries {
        RateSeries {
            rates: vec![rps; seconds],
            name: format!("steady-{rps}rps"),
            class_mix: Vec::new(),
        }
    }

    /// The paper's bursty 20-minute sample (Figure 5):
    /// steady base, sharp spike, gradual decay, return to base.
    ///
    /// `base` is the steady rate, `peak` the spike top.  Defaults in the
    /// figure benches: base 40, peak 100 (the published plot's axis scale).
    pub fn bursty(base: f64, peak: f64, seconds: usize, seed: u64) -> RateSeries {
        let mut rng = Rng::seed_from_u64(seed);
        let mut rates = Vec::with_capacity(seconds);
        let t_spike = (seconds as f64 * 0.5) as usize; // 600 of 1200
        let t_decay = (seconds as f64 * 2.0 / 3.0) as usize; // 800
        let t_return = (seconds as f64 * 5.0 / 6.0) as usize; // 1000
        for t in 0..seconds {
            let shape = if t < t_spike {
                base
            } else if t < t_decay {
                // fast ramp to the peak within ~20 s, then hold
                let dt = (t - t_spike) as f64;
                base + (peak - base) * (1.0 - (-dt / 8.0).exp())
            } else if t < t_return {
                // gradual decay back towards base
                let frac = (t - t_decay) as f64 / (t_return - t_decay) as f64;
                base + (peak - base) * (1.0 - frac)
            } else {
                base
            };
            let noise: f64 = rng.normal() * 0.03 * shape;
            rates.push((shape + noise).max(0.0));
        }
        RateSeries {
            rates,
            name: format!("bursty-{base}-{peak}"),
            class_mix: Vec::new(),
        }
    }

    /// A single burst at a chosen phase: steady `base` everywhere except a
    /// spike window `[start, start + len)` — fast exponential attack to
    /// `peak` over the first part of the window, hold, then linear decay
    /// back to `base` over the final 40%.  The fleet experiments interleave
    /// several of these (one per service, staggered starts) so bursts hit
    /// the shared cluster at different times.
    pub fn burst_window(
        base: f64,
        peak: f64,
        seconds: usize,
        start: usize,
        len: usize,
        seed: u64,
    ) -> RateSeries {
        let mut rng = Rng::seed_from_u64(seed);
        let end = (start + len).min(seconds);
        let decay_from = start + (len as f64 * 0.6) as usize;
        let rates = (0..seconds)
            .map(|t| {
                let shape = if t < start || t >= end {
                    base
                } else if t < decay_from {
                    // fast ramp to the peak within ~20 s, then hold
                    let dt = (t - start) as f64;
                    base + (peak - base) * (1.0 - (-dt / 8.0).exp())
                } else {
                    let frac = (t - decay_from) as f64 / (end - decay_from).max(1) as f64;
                    base + (peak - base) * (1.0 - frac)
                };
                let noise: f64 = rng.normal() * 0.03 * shape;
                (shape + noise).max(0.0)
            })
            .collect();
        RateSeries {
            rates,
            name: format!("burst-{base}-{peak}@{start}+{len}"),
            class_mix: Vec::new(),
        }
    }

    /// Parse a trace spec string (the CLI / `FleetConfig` grammar):
    /// `bursty | non-bursty | twitter | steady:<rps> |
    /// csv:<path>[:scale=<k>][:loop=<seconds>] |
    /// burst:<start_s>:<len_s>[:<peak_rps>]` — `base` scales the
    /// generators the same way the CLI's `--base` flag always has
    /// (`burst` defaults its peak to `2.5 × base`).  The `csv:` options
    /// adapt a recorded or external trace to a scenario: `scale=` host-
    /// scales the rates, `loop=` repeats the series cyclically out to the
    /// scenario horizon; both preserve a `# tiers:` class mix.
    pub fn from_spec(spec: &str, base: f64, seconds: usize, seed: u64) -> Result<RateSeries> {
        Ok(match spec {
            "bursty" => Trace::bursty(base, base * 2.5, seconds, seed),
            "non-bursty" => Trace::non_bursty(base * 0.5, base * 1.5, seconds, seed),
            "twitter" => Trace::twitter_like(base, seconds, seed),
            other => {
                if let Some(rps) = other.strip_prefix("steady:") {
                    Trace::steady(rps.parse()?, seconds)
                } else if let Some(rest) = other.strip_prefix("csv:") {
                    // options pop off the end, so paths containing ':'
                    // keep working
                    let mut segs: Vec<&str> = rest.split(':').collect();
                    let mut scale: Option<f64> = None;
                    let mut tile: Option<usize> = None;
                    while segs.len() > 1 {
                        let last = segs[segs.len() - 1];
                        if let Some(k) = last.strip_prefix("scale=") {
                            anyhow::ensure!(scale.is_none(), "duplicate scale= in {other}");
                            scale = Some(
                                k.parse().with_context(|| format!("bad scale in {other}"))?,
                            );
                        } else if let Some(s) = last.strip_prefix("loop=") {
                            anyhow::ensure!(tile.is_none(), "duplicate loop= in {other}");
                            tile = Some(
                                s.parse().with_context(|| format!("bad loop in {other}"))?,
                            );
                        } else {
                            break;
                        }
                        segs.pop();
                    }
                    let path = segs.join(":");
                    let mut t = Trace::from_csv(Path::new(&path))?;
                    if let Some(k) = scale {
                        t = t.scaled(k);
                    }
                    if let Some(s) = tile {
                        t = t.tiled(s);
                    }
                    t
                } else if let Some(rest) = other.strip_prefix("burst:") {
                    let parts: Vec<&str> = rest.split(':').collect();
                    anyhow::ensure!(
                        parts.len() == 2 || parts.len() == 3,
                        "burst:<start_s>:<len_s>[:<peak_rps>], got {other}"
                    );
                    let start: usize = parts[0].parse()?;
                    let len: usize = parts[1].parse()?;
                    let peak: f64 = match parts.get(2) {
                        Some(p) => p.parse()?,
                        None => base * 2.5,
                    };
                    Trace::burst_window(base, peak, seconds, start, len, seed)
                } else {
                    anyhow::bail!("unknown trace spec {other} (see `infadapter` usage)")
                }
            }
        })
    }

    /// Smooth non-bursty oscillation (Figure 8): a slow sinusoid between
    /// `low` and `high` with mild noise.
    pub fn non_bursty(low: f64, high: f64, seconds: usize, seed: u64) -> RateSeries {
        let mut rng = Rng::seed_from_u64(seed);
        let mid = (low + high) / 2.0;
        let amp = (high - low) / 2.0;
        let rates = (0..seconds)
            .map(|t| {
                let phase = 2.0 * std::f64::consts::PI * t as f64 / (seconds as f64 / 2.0);
                let shape = mid + amp * phase.sin();
                let noise: f64 = rng.normal() * 0.02 * shape;
                (shape + noise).max(0.0)
            })
            .collect();
        RateSeries {
            rates,
            name: format!("non-bursty-{low}-{high}"),
            class_mix: Vec::new(),
        }
    }

    /// Twitter-like series: diurnal + hourly seasonality, AR(1) noise, and
    /// Poisson-arriving spikes with fast attack and exponential decay.
    ///
    /// Mirrors `python/compile/tracegen.py::twitter_like` (the LSTM's
    /// training distribution) — keep the two in sync.
    pub fn twitter_like(base: f64, seconds: usize, seed: u64) -> RateSeries {
        let mut rng = Rng::seed_from_u64(seed);
        let diurnal_amp = 0.35;
        let hourly_amp = 0.10;
        let noise_sigma = 0.03;
        let noise_rho = 0.97;
        let spike_rate = 1.0 / 1800.0;
        let spike_mag = 1.2;
        let spike_tau = 60.0;
        let spike_attack = 8.0;

        let mut rates = vec![0.0f64; seconds];
        let mut ar = 0.0f64;
        for (t, r) in rates.iter_mut().enumerate() {
            let tf = t as f64;
            let seasonal = 1.0
                + diurnal_amp * (2.0 * std::f64::consts::PI * tf / 86400.0).sin()
                + hourly_amp * (2.0 * std::f64::consts::PI * tf / 3600.0 + 1.3).sin();
            let eps: f64 = rng.normal() * noise_sigma;
            ar = noise_rho * ar + eps;
            *r = base * seasonal * (1.0 + ar);
        }
        let n_spikes = rng.poisson((spike_rate * seconds as f64).max(1e-9));
        for _ in 0..n_spikes {
            let t0 = rng.f64() * seconds as f64;
            let mag = base * spike_mag * rng.exp1();
            for (t, r) in rates.iter_mut().enumerate() {
                let dt = t as f64 - t0;
                if dt >= 0.0 {
                    *r += mag * (1.0 - (-dt / spike_attack).exp()) * (-dt / spike_tau).exp();
                }
            }
        }
        for r in rates.iter_mut() {
            *r = r.max(0.0);
        }
        RateSeries {
            rates,
            name: format!("twitter-like-{base}"),
            class_mix: Vec::new(),
        }
    }

    /// Load `t,rps` or single-column CSV (one row per second).
    ///
    /// Strict by construction: rows must have 1 or 2 comma-separated
    /// fields (the rate is the last), anything else is rejected with a
    /// line-numbered error — a three-column or shifted-column file no
    /// longer silently parses as the wrong series.  One non-numeric
    /// header row is allowed before the first data row; blank lines and
    /// `#` comments are skipped anywhere (CRLF and trailing newlines are
    /// harmless).  A `# tiers: 0:7,1:3` directive attaches a per-request
    /// class mix, so tiered scenarios survive the CSV round trip.
    pub fn from_csv(path: &Path) -> Result<RateSeries> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading trace {path:?}"))?;
        let mut rates = Vec::new();
        let mut class_mix = Vec::new();
        let mut header_allowed = true;
        for (i, raw) in text.lines().enumerate() {
            let lineno = i + 1;
            let line = raw.trim();
            if line.is_empty() {
                continue;
            }
            if let Some(comment) = line.strip_prefix('#') {
                if let Some(mix) = comment.trim().strip_prefix("tiers:") {
                    class_mix = parse_tier_mix(mix)
                        .with_context(|| format!("{path:?}:{lineno}: bad tiers directive"))?;
                }
                continue;
            }
            let fields: Vec<&str> = line.split(',').collect();
            anyhow::ensure!(
                fields.len() <= 2,
                "{path:?}:{lineno}: expected `rps` or `t,rps`, got {} columns",
                fields.len()
            );
            let field = fields.last().expect("split never yields zero fields").trim();
            match field.parse::<f64>() {
                Ok(v) => {
                    anyhow::ensure!(
                        v.is_finite() && v >= 0.0,
                        "{path:?}:{lineno}: rate {v} is not finite and non-negative"
                    );
                    rates.push(v);
                    header_allowed = false;
                }
                // the single allowed non-numeric row: a leading header
                Err(_) if header_allowed => header_allowed = false,
                Err(_) => anyhow::bail!("{path:?}:{lineno}: bad rate {field:?}"),
            }
        }
        anyhow::ensure!(!rates.is_empty(), "empty trace file {path:?}");
        Ok(RateSeries {
            rates,
            name: path.file_stem().unwrap_or_default().to_string_lossy().into_owned(),
            class_mix,
        })
    }

    /// Write a series as CSV (`t,rps` header included).  Rates are written
    /// full-precision (shortest round-tripping decimal), so
    /// `to_csv → from_csv` is value-exact — a trace exported from a
    /// recorded run replays bit-identically.  A non-empty class mix is
    /// written as a `# tiers:` directive [`Self::from_csv`] reads back.
    pub fn to_csv(series: &RateSeries, path: &Path) -> Result<()> {
        let mut out = String::from("t,rps\n");
        if !series.class_mix.is_empty() {
            let mix: Vec<String> = series
                .class_mix
                .iter()
                .map(|(t, w)| format!("{t}:{w}"))
                .collect();
            out.push_str(&format!("# tiers: {}\n", mix.join(",")));
        }
        for (t, r) in series.rates.iter().enumerate() {
            out.push_str(&format!("{t},{r}\n"));
        }
        std::fs::write(path, out).with_context(|| format!("writing trace {path:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bursty_has_the_papers_phases() {
        let t = Trace::bursty(40.0, 100.0, 1200, 1);
        let avg = |lo: usize, hi: usize| {
            t.rates[lo..hi].iter().sum::<f64>() / (hi - lo) as f64
        };
        let steady = avg(100, 500);
        let spike = avg(650, 790);
        let back = avg(1050, 1200);
        assert!((steady - 40.0).abs() < 4.0, "steady {steady}");
        assert!(spike > 85.0, "spike {spike}");
        assert!((back - 40.0).abs() < 4.0, "back {back}");
        // decay is monotone-ish downward
        assert!(avg(810, 850) > avg(950, 1000));
    }

    #[test]
    fn non_bursty_stays_in_band() {
        let t = Trace::non_bursty(20.0, 60.0, 1200, 2);
        assert!(t.max() < 70.0);
        assert!(t.rates.iter().cloned().fold(f64::MAX, f64::min) > 10.0);
        assert!((t.mean() - 40.0).abs() < 5.0);
    }

    #[test]
    fn twitter_like_is_deterministic_per_seed() {
        let a = Trace::twitter_like(40.0, 2000, 7);
        let b = Trace::twitter_like(40.0, 2000, 7);
        assert_eq!(a.rates, b.rates);
        let c = Trace::twitter_like(40.0, 2000, 8);
        assert_ne!(a.rates, c.rates);
    }

    #[test]
    fn twitter_like_is_nonnegative_and_near_base() {
        let t = Trace::twitter_like(40.0, 10_000, 3);
        assert!(t.rates.iter().all(|&r| r >= 0.0));
        assert!((t.mean() - 40.0).abs() < 15.0, "mean {}", t.mean());
    }

    #[test]
    fn burst_window_spikes_only_inside_its_window() {
        let t = Trace::burst_window(30.0, 150.0, 600, 200, 100, 7);
        let avg = |lo: usize, hi: usize| {
            t.rates[lo..hi].iter().sum::<f64>() / (hi - lo) as f64
        };
        assert!((avg(0, 190) - 30.0).abs() < 3.0, "pre {}", avg(0, 190));
        assert!(avg(230, 255) > 120.0, "peak {}", avg(230, 255));
        assert!((avg(320, 600) - 30.0).abs() < 3.0, "post {}", avg(320, 600));
        assert_eq!(t.duration_s(), 600);
    }

    #[test]
    fn from_spec_parses_the_cli_grammar() {
        assert_eq!(
            Trace::from_spec("steady:25", 40.0, 30, 1).unwrap().rates,
            vec![25.0; 30]
        );
        let b = Trace::from_spec("burst:100:50:90", 30.0, 300, 2).unwrap();
        assert!(b.max() > 70.0);
        assert!(b.rates[..90].iter().sum::<f64>() / 90.0 < 35.0);
        assert!(Trace::from_spec("bursty", 40.0, 120, 3).is_ok());
        assert!(Trace::from_spec("nope", 40.0, 120, 3).is_err());
        assert!(Trace::from_spec("burst:oops", 40.0, 120, 3).is_err());
    }

    #[test]
    fn csv_roundtrip() {
        let dir = crate::util::testutil::TempDir::new();
        let p = dir.path().join("trace.csv");
        let t = Trace::steady(12.5, 30);
        Trace::to_csv(&t, &p).unwrap();
        let back = Trace::from_csv(&p).unwrap();
        assert_eq!(back.rates.len(), 30);
        assert!((back.rates[0] - 12.5).abs() < 1e-9);
    }

    #[test]
    fn csv_roundtrip_is_value_exact() {
        // full-precision writing: a noisy generated trace survives
        // to_csv → from_csv with every f64 bit intact (the old {r:.4}
        // truncation failed this for any non-trivial rate)
        let dir = crate::util::testutil::TempDir::new();
        let p = dir.path().join("twitter.csv");
        let t = Trace::twitter_like(40.0, 600, 11);
        Trace::to_csv(&t, &p).unwrap();
        let back = Trace::from_csv(&p).unwrap();
        assert_eq!(back.rates, t.rates);
    }

    #[test]
    fn csv_roundtrips_the_class_mix() {
        let dir = crate::util::testutil::TempDir::new();
        let p = dir.path().join("tiered.csv");
        let t = Trace::steady(10.0, 20).with_class_mix(vec![(0, 7.0), (1, 3.0), (2, 0.5)]);
        Trace::to_csv(&t, &p).unwrap();
        let back = Trace::from_csv(&p).unwrap();
        assert_eq!(back.class_mix, t.class_mix);
        assert_eq!(back.rates, t.rates);
        // a bad directive is a line-numbered error, not a silent drop
        std::fs::write(&p, "t,rps\n# tiers: 0:oops\n0,1.0\n").unwrap();
        let err = format!("{:#}", Trace::from_csv(&p).unwrap_err());
        assert!(err.contains(":2"), "{err}");
        assert!(err.contains("tiers"), "{err}");
    }

    #[test]
    fn csv_rejects_extra_columns_with_line_numbers() {
        let dir = crate::util::testutil::TempDir::new();
        let p = dir.path().join("wide.csv");
        std::fs::write(&p, "0,1.5\n1,2.0,9.9\n").unwrap();
        let err = format!("{:#}", Trace::from_csv(&p).unwrap_err());
        assert!(err.contains(":2"), "{err}");
        assert!(err.contains("3 columns"), "{err}");
    }

    #[test]
    fn csv_handles_crlf_headerless_and_trailing_newlines() {
        let dir = crate::util::testutil::TempDir::new();
        let p = dir.path().join("t.csv");
        // CRLF line endings with a header
        std::fs::write(&p, "t,rps\r\n0,1.5\r\n1,2.5\r\n").unwrap();
        assert_eq!(Trace::from_csv(&p).unwrap().rates, vec![1.5, 2.5]);
        // headerless single-column, blank and trailing lines
        std::fs::write(&p, "3.5\n\n4.5\n\n").unwrap();
        assert_eq!(Trace::from_csv(&p).unwrap().rates, vec![3.5, 4.5]);
        // a non-numeric row after data is an error, not a second header
        std::fs::write(&p, "0,1.0\nt,rps\n").unwrap();
        let err = format!("{:#}", Trace::from_csv(&p).unwrap_err());
        assert!(err.contains(":2"), "{err}");
        assert!(err.contains("bad rate"), "{err}");
        // non-finite rates are rejected
        std::fs::write(&p, "0,inf\n").unwrap();
        assert!(Trace::from_csv(&p).is_err());
        std::fs::write(&p, "0,-1.0\n").unwrap();
        assert!(Trace::from_csv(&p).is_err());
    }

    #[test]
    fn from_spec_csv_supports_scale_and_loop() {
        let dir = crate::util::testutil::TempDir::new();
        let p = dir.path().join("short.csv");
        let t = Trace::steady(10.0, 30).with_class_mix(vec![(0, 1.0), (1, 1.0)]);
        Trace::to_csv(&t, &p).unwrap();
        let spec = format!("csv:{}:scale=0.5:loop=75", p.display());
        let got = Trace::from_spec(&spec, 0.0, 0, 0).unwrap();
        assert_eq!(got.duration_s(), 75);
        assert!((got.mean() - 5.0).abs() < 1e-12);
        // tiling and scaling both preserve the tier mix
        assert_eq!(got.class_mix, t.class_mix);
        let plain = Trace::from_spec(&format!("csv:{}", p.display()), 0.0, 0, 0).unwrap();
        assert_eq!(plain.rates, t.rates);
        assert!(Trace::from_spec(&format!("csv:{}:scale=zz", p.display()), 0.0, 0, 0).is_err());
    }

    #[test]
    fn tiled_repeats_cyclically() {
        let t = RateSeries {
            rates: vec![1.0, 2.0, 3.0],
            name: "t".into(),
            class_mix: vec![(1, 1.0)],
        };
        let long = t.tiled(7);
        assert_eq!(long.rates, vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0, 1.0]);
        assert_eq!(long.class_mix, t.class_mix);
        assert_eq!(t.tiled(2).rates, vec![1.0, 2.0]);
    }

    #[test]
    fn scaling_and_truncation() {
        let t = Trace::steady(10.0, 100).scaled(0.5).truncated(40);
        assert_eq!(t.duration_s(), 40);
        assert!((t.mean() - 5.0).abs() < 1e-9);
    }
}
