//! Arrival processes: turn a per-second rate series into request timestamps.

use super::RateSeries;
use crate::util::rng::Rng;

/// Generates concrete arrival timestamps from a rate trace.
pub struct ArrivalProcess;

impl ArrivalProcess {
    /// Non-homogeneous Poisson arrivals via per-second thinning: within
    /// second `t` the process is homogeneous with rate `rates[t]`.
    pub fn poisson(series: &RateSeries, seed: u64) -> Vec<f64> {
        let mut rng = Rng::seed_from_u64(seed);
        let mut out = Vec::with_capacity(series.total().ceil() as usize + 16);
        for (t, &rate) in series.rates.iter().enumerate() {
            if rate <= 0.0 {
                continue;
            }
            let mut clock = 0.0f64;
            loop {
                // Exponential inter-arrival within this second.
                clock += rng.exp1() / rate;
                if clock >= 1.0 {
                    break;
                }
                out.push(t as f64 + clock);
            }
        }
        out
    }

    /// Deterministic evenly-spaced arrivals (tests; worst-case-free load).
    pub fn uniform(series: &RateSeries) -> Vec<f64> {
        let mut out = Vec::with_capacity(series.total().ceil() as usize + 16);
        for (t, &rate) in series.rates.iter().enumerate() {
            let n = rate.round() as usize;
            for i in 0..n {
                out.push(t as f64 + (i as f64 + 0.5) / n as f64);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Trace;

    #[test]
    fn poisson_count_matches_expectation() {
        let series = Trace::steady(50.0, 200);
        let arrivals = ArrivalProcess::poisson(&series, 42);
        let expected = series.total();
        let got = arrivals.len() as f64;
        // 10k expected arrivals: 5-sigma band ~ +-500
        assert!(
            (got - expected).abs() < 500.0,
            "got {got}, expected {expected}"
        );
    }

    #[test]
    fn poisson_is_sorted_and_in_range() {
        let series = Trace::bursty(30.0, 90.0, 300, 5);
        let arrivals = ArrivalProcess::poisson(&series, 1);
        assert!(arrivals.windows(2).all(|w| w[0] <= w[1]));
        assert!(arrivals.iter().all(|&t| t >= 0.0 && t < 300.0));
    }

    #[test]
    fn poisson_is_deterministic_per_seed() {
        let series = Trace::steady(10.0, 50);
        assert_eq!(
            ArrivalProcess::poisson(&series, 9),
            ArrivalProcess::poisson(&series, 9)
        );
    }

    #[test]
    fn uniform_matches_rate_exactly() {
        let series = Trace::steady(7.0, 10);
        let arrivals = ArrivalProcess::uniform(&series);
        assert_eq!(arrivals.len(), 70);
    }
}
