//! Workload substrate: rate traces and arrival processes.
//!
//! The paper evaluates on a 20-minute sample of the archiveteam Twitter
//! trace plus a non-bursty sample.  That trace is not redistributable here,
//! so [`Trace`] generators synthesize the same *shapes* the paper describes
//! (see DESIGN.md §4):
//! * [`Trace::bursty`] — steady (0-600 s), spike (600-800 s), gradual decay
//!   (800-1000 s), return to base (1000-1200 s): exactly Figure 5's phases.
//! * [`Trace::non_bursty`] — smooth diurnal-style oscillation (Figure 8).
//! * [`Trace::twitter_like`] — seasonal baseline + AR(1) noise + Poisson
//!   spikes; the same recipe `python/compile/tracegen.py` trains the LSTM on.
//! * [`Trace::from_csv`] — plug in a real trace.
//!
//! [`ArrivalProcess`] turns a rate trace into concrete request timestamps
//! (non-homogeneous Poisson by default, or deterministic for tests).

mod arrivals;
mod traces;

pub use arrivals::ArrivalProcess;
pub use traces::Trace;

/// Per-second request rates plus bookkeeping.
#[derive(Debug, Clone)]
pub struct RateSeries {
    /// requests/second, one entry per second.
    pub rates: Vec<f64>,
    pub name: String,
}

impl RateSeries {
    pub fn duration_s(&self) -> usize {
        self.rates.len()
    }

    pub fn max(&self) -> f64 {
        self.rates.iter().cloned().fold(0.0, f64::max)
    }

    pub fn mean(&self) -> f64 {
        if self.rates.is_empty() {
            return 0.0;
        }
        self.rates.iter().sum::<f64>() / self.rates.len() as f64
    }

    /// Total expected number of requests.
    pub fn total(&self) -> f64 {
        self.rates.iter().sum()
    }

    /// Multiply every rate by `k` (host-scale a paper-scale trace).
    pub fn scaled(&self, k: f64) -> Self {
        Self {
            rates: self.rates.iter().map(|r| r * k).collect(),
            name: format!("{}*{k:.3}", self.name),
        }
    }

    /// Clip to the first `seconds` seconds.
    pub fn truncated(&self, seconds: usize) -> Self {
        Self {
            rates: self.rates[..seconds.min(self.rates.len())].to_vec(),
            name: self.name.clone(),
        }
    }
}
